"""L1 Pallas kernel: one batched Vivaldi spring-relaxation step.

Vivaldi [Dabek et al., SIGCOMM'04] embeds networked nodes into a
d-dimensional coordinate space such that Euclidean distance approximates
round-trip time. Oakestra's LDP scheduler (paper Alg. 2) consumes these
coordinates for its latency filters, and the simulator embeds its measured
RTT matrix through repeated application of this kernel.

The classic algorithm processes one (i, j) sample at a time; this kernel is
the batched/synchronous variant: every node relaxes against *all* peers at
once, which is the natural TPU formulation -- the (N, N) RTT matrix is
tiled into (BLOCK, N) row strips via ``BlockSpec`` (one grid step per
strip), and the full coordinate/error vectors (small: N*(D+1) f32) ride
along whole in VMEM. Pairs with ``rtt <= 0`` (self-pairs, unmeasured links)
are masked out.

Update rule (matching ``ref.vivaldi_step_ref`` exactly -- the pytest oracle):

  w_ij   = e_i / (e_i + e_j)                    confidence weighting
  err_ij = rtt_ij - ||x_i - x_j||               raw spring displacement
  u_ij   = (x_i - x_j) / max(||x_i - x_j||, eps)
  x_i   += cc * mean_j[ w_ij * err_ij * u_ij ]  coordinate step
  e_i    = (1-ce*wbar_i) * e_i + ce*wbar_i * mean_j[ |err_ij| / rtt_ij ]

``interpret=True`` is mandatory (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 64
EPS = 1e-6
CC = 0.25   # coordinate gain (delta in the paper's Vivaldi reference)
CE = 0.25   # error-estimate gain


def _vivaldi_kernel(
    x_rows_ref,   # f32[BLOCK, D]  coordinates of this row strip
    err_rows_ref,  # f32[BLOCK]    error estimates of this row strip
    x_all_ref,    # f32[N, D]      all coordinates (replicated per step)
    err_all_ref,  # f32[N]         all error estimates (replicated)
    rtt_ref,      # f32[BLOCK, N]  measured RTTs, row strip
    x_out_ref,    # f32[BLOCK, D]  out: updated coordinates
    err_out_ref,  # f32[BLOCK]     out: updated error estimates
):
    x_i = x_rows_ref[...]
    e_i = err_rows_ref[...]
    x_j = x_all_ref[...]
    e_j = err_all_ref[...]
    rtt = rtt_ref[...]

    valid = rtt > 0.0                                   # [B, N]
    diff = x_i[:, None, :] - x_j[None, :, :]            # [B, N, D]
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=-1))      # [B, N]
    unit = diff / jnp.maximum(dist, EPS)[..., None]     # [B, N, D]

    w = e_i[:, None] / jnp.maximum(e_i[:, None] + e_j[None, :], EPS)  # [B, N]
    err = rtt - dist                                    # [B, N]
    wv = jnp.where(valid, w, 0.0)
    n_valid = jnp.maximum(jnp.sum(valid.astype(jnp.float32), axis=1), 1.0)

    force = jnp.sum((wv * err)[..., None] * unit, axis=1) / n_valid[:, None]
    x_out_ref[...] = x_i + CC * force

    rel = jnp.where(valid, jnp.abs(err) / jnp.maximum(rtt, EPS), 0.0)
    rel_bar = jnp.sum(rel, axis=1) / n_valid
    w_bar = jnp.sum(wv, axis=1) / n_valid
    alpha = CE * w_bar
    err_out_ref[...] = jnp.clip((1.0 - alpha) * e_i + alpha * rel_bar, 1e-3, 2.0)


@functools.partial(jax.jit, static_argnames=("block",))
def vivaldi_step(x, err, rtt, *, block: int = BLOCK):
    """One synchronous Vivaldi iteration. ``x: f32[N,D]``, ``err: f32[N]``,
    ``rtt: f32[N,N]`` (ms; <=0 entries ignored). Returns ``(x', err')``.
    """
    n, d = x.shape
    if n % block != 0:
        raise ValueError(f"N={n} must be a multiple of block={block}")
    grid = (n // block,)

    return pl.pallas_call(
        _vivaldi_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((block, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(x, err, x, err, rtt)
