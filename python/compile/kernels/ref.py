"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written in
straight-line ``jax.numpy`` with no Pallas, no tiling and no grid -- the
pytest suite (``python/tests/``) asserts ``allclose`` between kernel and
oracle across hypothesis-generated shapes and inputs. Keep the constants in
sync with the kernels (they are imported from there, not duplicated).
"""

from __future__ import annotations

import jax.numpy as jnp

from .ldp_score import EARTH_RADIUS_KM, NEG_INF
from .vivaldi_step import CC, CE, EPS


def haversine_km_ref(lat1, lon1, lat2, lon2):
    """Great-circle distance in km, inputs in radians (broadcasting)."""
    dlat = 0.5 * (lat2 - lat1)
    dlon = 0.5 * (lon2 - lon1)
    h = jnp.sin(dlat) ** 2 + jnp.cos(lat1) * jnp.cos(lat2) * jnp.sin(dlon) ** 2
    h = jnp.clip(h, 0.0, 1.0)
    return 2.0 * EARTH_RADIUS_KM * jnp.arcsin(jnp.sqrt(h))


def ldp_score_ref(caps, virt, geo, viv, req, req_virt, cons_geo, cons_viv,
                  cons_thr, cons_active):
    """Oracle for ``ldp_score.ldp_score`` (paper Alg. 2 + Alg. 1 score)."""
    res_ok = jnp.all(caps >= req[None, :], axis=1)
    virt_ok = jnp.bitwise_and(virt, req_virt[0]) == req_virt[0]
    feasible = jnp.logical_and(res_ok, virt_ok)

    d_gc = haversine_km_ref(
        geo[:, 0:1], geo[:, 1:2], cons_geo[None, :, 0], cons_geo[None, :, 1]
    )
    diff = viv[:, None, :] - cons_viv[None, :, :]
    d_viv = jnp.sqrt(jnp.sum(diff * diff, axis=-1))

    active = cons_active > 0.5
    cons_ok = jnp.logical_and(
        d_gc <= cons_thr[None, :, 0], d_viv <= cons_thr[None, :, 1]
    )
    cons_ok = jnp.logical_or(cons_ok, jnp.logical_not(active)[None, :])
    feasible = jnp.logical_and(feasible, jnp.all(cons_ok, axis=1))

    score = (caps[:, 0] - req[0]) + (caps[:, 1] - req[1])
    return jnp.where(feasible, score, NEG_INF), feasible.astype(jnp.float32)


def vivaldi_step_ref(x, err, rtt):
    """Oracle for ``vivaldi_step.vivaldi_step``."""
    valid = rtt > 0.0
    diff = x[:, None, :] - x[None, :, :]
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=-1))
    unit = diff / jnp.maximum(dist, EPS)[..., None]

    w = err[:, None] / jnp.maximum(err[:, None] + err[None, :], EPS)
    e = rtt - dist
    wv = jnp.where(valid, w, 0.0)
    n_valid = jnp.maximum(jnp.sum(valid.astype(jnp.float32), axis=1), 1.0)

    force = jnp.sum((wv * e)[..., None] * unit, axis=1) / n_valid[:, None]
    x_new = x + CC * force

    rel = jnp.where(valid, jnp.abs(e) / jnp.maximum(rtt, EPS), 0.0)
    rel_bar = jnp.sum(rel, axis=1) / n_valid
    w_bar = jnp.sum(wv, axis=1) / n_valid
    alpha = CE * w_bar
    err_new = jnp.clip((1.0 - alpha) * err + alpha * rel_bar, 1e-3, 2.0)
    return x_new, err_new
