"""L1 Pallas kernel: batched LDP worker feasibility + scoring (paper Alg. 2).

This is the compute hot-spot of Oakestra's Latency & Distance aware
Placement scheduler (paper Fig. 8b shows its cost escalating with
infrastructure size). For every candidate worker the kernel evaluates, in a
single streaming pass:

  * resource feasibility    (Alg. 2 line 1: cpu / mem / disk >= request,
                             virtualization bitmask superset),
  * S2S / S2U constraints   (Alg. 2 lines 2-16: great-circle distance to a
                             geographic target under ``geo_thr`` AND Vivaldi
                             Euclidean distance to a coordinate target under
                             ``viv_thr``, per constraint row),
  * the ROM score           (Alg. 1 strategy: (A_cpu - Q_cpu) + (A_mem -
                             Q_mem)), masked to -inf for infeasible workers.

TPU-shaped design (see DESIGN.md "Hardware adaptation"): workers are tiled
in row blocks of ``BLOCK`` via ``BlockSpec`` so each grid step streams one
(BLOCK, F) tile HBM->VMEM; the constraint table (K rows) is tiny and mapped
whole into every step. All math is elementwise/VPU-friendly -- no gathers,
no data-dependent control flow -- and the mask is carried in f32 so the
kernel is a pure map over rows. ``interpret=True`` is mandatory on this
image: real-TPU lowering emits a Mosaic custom-call the CPU PJRT client
cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-block size: multiple of the 8x128 VPU tile; 128 rows x ~16 f32
# features is ~8 KiB of VMEM per input tile, far under the ~16 MiB budget.
BLOCK = 128

# Earth radius used for great-circle distances, in km (matches ref.py and
# the rust `geo` module -- keep the three in sync).
EARTH_RADIUS_KM = 6371.0

NEG_INF = -1e30


def _haversine_km(lat1, lon1, lat2, lon2):
    """Great-circle distance in km; inputs in radians. dist_gc in Alg. 2."""
    dlat = 0.5 * (lat2 - lat1)
    dlon = 0.5 * (lon2 - lon1)
    h = jnp.sin(dlat) ** 2 + jnp.cos(lat1) * jnp.cos(lat2) * jnp.sin(dlon) ** 2
    # Clip for numerical safety: h can exceed 1 by epsilon in f32.
    h = jnp.clip(h, 0.0, 1.0)
    return 2.0 * EARTH_RADIUS_KM * jnp.arcsin(jnp.sqrt(h))


def _ldp_kernel(
    caps_ref,       # f32[BLOCK, 3]   available cpu, mem, disk
    virt_ref,       # i32[BLOCK]      supported virtualization bitmask
    geo_ref,        # f32[BLOCK, 2]   worker lat, lon (radians)
    viv_ref,        # f32[BLOCK, D]   worker Vivaldi coordinates
    req_ref,        # f32[3]          requested cpu, mem, disk
    req_virt_ref,   # i32[1]          required virtualization bits
    cons_geo_ref,   # f32[K, 2]       per-constraint geo target (radians)
    cons_viv_ref,   # f32[K, D]       per-constraint Vivaldi target
    cons_thr_ref,   # f32[K, 2]       per-constraint (geo_thr_km, viv_thr_ms)
    cons_active_ref,  # f32[K]        1.0 = constraint enforced
    score_ref,      # f32[BLOCK]      out: masked ROM score
    mask_ref,       # f32[BLOCK]      out: 1.0 feasible / 0.0 infeasible
):
    caps = caps_ref[...]
    req = req_ref[...]

    # --- Alg. 2 line 1: resource + virtualization feasibility -------------
    res_ok = jnp.all(caps >= req[None, :], axis=1)
    virt = virt_ref[...]
    req_virt = req_virt_ref[0]
    virt_ok = jnp.bitwise_and(virt, req_virt) == req_virt
    feasible = jnp.logical_and(res_ok, virt_ok)

    # --- Alg. 2 lines 2-16: latency & distance constraints ----------------
    # [BLOCK, K] great-circle distance worker -> constraint target.
    geo = geo_ref[...]
    cons_geo = cons_geo_ref[...]
    d_gc = _haversine_km(
        geo[:, 0:1], geo[:, 1:2], cons_geo[None, :, 0], cons_geo[None, :, 1]
    )
    # [BLOCK, K] Euclidean distance in the Vivaldi embedding (approx RTT ms).
    viv = viv_ref[...]
    cons_viv = cons_viv_ref[...]
    diff = viv[:, None, :] - cons_viv[None, :, :]
    d_viv = jnp.sqrt(jnp.sum(diff * diff, axis=-1))

    thr = cons_thr_ref[...]
    active = cons_active_ref[...] > 0.5
    cons_ok = jnp.logical_and(d_gc <= thr[None, :, 0], d_viv <= thr[None, :, 1])
    cons_ok = jnp.logical_or(cons_ok, jnp.logical_not(active)[None, :])
    feasible = jnp.logical_and(feasible, jnp.all(cons_ok, axis=1))

    # --- Alg. 1 scoring strategy: spare cpu + spare mem --------------------
    score = (caps[:, 0] - req[0]) + (caps[:, 1] - req[1])
    score_ref[...] = jnp.where(feasible, score, NEG_INF)
    mask_ref[...] = feasible.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def ldp_score(
    caps, virt, geo, viv, req, req_virt, cons_geo, cons_viv, cons_thr,
    cons_active, *, block: int = BLOCK,
):
    """Tiled LDP feasibility + score over ``N`` workers.

    ``N`` must be a multiple of ``block`` (the AOT wrapper pads; padded rows
    carry zero capacity so they are always infeasible). Returns
    ``(score f32[N], mask f32[N])``.
    """
    n, _ = caps.shape
    k, d = cons_viv.shape
    if n % block != 0:
        raise ValueError(f"N={n} must be a multiple of block={block}")
    grid = (n // block,)

    row = pl.BlockSpec((block, None), lambda i: (i, 0))
    row1 = pl.BlockSpec((block,), lambda i: (i,))
    # Small operands are replicated whole into every grid step.
    whole = lambda *shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    return pl.pallas_call(
        _ldp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 3), lambda i: (i, 0)),
            row1,
            pl.BlockSpec((block, 2), lambda i: (i, 0)),
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            whole(3),
            whole(1),
            whole(k, 2),
            whole(k, d),
            whole(k, 2),
            whole(k),
        ],
        out_specs=[row1, row1],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(caps, virt, geo, viv, req, req_virt, cons_geo, cons_viv, cons_thr,
      cons_active)
