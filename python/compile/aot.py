"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

Run once by ``make artifacts``; Python never appears on the Rust request
path. For each entry point we lower a jitted function at fixed example
shapes to StableHLO, convert to an XlaComputation and dump HLO **text**
(NOT ``.serialize()``): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the image's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs (``artifacts/``):
  <name>.hlo.txt      one per entry-point variant
  manifest.json       machine-readable input/output specs consumed by
                      ``rust/src/runtime/artifacts.rs``

All entries are lowered with ``return_tuple=True``; the Rust side unwraps
with ``Literal::to_tuple()``.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, fn, [(shape, dtype), ...]) -- shapes are the padded static sizes
# the Rust runtime feeds. N variants let the scheduler pick the smallest
# artifact that fits the live worker count.
LDP_K = 4
VIV_D = model.VIVALDI_DIM


def _ldp_spec(n: int):
    return [
        ((n, 3), jnp.float32),     # caps
        ((n,), jnp.int32),         # virt
        ((n, 2), jnp.float32),     # geo
        ((n, VIV_D), jnp.float32),  # viv
        ((3,), jnp.float32),       # req
        ((1,), jnp.int32),         # req_virt
        ((LDP_K, 2), jnp.float32),  # cons_geo
        ((LDP_K, VIV_D), jnp.float32),  # cons_viv
        ((LDP_K, 2), jnp.float32),  # cons_thr
        ((LDP_K,), jnp.float32),   # cons_active
    ]


ENTRIES = [
    ("ldp_score_512", model.ldp_pipeline, _ldp_spec(512)),
    ("ldp_score_2048", model.ldp_pipeline, _ldp_spec(2048)),
    ("vivaldi_embed_256", functools.partial(model.vivaldi_embed, steps=16),
     [((256, 256), jnp.float32)]),
    ("trilaterate_16", model.trilaterate,
     [((16, VIV_D), jnp.float32), ((16,), jnp.float32)]),
    ("detector_1x64", model.detector_fwd, [((1, 64, 64, 3), jnp.float32)]),
    ("detector_8x64", model.detector_fwd, [((8, 64, 64, 3), jnp.float32)]),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, in_specs):
    args = [jax.ShapeDtypeStruct(shape, dtype) for shape, dtype in in_specs]
    lowered = jax.jit(fn).lower(*args)
    out_avals = jax.eval_shape(fn, *args)
    if not isinstance(out_avals, (tuple, list)):
        out_avals = (out_avals,)
    return to_hlo_text(lowered), out_avals


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts/model.hlo.txt",
                        help="path of the marker artifact (its directory "
                             "receives all artifacts + manifest.json)")
    args = parser.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)

    manifest = {}
    for name, fn, in_specs in ENTRIES:
        text, out_avals = lower_entry(fn, in_specs)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(shape), "dtype": jnp.dtype(dtype).name}
                for shape, dtype in in_specs
            ],
            "outputs": [
                {"shape": list(a.shape), "dtype": jnp.dtype(a.dtype).name}
                for a in out_avals
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)

    # Marker file keeps the Makefile's single-target dependency simple.
    with open(os.path.abspath(args.out), "w") as f:
        f.write("\n".join(sorted(manifest)) + "\n")
    print(f"wrote manifest with {len(manifest)} entries")


if __name__ == "__main__":
    main()
