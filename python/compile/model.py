"""L2: the JAX compute graphs AOT-lowered for the Rust coordinator.

Four entry points, each exported to HLO text by ``aot.py`` and executed at
runtime through ``rust/src/runtime`` (PJRT CPU client):

* ``ldp_pipeline``   -- batched LDP feasibility+score (calls the L1 Pallas
                        kernel); the scheduler hot path for large clusters.
* ``vivaldi_embed``  -- embeds a measured RTT matrix into Vivaldi
                        coordinates by scanning the L1 spring-update kernel.
* ``trilaterate``    -- approximates a user's Vivaldi position from RTT
                        probes to anchor workers (paper Alg. 2 line 13) by
                        fixed-step gradient descent.
* ``detector_fwd``   -- small CNN standing in for YOLOv3 in the
                        video-analytics workload (weights baked into the
                        artifact from a fixed seed; see DESIGN.md
                        substitution ledger).

Python never runs on the request path: these functions exist to be lowered
once (``make artifacts``) and then served from Rust.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.ldp_score import ldp_score
from .kernels.vivaldi_step import vivaldi_step

VIVALDI_DIM = 4        # 3 spatial dims + height-like slack dimension
TRILAT_ITERS = 128     # fixed GD iterations for user-position estimation
TRILAT_LR = 0.5


def ldp_pipeline(caps, virt, geo, viv, req, req_virt, cons_geo, cons_viv,
                 cons_thr, cons_active):
    """LDP scoring over a padded worker table. Returns (score, mask).

    Shapes (N static per artifact variant, K = max constraint rows):
      caps f32[N,3], virt i32[N], geo f32[N,2], viv f32[N,D], req f32[3],
      req_virt i32[1], cons_geo f32[K,2], cons_viv f32[K,D], cons_thr
      f32[K,2], cons_active f32[K].
    Padded rows must carry zero capacity so they fail feasibility.
    """
    return ldp_score(caps, virt, geo, viv, req, req_virt, cons_geo,
                     cons_viv, cons_thr, cons_active)


def vivaldi_embed(rtt, steps: int = 16):
    """Embed ``rtt f32[N,N]`` into Vivaldi space; returns (coords, err).

    Deterministic non-random init (index-based spiral) so the artifact has a
    single input; repeated spring relaxation breaks the symmetry.
    """
    n = rtt.shape[0]
    idx = jnp.arange(n, dtype=jnp.float32)
    # Deterministic low-symmetry init: points on a small spiral.
    init = jnp.stack(
        [
            jnp.cos(0.7 * idx) * (1.0 + 0.01 * idx),
            jnp.sin(0.7 * idx) * (1.0 + 0.01 * idx),
            0.05 * idx,
            jnp.ones_like(idx),
        ],
        axis=1,
    )[:, :VIVALDI_DIM]
    err0 = jnp.ones((n,), jnp.float32)

    def body(carry, _):
        x, e = carry
        x, e = vivaldi_step(x, e, rtt)
        return (x, e), None

    (x, e), _ = jax.lax.scan(body, (init, err0), None, length=steps)
    return x, e


def trilaterate(anchors, rtts):
    """Estimate a user's Vivaldi coordinates from probe RTTs (Alg. 2 l.13).

    ``anchors f32[M,D]`` are Vivaldi coordinates of the sampled workers,
    ``rtts f32[M]`` the measured worker->user round-trip times in ms
    (<=0 entries are ignored as failed probes). Minimizes
    sum_i (||u - a_i|| - rtt_i)^2 by TRILAT_ITERS fixed GD steps from the
    weighted anchor centroid. Returns (u f32[D], residual f32[1]).
    """
    valid = (rtts > 0.0).astype(jnp.float32)
    n_valid = jnp.maximum(jnp.sum(valid), 1.0)
    u0 = jnp.sum(anchors * valid[:, None], axis=0) / n_valid

    def step(_, u):
        diff = u[None, :] - anchors
        dist = jnp.sqrt(jnp.sum(diff * diff, axis=1) + 1e-9)
        g = 2.0 * valid * (dist - rtts) / dist
        grad = jnp.sum(g[:, None] * diff, axis=0) / n_valid
        return u - TRILAT_LR * grad

    u = jax.lax.fori_loop(0, TRILAT_ITERS, step, u0)
    diff = u[None, :] - anchors
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=1) + 1e-9)
    residual = jnp.sum(valid * (dist - rtts) ** 2) / n_valid
    return u, residual.reshape((1,))


def _detector_params(key=None):
    """Fixed-seed CNN weights, baked into the HLO artifact as constants."""
    if key is None:
        key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    scale = 0.1
    return {
        "c1": jax.random.normal(k1, (3, 3, 3, 8), jnp.float32) * scale,
        "c2": jax.random.normal(k2, (3, 3, 8, 16), jnp.float32) * scale,
        "head": jax.random.normal(k3, (16, 5), jnp.float32) * scale,
    }


def detector_fwd(frames):
    """Tiny detector over ``frames f32[B,64,64,3]`` -> grid ``f32[B,8,8,5]``.

    Two stride-2 convs + ReLU, a stride-2 average pool, and a per-cell
    linear head emitting (objectness, dx, dy, w, h) -- a YOLO-shaped output
    at toy scale. The point is a fixed, real compute cost executed through
    the PJRT runtime by the video-analytics workload, not detection quality.
    """
    p = _detector_params()
    x = jax.lax.conv_general_dilated(
        frames, p["c1"], window_strides=(2, 2), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = jax.nn.relu(x)
    x = jax.lax.conv_general_dilated(
        x, p["c2"], window_strides=(2, 2), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = jax.nn.relu(x)
    # 16x16 -> 8x8 grid cells.
    x = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
    return jnp.einsum("bhwc,co->bhwo", x, p["head"])
