"""AOT path tests: every exported entry lowers to parseable HLO text with
the manifest-declared signatures, and the HLO is the 64-bit-id-safe *text*
format (never a serialized proto)."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_all_entries_lower():
    for name, fn, in_specs in aot.ENTRIES:
        text, out_avals = aot.lower_entry(fn, in_specs)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        assert len(out_avals) >= 1, name


def test_entry_names_unique_and_variants_cover_scales():
    names = [e[0] for e in aot.ENTRIES]
    assert len(names) == len(set(names))
    ldp = [e for e in aot.ENTRIES if e[0].startswith("ldp_score")]
    sizes = sorted(e[2][0][0][0] for e in ldp)
    assert sizes == [512, 2048], "scheduler needs small+large LDP variants"


def test_ldp_artifact_io_signature():
    (name, fn, in_specs) = next(e for e in aot.ENTRIES if e[0] == "ldp_score_512")
    _, out_avals = aot.lower_entry(fn, in_specs)
    assert [tuple(a.shape) for a in out_avals] == [(512,), (512,)]
    assert all(a.dtype == jnp.float32 for a in out_avals)


@pytest.mark.skipif(not os.path.isdir(ART), reason="run `make artifacts` first")
def test_manifest_matches_disk():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert set(manifest) == {e[0] for e in aot.ENTRIES}
    for name, meta in manifest.items():
        path = os.path.join(ART, meta["file"])
        assert os.path.isfile(path), path
        with open(path) as f:
            head = f.read(64)
        assert head.startswith("HloModule"), name
        assert len(meta["inputs"]) >= 1 and len(meta["outputs"]) >= 1
