"""Kernel-vs-oracle correctness: the CORE signal for the L1 layer.

Hypothesis sweeps shapes and input distributions; every case asserts
``allclose`` between the tiled Pallas kernel (interpret=True) and the
straight-line jnp oracle in ``compile.kernels.ref``.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.ldp_score import NEG_INF, ldp_score
from compile.kernels.vivaldi_step import vivaldi_step

SET = dict(deadline=None, max_examples=20, print_blob=True)
D = 4


def _ldp_inputs(seed: int, n: int, k: int, feasible_bias: bool):
    rng = np.random.default_rng(seed)
    caps = rng.uniform(0.0, 8.0, (n, 3)).astype(np.float32)
    virt = rng.integers(0, 8, (n,)).astype(np.int32)
    geo = np.stack(
        [rng.uniform(-np.pi / 2, np.pi / 2, n), rng.uniform(-np.pi, np.pi, n)], 1
    ).astype(np.float32)
    viv = rng.normal(0.0, 40.0, (n, D)).astype(np.float32)
    if feasible_bias:
        req = np.array([0.5, 0.5, 0.0], np.float32)
        req_virt = np.array([0], np.int32)
        thr = np.stack([rng.uniform(5000, 20000, k), rng.uniform(150, 400, k)], 1)
    else:
        req = rng.uniform(0.0, 8.0, 3).astype(np.float32)
        req_virt = np.array([rng.integers(0, 8)], np.int32)
        thr = np.stack([rng.uniform(10, 5000, k), rng.uniform(5, 200, k)], 1)
    cons_geo = np.stack(
        [rng.uniform(-np.pi / 2, np.pi / 2, k), rng.uniform(-np.pi, np.pi, k)], 1
    ).astype(np.float32)
    cons_viv = rng.normal(0.0, 40.0, (k, D)).astype(np.float32)
    cons_active = (rng.uniform(0, 1, k) > 0.4).astype(np.float32)
    return (caps, virt, geo, viv, req, req_virt, cons_geo, cons_viv,
            thr.astype(np.float32), cons_active)


@settings(**SET)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_blocks=st.integers(1, 4),
    feasible_bias=st.booleans(),
)
def test_ldp_score_matches_ref(seed, n_blocks, feasible_bias):
    args = _ldp_inputs(seed, 128 * n_blocks, 4, feasible_bias)
    s, m = ldp_score(*map(jnp.asarray, args))
    sr, mr = ref.ldp_score_ref(*map(jnp.asarray, args))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mr))


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_ldp_score_all_constraints_inactive_is_rom(seed):
    """With no active S2S/S2U rows, LDP degenerates to the ROM filter."""
    (caps, virt, geo, viv, req, req_virt, cg, cv, thr, _) = _ldp_inputs(
        seed, 128, 4, True
    )
    inactive = np.zeros(4, np.float32)
    s, m = ldp_score(*map(jnp.asarray,
                          (caps, virt, geo, viv, req, req_virt, cg, cv, thr,
                           inactive)))
    res_ok = (caps >= req[None, :]).all(1) & ((virt & req_virt[0]) == req_virt[0])
    np.testing.assert_array_equal(np.asarray(m).astype(bool), res_ok)
    # Feasible scores are exactly the ROM strategy value.
    want = (caps[:, 0] - req[0]) + (caps[:, 1] - req[1])
    np.testing.assert_allclose(
        np.asarray(s)[res_ok], want[res_ok], rtol=1e-5, atol=1e-5
    )


def test_ldp_score_zero_capacity_rows_infeasible():
    """Padded rows (zero capacity) must never be selected."""
    args = list(_ldp_inputs(7, 256, 4, True))
    args[0][128:] = 0.0  # zero out capacity of the tail rows
    s, m = ldp_score(*map(jnp.asarray, args))
    assert float(np.asarray(m)[128:].max()) == 0.0
    assert float(np.asarray(s)[128:].max()) == float(np.float32(NEG_INF))


def test_ldp_score_rejects_non_multiple_of_block():
    args = _ldp_inputs(0, 128, 4, True)
    args = list(map(jnp.asarray, args))
    bad = [jnp.concatenate([a, a[:7]]) if i in (0, 1, 2, 3) else a
           for i, a in enumerate(args)]
    with pytest.raises(ValueError, match="multiple of block"):
        ldp_score(*bad)


def _vivaldi_inputs(seed: int, n: int):
    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, 10.0, (n, D)).astype(np.float32)
    err = rng.uniform(0.05, 1.5, (n,)).astype(np.float32)
    rtt = np.abs(rng.normal(60.0, 25.0, (n, n))).astype(np.float32)
    rtt = (rtt + rtt.T) / 2.0
    np.fill_diagonal(rtt, 0.0)
    # Knock out a few pairs to exercise the missing-measurement mask.
    drop = rng.uniform(0, 1, (n, n)) < 0.05
    rtt[drop | drop.T] = 0.0
    return x, err, rtt


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1), n_blocks=st.integers(1, 3))
def test_vivaldi_step_matches_ref(seed, n_blocks):
    x, err, rtt = _vivaldi_inputs(seed, 64 * n_blocks)
    xn, en = vivaldi_step(jnp.asarray(x), jnp.asarray(err), jnp.asarray(rtt))
    xr, er = ref.vivaldi_step_ref(jnp.asarray(x), jnp.asarray(err), jnp.asarray(rtt))
    np.testing.assert_allclose(np.asarray(xn), np.asarray(xr), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(en), np.asarray(er), rtol=1e-4, atol=1e-4)


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_vivaldi_error_bounded(seed):
    """Error estimates stay inside the clip range under iteration."""
    x, err, rtt = _vivaldi_inputs(seed, 64)
    x, err, rtt = jnp.asarray(x), jnp.asarray(err), jnp.asarray(rtt)
    for _ in range(5):
        x, err = vivaldi_step(x, err, rtt)
    e = np.asarray(err)
    assert (e >= 1e-3 - 1e-7).all() and (e <= 2.0 + 1e-7).all()
    assert np.isfinite(np.asarray(x)).all()


def test_vivaldi_converges_on_line_topology():
    """Three collinear nodes: embedding distances approach the RTTs."""
    rtt = np.array(
        [[0, 50, 100], [50, 0, 50], [100, 50, 0]], np.float32
    )
    pad = np.zeros((64, 64), np.float32)
    pad[:3, :3] = rtt
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 30, (64, D)), jnp.float32)
    err = jnp.ones((64,), jnp.float32)
    r = jnp.asarray(pad)
    for _ in range(200):
        x, err = vivaldi_step(x, err, r)
    xa = np.asarray(x)
    d01 = np.linalg.norm(xa[0] - xa[1])
    d12 = np.linalg.norm(xa[1] - xa[2])
    assert abs(d01 - 50) < 10 and abs(d12 - 50) < 10
