"""L2 model-layer tests: shapes, trilateration accuracy, embedding quality,
detector determinism. These are the graphs the AOT path exports."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model

SET = dict(deadline=None, max_examples=15, print_blob=True)
D = model.VIVALDI_DIM


def _grid_rtt(n_side: int, spacing_ms: float) -> np.ndarray:
    """Ground-truth RTT matrix of an n_side x n_side grid of nodes."""
    pts = np.array(
        [(i, j) for i in range(n_side) for j in range(n_side)], np.float32
    ) * spacing_ms
    d = np.linalg.norm(pts[:, None, :] - pts[None, :, :], axis=-1)
    return d.astype(np.float32)


def test_vivaldi_embed_recovers_grid_distances():
    """Embedding a metric RTT matrix must approximate it well (median
    relative error under 20% after 64 steps) -- this is what LDP's latency
    filter quality rests on (paper sec. 7.3 'minor lapses due to Vivaldi')."""
    rtt = np.zeros((64, 64), np.float32)
    g = _grid_rtt(6, 20.0)  # 36 real nodes, 20 ms lattice spacing
    rtt[:36, :36] = g
    x, err = model.vivaldi_embed(jnp.asarray(rtt), steps=64)
    xa = np.asarray(x)[:36]
    est = np.linalg.norm(xa[:, None, :] - xa[None, :, :], axis=-1)
    mask = g > 0
    rel = np.abs(est[mask] - g[mask]) / g[mask]
    assert np.median(rel) < 0.20, f"median rel err {np.median(rel):.3f}"


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_trilaterate_recovers_planted_user(seed):
    """A user planted in Vivaldi space is recovered from exact RTTs."""
    rng = np.random.default_rng(seed)
    anchors = rng.normal(0.0, 50.0, (16, D)).astype(np.float32)
    user = rng.normal(0.0, 30.0, (D,)).astype(np.float32)
    rtts = np.linalg.norm(anchors - user[None, :], axis=1).astype(np.float32)
    u, res = model.trilaterate(jnp.asarray(anchors), jnp.asarray(rtts))
    est_d = np.linalg.norm(anchors - np.asarray(u)[None, :], axis=1)
    # Positions may differ (mirror symmetries) but distances must fit.
    np.testing.assert_allclose(est_d, rtts, rtol=0.15, atol=8.0)
    assert float(res[0]) < 25.0


def test_trilaterate_ignores_failed_probes():
    rng = np.random.default_rng(0)
    anchors = rng.normal(0.0, 50.0, (16, D)).astype(np.float32)
    user = np.zeros((D,), np.float32)
    rtts = np.linalg.norm(anchors - user[None, :], axis=1).astype(np.float32)
    # Mark half the probes failed with garbage coordinates in those anchors.
    bad = rtts.copy()
    bad[8:] = 0.0
    anchors2 = anchors.copy()
    anchors2[8:] = 1e4
    u, _ = model.trilaterate(jnp.asarray(anchors2), jnp.asarray(bad))
    d = np.linalg.norm(anchors[:8] - np.asarray(u)[None, :], axis=1)
    np.testing.assert_allclose(d, rtts[:8], rtol=0.2, atol=10.0)


def test_detector_shapes_and_determinism():
    frames = jnp.asarray(
        np.random.default_rng(1).uniform(0, 1, (8, 64, 64, 3)), jnp.float32
    )
    out1 = model.detector_fwd(frames)
    out2 = model.detector_fwd(frames)
    assert out1.shape == (8, 8, 8, 5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert np.isfinite(np.asarray(out1)).all()


def test_detector_batch_consistency():
    """Per-frame results are independent of batching."""
    rng = np.random.default_rng(2)
    frames = jnp.asarray(rng.uniform(0, 1, (4, 64, 64, 3)), jnp.float32)
    full = np.asarray(model.detector_fwd(frames))
    for b in range(4):
        one = np.asarray(model.detector_fwd(frames[b:b + 1]))
        np.testing.assert_allclose(one[0], full[b], rtol=1e-5, atol=1e-5)


def test_ldp_pipeline_is_kernel_passthrough():
    from compile.kernels import ref
    rng = np.random.default_rng(4)
    n, k = 128, 4
    args = (
        rng.uniform(0, 8, (n, 3)).astype(np.float32),
        rng.integers(0, 8, (n,)).astype(np.int32),
        rng.uniform(-1, 1, (n, 2)).astype(np.float32),
        rng.normal(0, 40, (n, D)).astype(np.float32),
        np.array([1, 1, 0], np.float32),
        np.array([0], np.int32),
        rng.uniform(-1, 1, (k, 2)).astype(np.float32),
        rng.normal(0, 40, (k, D)).astype(np.float32),
        rng.uniform(100, 9000, (k, 2)).astype(np.float32),
        np.ones((k,), np.float32),
    )
    s, m = model.ldp_pipeline(*map(jnp.asarray, args))
    sr, mr = ref.ldp_score_ref(*map(jnp.asarray, args))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mr))
