//! Vivaldi network coordinates (Dabek et al., SIGCOMM'04) — the latency
//! substrate of the LDP scheduler (paper Alg. 2, `dist_euc(A^viv)`), plus
//! trilateration of user positions from RTT probes (Alg. 2 line 13).
//!
//! Two implementations exist in this repo: the host implementation here
//! (incremental, per-sample — what the live NodeEngine runs) and the
//! batched L1 Pallas kernel (`python/compile/kernels/vivaldi_step.py`)
//! whose AOT artifact the simulator uses to embed whole RTT matrices via
//! [`crate::runtime`]. The update rules intentionally match.

use crate::util::Rng;

/// Embedding dimensionality — keep in sync with `model.VIVALDI_DIM`.
pub const DIM: usize = 4;

/// Coordinate gain; matches `vivaldi_step.CC`.
pub const CC: f64 = 0.25;
/// Error-estimate gain; matches `vivaldi_step.CE`.
pub const CE: f64 = 0.25;
const EPS: f64 = 1e-6;

/// A point in the Vivaldi embedding; Euclidean distance ≈ RTT in ms.
#[derive(Clone, Copy, PartialEq, Debug)]
pub struct Coord(pub [f64; DIM]);

impl Default for Coord {
    fn default() -> Self {
        Coord([0.0; DIM])
    }
}

impl Coord {
    pub fn distance(&self, other: &Coord) -> f64 {
        self.0
            .iter()
            .zip(other.0.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// Small deterministic jitter to break symmetry at origin.
    pub fn jittered(rng: &mut Rng) -> Coord {
        let mut c = [0.0; DIM];
        for x in &mut c {
            *x = rng.range(-0.5, 0.5);
        }
        Coord(c)
    }
}

/// Per-node Vivaldi state: coordinate + confidence (error estimate).
#[derive(Clone, Copy, Debug)]
pub struct VivaldiState {
    pub coord: Coord,
    pub error: f64,
}

impl Default for VivaldiState {
    fn default() -> Self {
        VivaldiState {
            coord: Coord::default(),
            error: 1.0,
        }
    }
}

impl VivaldiState {
    /// Classic incremental Vivaldi update against one measured sample:
    /// pulls/pushes `self` along the spring to `remote` so that embedding
    /// distance approaches `rtt_ms`. This is what each NodeEngine runs on
    /// every heartbeat RTT sample.
    pub fn observe(&mut self, remote: &VivaldiState, rtt_ms: f64) {
        if rtt_ms <= 0.0 {
            return;
        }
        let dist = self.coord.distance(&remote.coord);
        let w = self.error / (self.error + remote.error).max(EPS);
        let err = rtt_ms - dist;

        // Unit vector; random-ish deterministic direction at coincidence.
        let mut unit = [0.0; DIM];
        if dist > EPS {
            for (u, (a, b)) in unit
                .iter_mut()
                .zip(self.coord.0.iter().zip(remote.coord.0.iter()))
            {
                *u = (a - b) / dist;
            }
        } else {
            unit[0] = 1.0;
        }

        for (c, u) in self.coord.0.iter_mut().zip(unit.iter()) {
            *c += CC * w * err * u;
        }
        let rel = (err.abs() / rtt_ms.max(EPS)).min(2.0);
        let alpha = CE * w;
        self.error = ((1.0 - alpha) * self.error + alpha * rel).clamp(1e-3, 2.0);
    }
}

/// One synchronous batched relaxation step over a full RTT matrix —
/// the host twin of the L1 Pallas kernel (same formula, f64). Entries with
/// `rtt <= 0` are treated as unmeasured and skipped.
pub fn batch_step(coords: &mut [Coord], errors: &mut [f64], rtt: &[Vec<f64>]) {
    let n = coords.len();
    assert_eq!(errors.len(), n);
    assert_eq!(rtt.len(), n);
    let old_c = coords.to_vec();
    let old_e = errors.to_vec();

    for i in 0..n {
        let mut force = [0.0; DIM];
        let mut rel_sum = 0.0;
        let mut w_sum = 0.0;
        let mut n_valid: f64 = 0.0;
        for j in 0..n {
            let r = rtt[i][j];
            if r <= 0.0 {
                continue;
            }
            n_valid += 1.0;
            let dist = old_c[i].distance(&old_c[j]);
            let w = old_e[i] / (old_e[i] + old_e[j]).max(EPS);
            let err = r - dist;
            let d = dist.max(EPS);
            for (f, (a, b)) in force
                .iter_mut()
                .zip(old_c[i].0.iter().zip(old_c[j].0.iter()))
            {
                *f += w * err * (a - b) / d;
            }
            rel_sum += err.abs() / r.max(EPS);
            w_sum += w;
        }
        let nv = n_valid.max(1.0);
        for (c, f) in coords[i].0.iter_mut().zip(force.iter()) {
            *c += CC * f / nv;
        }
        let alpha = CE * (w_sum / nv);
        errors[i] = ((1.0 - alpha) * old_e[i] + alpha * rel_sum / nv).clamp(1e-3, 2.0);
    }
}

/// Embed an RTT matrix from scratch (host path; the accelerated path goes
/// through the `vivaldi_embed_256` HLO artifact).
pub fn embed(rtt: &[Vec<f64>], steps: usize, seed: u64) -> Vec<VivaldiState> {
    let n = rtt.len();
    let mut rng = Rng::seeded(seed);
    let mut coords: Vec<Coord> = (0..n).map(|_| Coord::jittered(&mut rng)).collect();
    let mut errors = vec![1.0; n];
    for _ in 0..steps {
        batch_step(&mut coords, &mut errors, rtt);
    }
    coords
        .into_iter()
        .zip(errors)
        .map(|(coord, error)| VivaldiState { coord, error })
        .collect()
}

/// Trilaterate an unknown position from RTT probes to known anchors
/// (paper Alg. 2 line 13: user position from `ping` samples). Fixed-step
/// gradient descent on Σ(‖u−aᵢ‖−rttᵢ)², matching `model.trilaterate`.
pub fn trilaterate(anchors: &[Coord], rtts_ms: &[f64]) -> Coord {
    assert_eq!(anchors.len(), rtts_ms.len());
    let valid: Vec<bool> = rtts_ms.iter().map(|&r| r > 0.0).collect();
    let nv = valid.iter().filter(|v| **v).count().max(1) as f64;

    let mut u = [0.0; DIM];
    for (a, v) in anchors.iter().zip(valid.iter()) {
        if *v {
            for (ui, ai) in u.iter_mut().zip(a.0.iter()) {
                *ui += ai / nv;
            }
        }
    }

    const ITERS: usize = 128;
    const LR: f64 = 0.5;
    for _ in 0..ITERS {
        let mut grad = [0.0; DIM];
        for ((a, &r), v) in anchors.iter().zip(rtts_ms).zip(valid.iter()) {
            if !*v {
                continue;
            }
            let mut d2 = 1e-9;
            for (ui, ai) in u.iter().zip(a.0.iter()) {
                d2 += (ui - ai) * (ui - ai);
            }
            let d = d2.sqrt();
            let g = 2.0 * (d - r) / d;
            for (gi, (ui, ai)) in grad.iter_mut().zip(u.iter().zip(a.0.iter())) {
                *gi += g * (ui - ai) / nv;
            }
        }
        for (ui, gi) in u.iter_mut().zip(grad.iter()) {
            *ui -= LR * gi;
        }
    }
    Coord(u)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_moves_towards_target_rtt() {
        let mut a = VivaldiState::default();
        let mut b = VivaldiState {
            coord: Coord([10.0, 0.0, 0.0, 0.0]),
            error: 1.0,
        };
        for _ in 0..300 {
            let snap_b = b;
            let snap_a = a;
            a.observe(&snap_b, 50.0);
            b.observe(&snap_a, 50.0);
        }
        let d = a.coord.distance(&b.coord);
        assert!((d - 50.0).abs() < 5.0, "distance {d}");
    }

    #[test]
    fn observe_ignores_invalid_rtt() {
        let mut a = VivaldiState::default();
        let before = a;
        a.observe(&VivaldiState::default(), 0.0);
        a.observe(&VivaldiState::default(), -3.0);
        assert_eq!(a.coord, before.coord);
        assert_eq!(a.error, before.error);
    }

    #[test]
    fn embed_recovers_triangle() {
        // 3 nodes on a line: rtt 50/50/100.
        let rtt = vec![
            vec![0.0, 50.0, 100.0],
            vec![50.0, 0.0, 50.0],
            vec![100.0, 50.0, 0.0],
        ];
        let st = embed(&rtt, 400, 9);
        let d01 = st[0].coord.distance(&st[1].coord);
        let d12 = st[1].coord.distance(&st[2].coord);
        assert!((d01 - 50.0).abs() < 8.0, "d01={d01}");
        assert!((d12 - 50.0).abs() < 8.0, "d12={d12}");
    }

    #[test]
    fn errors_stay_clamped() {
        let rtt = vec![
            vec![0.0, 20.0, 400.0],
            vec![20.0, 0.0, 30.0],
            vec![400.0, 30.0, 0.0],
        ];
        let st = embed(&rtt, 100, 1);
        for s in &st {
            assert!(s.error >= 1e-3 && s.error <= 2.0);
            assert!(s.coord.0.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn trilateration_recovers_planted_point() {
        let mut rng = Rng::seeded(5);
        let anchors: Vec<Coord> = (0..16)
            .map(|_| {
                let mut c = [0.0; DIM];
                for x in &mut c {
                    *x = rng.normal(0.0, 50.0);
                }
                Coord(c)
            })
            .collect();
        let user = Coord([13.0, -22.0, 8.0, 4.0]);
        let rtts: Vec<f64> = anchors.iter().map(|a| a.distance(&user)).collect();
        let est = trilaterate(&anchors, &rtts);
        // Distances to anchors must match even if position is mirrored.
        for (a, r) in anchors.iter().zip(&rtts) {
            assert!((a.distance(&est) - r).abs() < 5.0);
        }
    }

    #[test]
    fn trilateration_skips_failed_probes() {
        let anchors = vec![
            Coord([0.0, 0.0, 0.0, 0.0]),
            Coord([100.0, 0.0, 0.0, 0.0]),
            Coord([0.0, 100.0, 0.0, 0.0]),
            Coord([1e6, 1e6, 1e6, 1e6]), // garbage anchor, failed probe
        ];
        let user = Coord([30.0, 40.0, 0.0, 0.0]);
        let mut rtts: Vec<f64> = anchors.iter().map(|a| a.distance(&user)).collect();
        rtts[3] = 0.0; // probe failed
        let est = trilaterate(&anchors, &rtts);
        for i in 0..3 {
            assert!((anchors[i].distance(&est) - rtts[i]).abs() < 5.0);
        }
    }
}
