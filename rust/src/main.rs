//! `oakestra` — CLI launcher for the Oakestra reproduction.
//!
//! Subcommands (hand-rolled arg parsing; the offline crate set has no
//! clap):
//!
//! ```text
//! oakestra run [--config cfg.json]        run a testbed from a config
//! oakestra submit --sla app.json          deploy a Schema 1 SLA via the API
//! oakestra scale --replicas N             scale demo through the API
//! oakestra undeploy                       teardown demo through the API
//! oakestra status                         lifecycle status via the API
//! oakestra bench <fig|all>                regenerate a paper figure table
//! oakestra churn [--scenario all]         churn storm → BENCH_churn.json
//! oakestra ldp --workers N                one PJRT-accelerated LDP solve
//! oakestra lint [--strict] [--json]       determinism/protocol/flow static analysis
//! oakestra lint --graph                   emit PROTOCOL.json (flow graph + certificates)
//! oakestra lint --metrics-doc             emit METRICS.md from the source key registry
//! oakestra check-artifacts                verify AOT artifacts load + run
//! oakestra init-config [path]             write an example config
//! ```
//!
//! The lifecycle subcommands drive the typed northbound API v1
//! ([`oakestra::api`]) against a simulated testbed — the same code path
//! the integration tests and benches use.

// Same clippy triage as lib.rs (this file is its own crate root).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::collapsible_if)]
#![allow(clippy::collapsible_else_if)]

use anyhow::{anyhow, Result};
use oakestra::api::ApiResponse;
use oakestra::bench_harness as bh;
use oakestra::config::Config;
use oakestra::metrics::Table;
use oakestra::sla::ServiceSla;
use oakestra::util::{ServiceId, SimTime};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn flag_value<'a>(args: &'a [String], flag: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(args),
        Some("submit") => cmd_submit(args),
        Some("scale") => cmd_scale(args),
        Some("undeploy") => cmd_undeploy(args),
        Some("status") => cmd_status(args),
        Some("bench") => cmd_bench(args),
        Some("churn") => cmd_churn(args),
        Some("ldp") => cmd_ldp(args),
        Some("lint") => cmd_lint(args),
        Some("check-artifacts") => cmd_check_artifacts(),
        Some("init-config") => {
            let path = args.get(1).map(String::as_str).unwrap_or("oakestra.json");
            std::fs::write(path, Config::example_json())?;
            println!("wrote {path}");
            Ok(())
        }
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand '{other}' (try 'help')")),
    }
}

fn print_help() {
    println!(
        "oakestra — hierarchical edge orchestration (paper reproduction)\n\
         \n\
         USAGE:\n\
           oakestra run [--config cfg.json]   run a simulated testbed\n\
           oakestra submit --sla app.json     deploy a Schema 1 SLA via the northbound API\n\
           oakestra scale [--replicas N]      API scaling demo (up then status)\n\
           oakestra undeploy                  API teardown demo (submit, then undeploy)\n\
           oakestra status                    API status/list demo\n\
           oakestra bench <fig|all>           figures: 4a 4bc 5 6 7a 7b 8a 8b 9 10 ablations\n\
           oakestra churn [opts]              dynamic-workload churn bench (submit/scale/\n\
                                              migrate storms) → BENCH_churn.json\n\
             --scenario submit|scale|failover|spill|partition|crash|all\n\
                                              storm generators (default all;\n\
                                              spill = heavy catalog over undersized\n\
                                              clusters, defaults to a 16x6 shape;\n\
                                              partition = arrival churn + migration\n\
                                              drills under seeded cluster-uplink\n\
                                              cuts/flaps, defaults to 16x12 with the\n\
                                              heal-time anti-entropy resync gated;\n\
                                              crash = arrival churn + migration drills\n\
                                              under seeded cluster-orchestrator\n\
                                              crash-stops and epoch-fenced cold\n\
                                              restarts, defaults to 16x12 with the\n\
                                              crash-to-converged latency and\n\
                                              lost-replica count gated)\n\
             --seed N --duration S --scheduler rom|ldp\n\
             --shape CxW                      topology: C clusters x W workers each\n\
                                              (e.g. 16x6; --clusters/--workers override)\n\
             --threads N                      lane-sharded parallel sim core: one lane\n\
                                              per cluster drained by up to N threads\n\
                                              (0 = classic single-lane loop; reports\n\
                                              are bit-identical for every N >= 1)\n\
             --storm-10k                      64x160 10k-worker storm preset on the\n\
                                              lane engine (threads=4; flags override)\n\
             --services N                     cap on concurrently live churn services\n\
             --autoscale-cpu                  autoscaler keys off observed per-service\n\
                                              CPU telemetry instead of the synthetic\n\
                                              offered-load walk\n\
             --quick                          small CI-sized storm\n\
             --rejoin-chance P                killed workers rejoin as fresh nodes (0..1)\n\
             --strict                         exit non-zero on leaks, unanswered requests,\n\
                                              undrained messages or a census mismatch\n\
             --out PATH                       artifact path (default BENCH_churn.json)\n\
           oakestra ldp [--workers N]         PJRT-accelerated LDP placement demo\n\
           oakestra lint [opts]               token-level determinism/protocol analyzer\n\
             --strict                         exit non-zero if any rule exceeds the\n\
                                              LINT_BASELINE.json ratchet\n\
             --json                           machine-readable report on stdout\n\
             --graph                          emit the protocol flow graph + isolation\n\
                                              certificates (PROTOCOL.json) and exit\n\
             --metrics-doc                    emit the generated METRICS.md and exit\n\
             --update-baseline                rewrite LINT_BASELINE.json to current counts\n\
             --repo PATH                      repo root (default: nearest ancestor with\n\
                                              rust/src/lib.rs)\n\
           oakestra check-artifacts           verify the AOT artifact bundle\n\
           oakestra init-config [path]        write an example config\n\
         \n\
         Lifecycle subcommands accept --config cfg.json to pick a topology."
    );
}

fn cmd_run(args: &[String]) -> Result<()> {
    let cfg = match flag_value(args, "--config") {
        Some(path) => Config::from_file(path)?,
        None => Config::default(),
    };
    println!(
        "topology: {} cluster(s) × {} worker(s), scheduler {:?}, het={}",
        cfg.topology.clusters,
        cfg.topology.workers_per_cluster,
        cfg.topology.scheduler,
        cfg.topology.heterogeneous
    );
    let mut tb = bh::build_oakestra(cfg.testbed());
    tb.sim
        .core
        .net
        .impair_all(cfg.topology.impair_delay_ms, cfg.topology.impair_loss);
    tb.warm_up();
    for (i, (name, cpu, mem)) in cfg.services.iter().enumerate() {
        tb.submit(
            oakestra::sla::simple_sla(name, *cpu, *mem),
            SimTime::from_secs(13.0 + i as f64),
        );
    }
    tb.sim.run_until(SimTime::from_secs(cfg.duration_s));
    let times = tb.deploy_times_ms();
    println!(
        "deployed {}/{} services; mean deploy time {:.0} ms",
        times.len(),
        cfg.services.len(),
        oakestra::util::mean(&times)
    );
    let m = tb.sim.metrics();
    println!(
        "control messages: worker→cluster {}  cluster→worker {}  cluster→root {}  root→cluster {}",
        m.msgs(oakestra::messaging::labels::WORKER_TO_CLUSTER),
        m.msgs(oakestra::messaging::labels::CLUSTER_TO_WORKER),
        m.msgs(oakestra::messaging::labels::CLUSTER_TO_ROOT),
        m.msgs(oakestra::messaging::labels::ROOT_TO_CLUSTER),
    );
    Ok(())
}

/// Build a warmed-up testbed for the lifecycle subcommands.
fn lifecycle_testbed(args: &[String]) -> Result<(Config, bh::OakTestbed)> {
    let cfg = match flag_value(args, "--config") {
        Some(path) => Config::from_file(path)?,
        None => Config::default(),
    };
    let mut tb = bh::build_oakestra(cfg.testbed());
    tb.warm_up();
    Ok((cfg, tb))
}

/// Print every API response recorded for one request id.
fn print_responses(tb: &bh::OakTestbed, request_id: u64, verb: &str) {
    for r in tb.api_client().responses_for(request_id) {
        match r {
            ApiResponse::Status(s) => print!("{}", oakestra::api::format_status(s)),
            ApiResponse::Services(rows) => {
                for s in rows {
                    println!(
                        "  {} '{}': {} task(s), {} running, fully_running={}",
                        s.service, s.name, s.tasks, s.running_instances, s.fully_running
                    );
                }
            }
            ApiResponse::Error(e) => println!("{verb} error: {e}"),
            other => println!("{verb}: {other:?}"),
        }
    }
}

/// `oakestra submit --sla app.json`: full Schema 1 intake through the API.
fn cmd_submit(args: &[String]) -> Result<()> {
    let path = flag_value(args, "--sla")
        .ok_or_else(|| anyhow!("submit requires --sla <schema1.json>"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {path}: {e}"))?;
    let sla = ServiceSla::parse_json(&text)?;
    println!(
        "submitting '{}' ({} microservice(s)) through API v{}",
        sla.name,
        sla.constraints.len(),
        oakestra::api::API_VERSION
    );
    let (_cfg, mut tb) = lifecycle_testbed(args)?;
    let req = tb.submit(sla, SimTime::from_secs(13.0));
    tb.sim.run_until(SimTime::from_secs(45.0));
    let service = match tb.ack(req) {
        Some(ApiResponse::Submitted { service, instances }) => {
            println!("accepted as {service} with {} instance(s)", instances.len());
            *service
        }
        Some(ApiResponse::Error(e)) => return Err(anyhow!("rejected: {e}")),
        other => return Err(anyhow!("unexpected ack: {other:?}")),
    };
    print_responses(&tb, req, "submit"); // surfaces NoFeasiblePlacement events
    let at = tb.sim.now() + SimTime::from_secs(1.0);
    let sreq = tb.query_status(service, at);
    tb.sim.run_until(at + SimTime::from_secs(1.0));
    print_responses(&tb, sreq, "status");
    let times = tb.deploy_times_ms();
    if let Some(t) = times.first() {
        println!("deploy time: {t:.0} ms (submit → all tasks Running)");
    }
    Ok(())
}

/// `oakestra scale [--replicas N]`: submit one service, scale it, report.
fn cmd_scale(args: &[String]) -> Result<()> {
    let replicas: usize = flag_value(args, "--replicas")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(3);
    let (_cfg, mut tb) = lifecycle_testbed(args)?;
    let req = tb.submit(
        oakestra::sla::simple_sla("scaled", 150, 64),
        SimTime::from_secs(13.0),
    );
    tb.sim.run_until(SimTime::from_secs(30.0));
    let Some(ApiResponse::Submitted { service, .. }) = tb.ack(req) else {
        return Err(anyhow!("submission failed"));
    };
    let service: ServiceId = *service;
    println!("service {service} running; scaling task 0 to {replicas} replica(s)");
    let sc = tb.scale(service, Some(0), replicas, SimTime::from_secs(31.0));
    tb.sim.run_until(SimTime::from_secs(60.0));
    print_responses(&tb, sc, "scale");
    let at = tb.sim.now() + SimTime::from_secs(1.0);
    let sreq = tb.query_status(service, at);
    tb.sim.run_until(at + SimTime::from_secs(1.0));
    print_responses(&tb, sreq, "status");
    Ok(())
}

/// `oakestra undeploy`: submit one service, then tear it down via the API.
fn cmd_undeploy(args: &[String]) -> Result<()> {
    let (_cfg, mut tb) = lifecycle_testbed(args)?;
    let req = tb.submit(
        oakestra::sla::simple_sla("ephemeral", 150, 64),
        SimTime::from_secs(13.0),
    );
    tb.sim.run_until(SimTime::from_secs(30.0));
    let Some(ApiResponse::Submitted { service, .. }) = tb.ack(req) else {
        return Err(anyhow!("submission failed"));
    };
    let service: ServiceId = *service;
    println!("service {service} running; undeploying through the API");
    let ud = tb.undeploy(service, SimTime::from_secs(31.0));
    tb.sim.run_until(SimTime::from_secs(50.0));
    print_responses(&tb, ud, "undeploy");
    let at = tb.sim.now() + SimTime::from_secs(1.0);
    let sreq = tb.query_status(service, at);
    tb.sim.run_until(at + SimTime::from_secs(1.0));
    print_responses(&tb, sreq, "status");
    Ok(())
}

/// `oakestra status`: submit the configured services, then list + detail.
fn cmd_status(args: &[String]) -> Result<()> {
    let (cfg, mut tb) = lifecycle_testbed(args)?;
    let mut submits = Vec::new();
    for (i, (name, cpu, mem)) in cfg.services.iter().enumerate() {
        submits.push(tb.submit(
            oakestra::sla::simple_sla(name, *cpu, *mem),
            SimTime::from_secs(13.0 + i as f64),
        ));
    }
    tb.sim.run_until(SimTime::from_secs(40.0));
    let ls = tb.list_services(SimTime::from_secs(41.0));
    tb.sim.run_until(SimTime::from_secs(42.0));
    println!("services:");
    print_responses(&tb, ls, "list");
    let services: Vec<ServiceId> = submits
        .iter()
        .filter_map(|r| match tb.ack(*r) {
            Some(ApiResponse::Submitted { service, .. }) => Some(*service),
            _ => None,
        })
        .collect();
    for s in services {
        let at = tb.sim.now() + SimTime::from_secs(0.5);
        let sreq = tb.query_status(s, at);
        tb.sim.run_until(at + SimTime::from_secs(0.5));
        print_responses(&tb, sreq, "status");
    }
    Ok(())
}

fn print_tables(tables: &[Table]) {
    for t in tables {
        println!("{t}");
    }
}

fn cmd_bench(args: &[String]) -> Result<()> {
    let which = args.get(1).map(String::as_str).unwrap_or("all");
    let quick = args.iter().any(|a| a == "--quick");
    let sizes: Vec<usize> = if quick {
        vec![2, 6, 10]
    } else {
        vec![2, 4, 6, 8, 10]
    };
    let reps = if quick { 2 } else { 5 };

    let run = |name: &str| -> Result<Vec<Table>> {
        Ok(match name {
            "4a" => vec![bh::fig4a_deploy_time(&sizes, reps)],
            "4bc" => {
                let (a, b) = bh::fig4bc_idle_overhead(&sizes, 60.0);
                vec![a, b]
            }
            "5" => {
                let (a, b) =
                    bh::fig5_network_degradation(&[0.0, 50.0, 100.0, 175.0, 250.0], reps);
                vec![a, b]
            }
            "6" => vec![bh::fig6_cluster_ratio(45, reps)],
            "7a" => vec![bh::fig7a_control_messages(&[10, 50, 100, 200])],
            "7b" => vec![bh::fig7b_stress(&[10, 30, 60, 100])],
            "8a" => vec![bh::fig8a_schedulers_hpc(&[2, 4, 6, 8, 10], 10 * reps)],
            "8b" => vec![bh::fig8b_schedulers_scale(&[50, 100, 200, 350, 500], reps)],
            "9" => vec![
                bh::fig9_left_closest_rtt(&[1, 2, 4, 8], 500),
                bh::fig9_right_tunnel_transfer(&[10.0, 50.0, 100.0, 175.0, 250.0], 0.0),
            ],
            "10" => vec![bh::fig10_video_analytics(if quick { 30 } else { 100 })],
            "ablations" => vec![
                bh::ablations::ablate_telemetry(1200, 0.1),
                bh::ablations::ablate_delegation(500, 10, 10),
                bh::ablations::ablate_tunnel_lru(&[4, 8, 16, 32, 64], 64, 5000),
            ],
            other => return Err(anyhow!("unknown figure '{other}'")),
        })
    };

    if which == "all" {
        for name in ["4a", "4bc", "5", "6", "7a", "7b", "8a", "8b", "9", "10", "ablations"] {
            print_tables(&run(name)?);
        }
    } else {
        print_tables(&run(which)?);
    }
    Ok(())
}

/// `oakestra churn`: run the dynamic-workload churn bench (submit/scale/
/// migrate storms against the northbound API) and emit `BENCH_churn.json`
/// with per-lifecycle-op latency and control-plane msg/CPU cost.
fn cmd_churn(args: &[String]) -> Result<()> {
    let quick = args.iter().any(|a| a == "--quick");
    let mut cfg = if quick {
        bh::ChurnConfig::quick(42)
    } else {
        bh::ChurnConfig::default()
    };
    if let Some(s) = flag_value(args, "--seed") {
        cfg.seed = s.parse()?;
    }
    if let Some(s) = flag_value(args, "--scenario") {
        cfg.scenario = bh::ChurnScenario::parse(s).ok_or_else(|| {
            anyhow!(
                "unknown scenario '{s}' \
                 (submit|scale|failover|spill|partition|crash|all)"
            )
        })?;
        if cfg.scenario == bh::ChurnScenario::Spill {
            // The spill storm wants undersized clusters + fast arrivals;
            // start from its preset and let explicit flags override.
            // --quick still means quick: shrink the storm window instead
            // of silently dropping the flag.
            cfg = bh::ChurnConfig::spill_storm(cfg.seed);
            if quick {
                cfg.duration_s = 45.0;
                cfg.settle_s = 30.0;
                cfg.clusters = 8;
                cfg.workers_per_cluster = 4;
            }
        }
        if cfg.scenario == bh::ChurnScenario::Partition {
            // The partition storm needs its fault schedule installed;
            // start from the 16x12 flapping-uplink preset and let
            // explicit flags override. --quick shrinks the fleet, not
            // the cut windows — cuts must stay past the 30s lease or
            // the root never detects anything.
            cfg = bh::ChurnConfig::partition_storm(cfg.seed);
            if quick {
                cfg.clusters = 6;
                cfg.workers_per_cluster = 4;
                cfg.partition_clusters = 2;
                cfg.settle_s = 35.0;
            }
        }
        if cfg.scenario == bh::ChurnScenario::Crash {
            // The crash storm needs its kill/restart schedule installed;
            // start from the 16x12 preset and let explicit flags
            // override. --quick shrinks the fleet, not the outage
            // windows — the long outage must stay past the 30s lease or
            // the escalated-recovery path is never exercised.
            cfg = bh::ChurnConfig::crash_storm(cfg.seed);
            if quick {
                cfg.clusters = 6;
                cfg.workers_per_cluster = 4;
                cfg.crash_clusters = 2;
                cfg.settle_s = 35.0;
            }
        }
    }
    if args.iter().any(|a| a == "--storm-10k") {
        // The 10k-worker lane-sharded storm; explicit flags below still
        // override individual knobs (shape, duration, threads, ...).
        cfg = bh::ChurnConfig::storm_10k(cfg.seed);
    }
    if let Some(s) = flag_value(args, "--duration") {
        cfg.duration_s = s.parse()?;
    }
    if let Some(s) = flag_value(args, "--shape") {
        let (c, w) = bh::parse_shape(s)
            .ok_or_else(|| anyhow!("bad --shape '{s}' (expected CxW, e.g. 16x6)"))?;
        cfg.clusters = c;
        cfg.workers_per_cluster = w;
    }
    if let Some(s) = flag_value(args, "--clusters") {
        cfg.clusters = s.parse()?;
    }
    if let Some(s) = flag_value(args, "--workers") {
        cfg.workers_per_cluster = s.parse()?;
    }
    if args.iter().any(|a| a == "--autoscale-cpu") {
        cfg.cpu_autoscale = true;
    }
    if let Some(s) = flag_value(args, "--services") {
        cfg.max_live = s.parse()?;
    }
    if let Some(s) = flag_value(args, "--scheduler") {
        cfg.scheduler = oakestra::config::parse_scheduler(s)?;
    }
    if let Some(s) = flag_value(args, "--rejoin-chance") {
        cfg.rejoin_chance = s.parse()?;
    }
    if let Some(s) = flag_value(args, "--threads") {
        cfg.threads = s.parse()?;
    }
    let strict = args.iter().any(|a| a == "--strict");
    let out = flag_value(args, "--out").unwrap_or("BENCH_churn.json");
    println!(
        "churn: scenario={:?} seed={} topology {}x{} scheduler {:?}, \
         {}s virtual churn, threads={}",
        cfg.scenario,
        cfg.seed,
        cfg.clusters,
        cfg.workers_per_cluster,
        cfg.scheduler,
        cfg.duration_s,
        cfg.threads
    );
    let report = bh::run_churn(&cfg);
    print_tables(&report.tables());
    if report.unanswered_requests > 0 {
        eprintln!(
            "warning: {} API requests never received a response",
            report.unanswered_requests
        );
    }
    if report.leaked_instances > 0 || report.leaked_capacity_mc > 0 {
        eprintln!(
            "warning: leak after drain — {} instance(s), {} mc reserved",
            report.leaked_instances, report.leaked_capacity_mc
        );
    }
    if report.census_mismatch > 0 {
        eprintln!(
            "warning: root view and placement census disagree on {} live \
             instance(s) at t={:.0}ms:",
            report.census_mismatch, report.census_checked_at_ms
        );
        for row in &report.census_diff {
            eprintln!("  {row}");
        }
    }
    if report.pending_non_timer > 0 {
        eprintln!(
            "warning: {} message(s) still in flight after the quiescence \
             drain — the control plane never converged",
            report.pending_non_timer
        );
    }
    if report.watch_expired_unexcused > 0 {
        eprintln!(
            "warning: {} convergence watch(es) abandoned for services with \
             no partitioned cluster to blame",
            report.watch_expired_unexcused
        );
    }
    let partition_bad = report
        .partition
        .as_ref()
        .is_some_and(|p| p.resync_conflicts > 0 || p.unconverged_heals > 0);
    if let Some(p) = &report.partition {
        if p.resync_conflicts > 0 {
            eprintln!(
                "warning: {} resync adoption conflict(s) — an instance was \
                 adopted twice across a partition",
                p.resync_conflicts
            );
        }
        if p.unconverged_heals > 0 {
            eprintln!(
                "warning: {} heal(s) never reconverged the census",
                p.unconverged_heals
            );
        }
    }
    let crash_bad = report.crash.as_ref().is_some_and(|c| {
        c.lost_replicas > 0
            || c.resync_conflicts > 0
            || c.unconverged_crashes > 0
            || c.restarts != c.kills
            || c.restart_registers < c.restarts
    });
    if let Some(c) = &report.crash {
        if c.lost_replicas > 0 {
            eprintln!(
                "warning: {} replica(s) lost to coordinator crashes — the \
                 root still tracks capacity no cluster hosts",
                c.lost_replicas
            );
        }
        if c.resync_conflicts > 0 {
            eprintln!(
                "warning: {} resync adoption conflict(s) across a crash \
                 recovery",
                c.resync_conflicts
            );
        }
        if c.unconverged_crashes > 0 {
            eprintln!(
                "warning: {} crash(es) never reconverged the census",
                c.unconverged_crashes
            );
        }
        if c.restart_registers < c.restarts {
            eprintln!(
                "warning: only {} of {} restarts re-registered under a \
                 higher epoch",
                c.restart_registers, c.restarts
            );
        }
    }
    std::fs::write(out, report.to_json())
        .map_err(|e| anyhow!("writing {out}: {e}"))?;
    println!("wrote {out}");
    if strict
        && (report.leaked_instances > 0
            || report.leaked_capacity_mc > 0
            || report.unanswered_requests > 0
            || report.census_mismatch > 0
            || report.pending_non_timer > 0
            || report.watch_expired_unexcused > 0
            || partition_bad
            || crash_bad)
    {
        return Err(anyhow!(
            "strict churn check failed: leaks={}/{}mc unanswered={} \
             census_mismatch={} pending_non_timer={} watch_unexcused={} \
             partition_bad={} crash_bad={}",
            report.leaked_instances,
            report.leaked_capacity_mc,
            report.unanswered_requests,
            report.census_mismatch,
            report.pending_non_timer,
            report.watch_expired_unexcused,
            partition_bad,
            crash_bad
        ));
    }
    Ok(())
}

fn cmd_ldp(args: &[String]) -> Result<()> {
    let n: usize = flag_value(args, "--workers")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(500);
    let t = bh::fig8b_schedulers_scale(&[n], 3);
    println!("{t}");
    Ok(())
}

fn cmd_lint(args: &[String]) -> Result<()> {
    use oakestra::lint::{self, baseline};

    let strict = args.iter().any(|a| a == "--strict");
    let json = args.iter().any(|a| a == "--json");
    let update = args.iter().any(|a| a == "--update-baseline");
    let graph = args.iter().any(|a| a == "--graph");
    let metrics_doc = args.iter().any(|a| a == "--metrics-doc");

    let root = match flag_value(args, "--repo") {
        Some(p) => std::path::PathBuf::from(p),
        None => {
            let cwd = std::env::current_dir()?;
            lint::find_repo_root(&cwd).ok_or_else(|| {
                anyhow!(
                    "no repo root (rust/src/lib.rs) above {}; pass --repo PATH",
                    cwd.display()
                )
            })?
        }
    };
    let input = lint::gather(&root).map_err(|e| anyhow!(e))?;
    if graph {
        // Artifact mode: print PROTOCOL.json for CI to diff, nothing else.
        print!("{}", lint::protocol_graph_json(&input));
        return Ok(());
    }
    if metrics_doc {
        print!("{}", lint::metrics_doc_md(&input));
        return Ok(());
    }
    let report = lint::analyze(&input);

    let baseline_path = root.join("LINT_BASELINE.json");
    let base = baseline::Baseline::load(&baseline_path).map_err(|e| anyhow!(e))?;
    let rows = baseline::ratchet(&report.counts, &base);

    if update {
        let b = baseline::Baseline {
            rules: report.counts.clone(),
        };
        std::fs::write(&baseline_path, b.to_json())?;
        println!("wrote {}", baseline_path.display());
        return Ok(());
    }

    if json {
        print!("{}", lint::report_json(&report, &rows));
    } else {
        for v in &report.violations {
            println!("{}:{}:{}: [{}] {}", v.file, v.line, v.col, v.rule, v.message);
        }
        println!(
            "lint: {} file(s), {} violation(s)",
            report.files_scanned,
            report.violations.len()
        );
        for r in &rows {
            let status = if r.regressed() {
                "REGRESSED"
            } else if r.slack() {
                "slack (tighten baseline)"
            } else {
                "ok"
            };
            println!("  {:<18} {:>3} / baseline {:>3}  {status}", r.rule, r.count, r.baseline);
        }
    }

    let regressed: Vec<&baseline::RatchetRow> =
        rows.iter().filter(|r| r.regressed()).collect();
    if strict && !regressed.is_empty() {
        let names: Vec<&str> = regressed.iter().map(|r| r.rule.as_str()).collect();
        return Err(anyhow!(
            "lint --strict: {} rule(s) exceed the baseline ratchet: {}",
            regressed.len(),
            names.join(", ")
        ));
    }
    Ok(())
}

#[cfg(not(feature = "xla-accel"))]
fn cmd_check_artifacts() -> Result<()> {
    Err(anyhow!(
        "check-artifacts needs the PJRT bridge: rebuild with \
         `cargo run --features xla-accel -- check-artifacts`"
    ))
}

#[cfg(feature = "xla-accel")]
fn cmd_check_artifacts() -> Result<()> {
    let artifacts = oakestra::runtime::Artifacts::discover()?;
    println!("artifact dir: {}", artifacts.dir.display());
    let mut engine = oakestra::runtime::PjrtEngine::new(artifacts.clone())?;
    let mut names: Vec<&String> = artifacts.entries.keys().collect();
    names.sort();
    for name in names {
        engine.executable(name)?;
        println!("  {name}: compiled OK");
    }
    // Exercise one end-to-end execution per wrapper.
    let mut ldp = oakestra::runtime::LdpAccel::new(engine);
    let workers = vec![
        oakestra::runtime::LdpWorkerRow {
            cpu: 4.0,
            mem: 2.0,
            disk: 10.0,
            virt_bits: 1,
            lat_rad: 0.84,
            lon_rad: 0.2,
            viv: [0.0; 4],
        };
        16
    ];
    let (scores, mask) = ldp.score(&workers, [1.0, 0.5, 0.0], 1, &[])?;
    anyhow::ensure!(mask.iter().all(|m| *m) && scores.len() == 16);
    println!("  ldp_score executes OK (16 workers, all feasible)");

    let mut det = oakestra::runtime::Detector::discover()?;
    let frames = vec![0.5f32; 64 * 64 * 3];
    let grid = det.detect(&frames, 1)?;
    anyhow::ensure!(grid[0].len() == 8 * 8 * 5);
    println!("  detector executes OK (1 frame → 8×8×5 grid)");
    println!("all artifacts healthy");
    Ok(())
}
