//! Link model: per-pair delay/jitter/loss/bandwidth with `tc`-style
//! impairment overlays (the paper degrades its HET testbed with `tc`,
//! Fig. 5). Reliable transports absorb loss as retransmission delay
//! (TCP-like RTO); unreliable transports drop.

// lint: allow(hash-order, link overrides are lookup-only; never iterated)
use std::collections::HashMap;

use crate::util::{NodeId, Rng, SimTime};

/// One direction of a network link.
#[derive(Clone, Copy, Debug)]
pub struct LinkProfile {
    /// One-way propagation delay, ms.
    pub delay_ms: f64,
    /// Uniform jitter amplitude, ms (delay ± U(0, jitter)).
    pub jitter_ms: f64,
    /// Packet/message loss probability in [0, 1).
    pub loss: f64,
    /// Bandwidth in Mbit/s (serialization delay = bytes / bw).
    pub bandwidth_mbps: f64,
}

impl LinkProfile {
    /// Datacenter-grade LAN: the paper's HPC testbed (1 Gbps ethernet).
    pub fn lan() -> LinkProfile {
        LinkProfile {
            delay_ms: 0.25,
            jitter_ms: 0.05,
            loss: 0.0,
            bandwidth_mbps: 1000.0,
        }
    }

    /// Edge WiFi-ish link: HET testbed interconnect.
    pub fn wifi() -> LinkProfile {
        LinkProfile {
            delay_ms: 3.0,
            jitter_ms: 2.0,
            loss: 0.005,
            bandwidth_mbps: 100.0,
        }
    }

    /// Wide-area link with explicit parameters (inter-cluster, cloud).
    pub fn wan(delay_ms: f64, jitter_ms: f64, loss: f64) -> LinkProfile {
        LinkProfile {
            delay_ms,
            jitter_ms,
            loss,
            bandwidth_mbps: 100.0,
        }
    }

    /// Apply a `tc netem`-style impairment on top (Fig. 5: added delay /
    /// loss).
    #[must_use]
    pub fn impaired(mut self, add_delay_ms: f64, add_loss: f64) -> LinkProfile {
        self.delay_ms += add_delay_ms;
        self.loss = (self.loss + add_loss).min(0.95);
        self
    }
}

/// Transport semantics for a message.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Transport {
    /// TCP-like: loss becomes retransmission delay, delivery guaranteed.
    Reliable,
    /// UDP-like: loss drops the message.
    Unreliable,
}

/// The network: default profile + per-pair overrides (symmetric).
#[derive(Clone, Debug)]
pub struct Network {
    default: LinkProfile,
    // lint: allow(hash-order, keyed point lookups on the per-message hot path; order never observed)
    overrides: HashMap<(NodeId, NodeId), LinkProfile>,
    /// Global impairment applied to every link (tc on the shared segment).
    impair_delay_ms: f64,
    impair_loss: f64,
}

impl Default for Network {
    fn default() -> Self {
        Network {
            default: LinkProfile::lan(),
            // lint: allow(hash-order, construction only; see field comment)
            overrides: HashMap::new(),
            impair_delay_ms: 0.0,
            impair_loss: 0.0,
        }
    }
}

impl Network {
    fn key(a: NodeId, b: NodeId) -> (NodeId, NodeId) {
        if a <= b {
            (a, b)
        } else {
            (b, a)
        }
    }

    pub fn set_default(&mut self, p: LinkProfile) {
        self.default = p;
    }

    pub fn set_link(&mut self, a: NodeId, b: NodeId, p: LinkProfile) {
        self.overrides.insert(Self::key(a, b), p);
    }

    /// Global `tc netem`-style impairment (Fig. 5 sweeps this).
    pub fn impair_all(&mut self, add_delay_ms: f64, add_loss: f64) {
        self.impair_delay_ms = add_delay_ms;
        self.impair_loss = add_loss;
    }

    pub fn profile(&self, a: NodeId, b: NodeId) -> LinkProfile {
        let base = self
            .overrides
            .get(&Self::key(a, b))
            .copied()
            .unwrap_or(self.default);
        base.impaired(self.impair_delay_ms, self.impair_loss)
    }

    /// Ground-truth RTT sample (ping), ms.
    pub fn rtt_ms(&self, a: NodeId, b: NodeId, rng: &mut Rng) -> f64 {
        if a == b {
            return 0.05; // loopback
        }
        let p = self.profile(a, b);
        2.0 * (p.delay_ms + rng.range(0.0, p.jitter_ms.max(1e-9)))
    }

    /// Delivery delay for one message, or `None` if dropped (unreliable
    /// only). Reliable loss turns into RTO-backoff retransmissions.
    pub fn delivery_delay(
        &self,
        src: NodeId,
        dst: NodeId,
        bytes: usize,
        transport: Transport,
        rng: &mut Rng,
    ) -> Option<SimTime> {
        if src == dst {
            return Some(SimTime::from_micros(50)); // local socket
        }
        let p = self.profile(src, dst);
        let serialize_ms = (bytes as f64 * 8.0) / (p.bandwidth_mbps * 1000.0);
        let base_ms = p.delay_ms + rng.range(0.0, p.jitter_ms.max(1e-9)) + serialize_ms;
        match transport {
            Transport::Unreliable => {
                if rng.chance(p.loss) {
                    None
                } else {
                    Some(SimTime::from_millis(base_ms))
                }
            }
            Transport::Reliable => {
                // Geometric retransmission count; each retry waits an RTO
                // of max(200ms, 2*RTT) — the classic TCP floor.
                let mut total = base_ms;
                let rto_ms = (2.0 * 2.0 * p.delay_ms).max(200.0);
                let mut tries = 0;
                while rng.chance(p.loss) && tries < 16 {
                    total += rto_ms;
                    tries += 1;
                }
                Some(SimTime::from_millis(total))
            }
        }
    }

    /// Lower bound, in µs, on the delivery delay of any **remote**
    /// (src != dst) message under the current profiles — the lookahead
    /// window the lane-sharded sim may drain ahead of a barrier. Jitter,
    /// serialization and retransmissions only ever add delay, and the
    /// floor is monotone, so `floor(min(delay_ms) + impair) * 1000` is a
    /// safe bound; clamped to ≥ 1 µs so windows always make progress.
    /// Same-node delivery (a fixed 50 µs socket hop) never crosses a
    /// lane: nodes are homed whole onto lanes.
    pub(crate) fn min_remote_delay_us(&self) -> u64 {
        let min_ms = self
            .overrides
            .values()
            .map(|p| p.delay_ms)
            .fold(self.default.delay_ms, f64::min);
        (((min_ms + self.impair_delay_ms) * 1000.0).floor() as u64).max(1)
    }

    /// Steady-state TCP throughput on this link in Mbit/s: the minimum of
    /// the link bandwidth, the receive-window limit (1 MiB window / RTT)
    /// and the Mathis loss model MSS/(RTT·√loss) — used for the bulk
    /// transfer experiments (Fig. 9 right).
    pub fn tcp_throughput_mbps(&self, a: NodeId, b: NodeId) -> f64 {
        let p = self.profile(a, b);
        let rtt_s = (2.0 * p.delay_ms / 1000.0).max(1e-4);
        const WINDOW_BITS: f64 = 8.0 * 1024.0 * 1024.0; // 1 MiB rwnd
        let window_limit = WINDOW_BITS / rtt_s / 1e6;
        let mut tput = p.bandwidth_mbps.min(window_limit);
        if p.loss > 0.0 {
            const MSS_BITS: f64 = 1460.0 * 8.0;
            tput = tput.min(MSS_BITS / (rtt_s * p.loss.sqrt()) / 1e6);
        }
        tput
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lan_delivery_fast_and_lossless() {
        let net = Network::default();
        let mut rng = Rng::seeded(1);
        let d = net
            .delivery_delay(NodeId(0), NodeId(1), 256, Transport::Unreliable, &mut rng)
            .unwrap();
        assert!(d.as_millis() < 1.0, "{d}");
    }

    #[test]
    fn local_delivery_is_socket_cost() {
        let net = Network::default();
        let mut rng = Rng::seeded(1);
        let d = net
            .delivery_delay(NodeId(3), NodeId(3), 1 << 20, Transport::Reliable, &mut rng)
            .unwrap();
        assert_eq!(d.as_micros(), 50);
    }

    #[test]
    fn unreliable_drops_at_high_loss() {
        let mut net = Network::default();
        net.set_default(LinkProfile::wan(10.0, 0.0, 0.5));
        let mut rng = Rng::seeded(2);
        let mut drops = 0;
        for _ in 0..1000 {
            if net
                .delivery_delay(NodeId(0), NodeId(1), 64, Transport::Unreliable, &mut rng)
                .is_none()
            {
                drops += 1;
            }
        }
        assert!((400..600).contains(&drops), "drops={drops}");
    }

    #[test]
    fn reliable_converts_loss_to_delay() {
        let mut net = Network::default();
        net.set_default(LinkProfile::wan(10.0, 0.0, 0.3));
        let mut rng = Rng::seeded(3);
        let mut total = 0.0;
        for _ in 0..1000 {
            total += net
                .delivery_delay(NodeId(0), NodeId(1), 64, Transport::Reliable, &mut rng)
                .unwrap()
                .as_millis();
        }
        let mean = total / 1000.0;
        // ~0.3/(1-0.3) expected retransmissions * 200ms RTO + 10ms base.
        assert!(mean > 60.0 && mean < 130.0, "mean={mean}");
    }

    #[test]
    fn impairment_stacks_on_overrides() {
        let mut net = Network::default();
        net.set_link(NodeId(0), NodeId(1), LinkProfile::wan(20.0, 0.0, 0.0));
        net.impair_all(100.0, 0.1);
        let p = net.profile(NodeId(0), NodeId(1));
        assert!((p.delay_ms - 120.0).abs() < 1e-9);
        assert!((p.loss - 0.1).abs() < 1e-9);
        // Symmetric lookup.
        let q = net.profile(NodeId(1), NodeId(0));
        assert!((q.delay_ms - p.delay_ms).abs() < 1e-9);
    }

    #[test]
    fn min_remote_delay_tracks_fastest_link() {
        let mut net = Network::default();
        assert_eq!(net.min_remote_delay_us(), 250); // lan() default, 0.25ms
        net.set_default(LinkProfile::wan(50.0, 5.0, 0.0));
        assert_eq!(net.min_remote_delay_us(), 50_000);
        // A faster override lowers the bound.
        net.set_link(NodeId(0), NodeId(1), LinkProfile::lan());
        assert_eq!(net.min_remote_delay_us(), 250);
        // Impairment raises every link uniformly.
        net.impair_all(10.0, 0.0);
        assert_eq!(net.min_remote_delay_us(), 10_250);
        // Degenerate zero-delay profile still clamps to 1µs progress.
        let mut z = Network::default();
        z.set_default(LinkProfile::wan(0.0, 0.0, 0.0));
        assert_eq!(z.min_remote_delay_us(), 1);
    }

    #[test]
    fn tcp_throughput_decreases_with_rtt_and_loss() {
        let mut net = Network::default();
        net.set_default(LinkProfile::wan(10.0, 0.0, 0.01));
        let t10 = net.tcp_throughput_mbps(NodeId(0), NodeId(1));
        net.set_default(LinkProfile::wan(100.0, 0.0, 0.01));
        let t100 = net.tcp_throughput_mbps(NodeId(0), NodeId(1));
        assert!(t10 > t100);
        net.set_default(LinkProfile::wan(100.0, 0.0, 0.1));
        let lossy = net.tcp_throughput_mbps(NodeId(0), NodeId(1));
        assert!(lossy < t100);
        // No loss, tiny RTT → bandwidth-limited.
        net.set_default(LinkProfile::lan());
        assert_eq!(net.tcp_throughput_mbps(NodeId(0), NodeId(1)), 1000.0);
        // No loss, large RTT → window-limited.
        net.set_default(LinkProfile::wan(250.0, 0.0, 0.0));
        let w = net.tcp_throughput_mbps(NodeId(0), NodeId(1));
        assert!((w - 16.777).abs() < 0.1, "window limit {w}");
    }
}
