//! Link model: per-pair delay/jitter/loss/bandwidth with `tc`-style
//! impairment overlays (the paper degrades its HET testbed with `tc`,
//! Fig. 5). Reliable transports absorb loss as retransmission delay
//! (TCP-like RTO with exponential backoff, capped — a partitioned link
//! eventually *drops* instead of retrying forever); unreliable
//! transports drop. Scheduled [`LinkFault`]s cut a (src,dst) pair or a
//! whole node island's uplink over a virtual-time window, so partition
//! storms are seeded data installed before the run — the `Network` stays
//! immutable while events drain and thread-count determinism holds by
//! construction.

// lint: allow(hash-order, link overrides are lookup-only; never iterated)
use std::collections::HashMap;

use crate::util::{NodeId, Rng, SimTime};

/// One direction of a network link.
#[derive(Clone, Copy, Debug)]
pub struct LinkProfile {
    /// One-way propagation delay, ms.
    pub delay_ms: f64,
    /// Uniform jitter amplitude, ms (delay ± U(0, jitter)).
    pub jitter_ms: f64,
    /// Packet/message loss probability in [0, 1).
    pub loss: f64,
    /// Bandwidth in Mbit/s (serialization delay = bytes / bw).
    pub bandwidth_mbps: f64,
}

impl LinkProfile {
    /// Datacenter-grade LAN: the paper's HPC testbed (1 Gbps ethernet).
    pub fn lan() -> LinkProfile {
        LinkProfile {
            delay_ms: 0.25,
            jitter_ms: 0.05,
            loss: 0.0,
            bandwidth_mbps: 1000.0,
        }
    }

    /// Edge WiFi-ish link: HET testbed interconnect.
    pub fn wifi() -> LinkProfile {
        LinkProfile {
            delay_ms: 3.0,
            jitter_ms: 2.0,
            loss: 0.005,
            bandwidth_mbps: 100.0,
        }
    }

    /// Wide-area link with explicit parameters (inter-cluster, cloud).
    pub fn wan(delay_ms: f64, jitter_ms: f64, loss: f64) -> LinkProfile {
        LinkProfile {
            delay_ms,
            jitter_ms,
            loss,
            bandwidth_mbps: 100.0,
        }
    }

    /// Apply a `tc netem`-style impairment on top (Fig. 5: added delay /
    /// loss).
    #[must_use]
    pub fn impaired(mut self, add_delay_ms: f64, add_loss: f64) -> LinkProfile {
        self.delay_ms += add_delay_ms;
        self.loss = (self.loss + add_loss).min(0.95);
        self
    }
}

/// Transport semantics for a message.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Transport {
    /// TCP-like: loss becomes retransmission delay, delivery guaranteed
    /// up to the retransmit cap.
    Reliable,
    /// UDP-like: loss drops the message.
    Unreliable,
}

/// What a scheduled [`LinkFault`] severs.
#[derive(Clone, Copy, Debug)]
pub enum FaultScope {
    /// One symmetric (a, b) link.
    Pair(NodeId, NodeId),
    /// Every link with exactly one endpoint inside the inclusive node-id
    /// range `[lo, hi]` — an island partition: the range keeps talking to
    /// itself, the rest of the world keeps talking to itself, and nothing
    /// crosses the boundary. Cluster subtrees are minted with contiguous
    /// node ids, so one island fault cuts a whole cluster's uplink.
    Island(NodeId, NodeId),
}

/// One seeded partition window: the scoped links are down for
/// `from <= t < until`. Installed before the run; never mutated while
/// events drain.
#[derive(Clone, Copy, Debug)]
pub struct LinkFault {
    pub scope: FaultScope,
    pub from: SimTime,
    pub until: SimTime,
}

impl LinkFault {
    fn cuts(&self, a: NodeId, b: NodeId, at: SimTime) -> bool {
        if at < self.from || at >= self.until {
            return false;
        }
        match self.scope {
            FaultScope::Pair(x, y) => Network::key(a, b) == Network::key(x, y),
            FaultScope::Island(lo, hi) => {
                let inside = |n: NodeId| lo <= n && n <= hi;
                inside(a) != inside(b)
            }
        }
    }
}

/// Outcome of one [`Network::deliver`] draw.
#[derive(Clone, Copy, Debug)]
pub enum Delivery {
    /// The message arrives after `delay`, having burned `retransmits`
    /// RTO-paced resends first (0 for a clean first attempt).
    Delivered { delay: SimTime, retransmits: u32 },
    /// Unreliable loss (or an unreliable send into a cut link).
    Lost,
    /// Reliable send exhausted the retransmit cap — the link stayed
    /// lossy/cut past every backoff attempt and the sender gives up.
    DroppedAfterRetry { retransmits: u32 },
}

/// The network: default profile + per-pair overrides (symmetric) + a
/// schedule of partition faults.
#[derive(Clone, Debug)]
pub struct Network {
    default: LinkProfile,
    // lint: allow(hash-order, keyed point lookups on the per-message hot path; order never observed)
    overrides: HashMap<(NodeId, NodeId), LinkProfile>,
    /// Global impairment applied to every link (tc on the shared segment).
    impair_delay_ms: f64,
    impair_loss: f64,
    /// Seeded partition schedule. Order-independent (membership test
    /// only); cuts only ever *add* delay or drop messages, so the
    /// [`Self::min_remote_delay_us`] lane-lookahead bound stays valid
    /// under any fault schedule.
    faults: Vec<LinkFault>,
    /// Max RTO-paced resends a reliable send burns before giving up.
    retransmit_cap: u32,
}

impl Default for Network {
    fn default() -> Self {
        Network {
            default: LinkProfile::lan(),
            // lint: allow(hash-order, construction only; see field comment)
            overrides: HashMap::new(),
            impair_delay_ms: 0.0,
            impair_loss: 0.0,
            faults: Vec::new(),
            retransmit_cap: 16,
        }
    }
}

impl Network {
    fn key(a: NodeId, b: NodeId) -> (NodeId, NodeId) {
        if a <= b {
            (a, b)
        } else {
            (b, a)
        }
    }

    pub fn set_default(&mut self, p: LinkProfile) {
        self.default = p;
    }

    pub fn set_link(&mut self, a: NodeId, b: NodeId, p: LinkProfile) {
        self.overrides.insert(Self::key(a, b), p);
    }

    /// Global `tc netem`-style impairment (Fig. 5 sweeps this).
    pub fn impair_all(&mut self, add_delay_ms: f64, add_loss: f64) {
        self.impair_delay_ms = add_delay_ms;
        self.impair_loss = add_loss;
    }

    /// Schedule a cut of the symmetric (a, b) link for `from <= t < until`.
    pub fn cut_link(&mut self, a: NodeId, b: NodeId, from: SimTime, until: SimTime) {
        self.faults.push(LinkFault {
            scope: FaultScope::Pair(a, b),
            from,
            until,
        });
    }

    /// Schedule an island partition: every link with exactly one endpoint
    /// in `[lo, hi]` is down for `from <= t < until`.
    pub fn cut_island(&mut self, lo: NodeId, hi: NodeId, from: SimTime, until: SimTime) {
        self.faults.push(LinkFault {
            scope: FaultScope::Island(lo, hi),
            from,
            until,
        });
    }

    /// Cap on RTO-paced reliable resends (default 16).
    pub fn set_retransmit_cap(&mut self, cap: u32) {
        self.retransmit_cap = cap;
    }

    /// Is the (a, b) link severed by any scheduled fault at `at`?
    pub fn is_cut(&self, a: NodeId, b: NodeId, at: SimTime) -> bool {
        self.faults.iter().any(|f| f.cuts(a, b, at))
    }

    pub fn profile(&self, a: NodeId, b: NodeId) -> LinkProfile {
        let base = self
            .overrides
            .get(&Self::key(a, b))
            .copied()
            .unwrap_or(self.default);
        base.impaired(self.impair_delay_ms, self.impair_loss)
    }

    /// Ground-truth RTT sample (ping), ms.
    pub fn rtt_ms(&self, a: NodeId, b: NodeId, rng: &mut Rng) -> f64 {
        if a == b {
            return 0.05; // loopback
        }
        let p = self.profile(a, b);
        2.0 * (p.delay_ms + rng.range(0.0, p.jitter_ms.max(1e-9)))
    }

    /// Resolve one message send issued at `now`. Unreliable sends into a
    /// cut link (or a lossy draw) are [`Delivery::Lost`]. Reliable sends
    /// park and retry on an exponential RTO backoff — an attempt that
    /// lands inside a cut window fails without consuming an rng draw (the
    /// wire is down; there is nothing probabilistic about it) — until
    /// either an attempt lands on a healed, non-lossy draw (delivered
    /// with the accumulated backoff as extra delay) or the retransmit cap
    /// is exhausted ([`Delivery::DroppedAfterRetry`]). With no faults
    /// scheduled the rng draw order is identical to the classic model:
    /// one jitter draw, then one loss draw per attempt.
    pub fn deliver(
        &self,
        src: NodeId,
        dst: NodeId,
        bytes: usize,
        transport: Transport,
        now: SimTime,
        rng: &mut Rng,
    ) -> Delivery {
        if src == dst {
            return Delivery::Delivered {
                delay: SimTime::from_micros(50), // local socket
                retransmits: 0,
            };
        }
        let p = self.profile(src, dst);
        let serialize_ms = (bytes as f64 * 8.0) / (p.bandwidth_mbps * 1000.0);
        let base_ms = p.delay_ms + rng.range(0.0, p.jitter_ms.max(1e-9)) + serialize_ms;
        match transport {
            Transport::Unreliable => {
                if self.is_cut(src, dst, now) || rng.chance(p.loss) {
                    Delivery::Lost
                } else {
                    Delivery::Delivered {
                        delay: SimTime::from_millis(base_ms),
                        retransmits: 0,
                    }
                }
            }
            Transport::Reliable => {
                // RTO floor: max(200ms, 2*RTT) — the classic TCP floor —
                // doubling per retry, capped per-interval at 15s.
                let mut rto_ms = (2.0 * 2.0 * p.delay_ms).max(200.0);
                let mut offset_ms = 0.0;
                let mut retransmits = 0u32;
                loop {
                    let at = now + SimTime::from_millis(offset_ms);
                    let attempt_lost =
                        self.is_cut(src, dst, at) || rng.chance(p.loss);
                    if !attempt_lost {
                        return Delivery::Delivered {
                            delay: SimTime::from_millis(offset_ms + base_ms),
                            retransmits,
                        };
                    }
                    if retransmits >= self.retransmit_cap {
                        return Delivery::DroppedAfterRetry { retransmits };
                    }
                    retransmits += 1;
                    offset_ms += rto_ms;
                    rto_ms = (rto_ms * 2.0).min(15_000.0);
                }
            }
        }
    }

    /// Lower bound, in µs, on the delivery delay of any **remote**
    /// (src != dst) message under the current profiles — the lookahead
    /// window the lane-sharded sim may drain ahead of a barrier. Jitter,
    /// serialization and retransmissions only ever add delay, and the
    /// floor is monotone, so `floor(min(delay_ms) + impair) * 1000` is a
    /// safe bound; clamped to ≥ 1 µs so windows always make progress.
    /// Scheduled link faults never lower it either: a cut attempt adds
    /// RTO backoff or drops the message entirely, so every delivery that
    /// *does* happen is still at least one base propagation delay out.
    /// Same-node delivery (a fixed 50 µs socket hop) never crosses a
    /// lane: nodes are homed whole onto lanes.
    pub(crate) fn min_remote_delay_us(&self) -> u64 {
        let min_ms = self
            .overrides
            .values()
            .map(|p| p.delay_ms)
            .fold(self.default.delay_ms, f64::min);
        (((min_ms + self.impair_delay_ms) * 1000.0).floor() as u64).max(1)
    }

    /// Steady-state TCP throughput on this link in Mbit/s: the minimum of
    /// the link bandwidth, the receive-window limit (1 MiB window / RTT)
    /// and the Mathis loss model MSS/(RTT·√loss) — used for the bulk
    /// transfer experiments (Fig. 9 right).
    pub fn tcp_throughput_mbps(&self, a: NodeId, b: NodeId) -> f64 {
        let p = self.profile(a, b);
        let rtt_s = (2.0 * p.delay_ms / 1000.0).max(1e-4);
        const WINDOW_BITS: f64 = 8.0 * 1024.0 * 1024.0; // 1 MiB rwnd
        let window_limit = WINDOW_BITS / rtt_s / 1e6;
        let mut tput = p.bandwidth_mbps.min(window_limit);
        if p.loss > 0.0 {
            const MSS_BITS: f64 = 1460.0 * 8.0;
            tput = tput.min(MSS_BITS / (rtt_s * p.loss.sqrt()) / 1e6);
        }
        tput
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn deliver_at(
        net: &Network,
        src: NodeId,
        dst: NodeId,
        bytes: usize,
        transport: Transport,
        now: SimTime,
        rng: &mut Rng,
    ) -> Delivery {
        net.deliver(src, dst, bytes, transport, now, rng)
    }

    fn delivered(d: Delivery) -> SimTime {
        match d {
            Delivery::Delivered { delay, .. } => delay,
            other => panic!("expected delivery, got {other:?}"),
        }
    }

    #[test]
    fn lan_delivery_fast_and_lossless() {
        let net = Network::default();
        let mut rng = Rng::seeded(1);
        let d = delivered(deliver_at(
            &net,
            NodeId(0),
            NodeId(1),
            256,
            Transport::Unreliable,
            SimTime::ZERO,
            &mut rng,
        ));
        assert!(d.as_millis() < 1.0, "{d}");
    }

    #[test]
    fn local_delivery_is_socket_cost() {
        let net = Network::default();
        let mut rng = Rng::seeded(1);
        let d = delivered(deliver_at(
            &net,
            NodeId(3),
            NodeId(3),
            1 << 20,
            Transport::Reliable,
            SimTime::ZERO,
            &mut rng,
        ));
        assert_eq!(d.as_micros(), 50);
    }

    #[test]
    fn unreliable_drops_at_high_loss() {
        let mut net = Network::default();
        net.set_default(LinkProfile::wan(10.0, 0.0, 0.5));
        let mut rng = Rng::seeded(2);
        let mut drops = 0;
        for _ in 0..1000 {
            if matches!(
                deliver_at(
                    &net,
                    NodeId(0),
                    NodeId(1),
                    64,
                    Transport::Unreliable,
                    SimTime::ZERO,
                    &mut rng
                ),
                Delivery::Lost
            ) {
                drops += 1;
            }
        }
        assert!((400..600).contains(&drops), "drops={drops}");
    }

    #[test]
    fn reliable_converts_loss_to_delay() {
        let mut net = Network::default();
        net.set_default(LinkProfile::wan(10.0, 0.0, 0.3));
        let mut rng = Rng::seeded(3);
        let mut total = 0.0;
        let mut retransmits = 0u32;
        for _ in 0..1000 {
            match deliver_at(
                &net,
                NodeId(0),
                NodeId(1),
                64,
                Transport::Reliable,
                SimTime::ZERO,
                &mut rng,
            ) {
                Delivery::Delivered { delay, retransmits: r } => {
                    total += delay.as_millis();
                    retransmits += r;
                }
                other => panic!("loss=0.3 never exhausts a 16-retry cap: {other:?}"),
            }
        }
        let mean = total / 1000.0;
        // E[extra] = Σ 0.3^k · 200·2^(k-1) ≈ 150ms of backoff + 10ms base.
        assert!(mean > 80.0 && mean < 260.0, "mean={mean}");
        // ~0.3/(1-0.3) ≈ 0.43 expected retransmissions per send.
        assert!((300..600).contains(&retransmits), "retransmits={retransmits}");
    }

    #[test]
    fn cut_link_drops_unreliable_and_parks_reliable() {
        let mut net = Network::default();
        net.set_default(LinkProfile::wan(10.0, 0.0, 0.0));
        let cut_from = SimTime::from_secs(10.0);
        let cut_until = SimTime::from_secs(11.0);
        net.cut_link(NodeId(0), NodeId(1), cut_from, cut_until);
        let mut rng = Rng::seeded(4);

        // Before the window: clean first-attempt delivery.
        let d = deliver_at(
            &net,
            NodeId(0),
            NodeId(1),
            64,
            Transport::Reliable,
            SimTime::ZERO,
            &mut rng,
        );
        match d {
            Delivery::Delivered { retransmits, .. } => assert_eq!(retransmits, 0),
            other => panic!("{other:?}"),
        }

        // Inside the window: unreliable drops, symmetric in direction.
        for (a, b) in [(NodeId(0), NodeId(1)), (NodeId(1), NodeId(0))] {
            assert!(matches!(
                deliver_at(&net, a, b, 64, Transport::Unreliable, cut_from, &mut rng),
                Delivery::Lost
            ));
        }

        // Inside the window: reliable parks on RTO backoff and arrives
        // only after the heal (cut attempts consume no rng draw, so this
        // is exact: 1s cut, 200ms RTO → 5 burned attempts, 200+400ms of
        // backoff already exceed the window).
        let sent = SimTime::from_secs(10.5);
        match deliver_at(&net, NodeId(0), NodeId(1), 64, Transport::Reliable, sent, &mut rng)
        {
            Delivery::Delivered { delay, retransmits } => {
                assert!(retransmits > 0, "must have parked");
                assert!(
                    sent + delay >= cut_until,
                    "arrived at {} before heal {}",
                    sent + delay,
                    cut_until
                );
            }
            other => panic!("{other:?}"),
        }

        // Unaffected pair keeps flowing during the window.
        assert!(matches!(
            deliver_at(&net, NodeId(2), NodeId(3), 64, Transport::Unreliable, sent, &mut rng),
            Delivery::Delivered { .. }
        ));
    }

    #[test]
    fn long_cut_exhausts_retransmit_cap() {
        let mut net = Network::default();
        net.set_default(LinkProfile::wan(10.0, 0.0, 0.0));
        net.set_retransmit_cap(4);
        // A cut far longer than 4 backoff attempts can outwait.
        net.cut_link(NodeId(0), NodeId(1), SimTime::ZERO, SimTime::from_secs(3600.0));
        let mut rng = Rng::seeded(5);
        match deliver_at(
            &net,
            NodeId(0),
            NodeId(1),
            64,
            Transport::Reliable,
            SimTime::from_secs(1.0),
            &mut rng,
        ) {
            Delivery::DroppedAfterRetry { retransmits } => assert_eq!(retransmits, 4),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn island_cut_severs_only_boundary_links() {
        let mut net = Network::default();
        net.set_default(LinkProfile::wan(5.0, 0.0, 0.0));
        // Island [10, 19] partitioned for the whole test window.
        net.cut_island(
            NodeId(10),
            NodeId(19),
            SimTime::ZERO,
            SimTime::from_secs(100.0),
        );
        let at = SimTime::from_secs(1.0);
        // Boundary-crossing links are down, both directions.
        assert!(net.is_cut(NodeId(0), NodeId(10), at));
        assert!(net.is_cut(NodeId(19), NodeId(20), at));
        // Intra-island and outside-world links keep working.
        assert!(!net.is_cut(NodeId(10), NodeId(19), at));
        assert!(!net.is_cut(NodeId(0), NodeId(20), at));
        // And the window actually ends.
        assert!(!net.is_cut(NodeId(0), NodeId(10), SimTime::from_secs(100.0)));
    }

    #[test]
    fn impairment_stacks_on_overrides() {
        let mut net = Network::default();
        net.set_link(NodeId(0), NodeId(1), LinkProfile::wan(20.0, 0.0, 0.0));
        net.impair_all(100.0, 0.1);
        let p = net.profile(NodeId(0), NodeId(1));
        assert!((p.delay_ms - 120.0).abs() < 1e-9);
        assert!((p.loss - 0.1).abs() < 1e-9);
        // Symmetric lookup.
        let q = net.profile(NodeId(1), NodeId(0));
        assert!((q.delay_ms - p.delay_ms).abs() < 1e-9);
    }

    #[test]
    fn min_remote_delay_tracks_fastest_link() {
        let mut net = Network::default();
        assert_eq!(net.min_remote_delay_us(), 250); // lan() default, 0.25ms
        net.set_default(LinkProfile::wan(50.0, 5.0, 0.0));
        assert_eq!(net.min_remote_delay_us(), 50_000);
        // A faster override lowers the bound.
        net.set_link(NodeId(0), NodeId(1), LinkProfile::lan());
        assert_eq!(net.min_remote_delay_us(), 250);
        // Impairment raises every link uniformly.
        net.impair_all(10.0, 0.0);
        assert_eq!(net.min_remote_delay_us(), 10_250);
        // Degenerate zero-delay profile still clamps to 1µs progress.
        let mut z = Network::default();
        z.set_default(LinkProfile::wan(0.0, 0.0, 0.0));
        assert_eq!(z.min_remote_delay_us(), 1);
        // Fault schedules never lower the lookahead bound: cuts only add
        // backoff delay or drop outright.
        let mut c = Network::default();
        c.cut_island(NodeId(0), NodeId(9), SimTime::ZERO, SimTime::from_secs(60.0));
        assert_eq!(c.min_remote_delay_us(), 250);
    }

    #[test]
    fn tcp_throughput_decreases_with_rtt_and_loss() {
        let mut net = Network::default();
        net.set_default(LinkProfile::wan(10.0, 0.0, 0.01));
        let t10 = net.tcp_throughput_mbps(NodeId(0), NodeId(1));
        net.set_default(LinkProfile::wan(100.0, 0.0, 0.01));
        let t100 = net.tcp_throughput_mbps(NodeId(0), NodeId(1));
        assert!(t10 > t100);
        net.set_default(LinkProfile::wan(100.0, 0.0, 0.1));
        let lossy = net.tcp_throughput_mbps(NodeId(0), NodeId(1));
        assert!(lossy < t100);
        // No loss, tiny RTT → bandwidth-limited.
        net.set_default(LinkProfile::lan());
        assert_eq!(net.tcp_throughput_mbps(NodeId(0), NodeId(1)), 1000.0);
        // No loss, large RTT → window-limited.
        net.set_default(LinkProfile::wan(250.0, 0.0, 0.0));
        let w = net.tcp_throughput_mbps(NodeId(0), NodeId(1));
        assert!((w - 16.777).abs() < 0.1, "window limit {w}");
    }
}
