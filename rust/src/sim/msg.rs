//! The unified simulation message set: Oakestra's control protocol
//! ([`OakMsg`]), the flat Kubernetes-family baseline protocol
//! ([`KubeMsg`]), data-plane traffic ([`DataMsg`]) and timers.
//!
//! Wire sizes are charged explicitly at each send site (the byte counts
//! behind Fig. 7a); keeping payloads as plain structs in one place keeps
//! the protocol reviewable the way a `.proto` file would be.

use crate::hierarchy::AggregateStats;
use crate::model::{Capacity, ServiceState, WorkerSpec};
use crate::netmanager::{ServiceIp, TableEntry};
use crate::sim::ActorId;
use crate::sla::TaskSla;
use crate::util::{ClusterId, InstanceId, NodeId, ServiceId, SimTime, TaskId};
use crate::vivaldi::VivaldiState;

/// Periodic timer kinds (the owner interprets them).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TimerKind {
    /// Worker → cluster push-based telemetry tick (λ(Rₙⁱ), §4.1).
    WorkerTelemetry,
    /// Cluster → parent aggregate push tick.
    ClusterAggregate,
    /// Orchestrator health sweep (failure detection).
    HealthSweep,
    /// Root↔cluster WebSocket liveness ping (§6 Orchestration).
    LivenessPing,
    /// Kubelet status update / watch resync (baselines).
    KubeletSync,
    /// Controller-manager reconcile loop (baselines).
    Reconcile,
    /// Workload-specific tick (frame generation, request generation...).
    Workload,
    /// Tunnel garbage collection sweep (§5 configured/active links).
    TunnelGc,
    /// Cluster conversion-table dissemination tick: flush the coalesced
    /// per-worker `TableEntry` delta buffers (§5 subscription pushes are
    /// batched per destination instead of one message per change).
    TableFlush,
    Custom(u32),
}

/// Why a cluster minted a replacement instance without root involvement
/// (paper §4.2 delegated autonomy): the successor-registration protocol
/// carries the reason so the root can apply the right retirement
/// semantics to the original (a migration original keeps running until
/// cutover; a recovery original is already dead).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ReplacementReason {
    /// SLA-violation or API-driven migration: the original is torn down
    /// once the replacement reports Running.
    Migration,
    /// Local recovery after a worker death: the original is gone.
    LocalRecovery,
}

/// One row of the local-instance census a worker attaches to its
/// (re-)registration handshake: everything a freshly restarted cluster
/// orchestrator needs to rebuild its `InstanceTable` entry for a
/// surviving container bottom-up — the reservation (capacity
/// re-derivation), the SLA (QoS watching must keep working), and the
/// replacement lineage (pending root adoptions must survive the crash).
#[derive(Clone, Debug)]
pub struct CensusRow {
    pub instance: InstanceId,
    pub task: TaskId,
    pub state: ServiceState,
    pub request: Capacity,
    pub sla: TaskSla,
    /// `(original, reason)` if this instance is a cluster-minted
    /// replacement whose adoption verdict may have died with the old
    /// incarnation's outbox.
    pub origin: Option<(InstanceId, ReplacementReason)>,
}

/// Oakestra control-plane protocol (paper Fig. 1 steps ①–⑪).
#[derive(Clone, Debug)]
pub enum OakMsg {
    // -- registration ----------------------------------------------------
    /// Operator registers a cluster orchestrator with the root (or a
    /// sub-cluster with its parent). `epoch` is the orchestrator's
    /// incarnation number: a crash-restart re-registers under a higher
    /// epoch, which is how the root tells a fast restart apart from a
    /// duplicate registration or a partitioned straggler.
    RegisterCluster {
        cluster: ClusterId,
        orchestrator: ActorId,
        parent: ClusterId,
        epoch: u64,
    },
    RegisterClusterAck {
        accepted: bool,
    },
    /// Worker joins its cluster orchestrator; carries capacity &
    /// capabilities (§3.2.3) and receives its overlay subnet. On a
    /// re-registration (cluster orchestrator restarted) the census
    /// carries every locally hosted instance so the new incarnation can
    /// rebuild its tables bottom-up; a first registration sends it empty.
    RegisterWorker {
        spec: WorkerSpec,
        engine: ActorId,
        census: Vec<CensusRow>,
    },
    /// `epoch` stamps the answering incarnation: workers remember the
    /// highest epoch seen and fence commands from older (dead) ones.
    RegisterWorkerAck {
        subnet: u32,
        epoch: u64,
    },

    // -- telemetry (§4.1) --------------------------------------------------
    /// Push-based worker report over the intra-cluster MQTT link.
    WorkerReport {
        node: NodeId,
        used: Capacity,
        vivaldi: VivaldiState,
        /// (id, state, qos_ms, observed cpu draw in millicores).
        instances: Vec<(InstanceId, ServiceState, f64, u32)>,
    },
    /// Push-based aggregate over the inter-cluster WebSocket link.
    /// Delta-coalesced: clusters suppress ticks whose aggregate moved
    /// less than the configured threshold (bounded by a max-staleness
    /// resend), so each report the root ingests is a meaningful move.
    ClusterReport {
        cluster: ClusterId,
        stats: AggregateStats,
        running_instances: usize,
        /// Per-service observed CPU (millicores, Running instances only)
        /// summed across this cluster's workers — the QoS-telemetry feed
        /// behind `ServiceStatus.observed_cpu_mc`.
        service_cpu: Vec<(ServiceId, u64)>,
    },
    /// WS liveness ping/pong. The pong names its cluster so the root can
    /// refresh that link's liveness directly — with aggregate reports
    /// delta-coalesced they no longer double as a reliable heartbeat.
    Ping,
    Pong {
        cluster: ClusterId,
    },
    /// Membership gossip: orchestrator → worker sample of peer Vivaldi
    /// states so workers can run decentralized coordinate updates.
    PeerHint {
        peers: Vec<(NodeId, VivaldiState)>,
    },

    // -- northbound API (v1, paper §3.2.1) ---------------------------------
    /// Typed northbound call arriving at the root service manager. The
    /// envelope carries version, correlation id, operation and reply
    /// address; see [`crate::api`]. This is the only way lifecycle
    /// operations (submit/scale/migrate/undeploy/status) enter the
    /// hierarchy.
    ApiCall(Box<crate::api::ApiEnvelope>),
    /// Root's answer (or asynchronous event) for one API call.
    ApiReturn {
        request_id: u64,
        response: Box<crate::api::ApiResponse>,
    },

    // -- deployment (steps ①–⑨) -------------------------------------------
    /// Root delegates one task to a cluster orchestrator (step ③/④),
    /// carrying τ and Q_τ. `attempt` counts priority-list retries.
    DelegateTask {
        task: TaskId,
        instance: InstanceId,
        sla: TaskSla,
        attempt: u32,
    },
    /// Cluster answers the root: placed on `worker`, or infeasible.
    DelegationResult {
        task: TaskId,
        instance: InstanceId,
        worker: Option<NodeId>,
        calc_time: SimTime,
    },
    /// Cluster orchestrator instructs a worker's NodeEngine (step ⑦).
    /// Carries the full SLA and (for minted replacements) the lineage so
    /// the worker's census can reconstruct the cluster's tables after an
    /// orchestrator crash; `epoch` fences the command against arriving
    /// from an incarnation that has since died (0 = unset/legacy).
    DeployInstance {
        instance: InstanceId,
        task: TaskId,
        request: Capacity,
        image_mb: u32,
        service_ips: Vec<ServiceIp>,
        sla: TaskSla,
        origin: Option<(InstanceId, ReplacementReason)>,
        epoch: u64,
    },
    /// NodeEngine confirms the container is up (→ Running) or failed.
    InstanceStatus {
        instance: InstanceId,
        node: NodeId,
        state: ServiceState,
    },
    /// Epoch-fenced like [`OakMsg::DeployInstance`]: a teardown queued by
    /// a dead incarnation must not fire under the new one, whose rebuilt
    /// census may have re-legitimized the instance.
    UndeployInstance {
        instance: InstanceId,
        epoch: u64,
    },
    /// Root tears a whole service down: every cluster undeploys all local
    /// instances of the service, including replacements it minted itself
    /// during migrations/local recovery (which the root never tracked).
    UndeployService {
        service: ServiceId,
    },
    /// Root/driver callback when a whole service reaches Running.
    ServiceDeployed {
        service: ServiceId,
        elapsed: SimTime,
    },
    /// Root instructs the owning cluster to migrate one instance away
    /// from its current worker (API-driven migration; paper §6:
    /// rescheduling + deferred teardown of the original).
    MigrateInstance {
        instance: InstanceId,
    },
    /// Successor registration (cluster → root, sent at mint time): the
    /// cluster autonomously created `replacement` to supersede
    /// `original` (§4.2 delegated scheduling) and the root must adopt it
    /// into the service database so the global placement view (§3.2.1)
    /// stays authoritative. Answered by [`OakMsg::InstanceReplacedAck`].
    InstanceReplaced {
        cluster: ClusterId,
        service: ServiceId,
        task: TaskId,
        original: InstanceId,
        replacement: InstanceId,
        reason: ReplacementReason,
    },
    /// Root's verdict on a successor registration. `adopted == false`
    /// (service retired/unknown or broken lineage) obliges the cluster
    /// to tear the replacement down — mirroring the `ServiceRetired`
    /// discipline: a refused instance must never outlive the refusal.
    InstanceReplacedAck {
        original: InstanceId,
        replacement: InstanceId,
        adopted: bool,
    },

    // -- overlay networking (steps ⑩–⑪, §5) --------------------------------
    /// Worker asks its cluster service manager to resolve a ServiceIP.
    ResolveIp {
        from: NodeId,
        query: ServiceIp,
    },
    /// Resolution answer / push update of conversion-table entries.
    TableUpdate {
        entries: Vec<TableEntry>,
    },
    /// Recursive resolution: cluster asks root for foreign instances.
    ResolveIpUp {
        cluster: ClusterId,
        from: NodeId,
        query: ServiceIp,
    },

    // -- failure handling ---------------------------------------------------
    /// Cluster tells root it cannot host an instance anymore (reschedule
    /// up the hierarchy, §4.2).
    EscalateReschedule {
        task: TaskId,
        instance: InstanceId,
        sla: TaskSla,
    },

    // -- partition recovery (anti-entropy resync) ---------------------------
    /// Root → cluster after a lease heal: "your uplink was partitioned;
    /// send me your authoritative census so we can reconcile." Answered
    /// by [`OakMsg::ResyncSnapshot`].
    ResyncRequest,
    /// Cluster → root: the full live-instance census plus the log of
    /// replacements minted while the uplink was down and still awaiting
    /// an adoption verdict. The root replays the log through the
    /// idempotent adoption machinery, fails root-side records absent
    /// from the census, and tears down true orphans — nothing is lost or
    /// double-applied even when the snapshot races duplicate outbox
    /// replays.
    ResyncSnapshot {
        cluster: ClusterId,
        /// Every non-terminal local record: (instance, task, state, node).
        instances: Vec<(InstanceId, TaskId, ServiceState, NodeId)>,
        /// Unacked minted replacements: (service, task, original,
        /// replacement, reason).
        replacements: Vec<(ServiceId, TaskId, InstanceId, InstanceId, ReplacementReason)>,
    },
}

/// Flat Kubernetes-family control protocol (baselines; DESIGN.md ledger).
#[derive(Clone, Debug)]
pub enum KubeMsg {
    /// kubelet → apiserver node status (10 s default period).
    NodeStatus {
        node: NodeId,
        used: Capacity,
    },
    /// kubelet list/watch registration + periodic resync (full state).
    WatchSync {
        node: NodeId,
    },
    /// apiserver → kubelet watch event (pod spec changed).
    WatchEvent {
        bytes: usize,
    },
    /// Client submits a pod/deployment.
    SubmitPod {
        service: ServiceId,
        request: Capacity,
        image_mb: u32,
        reply_to: Option<ActorId>,
    },
    /// scheduler binds pod → node (goes through apiserver + store).
    Bind {
        service: ServiceId,
        node: NodeId,
    },
    /// kubelet reports pod phase.
    PodStatus {
        service: ServiceId,
        node: NodeId,
        running: bool,
    },
    /// store (etcd/dqlite/sqlite) write round-trip completion.
    StoreCommit {
        key: u64,
    },
    /// kubelet node lease renewal (default 10 s period, light object).
    LeaseRenew {
        node: NodeId,
    },
    /// kubelet → apiserver object fetch before running a pod (pod spec,
    /// secrets/configmaps — each a full round trip).
    SpecFetch {
        service: ServiceId,
        node: NodeId,
        round: u8,
    },
    SpecReply {
        service: ServiceId,
        round: u8,
    },
    /// Post-Running condition PATCH (Initialized/Ready/ContainersReady).
    ConditionPatch {
        service: ServiceId,
        node: NodeId,
    },
    /// Driver callback mirroring `ServiceDeployed`.
    PodDeployed {
        service: ServiceId,
        elapsed: SimTime,
    },
}

/// Application/data-plane traffic.
#[derive(Clone, Debug)]
pub enum DataMsg {
    Ping {
        seq: u32,
    },
    /// HTTP-ish request to a semantic ServiceIP (Fig. 9 left).
    Request {
        id: u64,
        from: ActorId,
        target: ServiceIp,
        bytes: usize,
        sent_at: SimTime,
    },
    Response {
        id: u64,
        bytes: usize,
        sent_at: SimTime,
    },
    /// Video pipeline: a frame (or batch) handed to the next stage.
    Frame {
        stream: u32,
        frame: u64,
        stage: u8,
        produced_at: SimTime,
    },
    /// Nginx stress workload tick: apply load to the hosting worker.
    StressLoad {
        rps: f64,
    },
}

/// Top-level message envelope.
#[derive(Clone, Debug)]
pub enum SimMsg {
    Timer(TimerKind),
    Oak(OakMsg),
    Kube(KubeMsg),
    Data(DataMsg),
}

impl SimMsg {
    /// Approximate wire size used when a call site has no better estimate.
    pub fn default_wire_bytes(&self) -> usize {
        match self {
            SimMsg::Timer(_) => 0,
            SimMsg::Oak(m) => match m {
                OakMsg::RegisterCluster { .. } => 520,
                OakMsg::RegisterClusterAck { .. } => 64,
                // Census rows carry the full SLA, so they are priced like
                // small SLA documents rather than bare instance triples.
                OakMsg::RegisterWorker { census, .. } => 768 + 96 * census.len(),
                OakMsg::RegisterWorkerAck { .. } => 72,
                OakMsg::WorkerReport { instances, .. } => 180 + 28 * instances.len(),
                OakMsg::ClusterReport { service_cpu, .. } => 256 + 12 * service_cpu.len(),
                OakMsg::Ping => 16,
                OakMsg::Pong { .. } => 24,
                OakMsg::PeerHint { peers } => 16 + 40 * peers.len(),
                OakMsg::ApiCall(env) => match &env.request {
                    // A full Schema 1 JSON document dominates the call.
                    crate::api::ApiRequest::SubmitService { sla } => {
                        512 + 256 * sla.constraints.len()
                    }
                    _ => 128,
                },
                OakMsg::ApiReturn { response, .. } => match response.as_ref() {
                    crate::api::ApiResponse::Status(s) => 128 + 56 * s.instances.len(),
                    crate::api::ApiResponse::Services(rows) => 64 + 64 * rows.len(),
                    _ => 96,
                },
                OakMsg::DelegateTask { .. } => 640,
                OakMsg::DelegationResult { .. } => 96,
                OakMsg::DeployInstance { service_ips, .. } => {
                    384 + 32 * service_ips.len()
                }
                OakMsg::InstanceStatus { .. } => 96,
                OakMsg::UndeployInstance { .. } => 72,
                OakMsg::UndeployService { .. } => 64,
                OakMsg::ServiceDeployed { .. } => 64,
                OakMsg::MigrateInstance { .. } => 64,
                OakMsg::InstanceReplaced { .. } => 128,
                OakMsg::InstanceReplacedAck { .. } => 64,
                OakMsg::ResolveIp { .. } | OakMsg::ResolveIpUp { .. } => 96,
                OakMsg::TableUpdate { entries } => 48 + 48 * entries.len(),
                OakMsg::EscalateReschedule { .. } => 640,
                OakMsg::ResyncRequest => 64,
                OakMsg::ResyncSnapshot {
                    instances,
                    replacements,
                    ..
                } => 128 + 40 * instances.len() + 48 * replacements.len(),
            },
            SimMsg::Kube(m) => match m {
                // Kubernetes node status objects are famously fat
                // (conditions, images, allocatable...) — ~10 KB uncompressed;
                // K3s trims but stays KB-scale. (Fig. 7a's 2× message volume
                // comes from *counts*; sizes feed the bandwidth lines.)
                KubeMsg::NodeStatus { .. } => 8 * 1024,
                KubeMsg::WatchSync { .. } => 2 * 1024,
                KubeMsg::WatchEvent { bytes } => *bytes,
                KubeMsg::SubmitPod { .. } => 2 * 1024,
                KubeMsg::Bind { .. } => 1024,
                KubeMsg::PodStatus { .. } => 2 * 1024,
                KubeMsg::StoreCommit { .. } => 512,
                KubeMsg::LeaseRenew { .. } => 512,
                KubeMsg::SpecFetch { .. } => 512,
                KubeMsg::SpecReply { .. } => 3 * 1024,
                KubeMsg::ConditionPatch { .. } => 2 * 1024,
                KubeMsg::PodDeployed { .. } => 64,
            },
            SimMsg::Data(m) => match m {
                DataMsg::Ping { .. } => 64,
                DataMsg::Request { bytes, .. } | DataMsg::Response { bytes, .. } => *bytes,
                DataMsg::Frame { .. } => 64 * 1024,
                DataMsg::StressLoad { .. } => 0,
            },
        }
    }
}
