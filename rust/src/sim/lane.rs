//! Per-lane event machinery for the sharded simulator core.
//!
//! A **lane** is one independently drainable shard of the event loop:
//! its own event heap, RNG stream, metrics sink, container-runtime cache
//! and failure bitmap, plus the actors homed on its nodes. Lanes are cut
//! along the certified isolation boundaries (`rust/src/lint/isolation.rs`:
//! root lane + one lane per cluster subtree), so within a synchronization
//! window no two lanes touch the same state and they can drain in
//! parallel.
//!
//! Cross-lane interaction rides the network: a send whose target actor
//! lives on another lane is staged in a [`LaneOutbox`] slot and merged
//! into the target lane's heap at the window barrier, in fixed
//! `(origin_lane, origin_ix)` order — which makes the merged event order
//! (and therefore every downstream RNG draw) independent of how many
//! threads drained the window. Node-failure flips staged by an actor are
//! broadcast the same way.
//!
//! Within a lane, all events at the minimal pending `SimTime` are drained
//! as one **batch** before the heap is consulted again: new events pushed
//! during the batch park in a defer buffer and join the heap afterwards.
//! Because every push carries `at >= now` and a fresh (higher) sequence
//! number, batch order is exactly the order the one-event-at-a-time loop
//! would have produced — the batch only saves heap churn. The win is
//! counted under `sim.lane.batch_events` / `sim.lane.batch_drains`.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Mutex;

use crate::metrics::Metrics;
use crate::util::{NodeId, Rng, SimTime};

use super::{Actor, ActorId, ContainerRuntime, Ctx, SimCore, SimMsg};

pub(crate) const BATCH_EVENTS_KEY: &str = "sim.lane.batch_events";
pub(crate) const BATCH_DRAINS_KEY: &str = "sim.lane.batch_drains";

/// One queued delivery. Orders by `(at, seq)`: virtual time first, then
/// the per-lane push sequence number as a deterministic tiebreak.
#[derive(Debug)]
pub(crate) struct Event {
    pub(crate) at: SimTime,
    pub(crate) seq: u64,
    pub(crate) target: ActorId,
    pub(crate) msg: SimMsg,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// A delivery bound for another lane, parked until the window barrier.
/// `(origin_lane, origin_ix)` is a unique, thread-count-independent stamp
/// that fixes the merge order.
#[derive(Debug)]
pub(crate) struct OutMsg {
    pub(crate) at: SimTime,
    pub(crate) target: ActorId,
    pub(crate) msg: SimMsg,
    pub(crate) origin_lane: u32,
    pub(crate) origin_ix: u64,
}

/// A node-failure transition staged by an actor mid-window; applied to
/// every other lane's failure bitmap at the barrier.
#[derive(Clone, Debug)]
pub(crate) struct Flip {
    pub(crate) origin_lane: u32,
    pub(crate) origin_ix: u64,
    pub(crate) node: NodeId,
    pub(crate) failed: bool,
}

/// Everything one lane owns except its actors (split out so a dispatched
/// actor can borrow the core mutably while it is detached).
pub(crate) struct LaneCore {
    pub(crate) id: u32,
    /// Virtual time of the last event this lane executed.
    pub(crate) clock: SimTime,
    queue: BinaryHeap<Reverse<Event>>,
    seq: u64,
    /// O(1)-maintained mirror of `queue.len() + defer.len()`.
    n_events: usize,
    /// Queued events that are NOT timers (messages in flight). Timers are
    /// self-rescheduling background noise; this counter is what
    /// quiescence (and churn's leak audits) actually care about.
    pub(crate) non_timer_pending: usize,
    pub(crate) rng: Rng,
    pub(crate) metrics: Metrics,
    /// Image-pull cache; per-lane is exact because a node's pulls are
    /// only ever issued from its own lane.
    pub(crate) containers: ContainerRuntime,
    /// `failed[node]` — this lane's view of the crash bitmap. Flips made
    /// by other lanes arrive at window barriers.
    failed: Vec<bool>,
    /// Same-tick batch parking: pushes made while draining a batch land
    /// here (already sequenced) and join the heap when the batch ends.
    defer: Vec<Event>,
    deferring: bool,
    /// Monotonic stamp shared by cross-lane messages and failure flips.
    cross_ix: u64,
}

impl LaneCore {
    pub(crate) fn new(id: u32, rng: Rng) -> Self {
        LaneCore {
            id,
            clock: SimTime::ZERO,
            queue: BinaryHeap::new(),
            seq: 0,
            n_events: 0,
            non_timer_pending: 0,
            rng,
            metrics: Metrics::default(),
            containers: ContainerRuntime::default(),
            failed: Vec::new(),
            defer: Vec::new(),
            deferring: false,
            cross_ix: 0,
        }
    }

    pub(crate) fn push(&mut self, at: SimTime, target: ActorId, msg: SimMsg) {
        if !matches!(msg, SimMsg::Timer(_)) {
            self.non_timer_pending += 1;
        }
        self.n_events += 1;
        let seq = self.seq;
        self.seq += 1;
        let ev = Event {
            at,
            seq,
            target,
            msg,
        };
        if self.deferring {
            self.defer.push(ev);
        } else {
            self.queue.push(Reverse(ev));
        }
    }

    /// Virtual time of the next queued event, if any.
    pub(crate) fn next_at(&self) -> Option<SimTime> {
        self.queue.peek().map(|Reverse(e)| e.at)
    }

    /// Pop the next event only if it sits exactly at `at` (batch drain).
    fn pop_at(&mut self, at: SimTime) -> Option<Event> {
        if !matches!(self.queue.peek(), Some(Reverse(e)) if e.at == at) {
            return None;
        }
        let Reverse(ev) = self.queue.pop().unwrap();
        self.note_pop(&ev);
        Some(ev)
    }

    /// Pop the next event unconditionally (legacy quiescence loop).
    pub(crate) fn pop_next(&mut self) -> Option<Event> {
        let Reverse(ev) = self.queue.pop()?;
        self.note_pop(&ev);
        Some(ev)
    }

    fn note_pop(&mut self, ev: &Event) {
        if !matches!(ev.msg, SimMsg::Timer(_)) {
            self.non_timer_pending -= 1;
        }
        self.n_events -= 1;
    }

    fn flush_defer(&mut self) {
        while let Some(ev) = self.defer.pop() {
            self.queue.push(Reverse(ev));
        }
        debug_assert_eq!(
            self.n_events,
            self.queue.len(),
            "lane {} event counter drifted from its heap",
            self.id
        );
    }

    /// Total queued events (timers included), O(1).
    pub(crate) fn pending_events(&self) -> usize {
        debug_assert_eq!(self.n_events, self.queue.len() + self.defer.len());
        self.n_events
    }

    pub(crate) fn is_failed(&self, node: NodeId) -> bool {
        self.failed.get(node.0 as usize).copied().unwrap_or(false)
    }

    pub(crate) fn set_failed(&mut self, node: NodeId, failed: bool) {
        let i = node.0 as usize;
        if i >= self.failed.len() {
            if !failed {
                return; // clearing a node that was never failed
            }
            self.failed.resize(i + 1, false);
        }
        self.failed[i] = failed;
    }

    pub(crate) fn next_cross_ix(&mut self) -> u64 {
        let ix = self.cross_ix;
        self.cross_ix += 1;
        ix
    }

    /// Drop every queued event addressed to `target` — crash injection:
    /// the in-flight messages and pending timers of a crash-stopped
    /// actor die with it. Returns how many of the dropped events were
    /// messages (non-timers), which the harness reports as the crash's
    /// message loss. Maintains the O(1) `n_events` / `non_timer_pending`
    /// mirrors; heap order among survivors is untouched because
    /// `(at, seq)` stamps are preserved.
    pub(crate) fn purge_actor(&mut self, target: ActorId) -> usize {
        let mut dropped_msgs = 0usize;
        let mut note_drop = |ev: &Event, msgs: &mut usize, non_timer: &mut usize| {
            if !matches!(ev.msg, SimMsg::Timer(_)) {
                *non_timer -= 1;
                *msgs += 1;
            }
        };
        let drained = std::mem::take(&mut self.queue).into_vec();
        let mut kept = Vec::with_capacity(drained.len());
        for Reverse(ev) in drained {
            if ev.target == target {
                self.n_events -= 1;
                note_drop(&ev, &mut dropped_msgs, &mut self.non_timer_pending);
            } else {
                kept.push(Reverse(ev));
            }
        }
        self.queue = BinaryHeap::from(kept);
        // External crash calls run between windows, so `defer` is
        // normally empty — but keep the counters exact regardless.
        let before = self.defer.len();
        let mut kept_defer = Vec::with_capacity(before);
        for ev in std::mem::take(&mut self.defer) {
            if ev.target == target {
                self.n_events -= 1;
                note_drop(&ev, &mut dropped_msgs, &mut self.non_timer_pending);
            } else {
                kept_defer.push(ev);
            }
        }
        self.defer = kept_defer;
        debug_assert_eq!(self.n_events, self.queue.len() + self.defer.len());
        dropped_msgs
    }
}

/// One shard of the simulator: its actors plus the lane core.
pub(crate) struct Lane {
    pub(crate) actors: Vec<Option<Box<dyn Actor>>>,
    pub(crate) core: LaneCore,
}

impl Lane {
    pub(crate) fn new(id: u32, rng: Rng) -> Self {
        Lane {
            actors: Vec::new(),
            core: LaneCore::new(id, rng),
        }
    }
}

/// Per-window staging area for cross-lane traffic: one mutex-guarded
/// inbox per target lane plus the shared failure-flip list. Append order
/// under threads is arbitrary; the merge sorts by the origin stamp, so
/// nothing downstream can observe it.
pub(crate) struct LaneOutbox {
    boxes: Vec<Mutex<Vec<OutMsg>>>,
    flips: Mutex<Vec<Flip>>,
}

impl LaneOutbox {
    pub(crate) fn new(n_lanes: usize) -> Self {
        LaneOutbox {
            boxes: (0..n_lanes).map(|_| Mutex::new(Vec::new())).collect(),
            flips: Mutex::new(Vec::new()),
        }
    }

    pub(crate) fn post(&self, target_lane: usize, msg: OutMsg) {
        self.boxes[target_lane].lock().unwrap().push(msg);
    }

    pub(crate) fn stage_flip(&self, flip: Flip) {
        self.flips.lock().unwrap().push(flip);
    }

    /// Snapshot (not drain): every worker thread applies the same sorted
    /// list to its own lanes; the lead thread clears it at the barrier.
    pub(crate) fn flips_snapshot_sorted(&self) -> Vec<Flip> {
        let mut v = self.flips.lock().unwrap().clone();
        v.sort_unstable_by_key(|f| (f.origin_lane, f.origin_ix));
        v
    }

    pub(crate) fn clear_flips(&self) {
        self.flips.lock().unwrap().clear();
    }

    pub(crate) fn take_inbox(&self, lane: usize) -> Vec<OutMsg> {
        std::mem::take(&mut *self.boxes[lane].lock().unwrap())
    }
}

/// Dispatch one event to its actor on this lane.
pub(crate) fn dispatch_event(
    lane: &mut Lane,
    shared: &SimCore,
    outbox: Option<&LaneOutbox>,
    ev: Event,
) {
    let Event { at, target, msg, .. } = ev;
    lane.core.clock = at;
    let slot = shared.slot_of(target);
    // Detach the actor so it can borrow the lane core mutably.
    let Some(mut actor) = lane.actors[slot].take() else {
        return; // actor removed mid-flight
    };
    let node = shared.node_of(target);
    {
        let mut ctx = Ctx {
            now: at,
            self_id: target,
            self_node: node,
            lane: &mut lane.core,
            shared,
            outbox,
        };
        actor.handle(&mut ctx, msg);
    }
    lane.actors[slot] = Some(actor);
}

/// Drain every event with `at <= limit`, batching same-instant runs.
/// With `outbox: None` (single-lane sim) this IS the legacy `run_until`
/// loop: identical dispatch order, fewer heap operations.
pub(crate) fn drain_lane(
    lane: &mut Lane,
    limit: SimTime,
    shared: &SimCore,
    outbox: Option<&LaneOutbox>,
) {
    loop {
        let Some(at) = lane.core.next_at() else {
            break;
        };
        if at > limit {
            break;
        }
        lane.core.deferring = true;
        let mut batched = 0u64;
        while let Some(ev) = lane.core.pop_at(at) {
            batched += 1;
            dispatch_event(lane, shared, outbox, ev);
        }
        lane.core.deferring = false;
        lane.core.flush_defer();
        lane.core.metrics.add(BATCH_EVENTS_KEY, batched);
        lane.core.metrics.inc(BATCH_DRAINS_KEY);
    }
}

/// Fold one window's cross-lane arrivals (and other lanes' failure
/// flips) into this lane. `inbox` is sorted by the origin stamp so the
/// resulting sequence numbers — and every later tiebreak — are the same
/// no matter which thread drained which lane.
///
/// Link faults don't weaken the `m.at > horizon` invariant below: a cut
/// link either drops the message (it never reaches an inbox) or parks it
/// with RTO backoff, and a parked delivery lands *no earlier than* the
/// link's base delay after the send — still past the window horizon.
pub(crate) fn merge_lane(
    lane: &mut Lane,
    mut inbox: Vec<OutMsg>,
    flips: &[Flip],
    horizon: SimTime,
) {
    inbox.sort_unstable_by_key(|m| (m.origin_lane, m.origin_ix));
    for m in inbox {
        debug_assert!(
            m.at > horizon,
            "cross-lane delivery at {} inside the window ending {horizon}: \
             cross-lane interaction must ride the network (>= the minimum \
             remote link delay)",
            m.at
        );
        lane.core.push(m.at, m.target, m.msg);
    }
    for f in flips {
        if f.origin_lane != lane.core.id {
            lane.core.set_failed(f.node, f.failed);
        }
    }
}

/// Per-lane RNG stream: lane 0 keeps the master seed's stream (so a
/// single-lane sim is bit-identical to the unsharded simulator); lane k
/// derives an independent stream by golden-ratio offset.
pub(crate) fn lane_rng(seed: u64, k: u32) -> Rng {
    Rng::seeded(seed.wrapping_add((k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)))
}
