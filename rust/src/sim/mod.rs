//! Deterministic discrete-event simulator — the testbed substrate standing
//! in for the paper's HPC (VM cluster) and HET (heterogeneous edge) setups
//! (§7.1, and DESIGN.md's substitution ledger).
//!
//! Entities (orchestrators, workers, baseline control planes, workload
//! drivers) are [`Actor`]s pinned to simulated nodes. Actors exchange
//! [`SimMsg`]s through a network model with per-link delay/jitter/loss/
//! bandwidth, consume CPU via explicit cost charging (feeding the
//! utilization figures), and set timers.
//!
//! # Lane-sharded event loop
//!
//! The event loop is sharded into **lanes** ([`lane::Lane`]) cut along
//! the boundaries the `lane-isolation` lint certifies: by convention
//! lane 0 hosts the root tier (plus clients/drivers co-located on the
//! root node) and each cluster subtree gets its own lane. Every lane
//! owns its heap, RNG stream, metrics sink and failure bitmap; [`Ctx`]
//! is the single reroute point — a send whose target actor is homed on
//! another lane parks in a [`lane::LaneOutbox`] instead of a heap.
//!
//! Lanes drain **conservatively** in windows: with `T` the minimum next
//! event time across lanes and `L` the minimum remote link delay
//! ([`Network::min_remote_delay_us`]), every lane may safely run to
//! `T + L - 1` because no cross-lane message sent inside the window can
//! arrive before `T + L`. At the window barrier, staged messages merge
//! into their target lanes in fixed `(origin_lane, origin_ix)` order, so
//! the sequence numbers they draw — and every later event tiebreak and
//! RNG draw — are identical whether the window was drained by one thread
//! or eight. Same seed, same `--threads`-independent trace, enforced by
//! `rust/tests/golden.rs` and `rust/tests/lane_props.rs`.
//!
//! A sim left unsharded (the default: `Sim::new` without
//! [`Sim::shard_lanes`]) has exactly one lane and skips the window
//! machinery entirely — that path is bit-identical to the pre-lane
//! sequential simulator, which the churn golden fixture pins.
//!
//! Event order is fully deterministic in both modes: ties on the virtual
//! clock break by per-lane sequence number, and all randomness flows
//! from seeded per-lane RNG streams.

mod container;
pub(crate) mod lane;
mod msg;
mod network;

pub use container::ContainerRuntime;
pub use msg::{CensusRow, DataMsg, KubeMsg, OakMsg, ReplacementReason, SimMsg, TimerKind};
pub use network::{Delivery, FaultScope, LinkFault, LinkProfile, Network, Transport};

use std::any::Any;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Barrier;

use crate::metrics::Metrics;
use crate::model::NodeClass;
use crate::util::{NodeId, Rng, SimTime};

use lane::{
    dispatch_event, drain_lane, lane_rng, merge_lane, Flip, Lane, LaneCore, LaneOutbox, OutMsg,
};

/// Dense actor handle (index into the actor table).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct ActorId(pub u32);

/// A simulated entity. `handle` runs to completion at a virtual instant;
/// side effects (sends, timers, cpu charges) go through [`Ctx`].
///
/// `Send` because lanes (and the actors homed on them) migrate across
/// the worker threads that drain a window.
pub trait Actor: Send {
    fn handle(&mut self, ctx: &mut Ctx<'_>, msg: SimMsg);
    /// Downcasting support so tests/benches can inspect actor state.
    fn as_any(&self) -> &dyn Any;
    fn as_any_mut(&mut self) -> &mut dyn Any;
}

/// Static description of a simulated node.
#[derive(Clone, Debug)]
pub struct SimNode {
    pub class: NodeClass,
}

/// State shared read-only by every lane while a window drains: the node
/// and actor tables (append-only between runs), the network model, and
/// the lane topology. Mutable per-run state (heaps, RNGs, metrics,
/// failure bitmaps) lives in each [`LaneCore`].
pub struct SimCore {
    pub net: Network,
    /// Node table indexed by dense `NodeId` (same keying discipline as
    /// `metrics.node_usage`); `None` slots are never-registered ids.
    nodes: Vec<Option<SimNode>>,
    actor_node: Vec<NodeId>,
    /// Lane homing an actor / a node (parallel to `actor_node`/`nodes`).
    actor_lane: Vec<u32>,
    /// Index of the actor within its lane's actor table.
    actor_slot: Vec<u32>,
    node_lane: Vec<u32>,
    /// Worker threads a sharded sim may use per window (0/1 = drain
    /// lanes sequentially; still windowed once sharded).
    threads: usize,
    master_seed: u64,
}

impl SimCore {
    pub fn node_of(&self, actor: ActorId) -> NodeId {
        self.actor_node[actor.0 as usize]
    }

    pub fn node_class(&self, node: NodeId) -> NodeClass {
        self.nodes[node.0 as usize]
            .as_ref()
            .unwrap_or_else(|| panic!("unknown node {node}"))
            .class
    }

    pub(crate) fn lane_of(&self, actor: ActorId) -> u32 {
        self.actor_lane[actor.0 as usize]
    }

    pub(crate) fn slot_of(&self, actor: ActorId) -> usize {
        self.actor_slot[actor.0 as usize] as usize
    }
}

/// Actor-facing API for one dispatch. This is the lane boundary the
/// `lane-isolation` lint certifies: every accessor below touches only
/// the dispatching lane's own state (`lane`) or the append-frozen shared
/// tables (`shared`), and the push path reroutes cross-lane sends into
/// the window outbox.
pub struct Ctx<'a> {
    pub now: SimTime,
    pub self_id: ActorId,
    /// Node hosting `self_id`, resolved once per dispatch instead of once
    /// per `send`/`charge_cpu` call (the sim's hottest lookups).
    pub self_node: NodeId,
    pub(crate) lane: &'a mut LaneCore,
    pub(crate) shared: &'a SimCore,
    pub(crate) outbox: Option<&'a LaneOutbox>,
}

impl<'a> Ctx<'a> {
    /// Route a delivery: own lane goes straight onto the heap (or the
    /// same-tick defer buffer); another lane's parks in the outbox until
    /// the window barrier.
    fn push(&mut self, at: SimTime, to: ActorId, msg: SimMsg) {
        let target_lane = self.shared.lane_of(to);
        if target_lane == self.lane.id {
            self.lane.push(at, to, msg);
            return;
        }
        let outbox = self
            .outbox
            .expect("cross-lane send outside a window (unsharded sim has one lane)");
        let origin_ix = self.lane.next_cross_ix();
        outbox.post(
            target_lane as usize,
            OutMsg {
                at,
                target: to,
                msg,
                origin_lane: self.lane.id,
                origin_ix,
            },
        );
    }

    /// Shared transmit path of [`Ctx::send`] and
    /// [`Ctx::send_unreliable`]: one failed-endpoint check, one message
    /// accounting record, one delivery-delay draw.
    fn transmit(
        &mut self,
        to: ActorId,
        msg: SimMsg,
        bytes: usize,
        label: &'static str,
        transport: Transport,
    ) {
        let src = self.self_node;
        let dst = self.shared.node_of(to);
        if self.lane.is_failed(src) || self.lane.is_failed(dst) {
            self.lane.metrics.inc("net.dropped_failed_node");
            return;
        }
        self.lane.metrics.record_msg(label, bytes);
        let now = self.now;
        match self
            .shared
            .net
            .deliver(src, dst, bytes, transport, now, &mut self.lane.rng)
        {
            Delivery::Delivered { delay, retransmits } => {
                if retransmits > 0 {
                    self.lane.metrics.add("net.retransmit", retransmits as u64);
                }
                let at = self.now + delay;
                self.push(at, to, msg);
            }
            Delivery::Lost => self.lane.metrics.inc("net.lost"),
            Delivery::DroppedAfterRetry { retransmits } => {
                self.lane.metrics.add("net.retransmit", retransmits as u64);
                self.lane.metrics.inc("net.dropped_after_retry");
            }
        }
    }

    /// Send over the network; delivery is delayed by the link model and
    /// message accounting is recorded under `label` (figure 7a counts
    /// these). Messages involving failed nodes are silently dropped —
    /// exactly what a dead edge node looks like from the outside.
    pub fn send(&mut self, to: ActorId, msg: SimMsg, bytes: usize, label: &'static str) {
        self.transmit(to, msg, bytes, label, Transport::Reliable);
    }

    /// Send via an unreliable (UDP-like) transport: lost messages vanish.
    pub fn send_unreliable(
        &mut self,
        to: ActorId,
        msg: SimMsg,
        bytes: usize,
        label: &'static str,
    ) {
        self.transmit(to, msg, bytes, label, Transport::Unreliable);
    }

    /// Deliver without touching the network (same-process components, e.g.
    /// service manager → scheduler inside one orchestrator). Same-process
    /// means same node, so this never crosses a lane.
    pub fn send_local(&mut self, to: ActorId, msg: SimMsg) {
        let at = self.now;
        self.push(at, to, msg);
    }

    /// Set a timer on self.
    pub fn schedule(&mut self, delay: SimTime, msg: SimMsg) {
        let at = self.now + delay;
        let id = self.self_id;
        self.push(at, id, msg);
    }

    /// Set a timer for another actor (used by experiment drivers).
    pub fn schedule_for(&mut self, to: ActorId, delay: SimTime, msg: SimMsg) {
        let at = self.now + delay;
        self.push(at, to, msg);
    }

    /// Charge control-plane CPU time to this actor's node, scaled by the
    /// node's speed factor (a Pi burns more wall-clock per unit work).
    pub fn charge_cpu(&mut self, cpu_ms: f64) {
        let node = self.self_node;
        let scaled = cpu_ms / self.shared.node_class(node).speed_factor();
        let now = self.now;
        self.lane.metrics.usage_mut(node).charge_cpu(now, scaled);
    }

    /// Adjust this node's resident-memory gauge.
    pub fn add_mem(&mut self, delta_mb: f64) {
        let node = self.self_node;
        self.lane.metrics.usage_mut(node).add_mem(delta_mb);
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.lane.rng
    }

    pub fn metrics(&mut self) -> &mut Metrics {
        &mut self.lane.metrics
    }

    pub fn my_node(&self) -> NodeId {
        self.self_node
    }

    /// Ground-truth RTT between two nodes (for ping emulation: Vivaldi
    /// feeds on these; the *scheduler* never reads them directly).
    pub fn rtt_ms(&mut self, a: NodeId, b: NodeId) -> f64 {
        self.shared.net.rtt_ms(a, b, &mut self.lane.rng)
    }

    /// Node hosting `actor`. Dispatchers must use this instead of
    /// reaching into the core directly: `Ctx` is the lane boundary the
    /// `lane-isolation` lint certifies, and the sharded event loop
    /// reroutes exactly these calls at lane edges.
    pub fn node_of(&self, actor: ActorId) -> NodeId {
        self.shared.node_of(actor)
    }

    /// Crash-stop status of `node` — this lane's view of it; transitions
    /// made from other lanes become visible at the next window barrier
    /// (bounded by the minimum remote link delay, i.e. no sooner than
    /// any message from that lane could have told us).
    pub fn is_failed(&self, node: NodeId) -> bool {
        self.lane.is_failed(node)
    }

    /// Fail / recover a node from inside the simulation (drill drivers).
    /// Applies to this lane immediately and broadcasts to the other
    /// lanes at the window barrier.
    pub fn set_node_failed(&mut self, node: NodeId, failed: bool) {
        self.lane.set_failed(node, failed);
        if let Some(outbox) = self.outbox {
            let origin_ix = self.lane.next_cross_ix();
            outbox.stage_flip(Flip {
                origin_lane: self.lane.id,
                origin_ix,
                node,
                failed,
            });
        }
    }

    /// Hardware class of `node` (see [`Ctx::node_of`] for why this
    /// wrapper exists).
    pub fn node_class(&self, node: NodeId) -> NodeClass {
        self.shared.node_class(node)
    }

    /// Container cold-start time on `node`: image pull (cached layers
    /// skip the registry) + runtime start jitter, scaled by the node's
    /// speed class. Bundled here so dispatchers never touch the
    /// container-runtime or rng state directly.
    pub fn container_deploy_time(
        &mut self,
        node: NodeId,
        image_key: u64,
        image_mb: u32,
    ) -> SimTime {
        let pull = self.lane.containers.pull_time(node, image_key, image_mb);
        let start = self.lane.containers.start_latency(&mut self.lane.rng);
        let speed = self.shared.node_class(node).speed_factor();
        SimTime::from_micros(((pull + start).as_micros() as f64 / speed) as u64)
    }
}

/// What a windowed run is trying to reach (see [`window_horizon`]).
#[derive(Clone, Copy)]
enum RunMode {
    /// Drain everything with `at <= until`.
    Until(SimTime),
    /// Drain until no message (non-timer event) is in flight, or the
    /// hard limit passes.
    Quiesce(SimTime),
}

/// Pure stop/continue decision for one window, given the global minimum
/// next-event time `t_us` and the global in-flight message count. Every
/// worker thread evaluates this on identical inputs and reaches the
/// identical decision — no leader, no extra barrier.
fn window_horizon(t_us: u64, live: usize, lmin_us: u64, mode: RunMode) -> Option<u64> {
    match mode {
        RunMode::Until(until) => {
            if t_us == u64::MAX || t_us > until.0 {
                None
            } else {
                Some(until.0.min(t_us + lmin_us - 1))
            }
        }
        RunMode::Quiesce(hard_limit) => {
            if live == 0 || t_us == u64::MAX || t_us > hard_limit.0 {
                None
            } else {
                Some(hard_limit.0.min(t_us + lmin_us - 1))
            }
        }
    }
}

/// Windowed engine, one thread: barrier-free but the same
/// window/drain/merge phase structure as the threaded path, so the event
/// trace is identical by construction. Returns the non-timer backlog at
/// the stop decision.
fn run_windows_seq(
    lanes: &mut [Lane],
    core: &SimCore,
    outbox: &LaneOutbox,
    lmin_us: u64,
    mode: RunMode,
) -> usize {
    loop {
        let mut t = u64::MAX;
        let mut live = 0usize;
        for lane in lanes.iter() {
            if let Some(at) = lane.core.next_at() {
                t = t.min(at.0);
            }
            live += lane.core.non_timer_pending;
        }
        let Some(h) = window_horizon(t, live, lmin_us, mode) else {
            return live;
        };
        let horizon = SimTime(h);
        for lane in lanes.iter_mut() {
            drain_lane(lane, horizon, core, Some(outbox));
        }
        let flips = outbox.flips_snapshot_sorted();
        for lane in lanes.iter_mut() {
            let inbox = outbox.take_inbox(lane.core.id as usize);
            merge_lane(lane, inbox, &flips, horizon);
        }
        outbox.clear_flips();
    }
}

/// Windowed engine, scoped worker threads over contiguous lane chunks.
/// Four barriers per window: publish minima → (all read the same
/// decision inputs) drain → (all drains done) merge → (all merges done)
/// lead thread resets the accumulators → next window.
fn run_windows_par(
    lanes: &mut [Lane],
    core: &SimCore,
    outbox: &LaneOutbox,
    lmin_us: u64,
    mode: RunMode,
    threads: usize,
) -> usize {
    let chunk = lanes.len().div_ceil(threads);
    let chunks: Vec<&mut [Lane]> = lanes.chunks_mut(chunk).collect();
    let barrier = Barrier::new(chunks.len());
    let t_min = AtomicU64::new(u64::MAX);
    let live = AtomicUsize::new(0);
    let leftover = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for (ti, my_lanes) in chunks.into_iter().enumerate() {
            let (barrier, t_min, live, leftover) = (&barrier, &t_min, &live, &leftover);
            s.spawn(move || loop {
                let mut local_t = u64::MAX;
                let mut local_live = 0usize;
                for lane in my_lanes.iter() {
                    if let Some(at) = lane.core.next_at() {
                        local_t = local_t.min(at.0);
                    }
                    local_live += lane.core.non_timer_pending;
                }
                t_min.fetch_min(local_t, Ordering::SeqCst);
                live.fetch_add(local_live, Ordering::SeqCst);
                barrier.wait();
                let t = t_min.load(Ordering::SeqCst);
                let g_live = live.load(Ordering::SeqCst);
                let Some(h) = window_horizon(t, g_live, lmin_us, mode) else {
                    leftover.fetch_add(local_live, Ordering::SeqCst);
                    break;
                };
                let horizon = SimTime(h);
                for lane in my_lanes.iter_mut() {
                    drain_lane(lane, horizon, core, Some(outbox));
                }
                barrier.wait();
                let flips = outbox.flips_snapshot_sorted();
                for lane in my_lanes.iter_mut() {
                    let inbox = outbox.take_inbox(lane.core.id as usize);
                    merge_lane(lane, inbox, &flips, horizon);
                }
                barrier.wait();
                if ti == 0 {
                    t_min.store(u64::MAX, Ordering::SeqCst);
                    live.store(0, Ordering::SeqCst);
                    outbox.clear_flips();
                }
                barrier.wait();
            });
        }
    });
    leftover.into_inner()
}

/// The simulator: lanes (actors + per-lane cores) over the shared core.
pub struct Sim {
    lanes: Vec<Lane>,
    pub core: SimCore,
}

impl Sim {
    pub fn new(seed: u64) -> Self {
        Sim {
            lanes: vec![Lane::new(0, lane_rng(seed, 0))],
            core: SimCore {
                net: Network::default(),
                nodes: Vec::new(),
                actor_node: Vec::new(),
                actor_lane: Vec::new(),
                actor_slot: Vec::new(),
                node_lane: Vec::new(),
                threads: 0,
                master_seed: seed,
            },
        }
    }

    /// Split the event loop into `n_lanes` lanes drained by up to
    /// `threads` worker threads per window (`0`/`1` = windowed but
    /// sequential). Must be called before any node or actor is added:
    /// lane homing is fixed at registration. Lane 0 keeps the master
    /// RNG stream; lanes `1..` get derived independent streams.
    pub fn shard_lanes(&mut self, n_lanes: usize, threads: usize) {
        assert!(n_lanes >= 1, "a sim needs at least one lane");
        assert!(
            self.core.nodes.is_empty() && self.core.actor_node.is_empty(),
            "shard_lanes must run before nodes/actors are registered"
        );
        let seed = self.core.master_seed;
        self.lanes = (0..n_lanes as u32).map(|k| Lane::new(k, lane_rng(seed, k))).collect();
        self.core.threads = threads;
    }

    /// Re-derive every lane's RNG stream from a fresh master seed
    /// (test harnesses that rebuild identical topologies).
    pub fn reseed(&mut self, seed: u64) {
        self.core.master_seed = seed;
        for (k, lane) in self.lanes.iter_mut().enumerate() {
            lane.core.rng = lane_rng(seed, k as u32);
        }
    }

    pub fn add_node(&mut self, node: NodeId, class: NodeClass) {
        self.add_node_in_lane(node, class, 0);
    }

    /// Register `node` homed on `lane`. Nodes (and the actors on them)
    /// never migrate between lanes.
    pub fn add_node_in_lane(&mut self, node: NodeId, class: NodeClass, lane: usize) {
        assert!(lane < self.lanes.len(), "lane {lane} out of range");
        let i = node.0 as usize;
        if i >= self.core.nodes.len() {
            self.core.nodes.resize_with(i + 1, || None);
            self.core.node_lane.resize(i + 1, 0);
        }
        let prev = self.core.nodes[i].replace(SimNode { class });
        assert!(prev.is_none(), "node {node} registered twice");
        self.core.node_lane[i] = lane as u32;
    }

    pub fn add_actor(&mut self, node: NodeId, actor: Box<dyn Actor>) -> ActorId {
        assert!(
            matches!(self.core.nodes.get(node.0 as usize), Some(Some(_))),
            "actor on unknown node {node}"
        );
        let id = ActorId(self.core.actor_node.len() as u32);
        let lane_ix = self.core.node_lane[node.0 as usize];
        let lane = &mut self.lanes[lane_ix as usize];
        self.core.actor_lane.push(lane_ix);
        self.core.actor_slot.push(lane.actors.len() as u32);
        lane.actors.push(Some(actor));
        self.core.actor_node.push(node);
        id
    }

    /// Inject a message at a given virtual time (experiment drivers).
    pub fn inject(&mut self, at: SimTime, target: ActorId, msg: SimMsg) {
        let lane = self.core.lane_of(target) as usize;
        self.lanes[lane].core.push(at, target, msg);
    }

    /// Run until the queues drain or the next event lies beyond `until`.
    /// The clock is left at the last *executed* event.
    pub fn run_until(&mut self, until: SimTime) {
        if self.lanes.len() == 1 {
            // Single lane: the legacy sequential loop (batched; no
            // windows, no outbox, bit-identical to the unsharded sim).
            drain_lane(&mut self.lanes[0], until, &self.core, None);
            return;
        }
        self.run_windows(RunMode::Until(until));
    }

    /// Drain every in-flight **message** (non-timer event), processing
    /// timers along the way as the clock passes them, and stop the moment
    /// the queue holds nothing but timers — i.e. the control plane is
    /// momentarily quiescent. Periodic timers re-arm forever, so "drain
    /// everything" is undefined; "no message in flight" is the meaningful
    /// convergence point (churn's leak audits snapshot state here).
    /// Returns the non-timer backlog still pending (0 unless
    /// `hard_limit` was hit first).
    ///
    /// A sharded sim stops at window granularity: the zero-in-flight
    /// check runs at each barrier, so a timer firing inside the final
    /// window may push the stop one window (< the minimum link delay)
    /// later than the unsharded loop would — identically so for every
    /// thread count.
    pub fn run_to_quiescence(&mut self, hard_limit: SimTime) -> usize {
        if self.lanes.len() == 1 {
            // Exact legacy per-event loop: quiescence is re-checked
            // after every single dispatch.
            loop {
                let lane = &mut self.lanes[0];
                if lane.core.non_timer_pending == 0 {
                    break;
                }
                match lane.core.next_at() {
                    Some(at) if at <= hard_limit => {}
                    _ => break,
                }
                let ev = lane.core.pop_next().unwrap();
                dispatch_event(lane, &self.core, None, ev);
            }
            return self.lanes[0].core.non_timer_pending;
        }
        self.run_windows(RunMode::Quiesce(hard_limit))
    }

    fn run_windows(&mut self, mode: RunMode) -> usize {
        let lmin_us = self.core.net.min_remote_delay_us();
        let outbox = LaneOutbox::new(self.lanes.len());
        let threads = self.core.threads.clamp(1, self.lanes.len());
        let core = &self.core;
        let lanes = &mut self.lanes[..];
        if threads == 1 {
            run_windows_seq(lanes, core, &outbox, lmin_us, mode)
        } else {
            run_windows_par(lanes, core, &outbox, lmin_us, mode, threads)
        }
    }

    /// Total queued events (timers included) — an O(lanes) sum of
    /// per-lane maintained counters.
    pub fn pending_events(&self) -> usize {
        self.lanes.iter().map(|l| l.core.pending_events()).sum()
    }

    /// Queued events that are in-flight messages rather than timers.
    pub fn pending_non_timer_events(&self) -> usize {
        self.lanes.iter().map(|l| l.core.non_timer_pending).sum()
    }

    /// Virtual time of the last executed event across all lanes.
    pub fn now(&self) -> SimTime {
        self.lanes
            .iter()
            .map(|l| l.core.clock)
            .max()
            .unwrap_or(SimTime::ZERO)
    }

    /// Number of event-loop lanes (1 unless [`Sim::shard_lanes`] ran).
    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    /// Merged view of every lane's metrics sink, folded in lane-index
    /// order (deterministic for counters, histogram sample order, and
    /// float accumulation alike).
    pub fn metrics(&self) -> Metrics {
        let mut merged = self.lanes[0].core.metrics.clone();
        for lane in &self.lanes[1..] {
            merged.merge_from(&lane.core.metrics);
        }
        merged
    }

    /// Set the shared container-registry bandwidth on every lane's
    /// runtime cache.
    pub fn set_registry_mbps(&mut self, mbps: f64) {
        for lane in &mut self.lanes {
            lane.core.containers.registry_mbps = mbps;
        }
    }

    /// Inspect an actor's state (tests/benches).
    pub fn actor_as<T: 'static>(&self, id: ActorId) -> Option<&T> {
        let lane = self.core.lane_of(id) as usize;
        let slot = self.core.slot_of(id);
        self.lanes[lane].actors[slot]
            .as_deref()
            .and_then(|a| a.as_any().downcast_ref::<T>())
    }

    pub fn actor_as_mut<T: 'static>(&mut self, id: ActorId) -> Option<&mut T> {
        let lane = self.core.lane_of(id) as usize;
        let slot = self.core.slot_of(id);
        self.lanes[lane].actors[slot]
            .as_deref_mut()
            .and_then(|a| a.as_any_mut().downcast_mut::<T>())
    }

    /// Fail / recover a node (failure-injection experiments, §4.2).
    /// External callers run between windows, so the flip lands on every
    /// lane's bitmap synchronously.
    pub fn set_node_failed(&mut self, node: NodeId, failed: bool) {
        for lane in &mut self.lanes {
            lane.core.set_failed(node, failed);
        }
    }

    /// Crash-stop an actor: discard its state and drop every event
    /// already queued for it — in-flight messages and pending timers die
    /// with the process. The slot stays reserved, so the `ActorId`
    /// remains valid: peers keep addressing it, and deliveries arriving
    /// while the slot is empty are silently dropped (exactly what a dead
    /// process looks like from the network). Repopulate the slot with
    /// [`Sim::restart_actor`]. Returns the number of in-flight messages
    /// destroyed. External callers must run between windows, the same
    /// discipline as [`Sim::set_node_failed`].
    pub fn crash_actor(&mut self, id: ActorId) -> usize {
        let lane = self.core.lane_of(id) as usize;
        let slot = self.core.slot_of(id);
        self.lanes[lane].actors[slot] = None;
        self.lanes[lane].core.purge_actor(id)
    }

    /// Cold-restart a crashed actor: a fresh instance takes over the
    /// same slot, so the `ActorId` (and every peer's stored address)
    /// stays valid across the incarnation change. Panics if the slot is
    /// still occupied — crash first.
    pub fn restart_actor(&mut self, id: ActorId, actor: Box<dyn Actor>) {
        let lane = self.core.lane_of(id) as usize;
        let slot = self.core.slot_of(id);
        assert!(
            self.lanes[lane].actors[slot].is_none(),
            "restart_actor over a live actor {id:?}; crash_actor first"
        );
        self.lanes[lane].actors[slot] = Some(actor);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Ping-pong actor pair used to validate ordering and determinism.
    struct Pinger {
        peer: Option<ActorId>,
        sent: u32,
        got: u32,
        limit: u32,
    }
    impl Actor for Pinger {
        fn handle(&mut self, ctx: &mut Ctx<'_>, msg: SimMsg) {
            match msg {
                SimMsg::Timer(TimerKind::Custom(_)) => {
                    if let Some(p) = self.peer {
                        self.sent += 1;
                        ctx.send(p, SimMsg::Data(DataMsg::Ping { seq: self.sent }), 64, "test");
                    }
                }
                SimMsg::Data(DataMsg::Ping { seq }) => {
                    self.got += 1;
                    if seq < self.limit {
                        if let Some(p) = self.peer {
                            ctx.send(p, SimMsg::Data(DataMsg::Ping { seq: seq + 1 }), 64, "test");
                        }
                    }
                    ctx.charge_cpu(0.1);
                }
                _ => {}
            }
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn Any {
            self
        }
    }

    fn build() -> (Sim, ActorId, ActorId) {
        let mut sim = Sim::new(1);
        sim.add_node(NodeId(0), NodeClass::S);
        sim.add_node(NodeId(1), NodeClass::S);
        sim.core.net.set_default(LinkProfile::lan());
        let a = sim.add_actor(
            NodeId(0),
            Box::new(Pinger {
                peer: None,
                sent: 0,
                got: 0,
                limit: 10,
            }),
        );
        let b = sim.add_actor(
            NodeId(1),
            Box::new(Pinger {
                peer: Some(a),
                sent: 0,
                got: 0,
                limit: 10,
            }),
        );
        sim.actor_as_mut::<Pinger>(a).unwrap().peer = Some(b);
        (sim, a, b)
    }

    #[test]
    fn ping_pong_advances_clock_and_counts() {
        let (mut sim, a, b) = build();
        sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
        sim.run_until(SimTime::from_secs(10.0));
        let pa = sim.actor_as::<Pinger>(a).unwrap();
        let pb = sim.actor_as::<Pinger>(b).unwrap();
        assert_eq!(pb.got, 5); // seqs 1,3,5,7,9
        assert_eq!(pa.got, 5); // seqs 2,4,6,8,10
        assert!(sim.now() > SimTime::ZERO);
        assert_eq!(sim.metrics().msgs("test"), 10);
    }

    #[test]
    fn identical_seeds_identical_traces() {
        let run = |seed| {
            let (mut sim, a, _) = build();
            sim.reseed(seed);
            sim.core.net.set_default(LinkProfile::wan(50.0, 5.0, 0.0));
            sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
            sim.run_until(SimTime::from_secs(30.0));
            sim.now().as_micros()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8)); // jitter differs with seed
    }

    #[test]
    fn failed_nodes_drop_traffic() {
        let (mut sim, a, _) = build();
        sim.set_node_failed(NodeId(1), true);
        sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
        sim.run_until(SimTime::from_secs(5.0));
        assert_eq!(
            sim.metrics().counter("net.dropped_failed_node"),
            1,
            "send to failed node must be dropped"
        );
        let pa = sim.actor_as::<Pinger>(a).unwrap();
        assert_eq!(pa.got, 0);
    }

    #[test]
    fn quiescence_drains_messages_but_not_timer_chains() {
        let (mut sim, a, _) = build();
        sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
        // A periodic timer chain that never sends messages.
        struct Ticker;
        impl Actor for Ticker {
            fn handle(&mut self, ctx: &mut Ctx<'_>, _: SimMsg) {
                ctx.schedule(SimTime::from_secs(1.0), SimMsg::Timer(TimerKind::Workload));
            }
            fn as_any(&self) -> &dyn Any {
                self
            }
            fn as_any_mut(&mut self) -> &mut dyn Any {
                self
            }
        }
        let t = sim.add_actor(NodeId(0), Box::new(Ticker));
        sim.inject(SimTime::ZERO, t, SimMsg::Timer(TimerKind::Workload));

        // Fire the bootstrap timers so the first ping is in flight, then
        // drain: quiescence stops at "no message in flight", not "queue
        // empty" (the ticker chain re-arms forever).
        sim.run_until(SimTime::ZERO);
        assert_eq!(sim.pending_non_timer_events(), 1, "first ping in flight");
        let leftover = sim.run_to_quiescence(SimTime::from_secs(60.0));
        assert_eq!(leftover, 0, "every in-flight message must drain");
        assert_eq!(sim.pending_non_timer_events(), 0);
        // The ping-pong exchange completed in full…
        let pa = sim.actor_as::<Pinger>(a).unwrap();
        assert_eq!(pa.got, 5);
        // …while the timer chain is still armed (not drained forever).
        assert!(sim.pending_events() >= 1, "ticker must stay scheduled");
        assert!(
            sim.now() < SimTime::from_secs(60.0),
            "quiescence must stop well before the hard limit"
        );
    }

    #[test]
    fn cpu_charges_scale_with_node_speed() {
        let mut sim = Sim::new(2);
        sim.add_node(NodeId(0), NodeClass::RaspberryPi4);
        struct Burner;
        impl Actor for Burner {
            fn handle(&mut self, ctx: &mut Ctx<'_>, _: SimMsg) {
                ctx.charge_cpu(35.0);
            }
            fn as_any(&self) -> &dyn Any {
                self
            }
            fn as_any_mut(&mut self) -> &mut dyn Any {
                self
            }
        }
        let a = sim.add_actor(NodeId(0), Box::new(Burner));
        sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
        sim.run_until(SimTime::from_secs(1.0));
        let metrics = sim.metrics();
        let util = metrics
            .usage(NodeId(0))
            .unwrap()
            .cpu_util(SimTime::ZERO, SimTime::from_secs(1.0));
        // 35ms at 0.35 speed = 100ms busy in a 1000ms window.
        assert!((util - 0.1).abs() < 1e-9, "util={util}");
    }

    /// Two-lane sim: same topology as `build()` but with each node homed
    /// on its own lane, so every ping crosses the window merge path.
    fn build_sharded(threads: usize) -> (Sim, ActorId, ActorId) {
        let mut sim = Sim::new(9);
        sim.shard_lanes(2, threads);
        sim.add_node_in_lane(NodeId(0), NodeClass::S, 0);
        sim.add_node_in_lane(NodeId(1), NodeClass::S, 1);
        sim.core.net.set_default(LinkProfile::wan(50.0, 5.0, 0.0));
        let a = sim.add_actor(
            NodeId(0),
            Box::new(Pinger {
                peer: None,
                sent: 0,
                got: 0,
                limit: 10,
            }),
        );
        let b = sim.add_actor(
            NodeId(1),
            Box::new(Pinger {
                peer: Some(a),
                sent: 0,
                got: 0,
                limit: 10,
            }),
        );
        sim.actor_as_mut::<Pinger>(a).unwrap().peer = Some(b);
        (sim, a, b)
    }

    #[test]
    fn lane_engine_is_thread_count_invariant() {
        let run = |threads: usize| {
            let (mut sim, a, b) = build_sharded(threads);
            sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
            sim.run_until(SimTime::from_secs(30.0));
            let m = sim.metrics();
            let got = (
                sim.actor_as::<Pinger>(a).unwrap().got,
                sim.actor_as::<Pinger>(b).unwrap().got,
            );
            (sim.now().as_micros(), m.msgs("test"), got, sim.pending_events())
        };
        let one = run(1);
        assert_eq!(one, run(2), "threads must not change the trace");
        assert_eq!(one.2, (5, 5), "full exchange across the lane boundary");
    }

    #[test]
    fn sharded_quiescence_matches_across_thread_counts() {
        let run = |threads: usize| {
            let (mut sim, a, _) = build_sharded(threads);
            sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
            let leftover = sim.run_to_quiescence(SimTime::from_secs(60.0));
            (leftover, sim.now().as_micros(), sim.pending_non_timer_events())
        };
        let one = run(1);
        assert_eq!(one, run(4));
        assert_eq!(one.0, 0, "pings must drain");
    }

    #[test]
    fn same_tick_batching_is_counted() {
        let (mut sim, a, _) = build();
        // Three independent deliveries at the same instant: one drain
        // round, three events.
        for _ in 0..3 {
            sim.inject(SimTime::from_secs(1.0), a, SimMsg::Timer(TimerKind::Custom(7)));
        }
        sim.run_until(SimTime::from_secs(2.0));
        let m = sim.metrics();
        let events = m.counter("sim.lane.batch_events");
        let drains = m.counter("sim.lane.batch_drains");
        assert!(events >= 3, "events={events}");
        assert!(drains >= 1 && drains < events, "drains={drains} events={events}");
    }

    #[test]
    fn crash_purges_inflight_and_restart_reuses_the_actor_id() {
        let (mut sim, a, b) = build();
        // One ping in flight towards b, plus a pending timer on b.
        sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
        sim.run_until(SimTime::ZERO);
        sim.inject(SimTime::from_secs(5.0), b, SimMsg::Timer(TimerKind::Custom(1)));
        assert_eq!(sim.pending_non_timer_events(), 1, "ping in flight");
        let total_before = sim.pending_events();

        // Crash b: the in-flight ping and its timer both die.
        let dropped = sim.crash_actor(b);
        assert_eq!(dropped, 1, "exactly the ping is message loss");
        assert_eq!(sim.pending_non_timer_events(), 0);
        assert_eq!(sim.pending_events(), total_before - 2, "timer purged too");
        assert!(sim.actor_as::<Pinger>(b).is_none(), "state is gone");

        // Deliveries to the empty slot are silently dropped.
        sim.inject(SimTime::from_secs(1.0), b, SimMsg::Data(DataMsg::Ping { seq: 1 }));
        sim.run_until(SimTime::from_secs(2.0));
        assert_eq!(sim.pending_non_timer_events(), 0, "dropped at dispatch");

        // Restart under the same ActorId: peers reach the new incarnation
        // without relearning addresses.
        sim.restart_actor(
            b,
            Box::new(Pinger {
                peer: Some(a),
                sent: 0,
                got: 0,
                limit: 10,
            }),
        );
        sim.inject(SimTime::from_secs(3.0), a, SimMsg::Timer(TimerKind::Custom(0)));
        sim.run_until(SimTime::from_secs(10.0));
        let pb = sim.actor_as::<Pinger>(b).unwrap();
        assert!(pb.got >= 1, "fresh incarnation receives on the old id");
    }

    #[test]
    fn crash_is_deterministic_across_same_seed_runs() {
        let run = |seed| {
            let (mut sim, a, b) = build();
            sim.reseed(seed);
            sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
            sim.run_until(SimTime::from_millis(1.0));
            let dropped = sim.crash_actor(b);
            sim.restart_actor(
                b,
                Box::new(Pinger {
                    peer: Some(a),
                    sent: 0,
                    got: 0,
                    limit: 4,
                }),
            );
            sim.inject(SimTime::from_secs(1.0), b, SimMsg::Timer(TimerKind::Custom(0)));
            sim.run_until(SimTime::from_secs(20.0));
            (dropped, sim.now().as_micros(), sim.metrics().msgs("test"))
        };
        assert_eq!(run(3), run(3));
    }

    #[test]
    fn pending_counters_stay_consistent() {
        let (mut sim, a, _) = build();
        sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
        assert_eq!(sim.pending_events(), 1);
        assert_eq!(sim.pending_non_timer_events(), 0, "timers are not messages");
        sim.run_until(SimTime::from_secs(10.0));
        assert_eq!(sim.pending_events(), 0, "lan ping-pong drains fully");
    }
}
