//! Deterministic discrete-event simulator — the testbed substrate standing
//! in for the paper's HPC (VM cluster) and HET (heterogeneous edge) setups
//! (§7.1, and DESIGN.md's substitution ledger).
//!
//! Entities (orchestrators, workers, baseline control planes, workload
//! drivers) are [`Actor`]s pinned to simulated nodes. Actors exchange
//! [`SimMsg`]s through a network model with per-link delay/jitter/loss/
//! bandwidth, consume CPU via explicit cost charging (feeding the
//! utilization figures), and set timers. Event order is fully
//! deterministic: ties on the virtual clock break by sequence number, and
//! all randomness flows from one seeded RNG.

mod container;
mod msg;
mod network;

pub use container::ContainerRuntime;
pub use msg::{DataMsg, KubeMsg, OakMsg, ReplacementReason, SimMsg, TimerKind};
pub use network::{LinkProfile, Network, Transport};

use std::any::Any;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::metrics::Metrics;
use crate::model::NodeClass;
use crate::util::{NodeId, Rng, SimTime};

/// Dense actor handle (index into the actor table).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct ActorId(pub u32);

/// A simulated entity. `handle` runs to completion at a virtual instant;
/// side effects (sends, timers, cpu charges) go through [`Ctx`].
pub trait Actor {
    fn handle(&mut self, ctx: &mut Ctx<'_>, msg: SimMsg);
    /// Downcasting support so tests/benches can inspect actor state.
    fn as_any(&self) -> &dyn Any;
    fn as_any_mut(&mut self) -> &mut dyn Any;
}

#[derive(Debug)]
struct Event {
    at: SimTime,
    seq: u64,
    target: ActorId,
    msg: SimMsg,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Static description of a simulated node.
#[derive(Clone, Debug)]
pub struct SimNode {
    pub class: NodeClass,
}

/// Everything except the actor table — actors receive `&mut SimCore`
/// through [`Ctx`] while they are temporarily detached for dispatch.
pub struct SimCore {
    pub clock: SimTime,
    queue: BinaryHeap<Reverse<Event>>,
    seq: u64,
    /// Queued events that are NOT timers (messages in flight). Timers are
    /// self-rescheduling background noise; this counter is what
    /// quiescence (and churn's leak audits) actually care about.
    non_timer_pending: usize,
    pub net: Network,
    pub rng: Rng,
    pub metrics: Metrics,
    /// Node table indexed by dense `NodeId` (same keying discipline as
    /// `metrics.node_usage`); `None` slots are never-registered ids.
    nodes: Vec<Option<SimNode>>,
    actor_node: Vec<NodeId>,
    /// `failed[node]` — `send` asks this twice per message, so it's a
    /// dense bitmap rather than a set; ids beyond the end are healthy.
    failed: Vec<bool>,
    pub containers: ContainerRuntime,
}

impl SimCore {
    fn push(&mut self, at: SimTime, target: ActorId, msg: SimMsg) {
        if !matches!(msg, SimMsg::Timer(_)) {
            self.non_timer_pending += 1;
        }
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Reverse(Event {
            at,
            seq,
            target,
            msg,
        }));
    }

    pub fn node_of(&self, actor: ActorId) -> NodeId {
        self.actor_node[actor.0 as usize]
    }

    pub fn node_class(&self, node: NodeId) -> NodeClass {
        self.nodes[node.0 as usize]
            .as_ref()
            .unwrap_or_else(|| panic!("unknown node {node}"))
            .class
    }

    pub fn is_failed(&self, node: NodeId) -> bool {
        self.failed.get(node.0 as usize).copied().unwrap_or(false)
    }

    pub fn set_failed(&mut self, node: NodeId, failed: bool) {
        let i = node.0 as usize;
        if i >= self.failed.len() {
            if !failed {
                return; // clearing a node that was never failed
            }
            self.failed.resize(i + 1, false);
        }
        self.failed[i] = failed;
    }
}

/// Actor-facing API for one dispatch.
pub struct Ctx<'a> {
    pub now: SimTime,
    pub self_id: ActorId,
    /// Node hosting `self_id`, resolved once per dispatch instead of once
    /// per `send`/`charge_cpu` call (the sim's hottest lookups).
    pub self_node: NodeId,
    pub core: &'a mut SimCore,
}

impl<'a> Ctx<'a> {
    /// Shared transmit path of [`Ctx::send`] and
    /// [`Ctx::send_unreliable`]: one failed-endpoint check, one message
    /// accounting record, one delivery-delay draw.
    fn transmit(
        &mut self,
        to: ActorId,
        msg: SimMsg,
        bytes: usize,
        label: &'static str,
        transport: Transport,
    ) {
        let src = self.self_node;
        let dst = self.core.node_of(to);
        if self.core.is_failed(src) || self.core.is_failed(dst) {
            self.core.metrics.inc("net.dropped_failed_node");
            return;
        }
        self.core.metrics.record_msg(label, bytes);
        match self
            .core
            .net
            .delivery_delay(src, dst, bytes, transport, &mut self.core.rng)
        {
            Some(delay) => {
                let at = self.now + delay;
                self.core.push(at, to, msg);
            }
            None => self.core.metrics.inc("net.lost"),
        }
    }

    /// Send over the network; delivery is delayed by the link model and
    /// message accounting is recorded under `label` (figure 7a counts
    /// these). Messages involving failed nodes are silently dropped —
    /// exactly what a dead edge node looks like from the outside.
    pub fn send(&mut self, to: ActorId, msg: SimMsg, bytes: usize, label: &'static str) {
        self.transmit(to, msg, bytes, label, Transport::Reliable);
    }

    /// Send via an unreliable (UDP-like) transport: lost messages vanish.
    pub fn send_unreliable(
        &mut self,
        to: ActorId,
        msg: SimMsg,
        bytes: usize,
        label: &'static str,
    ) {
        self.transmit(to, msg, bytes, label, Transport::Unreliable);
    }

    /// Deliver without touching the network (same-process components, e.g.
    /// service manager → scheduler inside one orchestrator).
    pub fn send_local(&mut self, to: ActorId, msg: SimMsg) {
        let at = self.now;
        self.core.push(at, to, msg);
    }

    /// Set a timer on self.
    pub fn schedule(&mut self, delay: SimTime, msg: SimMsg) {
        let at = self.now + delay;
        let id = self.self_id;
        self.core.push(at, id, msg);
    }

    /// Set a timer for another actor (used by experiment drivers).
    pub fn schedule_for(&mut self, to: ActorId, delay: SimTime, msg: SimMsg) {
        let at = self.now + delay;
        self.core.push(at, to, msg);
    }

    /// Charge control-plane CPU time to this actor's node, scaled by the
    /// node's speed factor (a Pi burns more wall-clock per unit work).
    pub fn charge_cpu(&mut self, cpu_ms: f64) {
        let node = self.self_node;
        let scaled = cpu_ms / self.core.node_class(node).speed_factor();
        let now = self.now;
        self.core.metrics.usage_mut(node).charge_cpu(now, scaled);
    }

    /// Adjust this node's resident-memory gauge.
    pub fn add_mem(&mut self, delta_mb: f64) {
        let node = self.self_node;
        self.core.metrics.usage_mut(node).add_mem(delta_mb);
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.core.rng
    }

    pub fn metrics(&mut self) -> &mut Metrics {
        &mut self.core.metrics
    }

    pub fn my_node(&self) -> NodeId {
        self.self_node
    }

    /// Ground-truth RTT between two nodes (for ping emulation: Vivaldi
    /// feeds on these; the *scheduler* never reads them directly).
    pub fn rtt_ms(&mut self, a: NodeId, b: NodeId) -> f64 {
        self.core.net.rtt_ms(a, b, &mut self.core.rng)
    }

    /// Node hosting `actor`. Dispatchers must use this instead of
    /// reaching into `core` directly: `Ctx` is the lane boundary the
    /// `lane-isolation` lint certifies, and the future sharded event
    /// loop reroutes exactly these calls at lane edges.
    pub fn node_of(&self, actor: ActorId) -> NodeId {
        self.core.node_of(actor)
    }

    /// Crash-stop status of `node` (see [`Ctx::node_of`] for why this
    /// wrapper exists).
    pub fn is_failed(&self, node: NodeId) -> bool {
        self.core.is_failed(node)
    }

    /// Container cold-start time on `node`: image pull (cached layers
    /// skip the registry) + runtime start jitter, scaled by the node's
    /// speed class. Bundled here so dispatchers never touch the
    /// container-runtime or rng state directly.
    pub fn container_deploy_time(
        &mut self,
        node: NodeId,
        image_key: u64,
        image_mb: u32,
    ) -> SimTime {
        let pull = self.core.containers.pull_time(node, image_key, image_mb);
        let start = self.core.containers.start_latency(&mut self.core.rng);
        let speed = self.core.node_class(node).speed_factor();
        SimTime::from_micros(((pull + start).as_micros() as f64 / speed) as u64)
    }
}

/// The simulator: actor table + core.
pub struct Sim {
    actors: Vec<Option<Box<dyn Actor>>>,
    pub core: SimCore,
}

impl Sim {
    pub fn new(seed: u64) -> Self {
        Sim {
            actors: Vec::new(),
            core: SimCore {
                clock: SimTime::ZERO,
                queue: BinaryHeap::new(),
                seq: 0,
                non_timer_pending: 0,
                net: Network::default(),
                rng: Rng::seeded(seed),
                metrics: Metrics::default(),
                nodes: Vec::new(),
                actor_node: Vec::new(),
                failed: Vec::new(),
                containers: ContainerRuntime::default(),
            },
        }
    }

    pub fn add_node(&mut self, node: NodeId, class: NodeClass) {
        let i = node.0 as usize;
        if i >= self.core.nodes.len() {
            self.core.nodes.resize_with(i + 1, || None);
        }
        let prev = self.core.nodes[i].replace(SimNode { class });
        assert!(prev.is_none(), "node {node} registered twice");
    }

    pub fn add_actor(&mut self, node: NodeId, actor: Box<dyn Actor>) -> ActorId {
        assert!(
            self.core
                .nodes
                .get(node.0 as usize)
                .map_or(false, |n| n.is_some()),
            "actor on unknown node {node}"
        );
        let id = ActorId(self.actors.len() as u32);
        self.actors.push(Some(actor));
        self.core.actor_node.push(node);
        id
    }

    /// Inject a message at a given virtual time (experiment drivers).
    pub fn inject(&mut self, at: SimTime, target: ActorId, msg: SimMsg) {
        self.core.push(at, target, msg);
    }

    /// Pop and dispatch the single next event. Returns false when the
    /// queue is empty. The shared step of [`Sim::run_until`] and
    /// [`Sim::run_to_quiescence`] — the non-timer backlog counter is
    /// maintained exactly here and in [`SimCore::push`].
    fn dispatch_one(&mut self) -> bool {
        let Some(Reverse(ev)) = self.core.queue.pop() else {
            return false;
        };
        if !matches!(ev.msg, SimMsg::Timer(_)) {
            self.core.non_timer_pending -= 1;
        }
        self.core.clock = ev.at;
        let idx = ev.target.0 as usize;
        // Detach the actor so it can borrow the core mutably.
        let Some(mut actor) = self.actors[idx].take() else {
            return true; // actor removed mid-flight
        };
        let node = self.core.node_of(ev.target);
        {
            let mut ctx = Ctx {
                now: ev.at,
                self_id: ev.target,
                self_node: node,
                core: &mut self.core,
            };
            actor.handle(&mut ctx, ev.msg);
        }
        self.actors[idx] = Some(actor);
        true
    }

    /// Run until the queue drains or the next event lies beyond `until`.
    /// The clock is left at the last *executed* event.
    pub fn run_until(&mut self, until: SimTime) {
        while self
            .core
            .queue
            .peek()
            .map_or(false, |Reverse(e)| e.at <= until)
        {
            self.dispatch_one();
        }
    }

    /// Drain every in-flight **message** (non-timer event), processing
    /// timers along the way as the clock passes them, and stop the moment
    /// the queue holds nothing but timers — i.e. the control plane is
    /// momentarily quiescent. Periodic timers re-arm forever, so "drain
    /// everything" is undefined; "no message in flight" is the meaningful
    /// convergence point (churn's leak audits snapshot state here).
    /// Returns the non-timer backlog still pending (0 unless
    /// `hard_limit` was hit first).
    pub fn run_to_quiescence(&mut self, hard_limit: SimTime) -> usize {
        while self.core.non_timer_pending > 0
            && self
                .core
                .queue
                .peek()
                .map_or(false, |Reverse(e)| e.at <= hard_limit)
        {
            self.dispatch_one();
        }
        self.core.non_timer_pending
    }

    /// Total queued events (timers included).
    pub fn pending_events(&self) -> usize {
        self.core.queue.len()
    }

    /// Queued events that are in-flight messages rather than timers.
    pub fn pending_non_timer_events(&self) -> usize {
        self.core.non_timer_pending
    }

    pub fn now(&self) -> SimTime {
        self.core.clock
    }

    /// Inspect an actor's state (tests/benches).
    pub fn actor_as<T: 'static>(&self, id: ActorId) -> Option<&T> {
        self.actors[id.0 as usize]
            .as_deref()
            .and_then(|a| a.as_any().downcast_ref::<T>())
    }

    pub fn actor_as_mut<T: 'static>(&mut self, id: ActorId) -> Option<&mut T> {
        self.actors[id.0 as usize]
            .as_deref_mut()
            .and_then(|a| a.as_any_mut().downcast_mut::<T>())
    }

    /// Fail / recover a node (failure-injection experiments, §4.2).
    pub fn set_node_failed(&mut self, node: NodeId, failed: bool) {
        self.core.set_failed(node, failed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Ping-pong actor pair used to validate ordering and determinism.
    struct Pinger {
        peer: Option<ActorId>,
        sent: u32,
        got: u32,
        limit: u32,
    }
    impl Actor for Pinger {
        fn handle(&mut self, ctx: &mut Ctx<'_>, msg: SimMsg) {
            match msg {
                SimMsg::Timer(TimerKind::Custom(_)) => {
                    if let Some(p) = self.peer {
                        self.sent += 1;
                        ctx.send(p, SimMsg::Data(DataMsg::Ping { seq: self.sent }), 64, "test");
                    }
                }
                SimMsg::Data(DataMsg::Ping { seq }) => {
                    self.got += 1;
                    if seq < self.limit {
                        if let Some(p) = self.peer {
                            ctx.send(p, SimMsg::Data(DataMsg::Ping { seq: seq + 1 }), 64, "test");
                        }
                    }
                    ctx.charge_cpu(0.1);
                }
                _ => {}
            }
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn Any {
            self
        }
    }

    fn build() -> (Sim, ActorId, ActorId) {
        let mut sim = Sim::new(1);
        sim.add_node(NodeId(0), NodeClass::S);
        sim.add_node(NodeId(1), NodeClass::S);
        sim.core.net.set_default(LinkProfile::lan());
        let a = sim.add_actor(
            NodeId(0),
            Box::new(Pinger {
                peer: None,
                sent: 0,
                got: 0,
                limit: 10,
            }),
        );
        let b = sim.add_actor(
            NodeId(1),
            Box::new(Pinger {
                peer: Some(a),
                sent: 0,
                got: 0,
                limit: 10,
            }),
        );
        sim.actor_as_mut::<Pinger>(a).unwrap().peer = Some(b);
        (sim, a, b)
    }

    #[test]
    fn ping_pong_advances_clock_and_counts() {
        let (mut sim, a, b) = build();
        sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
        sim.run_until(SimTime::from_secs(10.0));
        let pa = sim.actor_as::<Pinger>(a).unwrap();
        let pb = sim.actor_as::<Pinger>(b).unwrap();
        assert_eq!(pb.got, 5); // seqs 1,3,5,7,9
        assert_eq!(pa.got, 5); // seqs 2,4,6,8,10
        assert!(sim.now() > SimTime::ZERO);
        assert_eq!(sim.core.metrics.msgs("test"), 10);
    }

    #[test]
    fn identical_seeds_identical_traces() {
        let run = |seed| {
            let (mut sim, a, _) = build();
            sim.core.rng = Rng::seeded(seed);
            sim.core.net.set_default(LinkProfile::wan(50.0, 5.0, 0.0));
            sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
            sim.run_until(SimTime::from_secs(30.0));
            sim.now().as_micros()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8)); // jitter differs with seed
    }

    #[test]
    fn failed_nodes_drop_traffic() {
        let (mut sim, a, _) = build();
        sim.set_node_failed(NodeId(1), true);
        sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
        sim.run_until(SimTime::from_secs(5.0));
        assert_eq!(
            sim.core.metrics.counter("net.dropped_failed_node"),
            1,
            "send to failed node must be dropped"
        );
        let pa = sim.actor_as::<Pinger>(a).unwrap();
        assert_eq!(pa.got, 0);
    }

    #[test]
    fn quiescence_drains_messages_but_not_timer_chains() {
        let (mut sim, a, _) = build();
        sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
        // A periodic timer chain that never sends messages.
        struct Ticker;
        impl Actor for Ticker {
            fn handle(&mut self, ctx: &mut Ctx<'_>, _: SimMsg) {
                ctx.schedule(SimTime::from_secs(1.0), SimMsg::Timer(TimerKind::Workload));
            }
            fn as_any(&self) -> &dyn Any {
                self
            }
            fn as_any_mut(&mut self) -> &mut dyn Any {
                self
            }
        }
        let t = sim.add_actor(NodeId(0), Box::new(Ticker));
        sim.inject(SimTime::ZERO, t, SimMsg::Timer(TimerKind::Workload));

        // Fire the bootstrap timers so the first ping is in flight, then
        // drain: quiescence stops at "no message in flight", not "queue
        // empty" (the ticker chain re-arms forever).
        sim.run_until(SimTime::ZERO);
        assert_eq!(sim.pending_non_timer_events(), 1, "first ping in flight");
        let leftover = sim.run_to_quiescence(SimTime::from_secs(60.0));
        assert_eq!(leftover, 0, "every in-flight message must drain");
        assert_eq!(sim.pending_non_timer_events(), 0);
        // The ping-pong exchange completed in full…
        let pa = sim.actor_as::<Pinger>(a).unwrap();
        assert_eq!(pa.got, 5);
        // …while the timer chain is still armed (not drained forever).
        assert!(sim.pending_events() >= 1, "ticker must stay scheduled");
        assert!(
            sim.now() < SimTime::from_secs(60.0),
            "quiescence must stop well before the hard limit"
        );
    }

    #[test]
    fn cpu_charges_scale_with_node_speed() {
        let mut sim = Sim::new(2);
        sim.add_node(NodeId(0), NodeClass::RaspberryPi4);
        struct Burner;
        impl Actor for Burner {
            fn handle(&mut self, ctx: &mut Ctx<'_>, _: SimMsg) {
                ctx.charge_cpu(35.0);
            }
            fn as_any(&self) -> &dyn Any {
                self
            }
            fn as_any_mut(&mut self) -> &mut dyn Any {
                self
            }
        }
        let a = sim.add_actor(NodeId(0), Box::new(Burner));
        sim.inject(SimTime::ZERO, a, SimMsg::Timer(TimerKind::Custom(0)));
        sim.run_until(SimTime::from_secs(1.0));
        let util = sim
            .core
            .metrics
            .usage(NodeId(0))
            .unwrap()
            .cpu_util(SimTime::ZERO, SimTime::from_secs(1.0));
        // 35ms at 0.35 speed = 100ms busy in a 1000ms window.
        assert!((util - 0.1).abs() < 1e-9, "util={util}");
    }
}
