//! Container runtime model: image pulls and container start latency.
//!
//! The paper's workloads are Docker containers (§7.1); orchestration
//! overhead is measured *around* container start, so the runtime model
//! only needs realistic, deterministic-given-seed timings: a per-node
//! image cache (first pull pays bytes/bandwidth, repeats are free) plus a
//! lognormal-ish start latency.

use std::collections::BTreeSet;

use crate::util::{NodeId, Rng, SimTime};

/// Shared container-runtime state across all simulated nodes.
#[derive(Clone, Debug, Default)]
pub struct ContainerRuntime {
    /// (node, image-id) pairs already present locally.
    cache: BTreeSet<(NodeId, u64)>,
    /// Registry bandwidth for image pulls, Mbit/s.
    pub registry_mbps: f64,
}

impl ContainerRuntime {
    /// Time to pull an image on `node` (0 if cached), marking it cached.
    pub fn pull_time(&mut self, node: NodeId, image_id: u64, image_mb: u32) -> SimTime {
        if self.cache.contains(&(node, image_id)) {
            return SimTime::ZERO;
        }
        self.cache.insert((node, image_id));
        let mbps = if self.registry_mbps > 0.0 {
            self.registry_mbps
        } else {
            200.0
        };
        SimTime::from_secs(image_mb as f64 * 8.0 / mbps)
    }

    /// Container start latency: containerd+runc cold start, scaled by the
    /// node's speed factor at the call site. Mean ~270 ms with spread,
    /// floor 120 ms, tail capped at 800 ms — consistent with published
    /// containerd numbers for cached images.
    pub fn start_latency(&self, rng: &mut Rng) -> SimTime {
        let ms = 120.0 + rng.exponential(150.0);
        SimTime::from_millis(ms.min(800.0))
    }

    /// Forget a node's cache (node reset between experiment runs — the
    /// paper flushes memory/disk between runs, §7.1).
    pub fn flush_node(&mut self, node: NodeId) {
        self.cache.retain(|(n, _)| *n != node);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pull_is_cached_after_first() {
        let mut rt = ContainerRuntime::default();
        let t1 = rt.pull_time(NodeId(1), 42, 100);
        assert!(t1 > SimTime::ZERO);
        let t2 = rt.pull_time(NodeId(1), 42, 100);
        assert_eq!(t2, SimTime::ZERO);
        // Different node pulls again.
        let t3 = rt.pull_time(NodeId(2), 42, 100);
        assert!(t3 > SimTime::ZERO);
    }

    #[test]
    fn flush_invalidates_cache() {
        let mut rt = ContainerRuntime::default();
        rt.pull_time(NodeId(1), 42, 100);
        rt.flush_node(NodeId(1));
        assert!(rt.pull_time(NodeId(1), 42, 100) > SimTime::ZERO);
    }

    #[test]
    fn start_latency_bounded() {
        let mut rng = Rng::seeded(4);
        let mut rt = ContainerRuntime::default();
        rt.registry_mbps = 200.0;
        for _ in 0..1000 {
            let t = rt.start_latency(&mut rng).as_millis();
            assert!((120.0..=800.0).contains(&t), "{t}");
        }
    }
}
