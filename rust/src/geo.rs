//! Geographic positions and great-circle distances (`dist_gc` in paper
//! Alg. 2). Must stay numerically consistent with the L1 kernel
//! (`python/compile/kernels/ldp_score.py`): same Earth radius, same
//! haversine formulation — the pytest+proptest suites cross-check both.

/// Earth radius in km — keep in sync with `ldp_score.EARTH_RADIUS_KM`.
pub const EARTH_RADIUS_KM: f64 = 6371.0;

/// A geographic point in **radians** (consistent with the HLO artifacts).
#[derive(Clone, Copy, PartialEq, Debug, Default)]
pub struct GeoPoint {
    pub lat: f64,
    pub lon: f64,
}

impl GeoPoint {
    /// Construct from degrees (the SLA format uses degrees; everything
    /// internal uses radians).
    pub fn from_degrees(lat_deg: f64, lon_deg: f64) -> Self {
        GeoPoint {
            lat: lat_deg.to_radians(),
            lon: lon_deg.to_radians(),
        }
    }

    /// Great-circle (haversine) distance in km.
    pub fn distance_km(&self, other: &GeoPoint) -> f64 {
        let dlat = 0.5 * (other.lat - self.lat);
        let dlon = 0.5 * (other.lon - self.lon);
        let h = dlat.sin().powi(2)
            + self.lat.cos() * other.lat.cos() * dlon.sin().powi(2);
        2.0 * EARTH_RADIUS_KM * h.clamp(0.0, 1.0).sqrt().asin()
    }
}

/// A named operational area: the SLA `area` field maps to one of these
/// (paper Schema 1); clusters advertise their area so the root scheduler
/// can pre-filter (paper §4.2, "approximate geographical operation zones").
#[derive(Clone, Copy, PartialEq, Debug)]
pub struct Area {
    pub center: GeoPoint,
    pub radius_km: f64,
}

impl Area {
    pub fn contains(&self, p: &GeoPoint) -> bool {
        self.center.distance_km(p) <= self.radius_km
    }

    /// Whether two areas could overlap (root-level coarse filter).
    pub fn intersects(&self, other: &Area) -> bool {
        self.center.distance_km(&other.center) <= self.radius_km + other.radius_km
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn munich() -> GeoPoint {
        GeoPoint::from_degrees(48.137, 11.575)
    }
    fn berlin() -> GeoPoint {
        GeoPoint::from_degrees(52.520, 13.405)
    }

    #[test]
    fn zero_distance_to_self() {
        let p = munich();
        assert!(p.distance_km(&p) < 1e-9);
    }

    #[test]
    fn known_city_pair_distance() {
        // Munich–Berlin is ~504 km great-circle.
        let d = munich().distance_km(&berlin());
        assert!((d - 504.0).abs() < 5.0, "got {d}");
    }

    #[test]
    fn symmetric() {
        assert!(
            (munich().distance_km(&berlin()) - berlin().distance_km(&munich())).abs()
                < 1e-9
        );
    }

    #[test]
    fn antipodal_is_half_circumference() {
        let a = GeoPoint::from_degrees(0.0, 0.0);
        let b = GeoPoint::from_degrees(0.0, 180.0);
        let d = a.distance_km(&b);
        assert!((d - std::f64::consts::PI * EARTH_RADIUS_KM).abs() < 1.0);
    }

    #[test]
    fn area_contains_and_intersects() {
        let area = Area {
            center: munich(),
            radius_km: 100.0,
        };
        assert!(area.contains(&munich()));
        assert!(!area.contains(&berlin()));
        let wide = Area {
            center: berlin(),
            radius_km: 450.0,
        };
        assert!(area.intersects(&wide));
        let narrow = Area {
            center: berlin(),
            radius_km: 100.0,
        };
        assert!(!area.intersects(&narrow));
    }
}
