//! # oakestra-rs — hierarchical orchestration for edge computing
//!
//! A from-scratch reproduction of *"Oakestra: An Orchestrator for Edge
//! Computing"* (Bartolomeo et al., 2022): a hierarchical orchestration
//! framework with federated cluster management, delegated task scheduling
//! (ROM + LDP), and a semantic overlay network — plus every substrate the
//! paper's evaluation depends on (a deterministic discrete-event testbed,
//! flat Kubernetes/K3s/MicroK8s baseline protocol models, a WireGuard-like
//! tunnel comparator, and the paper's workloads).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordination plane: [`coordinator`] (root /
//!   cluster / worker state machines), [`scheduler`] (delegated ROM/LDP),
//!   [`netmanager`] (ServiceIP semantic addressing + ProxyTUN tunnels),
//!   [`telemetry`] (push-based λ-adaptive updates), [`hierarchy`] (the
//!   cluster tree *I = ⟨C,E⟩* with ⟨Σ,μ,σ⟩ aggregation).
//! * **L2/L1 (build-time Python, `python/compile`)** — the numeric
//!   placement pipeline (batched LDP scoring, Vivaldi embedding,
//!   trilateration) and the video-analytics detector, AOT-lowered to HLO
//!   text artifacts.
//! * **Runtime bridge** — [`runtime`] loads the artifacts through the PJRT
//!   CPU client so the Rust hot path executes them without Python.
//!
//! ## Determinism
//!
//! Everything in [`sim`] is a deterministic discrete-event simulation:
//! seeded RNG, virtual clock, reproducible event ordering. Benches and
//! tests rely on this — the same seed always yields the same trace.

pub mod baselines;
pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod geo;
pub mod hierarchy;
pub mod json;
pub mod messaging;
pub mod metrics;
pub mod model;
pub mod netmanager;
pub mod propcheck;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod sla;
pub mod telemetry;
pub mod util;
pub mod vivaldi;
pub mod workload;

pub use util::{NodeId, ServiceId, SimTime, TaskId};
