//! # oakestra-rs — hierarchical orchestration for edge computing
//!
//! A from-scratch reproduction of *"Oakestra: An Orchestrator for Edge
//! Computing"* (Bartolomeo et al., 2022): a hierarchical orchestration
//! framework with federated cluster management, delegated task scheduling
//! (ROM + LDP), and a semantic overlay network — plus every substrate the
//! paper's evaluation depends on (a deterministic discrete-event testbed,
//! flat Kubernetes/K3s/MicroK8s baseline protocol models, a WireGuard-like
//! tunnel comparator, and the paper's workloads).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordination plane: [`api`] (the typed
//!   northbound service lifecycle API v1 — submit/scale/migrate/undeploy/
//!   status, the single front door into the hierarchy), [`coordinator`]
//!   (root / cluster / worker state machines), [`scheduler`] (delegated
//!   ROM/LDP), [`netmanager`] (ServiceIP semantic addressing + ProxyTUN
//!   tunnels), [`telemetry`] (push-based λ-adaptive updates),
//!   [`hierarchy`] (the cluster tree *I = ⟨C,E⟩* with ⟨Σ,μ,σ⟩
//!   aggregation).
//! * **L2/L1 (build-time Python, `python/compile`)** — the numeric
//!   placement pipeline (batched LDP scoring, Vivaldi embedding,
//!   trilateration) and the video-analytics detector, AOT-lowered to HLO
//!   text artifacts.
//! * **Runtime bridge** — [`runtime`] loads the artifacts through the PJRT
//!   CPU client so the Rust hot path executes them without Python.
//!
//! ## Service lifecycle (northbound API v1)
//!
//! Every lifecycle operation flows through [`api::ApiRequest`] /
//! [`api::ApiResponse`] envelopes addressed to the root orchestrator:
//! `SubmitService` (full Schema 1 JSON via
//! [`sla::ServiceSla::parse_json`]), `ScaleService`, `MigrateInstance`,
//! `UndeployService`, `ServiceStatus` and `ListServices`, each with
//! structured [`api::ApiError`] variants (validation failure, unknown
//! service/instance, no feasible placement). The root validates and
//! routes; cluster orchestrators execute scale-up through the ROM/LDP
//! schedulers and scale-down/teardown via `UndeployInstance` with
//! capacity release and conversion-table cleanup; workers ack per
//! instance.
//!
//! ## Determinism
//!
//! Everything in [`sim`] is a deterministic discrete-event simulation:
//! seeded RNG, virtual clock, reproducible event ordering. Benches and
//! tests rely on this — the same seed always yields the same trace.

// Clippy triage for the CI `-D warnings` gate (pinned toolchain in
// ci.yml). Each allow is a deliberate style call for this codebase, not
// an unreviewed mute: protocol state machines take many plain scalars
// (too_many_arguments), the sim's event types carry their payloads
// inline (large_enum_variant), and bench tables favor explicit index
// loops that mirror the paper's formulas (needless_range_loop).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]
#![allow(clippy::new_without_default)]
#![allow(clippy::large_enum_variant)]
#![allow(clippy::collapsible_if)]
#![allow(clippy::collapsible_else_if)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::comparison_chain)]

pub mod api;
pub mod baselines;
pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod geo;
pub mod hierarchy;
pub mod json;
pub mod lint;
pub mod messaging;
pub mod metrics;
pub mod model;
pub mod netmanager;
pub mod propcheck;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod sla;
pub mod telemetry;
pub mod util;
pub mod vivaldi;
pub mod workload;

pub use util::{NodeId, ServiceId, SimTime, TaskId};
