//! PJRT runtime bridge: loads the HLO **text** artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path —
//! Python never runs at request time.
//!
//! Wiring follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Executables are compiled once and cached
//! per entry name. All entries are lowered with `return_tuple=True`, so
//! results unwrap via `Literal::to_tuple()`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow as eyre, Context, Result};

/// Input/output spec of one AOT entry (mirrors manifest.json).
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed artifact manifest + directory.
#[derive(Clone, Debug)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub entries: HashMap<String, EntrySpec>,
}

impl Artifacts {
    /// Load from an explicit directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let v = crate::json::parse(&text)?;
        let mut entries = HashMap::new();
        let obj = v
            .as_object()
            .ok_or_else(|| eyre!("manifest root must be an object"))?;
        for (name, e) in obj {
            let tensor = |t: &crate::json::Value| -> Result<TensorSpec> {
                Ok(TensorSpec {
                    shape: t
                        .get("shape")
                        .as_array()
                        .ok_or_else(|| eyre!("bad shape"))?
                        .iter()
                        .map(|d| d.as_u64().unwrap_or(0) as usize)
                        .collect(),
                    dtype: t
                        .get("dtype")
                        .as_str()
                        .ok_or_else(|| eyre!("bad dtype"))?
                        .to_string(),
                })
            };
            let specs = |key: &str| -> Result<Vec<TensorSpec>> {
                e.get(key)
                    .as_array()
                    .ok_or_else(|| eyre!("bad {key} list"))?
                    .iter()
                    .map(tensor)
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntrySpec {
                    file: e
                        .get("file")
                        .as_str()
                        .ok_or_else(|| eyre!("entry {name} missing file"))?
                        .to_string(),
                    inputs: specs("inputs")?,
                    outputs: specs("outputs")?,
                },
            );
        }
        Ok(Artifacts { dir, entries })
    }

    /// Resolve via `OAKESTRA_ARTIFACTS` env var or `./artifacts`.
    pub fn discover() -> Result<Artifacts> {
        let dir = std::env::var("OAKESTRA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"));
        Self::load(dir)
    }

    pub fn path_of(&self, entry: &str) -> Result<PathBuf> {
        let spec = self
            .entries
            .get(entry)
            .ok_or_else(|| eyre!("unknown artifact entry {entry}"))?;
        Ok(self.dir.join(&spec.file))
    }
}

/// PJRT engine: CPU client + compile-once executable cache.
///
/// Only functional with the `xla-accel` cargo feature (which expects a
/// local `xla` crate + XLA toolchain). Without the feature every
/// constructor returns a structured error and callers fall back to the
/// host implementations — the crate stays fully buildable offline.
#[cfg(feature = "xla-accel")]
pub struct PjrtEngine {
    client: xla::PjRtClient,
    artifacts: Artifacts,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions performed (perf accounting).
    pub executions: u64,
}

/// Stub engine compiled when the `xla-accel` feature is off; uninhabited
/// in practice because [`PjrtEngine::new`] always errors.
#[cfg(not(feature = "xla-accel"))]
pub struct PjrtEngine {
    artifacts: Artifacts,
    /// Executions performed (perf accounting).
    pub executions: u64,
}

/// The error every accelerated entry point returns without `xla-accel`.
#[cfg(not(feature = "xla-accel"))]
fn bridge_disabled() -> anyhow::Error {
    eyre!(
        "PJRT bridge disabled: build with `--features xla-accel` \
         (requires a local xla crate + XLA toolchain)"
    )
}

impl PjrtEngine {
    #[cfg(feature = "xla-accel")]
    pub fn new(artifacts: Artifacts) -> Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtEngine {
            client,
            artifacts,
            cache: HashMap::new(),
            executions: 0,
        })
    }

    #[cfg(not(feature = "xla-accel"))]
    pub fn new(artifacts: Artifacts) -> Result<PjrtEngine> {
        let _ = artifacts;
        Err(bridge_disabled())
    }

    pub fn discover() -> Result<PjrtEngine> {
        Self::new(Artifacts::discover()?)
    }

    pub fn has_entry(&self, entry: &str) -> bool {
        self.artifacts.entries.contains_key(entry)
    }

    /// Compile (or fetch the cached) executable for an entry.
    #[cfg(feature = "xla-accel")]
    pub fn executable(&mut self, entry: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(entry) {
            let path = self.artifacts.path_of(entry)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| eyre!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(entry.to_string(), exe);
        }
        Ok(&self.cache[entry])
    }

    /// Execute an entry with literal inputs; returns the unpacked tuple.
    #[cfg(feature = "xla-accel")]
    pub fn run(&mut self, entry: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.executions += 1;
        let exe = self.executable(entry)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

/// Worker feature row fed to the accelerated LDP scorer.
#[derive(Clone, Copy, Debug)]
pub struct LdpWorkerRow {
    pub cpu: f32,
    pub mem: f32,
    pub disk: f32,
    pub virt_bits: i32,
    pub lat_rad: f32,
    pub lon_rad: f32,
    pub viv: [f32; 4],
}

/// One constraint row (S2S or S2U after trilateration).
#[derive(Clone, Copy, Debug)]
pub struct LdpConstraintRow {
    pub geo_lat_rad: f32,
    pub geo_lon_rad: f32,
    pub viv: [f32; 4],
    pub geo_thr_km: f32,
    pub viv_thr_ms: f32,
    pub active: bool,
}

impl Default for LdpConstraintRow {
    fn default() -> Self {
        LdpConstraintRow {
            geo_lat_rad: 0.0,
            geo_lon_rad: 0.0,
            viv: [0.0; 4],
            geo_thr_km: 0.0,
            viv_thr_ms: 0.0,
            active: false,
        }
    }
}

/// PJRT-accelerated LDP batch scorer over the `ldp_score_{512,2048}`
/// artifacts (paper Alg. 2 on the whole worker table at once). Pads the
/// live worker count to the smallest fitting variant; padded rows carry
/// zero capacity so they are never feasible.
pub struct LdpAccel {
    engine: PjrtEngine,
    /// Reused flattening buffers (§Perf iteration 2: no per-call allocs
    /// on the scheduler hot path).
    scratch: LdpScratch,
}

#[derive(Default)]
#[cfg_attr(not(feature = "xla-accel"), allow(dead_code))]
struct LdpScratch {
    caps: Vec<f32>,
    virt: Vec<i32>,
    geo: Vec<f32>,
    viv: Vec<f32>,
}

pub const LDP_VARIANTS: [(usize, &str); 2] =
    [(512, "ldp_score_512"), (2048, "ldp_score_2048")];
pub const LDP_MAX_CONSTRAINTS: usize = 4;

impl LdpAccel {
    pub fn new(engine: PjrtEngine) -> LdpAccel {
        LdpAccel {
            engine,
            scratch: LdpScratch::default(),
        }
    }

    pub fn discover() -> Result<LdpAccel> {
        Ok(LdpAccel::new(PjrtEngine::discover()?))
    }

    pub fn executions(&self) -> u64 {
        self.engine.executions
    }

    /// Score all workers; returns (scores, feasibility) of `workers.len()`.
    #[cfg(not(feature = "xla-accel"))]
    pub fn score(
        &mut self,
        _workers: &[LdpWorkerRow],
        _req: [f32; 3],
        _req_virt: i32,
        _constraints: &[LdpConstraintRow],
    ) -> Result<(Vec<f32>, Vec<bool>)> {
        Err(bridge_disabled())
    }

    /// Score all workers; returns (scores, feasibility) of `workers.len()`.
    #[cfg(feature = "xla-accel")]
    pub fn score(
        &mut self,
        workers: &[LdpWorkerRow],
        req: [f32; 3],
        req_virt: i32,
        constraints: &[LdpConstraintRow],
    ) -> Result<(Vec<f32>, Vec<bool>)> {
        anyhow::ensure!(
            constraints.len() <= LDP_MAX_CONSTRAINTS,
            "at most {LDP_MAX_CONSTRAINTS} constraint rows per call"
        );
        let (n, entry) = LDP_VARIANTS
            .iter()
            .find(|(n, _)| *n >= workers.len())
            .copied()
            .ok_or_else(|| {
                eyre!(
                    "worker count {} exceeds largest LDP variant",
                    workers.len()
                )
            })?;

        let sc = &mut self.scratch;
        sc.caps.clear();
        sc.caps.resize(n * 3, 0.0);
        sc.virt.clear();
        sc.virt.resize(n, 0);
        sc.geo.clear();
        sc.geo.resize(n * 2, 0.0);
        sc.viv.clear();
        sc.viv.resize(n * 4, 0.0);
        let (caps, virt, geo, viv) = (&mut sc.caps, &mut sc.virt, &mut sc.geo, &mut sc.viv);
        for (i, w) in workers.iter().enumerate() {
            caps[i * 3] = w.cpu;
            caps[i * 3 + 1] = w.mem;
            caps[i * 3 + 2] = w.disk;
            virt[i] = w.virt_bits;
            geo[i * 2] = w.lat_rad;
            geo[i * 2 + 1] = w.lon_rad;
            viv[i * 4..i * 4 + 4].copy_from_slice(&w.viv);
        }
        let k = LDP_MAX_CONSTRAINTS;
        let mut cons_geo = vec![0f32; k * 2];
        let mut cons_viv = vec![0f32; k * 4];
        let mut cons_thr = vec![0f32; k * 2];
        let mut cons_active = vec![0f32; k];
        for (j, c) in constraints.iter().enumerate() {
            cons_geo[j * 2] = c.geo_lat_rad;
            cons_geo[j * 2 + 1] = c.geo_lon_rad;
            cons_viv[j * 4..j * 4 + 4].copy_from_slice(&c.viv);
            cons_thr[j * 2] = c.geo_thr_km;
            cons_thr[j * 2 + 1] = c.viv_thr_ms;
            cons_active[j] = if c.active { 1.0 } else { 0.0 };
        }

        let inputs = vec![
            xla::Literal::vec1(caps.as_slice()).reshape(&[n as i64, 3])?,
            xla::Literal::vec1(virt.as_slice()),
            xla::Literal::vec1(geo.as_slice()).reshape(&[n as i64, 2])?,
            xla::Literal::vec1(viv.as_slice()).reshape(&[n as i64, 4])?,
            xla::Literal::vec1(&req[..]),
            xla::Literal::vec1(&[req_virt]),
            xla::Literal::vec1(&cons_geo).reshape(&[k as i64, 2])?,
            xla::Literal::vec1(&cons_viv).reshape(&[k as i64, 4])?,
            xla::Literal::vec1(&cons_thr).reshape(&[k as i64, 2])?,
            xla::Literal::vec1(&cons_active),
        ];
        let out = self.engine.run(entry, &inputs)?;
        anyhow::ensure!(out.len() == 2, "ldp artifact must return (score, mask)");
        let scores: Vec<f32> = out[0].to_vec::<f32>()?;
        let mask: Vec<f32> = out[1].to_vec::<f32>()?;
        Ok((
            scores[..workers.len()].to_vec(),
            mask[..workers.len()].iter().map(|&m| m > 0.5).collect(),
        ))
    }

    /// Index of the best feasible worker, if any.
    pub fn best(
        &mut self,
        workers: &[LdpWorkerRow],
        req: [f32; 3],
        req_virt: i32,
        constraints: &[LdpConstraintRow],
    ) -> Result<Option<usize>> {
        let (scores, mask) = self.score(workers, req, req_virt, constraints)?;
        Ok(scores
            .iter()
            .zip(mask.iter())
            .enumerate()
            .filter(|(_, (_, m))| **m)
            .max_by(|a, b| a.1 .0.total_cmp(b.1 .0))
            .map(|(i, _)| i))
    }
}

/// Vivaldi embedding via the `vivaldi_embed_256` artifact: embeds an RTT
/// matrix (≤256 nodes, zero-padded) into coordinates.
#[cfg_attr(not(feature = "xla-accel"), allow(dead_code))]
pub struct VivaldiEmbed {
    engine: PjrtEngine,
}

impl VivaldiEmbed {
    pub fn new(engine: PjrtEngine) -> Self {
        VivaldiEmbed { engine }
    }

    #[cfg(not(feature = "xla-accel"))]
    pub fn embed(&mut self, _rtt: &[Vec<f64>]) -> Result<Vec<[f64; 4]>> {
        Err(bridge_disabled())
    }

    #[cfg(feature = "xla-accel")]
    pub fn embed(&mut self, rtt: &[Vec<f64>]) -> Result<Vec<[f64; 4]>> {
        const N: usize = 256;
        anyhow::ensure!(rtt.len() <= N, "at most {N} nodes");
        let mut flat = vec![0f32; N * N];
        for (i, row) in rtt.iter().enumerate() {
            for (j, v) in row.iter().enumerate() {
                flat[i * N + j] = *v as f32;
            }
        }
        let inputs = vec![xla::Literal::vec1(&flat).reshape(&[N as i64, N as i64])?];
        let out = self.engine.run("vivaldi_embed_256", &inputs)?;
        let coords: Vec<f32> = out[0].to_vec::<f32>()?;
        Ok((0..rtt.len())
            .map(|i| {
                [
                    coords[i * 4] as f64,
                    coords[i * 4 + 1] as f64,
                    coords[i * 4 + 2] as f64,
                    coords[i * 4 + 3] as f64,
                ]
            })
            .collect())
    }
}

/// The video-analytics detector (`detector_{1,8}x64` artifacts): a fixed
/// CNN standing in for YOLOv3 (DESIGN.md substitution ledger).
#[cfg_attr(not(feature = "xla-accel"), allow(dead_code))]
pub struct Detector {
    engine: PjrtEngine,
}

impl Detector {
    pub fn new(engine: PjrtEngine) -> Self {
        Detector { engine }
    }

    pub fn discover() -> Result<Detector> {
        Ok(Detector::new(PjrtEngine::discover()?))
    }

    /// Run detection over `batch` frames of 64×64×3 f32; returns the
    /// flattened detection grid per frame ([8×8×5] each).
    #[cfg(not(feature = "xla-accel"))]
    pub fn detect(&mut self, _frames: &[f32], _batch: usize) -> Result<Vec<Vec<f32>>> {
        Err(bridge_disabled())
    }

    /// Run detection over `batch` frames of 64×64×3 f32; returns the
    /// flattened detection grid per frame ([8×8×5] each).
    #[cfg(feature = "xla-accel")]
    pub fn detect(&mut self, frames: &[f32], batch: usize) -> Result<Vec<Vec<f32>>> {
        let entry = match batch {
            1 => "detector_1x64",
            8 => "detector_8x64",
            _ => return Err(eyre!("supported batch sizes: 1, 8")),
        };
        anyhow::ensure!(frames.len() == batch * 64 * 64 * 3, "bad frame buffer");
        let inputs =
            vec![xla::Literal::vec1(frames).reshape(&[batch as i64, 64, 64, 3])?];
        let out = self.engine.run(entry, &inputs)?;
        let grid: Vec<f32> = out[0].to_vec::<f32>()?;
        let per = 8 * 8 * 5;
        Ok((0..batch).map(|b| grid[b * per..(b + 1) * per].to_vec()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        cfg!(feature = "xla-accel") && Artifacts::discover().is_ok()
    }

    fn mk_workers(n: usize) -> Vec<LdpWorkerRow> {
        (0..n)
            .map(|i| LdpWorkerRow {
                cpu: 1.0 + (i % 8) as f32,
                mem: 0.5 + (i % 4) as f32,
                disk: 10.0,
                virt_bits: 0b1111,
                lat_rad: 0.84 + 0.001 * (i % 16) as f32,
                lon_rad: 0.20 + 0.001 * (i / 16) as f32,
                viv: [i as f32 % 30.0, (i / 2) as f32 % 20.0, 0.0, 0.0],
            })
            .collect()
    }

    #[test]
    fn ldp_accel_matches_host_semantics() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut accel = LdpAccel::discover().unwrap();
        let workers = mk_workers(100);
        let req = [2.0, 1.0, 0.0];
        let (scores, mask) = accel.score(&workers, req, 0b0001, &[]).unwrap();
        assert_eq!(scores.len(), 100);
        for (w, (s, m)) in workers.iter().zip(scores.iter().zip(mask.iter())) {
            let feasible = w.cpu >= req[0] && w.mem >= req[1];
            assert_eq!(*m, feasible, "worker {w:?}");
            if feasible {
                let want = (w.cpu - req[0]) + (w.mem - req[1]);
                assert!((s - want).abs() < 1e-4);
            } else {
                assert!(*s < -1e29);
            }
        }
    }

    #[test]
    fn ldp_accel_constraint_filters() {
        if !artifacts_available() {
            return;
        }
        let mut accel = LdpAccel::discover().unwrap();
        let workers = mk_workers(64);
        // Vivaldi constraint: within 15 ms of the origin.
        let cons = LdpConstraintRow {
            geo_lat_rad: 0.84,
            geo_lon_rad: 0.20,
            viv: [0.0; 4],
            geo_thr_km: 100_000.0,
            viv_thr_ms: 15.0,
            active: true,
        };
        let (_, mask) = accel.score(&workers, [0.5, 0.2, 0.0], 0, &[cons]).unwrap();
        for (w, m) in workers.iter().zip(mask.iter()) {
            let d = (w.viv[0].powi(2) + w.viv[1].powi(2)).sqrt();
            assert_eq!(*m, d <= 15.0, "viv dist {d}");
        }
        // Inactive constraint row: everything feasible again.
        let inactive = LdpConstraintRow {
            active: false,
            ..cons
        };
        let (_, mask2) = accel
            .score(&workers, [0.5, 0.2, 0.0], 0, &[inactive])
            .unwrap();
        assert!(mask2.iter().all(|m| *m));
    }

    #[test]
    fn ldp_accel_uses_larger_variant_beyond_512() {
        if !artifacts_available() {
            return;
        }
        let mut accel = LdpAccel::discover().unwrap();
        let workers = mk_workers(600);
        let (scores, mask) = accel.score(&workers, [0.5, 0.2, 0.0], 0, &[]).unwrap();
        assert_eq!(scores.len(), 600);
        assert!(mask.iter().all(|m| *m));
        let best = accel.best(&workers, [0.5, 0.2, 0.0], 0, &[]).unwrap();
        assert!(best.is_some());
    }

    #[test]
    fn vivaldi_embed_artifact_recovers_structure() {
        if !artifacts_available() {
            return;
        }
        let mut emb = VivaldiEmbed::new(PjrtEngine::discover().unwrap());
        // 3-node line within a padded 8-node matrix.
        let mut rtt = vec![vec![0.0; 8]; 8];
        rtt[0][1] = 50.0;
        rtt[1][0] = 50.0;
        rtt[1][2] = 50.0;
        rtt[2][1] = 50.0;
        rtt[0][2] = 100.0;
        rtt[2][0] = 100.0;
        let coords = emb.embed(&rtt).unwrap();
        let d = |a: [f64; 4], b: [f64; 4]| -> f64 {
            a.iter()
                .zip(b.iter())
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f64>()
                .sqrt()
        };
        // 16 steps won't fully converge; structure must still order:
        let d01 = d(coords[0], coords[1]);
        let d02 = d(coords[0], coords[2]);
        assert!(d02 > d01, "d02={d02} d01={d01}");
    }

    #[test]
    fn detector_runs_and_is_deterministic() {
        if !artifacts_available() {
            return;
        }
        let mut det = Detector::discover().unwrap();
        let frames: Vec<f32> = (0..64 * 64 * 3).map(|i| (i % 255) as f32 / 255.0).collect();
        let g1 = det.detect(&frames, 1).unwrap();
        let g2 = det.detect(&frames, 1).unwrap();
        assert_eq!(g1.len(), 1);
        assert_eq!(g1[0].len(), 8 * 8 * 5);
        assert_eq!(g1, g2);
        assert!(g1[0].iter().all(|v| v.is_finite()));
    }
}
