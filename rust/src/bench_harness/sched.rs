//! Fig. 6 (root+cluster scheduling time vs cluster/worker factorization),
//! Fig. 8a (ROM vs LDP in the 10-worker HPC testbed) and Fig. 8b (LDP at
//! up to 500 simulated edge servers, host vs PJRT-accelerated path).

// lint: allow(ambient-time, bench harness measures real wall-clock scheduler cost)
use std::time::Instant;

use crate::geo::GeoPoint;
use crate::metrics::Table;
use crate::model::{NodeClass, NodeProfile, WorkerSpec};
use crate::scheduler::{
    LdpContext, LdpScheduler, Placement, PlacementInput, RomScheduler, RomStrategy,
    TaskScheduler,
};
use crate::sla::{simple_sla, S2uConstraint, ServiceSla, TaskSla};
use crate::util::{mean, NodeId, Rng, ServiceId};
use crate::vivaldi::{Coord, VivaldiState};

/// A synthetic edge fabric: workers scattered geographically with a
/// latency plane whose Euclidean metric *is* the RTT (an ideal Vivaldi
/// embedding; the real embedding's error shows up in Fig. 8b's "lapses").
pub struct SyntheticFabric {
    pub workers: Vec<NodeProfile>,
    /// Ground-truth latency-plane position per worker (ms units).
    pub plane: Vec<[f64; 2]>,
    pub user_plane: [f64; 2],
    pub user_geo: GeoPoint,
}

pub fn synthetic_fabric(n: usize, seed: u64) -> SyntheticFabric {
    let mut rng = Rng::seeded(seed);
    let mut workers = Vec::with_capacity(n);
    let mut plane = Vec::with_capacity(n);
    for i in 0..n {
        // Latency plane: RTTs between 10 and 250 ms across the fabric
        // (paper: typical user↔cloud latency range).
        let p = [rng.range(0.0, 180.0), rng.range(0.0, 180.0)];
        let spec = WorkerSpec {
            node: NodeId(i as u32),
            class: if rng.chance(0.5) {
                NodeClass::M
            } else {
                NodeClass::L
            },
            // ~300 km metro region.
            location: GeoPoint::from_degrees(
                47.0 + rng.range(0.0, 2.5),
                10.5 + rng.range(0.0, 3.5),
            ),
        };
        let mut prof = NodeProfile::new(spec);
        prof.used = crate::model::Capacity::new(
            (rng.range(0.0, 1500.0)) as u32,
            (rng.range(0.0, 1024.0)) as u32,
            0,
        );
        prof.vivaldi = VivaldiState {
            coord: Coord([p[0], p[1], 0.0, 0.0]),
            error: 0.2,
        };
        workers.push(prof);
        plane.push(p);
    }
    let user_plane = [90.0, 90.0];
    SyntheticFabric {
        workers,
        plane,
        user_plane,
        user_geo: GeoPoint::from_degrees(48.1, 11.6),
    }
}

/// The paper's §7.3 SLA: 1 CPU, 100 MB, ≈20 ms latency, 120 km distance.
pub fn paper_sla() -> ServiceSla {
    let mut sla = simple_sla("fig8", 1000, 100);
    sla.constraints[0].s2u.push(S2uConstraint {
        user_location: GeoPoint::from_degrees(48.1, 11.6),
        geo_threshold_km: 120.0,
        latency_threshold_ms: 20.0,
        probe_count: 8,
    });
    sla
}

fn rtt_to_user(fabric: &SyntheticFabric, idx: usize) -> f64 {
    let p = fabric.plane[idx];
    let u = fabric.user_plane;
    ((p[0] - u[0]).powi(2) + (p[1] - u[1]).powi(2)).sqrt()
}

/// Run one scheduler over the fabric; returns (wall ms, placed idx).
pub fn run_host(
    fabric: &SyntheticFabric,
    sla: &TaskSla,
    ldp: bool,
    seed: u64,
) -> (f64, Option<usize>) {
    let input = PlacementInput {
        sla,
        workers: &fabric.workers,
        service_hint: ServiceId(0),
            exclude: None,
    };
    // lint: allow(ambient-time, wall-clock timing is the measurement itself)
    let t0 = Instant::now();
    let placement = if ldp {
        let plane: Vec<[f64; 2]> = fabric.plane.clone();
        let user = fabric.user_plane;
        let ping = move |node: NodeId, _c: &S2uConstraint| {
            let p = plane[node.0 as usize];
            ((p[0] - user[0]).powi(2) + (p[1] - user[1]).powi(2)).sqrt()
        };
        let ctx0 = LdpContext::default();
        let mut s = LdpScheduler::new(&ctx0, Box::new(ping), seed);
        s.place(&input)
    } else {
        let mut s = RomScheduler {
            strategy: RomStrategy::BestFit,
        };
        s.place(&input)
    };
    let wall = t0.elapsed().as_secs_f64() * 1000.0;
    let placed = match placement {
        Placement::Placed { worker, .. } => Some(worker.0 as usize),
        Placement::Infeasible => None,
    };
    (wall, placed)
}

/// Fig. 8a: ROM vs LDP calculation time and SLA satisfaction on 2–10
/// workers (HPC scale). `reps` independent fabrics per point.
pub fn fig8a_schedulers_hpc(sizes: &[usize], reps: usize) -> Table {
    let mut t = Table::new(
        "Fig 8a — scheduler calc time (ms) + SLA satisfaction, HPC scale",
        &[
            "workers",
            "rom_ms",
            "ldp_ms",
            "rom_rtt_ms",
            "ldp_rtt_ms",
            "ldp_lat_sla_ok",
            "ldp_geo_sla_ok",
        ],
    );
    let sla = paper_sla();
    for &n in sizes {
        let mut rom_ms = Vec::new();
        let mut ldp_ms = Vec::new();
        let mut rom_rtt = Vec::new();
        let mut ldp_rtt = Vec::new();
        let mut lat_ok = 0usize;
        let mut geo_ok = 0usize;
        let mut placed_n = 0usize;
        for r in 0..reps {
            let fabric = synthetic_fabric(n, 100 + r as u64);
            let (tw, p) = run_host(&fabric, &sla.constraints[0], false, r as u64);
            rom_ms.push(tw);
            if let Some(i) = p {
                rom_rtt.push(rtt_to_user(&fabric, i));
            }
            let (tw, p) = run_host(&fabric, &sla.constraints[0], true, r as u64);
            ldp_ms.push(tw);
            if let Some(i) = p {
                placed_n += 1;
                let rtt = rtt_to_user(&fabric, i);
                ldp_rtt.push(rtt);
                if rtt <= 20.0 * 1.25 {
                    lat_ok += 1;
                }
                if fabric.workers[i]
                    .spec
                    .location
                    .distance_km(&fabric.user_geo)
                    <= 120.0
                {
                    geo_ok += 1;
                }
            }
        }
        t.row(vec![
            n.to_string(),
            format!("{:.4}", mean(&rom_ms)),
            format!("{:.4}", mean(&ldp_ms)),
            format!("{:.1}", mean(&rom_rtt)),
            format!("{:.1}", mean(&ldp_rtt)),
            format!("{}/{placed_n}", lat_ok),
            format!("{}/{placed_n}", geo_ok),
        ]);
    }
    t
}

/// Fig. 8b: LDP calc time + achieved RTT at 50–500 workers; includes the
/// PJRT-accelerated batch path when artifacts are available.
pub fn fig8b_schedulers_scale(sizes: &[usize], reps: usize) -> Table {
    let mut t = Table::new(
        "Fig 8b — LDP at scale: calc time (ms) and achieved RTT (ms)",
        &[
            "workers",
            "ldp_host_ms",
            "ldp_pjrt_ms",
            "rom_rtt_ms",
            "ldp_rtt_ms",
            "ldp_lat_sla_ok",
        ],
    );
    let sla = paper_sla();
    let mut accel = crate::runtime::LdpAccel::discover().ok();
    // Warm both artifact variants so PJRT compilation (a one-off, build-
    // time-equivalent cost) stays out of the per-placement timings.
    if let Some(acc) = accel.as_mut() {
        for warm_n in [1usize, 1000] {
            let rows = vec![
                crate::runtime::LdpWorkerRow {
                    cpu: 1.0,
                    mem: 1.0,
                    disk: 1.0,
                    virt_bits: 1,
                    lat_rad: 0.0,
                    lon_rad: 0.0,
                    viv: [0.0; 4],
                };
                warm_n
            ];
            let _ = acc.score(&rows, [0.5, 0.5, 0.0], 1, &[]);
        }
    }
    for &n in sizes {
        let mut host_ms = Vec::new();
        let mut pjrt_ms = Vec::new();
        let mut rom_rtt = Vec::new();
        let mut ldp_rtt = Vec::new();
        let mut lat_ok = 0usize;
        let mut placed_n = 0usize;
        for r in 0..reps {
            let fabric = synthetic_fabric(n, 200 + r as u64);
            let (tw, p_rom) = run_host(&fabric, &sla.constraints[0], false, r as u64);
            let _ = tw;
            if let Some(i) = p_rom {
                rom_rtt.push(rtt_to_user(&fabric, i));
            }
            let (tw, p_ldp) = run_host(&fabric, &sla.constraints[0], true, r as u64);
            host_ms.push(tw);
            if let Some(i) = p_ldp {
                placed_n += 1;
                let rtt = rtt_to_user(&fabric, i);
                ldp_rtt.push(rtt);
                if rtt <= 25.0 {
                    lat_ok += 1;
                }
            }

            if let Some(acc) = accel.as_mut() {
                // Batch path: user position known exactly in the plane
                // (trilateration runs inside the artifact for S2U in the
                // live path; here the constraint row carries the target).
                let rows: Vec<crate::runtime::LdpWorkerRow> = fabric
                    .workers
                    .iter()
                    .map(|w| crate::runtime::LdpWorkerRow {
                        cpu: w.available().cpu_millicores as f32 / 1000.0,
                        mem: w.available().mem_mb as f32 / 1024.0,
                        disk: 10.0,
                        virt_bits: 0b1111,
                        lat_rad: w.spec.location.lat as f32,
                        lon_rad: w.spec.location.lon as f32,
                        viv: [
                            w.vivaldi.coord.0[0] as f32,
                            w.vivaldi.coord.0[1] as f32,
                            0.0,
                            0.0,
                        ],
                    })
                    .collect();
                let cons = crate::runtime::LdpConstraintRow {
                    geo_lat_rad: fabric.user_geo.lat as f32,
                    geo_lon_rad: fabric.user_geo.lon as f32,
                    viv: [
                        fabric.user_plane[0] as f32,
                        fabric.user_plane[1] as f32,
                        0.0,
                        0.0,
                    ],
                    geo_thr_km: 120.0,
                    viv_thr_ms: 20.0,
                    active: true,
                };
                // lint: allow(ambient-time, times the real PJRT execution)
                let t0 = Instant::now();
                let _ = acc.best(&rows, [1.0, 100.0 / 1024.0, 0.0], 0b0001, &[cons]);
                pjrt_ms.push(t0.elapsed().as_secs_f64() * 1000.0);
            }
        }
        t.row(vec![
            n.to_string(),
            format!("{:.3}", mean(&host_ms)),
            if pjrt_ms.is_empty() {
                "n/a".into()
            } else {
                format!("{:.3}", mean(&pjrt_ms))
            },
            format!("{:.1}", mean(&rom_rtt)),
            format!("{:.1}", mean(&ldp_rtt)),
            format!("{lat_ok}/{placed_n}"),
        ]);
    }
    t
}

/// Fig. 6: total scheduling time (root + cluster) for a fixed 45-worker
/// fabric factored into different (clusters × workers/cluster) shapes.
///
/// The root scores each cluster's aggregate; the selected cluster runs
/// LDP over its local worker table. Reported time is the calibrated
/// control-plane cost model used throughout the simulator
/// ([`crate::coordinator::costs`]: per-cluster root scoring, per-worker
/// LDP math, one trilateration solve) plus one intra-testbed delegation
/// round trip — the same quantities the paper measures end to end. The
/// minimum lands around 9 clusters × 5 workers, matching Fig. 6.
pub fn fig6_cluster_ratio(total_workers: usize, reps: usize) -> Table {
    let mut t = Table::new(
        "Fig 6 — scheduling time (ms) vs clusters × workers/cluster",
        &["clusters", "workers_per_cluster", "root_ms", "cluster_ms", "total_ms"],
    );
    let sla = paper_sla();
    let mut shapes: Vec<(usize, usize)> = Vec::new();
    for c in 1..=total_workers {
        if total_workers % c == 0 {
            shapes.push((c, total_workers / c));
        }
    }
    for (clusters, per) in shapes {
        let mut root_ms = Vec::new();
        let mut cluster_ms = Vec::new();
        for r in 0..reps {
            // Build per-cluster fabrics and their aggregates.
            let fabrics: Vec<SyntheticFabric> = (0..clusters)
                .map(|c| synthetic_fabric(per, 300 + (r * 64 + c) as u64))
                .collect();
            let aggs: Vec<crate::hierarchy::AggregateStats> = fabrics
                .iter()
                .map(|f| {
                    let avail: Vec<(crate::model::Capacity, crate::model::Virtualization)> =
                        f.workers
                            .iter()
                            .map(|w| (w.available(), w.spec.virtualization()))
                            .collect();
                    crate::hierarchy::AggregateStats::from_workers(
                        avail.iter().map(|(c, v)| (c, *v)),
                        None,
                    )
                })
                .collect();
            let pairs: Vec<(crate::util::ClusterId, &crate::hierarchy::AggregateStats)> =
                aggs.iter()
                    .enumerate()
                    .map(|(i, a)| (crate::util::ClusterId(i as u32 + 1), a))
                    .collect();

            let ranked = crate::scheduler::rank_clusters(&sla.constraints[0], &pairs);
            // Root-tier cost: score every cluster aggregate + one
            // delegation round trip over the HPC LAN.
            let root_cost = crate::coordinator::costs::ROOT_SCHED_PER_CLUSTER_MS
                * clusters as f64
                + 2.0 * 0.25;
            root_ms.push(root_cost);

            if let Some(best) = ranked.first() {
                let f = &fabrics[(best.cluster.0 - 1) as usize];
                // Validate the placement actually succeeds on this fabric;
                // the reported cost is the calibrated LDP model.
                let (_, placed) = run_host(f, &sla.constraints[0], true, r as u64);
                let _ = placed;
                let cost = crate::coordinator::costs::LDP_PER_WORKER_MS * per as f64
                    + crate::coordinator::costs::LDP_TRILATERATION_MS;
                cluster_ms.push(cost);
            }
        }
        t.row(vec![
            clusters.to_string(),
            per.to_string(),
            format!("{:.4}", mean(&root_ms)),
            format!("{:.4}", mean(&cluster_ms)),
            format!("{:.4}", mean(&root_ms) + mean(&cluster_ms)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ldp_meets_latency_sla_rom_does_not() {
        let sla = paper_sla();
        let mut ldp_hits = 0;
        let mut rom_rtts = Vec::new();
        let mut ldp_rtts = Vec::new();
        for r in 0..10 {
            let fabric = synthetic_fabric(100, 500 + r);
            let (_, p_rom) = run_host(&fabric, &sla.constraints[0], false, r);
            let (_, p_ldp) = run_host(&fabric, &sla.constraints[0], true, r);
            if let Some(i) = p_rom {
                rom_rtts.push(rtt_to_user(&fabric, i));
            }
            if let Some(i) = p_ldp {
                let rtt = rtt_to_user(&fabric, i);
                ldp_rtts.push(rtt);
                if rtt <= 25.0 {
                    ldp_hits += 1;
                }
            }
        }
        assert!(!ldp_rtts.is_empty());
        assert!(
            ldp_hits as f64 / ldp_rtts.len() as f64 > 0.8,
            "LDP should usually satisfy the 20 ms SLA ({ldp_hits}/{})",
            ldp_rtts.len()
        );
        assert!(
            mean(&rom_rtts) > 2.0 * mean(&ldp_rtts),
            "ROM rtt {:.1} should be far worse than LDP {:.1}",
            mean(&rom_rtts),
            mean(&ldp_rtts)
        );
    }

    #[test]
    fn ldp_cost_grows_with_fabric_size() {
        let sla = paper_sla();
        let time = |n: usize| {
            let fabric = synthetic_fabric(n, 7);
            // median of 5 to de-noise wall clock
            let mut ts: Vec<f64> = (0..5)
                .map(|r| run_host(&fabric, &sla.constraints[0], true, r).0)
                .collect();
            ts.sort_by(f64::total_cmp);
            ts[2]
        };
        let t50 = time(50);
        let t500 = time(500);
        assert!(t500 > t50, "t500={t500} t50={t50}");
    }

    #[test]
    fn fig6_has_interior_minimum() {
        let t = fig6_cluster_ratio(45, 3);
        let totals: Vec<f64> = t.rows.iter().map(|r| r[4].parse().unwrap()).collect();
        let min_idx = totals
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        // Neither the 1×45 nor the 45×1 extreme should be optimal.
        assert!(min_idx != 0 && min_idx != totals.len() - 1, "totals={totals:?}");
    }
}
