//! Fig. 9: networking experiments. Left — client→closest-server RTT under
//! each platform's balancing behaviour, exercising the real NetManager
//! components (conversion table, balancing policies, ProxyTUN). Right —
//! 100 MB transfer time through Oakestra's L4 tunnel vs WireGuard across
//! a delay sweep.

use crate::metrics::Table;
use crate::netmanager::{
    pick_instance, tunnel_transfer_time, ConversionTable, ProxyTun, ServiceIp,
    TableEntry, HANDSHAKE_MS, OAK_PKT_OVERHEAD_MS, WG_PKT_OVERHEAD_MS,
};
use crate::sim::{LinkProfile, Network};
use crate::util::{mean, InstanceId, NodeId, Rng, ServiceId, SimTime, TaskId};

fn tid() -> TaskId {
    TaskId {
        service: ServiceId(1),
        index: 0,
    }
}

/// Fig. 9 (left): mean request RTT from a client to an Nginx service with
/// `replicas` instances scattered over the fabric. Oakestra resolves the
/// `closest` ServiceIP through the conversion table and tunnels;
/// Kubernetes-family balancers (kube-proxy) spread round-robin and pay
/// their platform's proxy overhead.
pub fn fig9_left_closest_rtt(replica_counts: &[usize], reqs: usize) -> Table {
    let mut t = Table::new(
        "Fig 9 (left) — client→server request RTT (ms) by platform",
        &["replicas", "oakestra", "k3s", "k8s", "microk8s"],
    );
    // Per-request proxy/dataplane overhead (ms): Oakestra's userspace
    // ProxyTUN vs kube-proxy iptables paths on constrained nodes (the
    // paper attributes K8s/MicroK8s's poor showing to their co-resident
    // control-plane load on S VMs).
    const OAK_PROXY_MS: f64 = 2.0 * OAK_PKT_OVERHEAD_MS * 4.0; // 4 pkts/req
    const K3S_PROXY_MS: f64 = 0.15;
    const K8S_PROXY_MS: f64 = 9.0;
    const MK8S_PROXY_MS: f64 = 12.0;

    for &replicas in replica_counts {
        let mut rng = Rng::seeded(900 + replicas as u64);
        // Scatter replica RTTs from the client: 5..60 ms.
        let rtts: Vec<f64> = (0..replicas).map(|_| rng.range(5.0, 60.0)).collect();

        // Oakestra: conversion table with per-instance Vivaldi RTTs; the
        // client's gateway resolves `closest`, then tunnels (handshake on
        // first use only).
        let mut table = ConversionTable::default();
        table.apply(TableEntry {
            task: tid(),
            locations: rtts
                .iter()
                .enumerate()
                .map(|(i, r)| crate::netmanager::InstanceLocation {
                    instance: InstanceId(i as u64),
                    task: tid(),
                    node: NodeId(10 + i as u32),
                    rtt_ms: *r,
                })
                .collect(),
        });
        let mut tun = ProxyTun::default();
        let mut oak = Vec::new();
        for q in 0..reqs {
            let loc = pick_instance(&mut table, &ServiceIp::Closest(tid())).unwrap();
            let setup = tun.activate(loc.node, SimTime::from_millis(q as f64));
            oak.push(loc.rtt_ms + OAK_PROXY_MS + setup.as_millis());
        }

        // Flat platforms: round-robin over replicas + their proxy cost.
        let flat = |proxy_ms: f64| {
            let mut vals = Vec::new();
            for q in 0..reqs {
                vals.push(rtts[q % replicas] + proxy_ms);
            }
            mean(&vals)
        };
        let k3s = flat(K3S_PROXY_MS);
        let k8s = flat(K8S_PROXY_MS);
        let mk8s = flat(MK8S_PROXY_MS);

        t.row(vec![
            replicas.to_string(),
            format!("{:.1}", mean(&oak)),
            format!("{k3s:.1}"),
            format!("{k8s:.1}"),
            format!("{mk8s:.1}"),
        ]);
    }
    t
}

/// Fig. 9 (right): time to download 100 MB through each tunnel as the
/// client↔server delay grows. TCP throughput limits from the Mathis
/// model meet each tunnel's per-packet cost.
pub fn fig9_right_tunnel_transfer(delays_ms: &[f64], loss: f64) -> Table {
    let mut t = Table::new(
        "Fig 9 (right) — 100 MB transfer time (s): Oakestra tunnel vs WireGuard",
        &["delay_ms", "oakestra_s", "wireguard_s", "wg_advantage"],
    );
    const BYTES: u64 = 100 << 20;
    for &d in delays_ms {
        let mut net = Network::default();
        net.set_default(LinkProfile::wan(d, 0.0, loss));
        let tput = net.tcp_throughput_mbps(NodeId(0), NodeId(1));
        let oak = tunnel_transfer_time(BYTES, tput, OAK_PKT_OVERHEAD_MS).as_secs()
            + 2.0 * d / 1000.0
            + HANDSHAKE_MS / 1000.0;
        let wg = tunnel_transfer_time(BYTES, tput, WG_PKT_OVERHEAD_MS).as_secs()
            + 2.0 * d / 1000.0
            + HANDSHAKE_MS / 1000.0;
        t.row(vec![
            format!("{d:.0}"),
            format!("{oak:.1}"),
            format!("{wg:.1}"),
            format!("{:.1}%", (oak / wg - 1.0) * 100.0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closest_beats_round_robin_with_replicas() {
        let t = fig9_left_closest_rtt(&[1, 4], 200);
        let one = &t.rows[0];
        let four = &t.rows[1];
        let v = |r: &Vec<String>, i: usize| r[i].parse::<f64>().unwrap();
        // Single replica: K3s within ~10–20% better (no tunnel overhead).
        assert!(v(one, 2) <= v(one, 1), "k3s {} vs oak {}", v(one, 2), v(one, 1));
        // Multiple replicas: Oakestra's closest policy wins clearly.
        assert!(
            v(four, 1) < v(four, 2),
            "oak {} should beat k3s {} at 4 replicas",
            v(four, 1),
            v(four, 2)
        );
        // Heavy platforms are worst everywhere.
        assert!(v(four, 4) > v(four, 2));
    }

    #[test]
    fn wireguard_gap_closes_with_delay() {
        let t = fig9_right_tunnel_transfer(&[10.0, 100.0, 250.0], 0.0);
        let parse = |s: &str| s.trim_end_matches('%').parse::<f64>().unwrap();
        let low = parse(&t.rows[0][3]);
        let high = parse(&t.rows[2][3]);
        assert!(low > 3.0, "at 10 ms WireGuard should lead: {low}%");
        assert!(high < low, "gap must shrink with delay: {low}% -> {high}%");
        assert!(high < 5.0, "at 250 ms the gap should be small: {high}%");
    }
}
