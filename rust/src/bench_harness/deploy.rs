//! Fig. 4a (deployment time vs cluster size, scheduler on/off) and
//! Fig. 5 (deployment time under network impairment, HET testbed).

use crate::baselines::FrameworkProfile;
use crate::coordinator::SchedulerKind;
use crate::metrics::Table;
use crate::sla::simple_sla;
use crate::util::{mean, ServiceId, SimTime};

use super::testbed::{build_flat, build_oakestra, OakTestbedConfig};

/// Deploy `reps` tracker apps sequentially on an Oakestra testbed and
/// return the mean deployment time (ms).
fn oakestra_deploy_ms(
    seed: u64,
    workers: usize,
    scheduler: SchedulerKind,
    heterogeneous: bool,
    impair_delay_ms: f64,
    impair_loss: f64,
    reps: usize,
) -> f64 {
    let mut tb = build_oakestra(OakTestbedConfig {
        seed,
        clusters: 1,
        workers_per_cluster: workers,
        scheduler,
        heterogeneous,
        ..OakTestbedConfig::default()
    });
    tb.warm_up();
    tb.sim.core.net.impair_all(impair_delay_ms, impair_loss);
    for r in 0..reps {
        tb.submit(
            simple_sla(&format!("tracker-{r}"), 50, 32),
            SimTime::from_secs(13.0 + 3.0 * r as f64),
        );
    }
    tb.sim
        .run_until(SimTime::from_secs(13.0 + 3.0 * reps as f64 + 30.0));
    let times = tb.deploy_times_ms();
    mean(&times)
}

/// Same for a flat baseline.
fn flat_deploy_ms(
    profile: FrameworkProfile,
    seed: u64,
    workers: usize,
    heterogeneous: bool,
    impair_delay_ms: f64,
    impair_loss: f64,
    reps: usize,
) -> f64 {
    let mut tb = build_flat(
        profile,
        seed,
        workers,
        crate::model::NodeClass::S,
        heterogeneous,
        2_000.0,
    );
    tb.warm_up();
    tb.sim.core.net.impair_all(impair_delay_ms, impair_loss);
    for r in 0..reps {
        tb.submit_pod(
            ServiceId(1 + r as u32),
            None,
            SimTime::from_secs(13.0 + 3.0 * r as f64),
        );
    }
    tb.sim
        .run_until(SimTime::from_secs(13.0 + 3.0 * reps as f64 + 30.0));
    mean(&tb.deploy_times_ms())
}

/// "no scheduler" variants: Oakestra falls back to first-fit with zero
/// scoring; baselines get a near-instant scheduler poll and free scoring.
fn ns_profile(mut p: FrameworkProfile) -> FrameworkProfile {
    p.sched_per_node_ms = 0.0;
    p.sched_poll_ms = 10.0;
    p
}

/// Fig. 4a: mean service deployment time vs cluster size for each
/// framework, with (s) and without (ns) the scheduler.
pub fn fig4a_deploy_time(sizes: &[usize], reps: usize) -> Table {
    let mut t = Table::new(
        "Fig 4a — service deployment time (ms) vs cluster size",
        &[
            "workers",
            "oakestra_s",
            "oakestra_ns",
            "k3s_s",
            "k3s_ns",
            "k8s_s",
            "k8s_ns",
            "microk8s_s",
            "microk8s_ns",
        ],
    );
    // Average every cell over several independent seeds (the paper
    // repeats each experiment ≥10×, §7.1).
    const SEEDS: u64 = 3;
    for &n in sizes {
        let oak = |sched: SchedulerKind, base: u64| {
            let v: Vec<f64> = (0..SEEDS)
                .map(|s| oakestra_deploy_ms(base + s, n, sched, false, 0.0, 0.0, reps))
                .collect();
            mean(&v)
        };
        let row = |p: FrameworkProfile, base: u64| {
            let v: Vec<f64> = (0..SEEDS)
                .map(|s| flat_deploy_ms(p.clone(), base + s, n, false, 0.0, 0.0, reps))
                .collect();
            mean(&v)
        };
        let oak_s = oak(SchedulerKind::RomBestFit, 42);
        let oak_ns = oak(SchedulerKind::RomFirstFit, 52);
        let k3s_s = row(FrameworkProfile::k3s(), 62);
        let k3s_ns = row(ns_profile(FrameworkProfile::k3s()), 72);
        let k8s_s = row(FrameworkProfile::kubernetes(), 82);
        let k8s_ns = row(ns_profile(FrameworkProfile::kubernetes()), 92);
        let mk_s = row(FrameworkProfile::microk8s(), 102);
        let mk_ns = row(ns_profile(FrameworkProfile::microk8s()), 112);
        t.row(vec![
            n.to_string(),
            format!("{oak_s:.0}"),
            format!("{oak_ns:.0}"),
            format!("{k3s_s:.0}"),
            format!("{k3s_ns:.0}"),
            format!("{k8s_s:.0}"),
            format!("{k8s_ns:.0}"),
            format!("{mk_s:.0}"),
            format!("{mk_ns:.0}"),
        ]);
    }
    t
}

/// Fig. 5: Oakestra vs K3s deployment time in the HET testbed as `tc`
/// adds delay (and a loss variant the paper describes in prose: ~50%/60%
/// reduction at 20%/50% loss).
pub fn fig5_network_degradation(delays_ms: &[f64], reps: usize) -> (Table, Table) {
    let mut t = Table::new(
        "Fig 5 — HET deployment time (ms) vs added network delay",
        &["delay_ms", "oakestra", "k3s", "k3s/oakestra"],
    );
    for &d in delays_ms {
        let oakv: Vec<f64> = (0..3)
            .map(|s| oakestra_deploy_ms(152 + s, 6, SchedulerKind::RomBestFit, true, d, 0.0, reps))
            .collect();
        let k3sv: Vec<f64> = (0..3)
            .map(|s| flat_deploy_ms(FrameworkProfile::k3s(), 153 + s, 6, true, d, 0.0, reps))
            .collect();
        let oak = mean(&oakv);
        let k3s = mean(&k3sv);
        t.row(vec![
            format!("{d:.0}"),
            format!("{oak:.0}"),
            format!("{k3s:.0}"),
            format!("{:.2}", k3s / oak),
        ]);
    }
    let mut l = Table::new(
        "Fig 5 (prose) — HET deployment time (ms) vs packet loss",
        &["loss", "oakestra", "k3s", "reduction"],
    );
    for &loss in &[0.0, 0.2, 0.5] {
        let oak = oakestra_deploy_ms(54, 6, SchedulerKind::RomBestFit, true, 0.0, loss, reps);
        let k3s = flat_deploy_ms(FrameworkProfile::k3s(), 55, 6, true, 0.0, loss, reps);
        l.row(vec![
            format!("{loss:.0}%", loss = loss * 100.0),
            format!("{oak:.0}"),
            format!("{k3s:.0}"),
            format!("{:.0}%", (1.0 - oak / k3s) * 100.0),
        ]);
    }
    (t, l)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4a_shape_holds() {
        // Container-start noise is exponential; average across seeds ×
        // reps (the paper repeats every experiment ≥10×, §7.1).
        let avg = |f: &dyn Fn(u64) -> f64| {
            let v: Vec<f64> = (0..4).map(|s| f(s)).collect();
            crate::util::mean(&v)
        };
        let oak = avg(&|s| oakestra_deploy_ms(s, 6, SchedulerKind::RomBestFit, false, 0.0, 0.0, 4));
        let k3s = avg(&|s| flat_deploy_ms(FrameworkProfile::k3s(), s, 6, false, 0.0, 0.0, 4));
        let mk8s = flat_deploy_ms(FrameworkProfile::microk8s(), 3, 6, false, 0.0, 0.0, 4);
        let k8s = flat_deploy_ms(FrameworkProfile::kubernetes(), 4, 6, false, 0.0, 0.0, 4);
        // Paper: "K3s's performance closely matched Oakestra" on the LAN
        // testbed — they separate under network degradation (Fig. 5).
        assert!(oak < 1.2 * k3s, "oakestra {oak} should match/beat k3s {k3s}");
        assert!(k3s < k8s, "k3s {k3s} should beat k8s {k8s}");
        assert!(mk8s > 5.0 * oak, "microk8s {mk8s} should be ≫ oakestra {oak}");
        // Oakestra stays flat with size (container-start noise is the
        // dominant variance; average across seeds before comparing).
        let oak6 = avg(&|s| oakestra_deploy_ms(s, 6, SchedulerKind::RomBestFit, false, 0.0, 0.0, 4));
        let oak10 = avg(&|s| oakestra_deploy_ms(s, 10, SchedulerKind::RomBestFit, false, 0.0, 0.0, 4));
        assert!(
            (oak10 - oak6).abs() / oak6 < 0.4,
            "oak6={oak6} oak10={oak10}"
        );
    }

    #[test]
    fn fig5_oakestra_wins_under_delay() {
        let oak = oakestra_deploy_ms(5, 4, SchedulerKind::RomBestFit, true, 100.0, 0.0, 2);
        let k3s = flat_deploy_ms(FrameworkProfile::k3s(), 6, 4, true, 100.0, 0.0, 2);
        assert!(
            k3s > 1.15 * oak,
            "k3s {k3s} should exceed oakestra {oak} by ≥15% at 100 ms delay"
        );
    }
}
