//! Testbed assembly: build a full Oakestra deployment (root + clusters +
//! workers + driver) or a flat baseline deployment inside one simulator,
//! mirroring the paper's HPC/HET experiment setups (§7.1: XL VM root,
//! L VM cluster orchestrator / master, S VM workers).

use crate::api::{ApiClient, ApiRequest, ApiResponse};
use crate::baselines::{FlatKubelet, FlatMaster, FrameworkProfile};
use crate::coordinator::{
    ClusterConfig, ClusterOrchestrator, RootConfig, RootOrchestrator, SchedulerKind,
    WorkerConfig, WorkerEngine,
};
use crate::geo::GeoPoint;
use crate::model::{Capacity, NodeClass, WorkerSpec};
use crate::sim::{ActorId, LinkProfile, OakMsg, Sim, SimMsg, TimerKind};
use crate::util::{ClusterId, InstanceId, NodeId, ServiceId, SimTime};
use crate::workload::DeployDriver;

/// Which control plane a testbed runs.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Framework {
    Oakestra,
    K8s,
    MicroK8s,
    K3s,
}

impl Framework {
    pub fn name(self) -> &'static str {
        match self {
            Framework::Oakestra => "oakestra",
            Framework::K8s => "k8s",
            Framework::MicroK8s => "microk8s",
            Framework::K3s => "k3s",
        }
    }
    pub fn profile(self) -> Option<FrameworkProfile> {
        match self {
            Framework::Oakestra => None,
            Framework::K8s => Some(FrameworkProfile::kubernetes()),
            Framework::MicroK8s => Some(FrameworkProfile::microk8s()),
            Framework::K3s => Some(FrameworkProfile::k3s()),
        }
    }
    pub fn all() -> [Framework; 4] {
        [
            Framework::Oakestra,
            Framework::K8s,
            Framework::MicroK8s,
            Framework::K3s,
        ]
    }
}

#[derive(Clone, Debug)]
pub struct OakTestbedConfig {
    pub seed: u64,
    pub clusters: usize,
    pub workers_per_cluster: usize,
    pub scheduler: SchedulerKind,
    pub worker_class: NodeClass,
    /// HET testbed: mixed device classes + WiFi links.
    pub heterogeneous: bool,
    /// Fast local registry (pre-warmed images between repeated runs).
    pub registry_mbps: f64,
    /// Lane-sharded event loop: `0` keeps the classic single-lane
    /// sequential sim; `N >= 1` cuts one lane per cluster subtree (plus
    /// the root lane) drained by up to `N` worker threads per window.
    /// The event trace is identical for every `N >= 1`.
    pub threads: usize,
}

impl Default for OakTestbedConfig {
    fn default() -> Self {
        OakTestbedConfig {
            seed: 42,
            clusters: 1,
            workers_per_cluster: 4,
            scheduler: SchedulerKind::RomBestFit,
            worker_class: NodeClass::S,
            heterogeneous: false,
            registry_mbps: 2_000.0,
            threads: 0,
        }
    }
}

/// An assembled Oakestra deployment inside a simulator. All lifecycle
/// operations go through the typed northbound API ([`crate::api`]); the
/// `client` actor records every [`ApiResponse`] and deployment callback.
pub struct OakTestbed {
    pub sim: Sim,
    pub root: ActorId,
    pub root_node: NodeId,
    pub clusters: Vec<(NodeId, ActorId)>,
    /// All worker (node, engine) pairs across clusters.
    pub workers: Vec<(NodeId, ActorId)>,
    /// Worker node → index into `clusters` (owning orchestrator), kept
    /// current across [`OakTestbed::revive_worker`] rebirths.
    pub worker_cluster: std::collections::BTreeMap<NodeId, usize>,
    /// Per-cluster orchestrator incarnation epoch (starts at 1; bumped
    /// by every [`OakTestbed::restart_cluster`]).
    pub cluster_epochs: Vec<u64>,
    /// Next unused simulated-node id (revivals mint fresh identities).
    next_node: u32,
    /// The northbound [`ApiClient`] actor (the "developer").
    pub client: ActorId,
    pub cfg: OakTestbedConfig,
}

/// Geographic scatter used by both testbeds (Munich metro area grid).
pub fn scatter_location(i: usize) -> GeoPoint {
    GeoPoint::from_degrees(
        48.0 + 0.02 * (i % 16) as f64,
        11.4 + 0.03 * (i / 16) as f64,
    )
}

pub fn het_class(i: usize) -> NodeClass {
    match i % 4 {
        0 => NodeClass::RaspberryPi4,
        1 => NodeClass::IntelNuc,
        2 => NodeClass::MiniDesktop,
        _ => NodeClass::JetsonXavier,
    }
}

pub fn build_oakestra(cfg: OakTestbedConfig) -> OakTestbed {
    let mut sim = Sim::new(cfg.seed);
    if cfg.threads > 0 {
        // Lane 0 = root tier (+ client); lane c+1 = cluster c's subtree —
        // the shard boundaries the lane-isolation certificates prove safe.
        sim.shard_lanes(cfg.clusters + 1, cfg.threads);
    }
    sim.set_registry_mbps(cfg.registry_mbps);
    if cfg.heterogeneous {
        sim.core.net.set_default(LinkProfile::wifi());
    } else {
        sim.core.net.set_default(LinkProfile::lan());
    }

    // Node 0: XL root VM (+ the experiment driver process).
    let root_node = NodeId(0);
    sim.add_node(root_node, NodeClass::XL);
    let root = sim.add_actor(root_node, Box::new(RootOrchestrator::new(RootConfig::default())));
    let client = sim.add_actor(root_node, Box::new(ApiClient::new()));

    // Cluster orchestrators on L VMs, workers on S VMs (HPC) or HET mix.
    let mut clusters = Vec::new();
    let mut workers = Vec::new();
    let mut worker_cluster = std::collections::BTreeMap::new();
    let mut next_node = 1u32;
    for c in 0..cfg.clusters {
        let lane = if cfg.threads > 0 { c + 1 } else { 0 };
        let cnode = NodeId(next_node);
        next_node += 1;
        sim.add_node_in_lane(cnode, NodeClass::L, lane);
        let cid = ClusterId(c as u32 + 1);
        let orch = sim.add_actor(
            cnode,
            Box::new(ClusterOrchestrator::new(
                ClusterConfig::new(cid, cfg.scheduler),
                root,
            )),
        );
        clusters.push((cnode, orch));
        // Register cluster at t=1ms.
        sim.inject(
            SimTime::from_millis(1.0),
            orch,
            SimMsg::Timer(TimerKind::Custom(0)),
        );

        for w in 0..cfg.workers_per_cluster {
            let wi = (c * cfg.workers_per_cluster + w) as usize;
            let wnode = NodeId(next_node);
            next_node += 1;
            let class = if cfg.heterogeneous {
                het_class(wi)
            } else {
                cfg.worker_class
            };
            sim.add_node_in_lane(wnode, class, lane);
            let spec = WorkerSpec {
                node: wnode,
                class,
                location: scatter_location(wi),
            };
            let engine = sim.add_actor(
                wnode,
                Box::new(WorkerEngine::new(WorkerConfig::new(spec), orch)),
            );
            workers.push((wnode, engine));
            worker_cluster.insert(wnode, c);
            // Register workers shortly after their cluster.
            sim.inject(
                SimTime::from_millis(20.0 + w as f64),
                engine,
                SimMsg::Timer(TimerKind::Custom(0)),
            );
        }
    }

    // Teach every worker the actor handles of its peers (tunnel endpoint
    // discovery — carried by table entries in a live deployment).
    let pairs: Vec<(NodeId, ActorId)> = workers.clone();
    for (_, engine) in &workers {
        for (n, a) in &pairs {
            if let Some(w) = sim.actor_as_mut::<WorkerEngine>(*engine) {
                w.learn_node_actor(*n, *a);
            }
        }
    }

    let cluster_epochs = vec![1u64; cfg.clusters];
    OakTestbed {
        sim,
        root,
        root_node,
        clusters,
        workers,
        worker_cluster,
        cluster_epochs,
        next_node,
        client,
        cfg,
    }
}

impl OakTestbed {
    /// Let registration + first telemetry settle.
    pub fn warm_up(&mut self) {
        self.sim.run_until(SimTime::from_secs(12.0));
    }

    /// Issue one northbound API call at virtual time `at`; returns the
    /// request id under which responses land on the [`ApiClient`].
    pub fn api(&mut self, request: ApiRequest, at: SimTime) -> u64 {
        let client = self.client;
        let env = self
            .sim
            .actor_as_mut::<ApiClient>(client)
            .expect("testbed client is an ApiClient")
            .envelope(request, client);
        let id = env.request_id;
        self.sim
            // lint: route(root, northbound call addressed to the root orchestrator)
            .inject(at, self.root, SimMsg::Oak(OakMsg::ApiCall(Box::new(env))));
        id
    }

    /// Batched issue: inject a whole wave of API calls at one virtual
    /// instant (churn storms). Returns the request ids in issue order.
    pub fn api_batch(&mut self, requests: Vec<ApiRequest>, at: SimTime) -> Vec<u64> {
        let client = self.client;
        let envs = self
            .sim
            .actor_as_mut::<ApiClient>(client)
            .expect("testbed client is an ApiClient")
            .envelopes(requests, client);
        let ids: Vec<u64> = envs.iter().map(|e| e.request_id).collect();
        for env in envs {
            self.sim
                // lint: route(root, northbound call addressed to the root orchestrator)
                .inject(at, self.root, SimMsg::Oak(OakMsg::ApiCall(Box::new(env))));
        }
        ids
    }

    /// Fault injection: crash-stop one worker node (messages to/from it
    /// are dropped until the cluster's health sweep deregisters it).
    pub fn fail_worker(&mut self, node: NodeId) {
        self.sim.set_node_failed(node, true);
    }

    /// Fault injection: sever cluster `cluster_idx`'s uplink — the
    /// root↔cluster-orchestrator link — for `from <= t < until`. Traffic
    /// inside the cluster subtree keeps flowing, so the cluster operates
    /// autonomously for the window; root-side detection, degraded
    /// marking, and heal-time resync are exercised by the partition
    /// churn scenario. Must be installed before events drain past
    /// `from` (the schedule is seeded, not mutated mid-run).
    pub fn cut_cluster_uplink(&mut self, cluster_idx: usize, from: SimTime, until: SimTime) {
        let cnode = self.clusters[cluster_idx].0;
        self.sim.core.net.cut_link(self.root_node, cnode, from, until);
    }

    /// Fault injection (crash-recovery tentpole): crash-stop cluster
    /// `cluster_idx`'s orchestrator actor. Its entire authoritative
    /// state (worker table, instance table, outbox, migration
    /// bookkeeping) is discarded and every in-flight message addressed
    /// to it is dropped — distinct from [`OakTestbed::fail_worker`],
    /// which kills a *node*; here the node stays up and a fresh process
    /// can take over via [`OakTestbed::restart_cluster`]. Returns the
    /// number of dropped non-timer in-flight messages.
    pub fn crash_cluster(&mut self, cluster_idx: usize) -> usize {
        let orch = self.clusters[cluster_idx].1;
        self.sim.crash_actor(orch)
    }

    /// Cold-restart a crashed cluster orchestrator under the next
    /// incarnation epoch. The new process comes up Recovering with empty
    /// tables, re-registers with the root (epoch-stamped, so the root
    /// takes the fast-restart path instead of a partition escalation)
    /// and solicits worker re-registration — the simulated "broker
    /// connection reset" every worker observes — whose census-carrying
    /// handshakes rebuild the tables bottom-up. Returns the new epoch.
    pub fn restart_cluster(&mut self, cluster_idx: usize) -> u64 {
        let orch = self.clusters[cluster_idx].1;
        self.cluster_epochs[cluster_idx] += 1;
        let epoch = self.cluster_epochs[cluster_idx];
        let cid = ClusterId(cluster_idx as u32 + 1);
        let now = self.sim.now();
        self.sim.restart_actor(
            orch,
            Box::new(ClusterOrchestrator::restarted(
                ClusterConfig::new(cid, self.cfg.scheduler),
                self.root,
                epoch,
                now,
            )),
        );
        self.sim.inject(
            now + SimTime::from_millis(1.0),
            orch,
            SimMsg::Timer(TimerKind::Custom(0)),
        );
        // Broker reconnect staggers like the build-time registration
        // wave: each surviving worker of this cluster re-runs the
        // handshake, census attached. Workers on failed nodes are
        // solicited too — their handshake dies on the (dead) wire,
        // exactly as a real broker reset would play out.
        let mine: Vec<ActorId> = self
            .workers
            .iter()
            .filter(|(n, _)| self.worker_cluster.get(n) == Some(&cluster_idx))
            .map(|(_, a)| *a)
            .collect();
        for (i, engine) in mine.into_iter().enumerate() {
            self.sim.inject(
                now + SimTime::from_millis(5.0 + i as f64),
                engine,
                SimMsg::Timer(TimerKind::Custom(2)),
            );
        }
        epoch
    }

    /// Worker rejoin (ROADMAP: recovery, not just crash-stop): the
    /// hardware behind a crashed worker comes back as a **fresh node id**
    /// with an empty instance set and re-registers with the same cluster
    /// orchestrator through the normal `RegisterWorker` handshake. The
    /// old identity stays dead (its containers died with it); capacity
    /// returns under the new identity. Returns the new node id.
    pub fn revive_worker(&mut self, dead: NodeId) -> NodeId {
        let cluster_idx = *self
            .worker_cluster
            .get(&dead)
            .expect("revive_worker: node was never a worker of this testbed");
        let orch = self.clusters[cluster_idx].1;
        // The *same hardware* returns: reuse the dead worker's class and
        // location under the fresh identity, so rebirths never drift the
        // fleet's capacity mix (important for heterogeneous topologies).
        let dead_engine = self
            .workers
            .iter()
            .find(|(n, _)| *n == dead)
            .map(|(_, a)| *a)
            .expect("revive_worker: dead worker engine");
        let mut spec = self
            .sim
            .actor_as::<WorkerEngine>(dead_engine)
            .expect("worker actor")
            .cfg
            .spec
            .clone();
        let node = NodeId(self.next_node);
        self.next_node += 1;
        spec.node = node;
        // Reborn hardware rejoins its cluster's lane (lane 0 unsharded).
        let lane = if self.sim.lane_count() > 1 {
            cluster_idx + 1
        } else {
            0
        };
        self.sim.add_node_in_lane(node, spec.class, lane);
        let engine = self.sim.add_actor(
            node,
            Box::new(WorkerEngine::new(WorkerConfig::new(spec), orch)),
        );
        // Data-plane peer wiring, both directions (mirrors build-time
        // setup; dead peers are harmless — sends to them are dropped).
        let peers: Vec<(NodeId, ActorId)> = self.workers.clone();
        for (n, a) in &peers {
            if let Some(w) = self.sim.actor_as_mut::<WorkerEngine>(*a) {
                w.learn_node_actor(node, engine);
            }
            if let Some(w) = self.sim.actor_as_mut::<WorkerEngine>(engine) {
                w.learn_node_actor(*n, *a);
            }
        }
        self.workers.push((node, engine));
        self.worker_cluster.insert(node, cluster_idx);
        let at = self.sim.now();
        self.sim
            .inject(at, engine, SimMsg::Timer(TimerKind::Custom(0)));
        node
    }

    /// Submit an SLA through the northbound API; deployment completion
    /// lands on the client ([`ApiClient::deployed`]).
    pub fn submit(&mut self, sla: crate::sla::ServiceSla, at: SimTime) -> u64 {
        self.api(ApiRequest::SubmitService { sla }, at)
    }

    /// Scale one task (or all tasks) of a service to `replicas`.
    pub fn scale(
        &mut self,
        service: ServiceId,
        task: Option<u16>,
        replicas: usize,
        at: SimTime,
    ) -> u64 {
        self.api(
            ApiRequest::ScaleService {
                service,
                task,
                replicas,
            },
            at,
        )
    }

    /// Migrate one running instance away from its current worker.
    pub fn migrate(&mut self, service: ServiceId, instance: InstanceId, at: SimTime) -> u64 {
        self.api(ApiRequest::MigrateInstance { service, instance }, at)
    }

    /// Tear down every live instance of a service.
    pub fn undeploy(&mut self, service: ServiceId, at: SimTime) -> u64 {
        self.api(ApiRequest::UndeployService { service }, at)
    }

    /// Query the full lifecycle status of a service.
    pub fn query_status(&mut self, service: ServiceId, at: SimTime) -> u64 {
        self.api(ApiRequest::ServiceStatus { service }, at)
    }

    /// Enumerate all services.
    pub fn list_services(&mut self, at: SimTime) -> u64 {
        self.api(ApiRequest::ListServices, at)
    }

    /// The client's recorded responses (inspect after `run_until`).
    pub fn api_client(&self) -> &ApiClient {
        self.sim
            .actor_as::<ApiClient>(self.client)
            .expect("testbed client is an ApiClient")
    }

    /// Synchronous ack recorded for one request id, if any.
    pub fn ack(&self, request_id: u64) -> Option<&ApiResponse> {
        self.api_client().ack(request_id)
    }

    pub fn deploy_times_ms(&self) -> Vec<f64> {
        self.api_client()
            .deployed
            .values()
            .map(|t| t.as_millis())
            .collect()
    }
}

/// An assembled flat-baseline deployment (master + kubelets + driver).
pub struct FlatTestbed {
    pub sim: Sim,
    pub master: ActorId,
    pub master_node: NodeId,
    pub kubelets: Vec<(NodeId, ActorId)>,
    pub driver: ActorId,
    pub profile: FrameworkProfile,
}

pub fn build_flat(
    profile: FrameworkProfile,
    seed: u64,
    n_workers: usize,
    worker_class: NodeClass,
    heterogeneous: bool,
    registry_mbps: f64,
) -> FlatTestbed {
    let mut sim = Sim::new(seed);
    sim.set_registry_mbps(registry_mbps);
    if heterogeneous {
        sim.core.net.set_default(LinkProfile::wifi());
    } else {
        sim.core.net.set_default(LinkProfile::lan());
    }
    let master_node = NodeId(0);
    sim.add_node(master_node, NodeClass::L);
    let master = sim.add_actor(master_node, Box::new(FlatMaster::new(profile.clone())));
    let driver = sim.add_actor(master_node, Box::new(DeployDriver::new(0)));
    let mut kubelets = Vec::new();
    for i in 0..n_workers {
        let node = NodeId(1 + i as u32);
        let class = if heterogeneous {
            het_class(i)
        } else {
            worker_class
        };
        sim.add_node(node, class);
        let k = sim.add_actor(
            node,
            Box::new(FlatKubelet::new(profile.clone(), node, master)),
        );
        kubelets.push((node, k));
        // Bootstrap (the kubelet schedules its own tick chain on first
        // dispatch; injecting KubeletSync here would double the chain).
        sim.inject(
            SimTime::from_millis(20.0 + i as f64),
            k,
            SimMsg::Timer(TimerKind::Custom(0)),
        );
    }
    for (node, k) in &kubelets {
        sim.actor_as_mut::<FlatMaster>(master)
            .unwrap()
            .add_node(*node, *k, worker_class);
    }
    FlatTestbed {
        sim,
        master,
        master_node,
        kubelets,
        driver,
        profile,
    }
}

impl FlatTestbed {
    pub fn warm_up(&mut self) {
        self.sim.run_until(SimTime::from_secs(12.0));
    }

    /// The one submission helper of the baseline path. `None` requests
    /// the default small-pod footprint (100 mc, 32 MB).
    pub fn submit_pod(
        &mut self,
        service: ServiceId,
        request: Option<Capacity>,
        at: SimTime,
    ) {
        let request = request.unwrap_or(Capacity::new(100, 32, 0));
        let driver = self.driver;
        self.sim.inject(
            at,
            self.master,
            SimMsg::Kube(crate::sim::KubeMsg::SubmitPod {
                service,
                request,
                image_mb: 50,
                reply_to: Some(driver),
            }),
        );
    }

    pub fn deploy_times_ms(&self) -> Vec<f64> {
        self.sim
            .actor_as::<DeployDriver>(self.driver)
            .map(|d| {
                d.completed
                    .values()
                    .map(|t| t.as_millis())
                    .collect::<Vec<f64>>()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ServiceState;
    use crate::sla::simple_sla;

    #[test]
    fn oakestra_testbed_deploys_end_to_end() {
        let mut tb = build_oakestra(OakTestbedConfig::default());
        tb.warm_up();
        tb.submit(simple_sla("app", 200, 64), SimTime::from_secs(13.0));
        tb.sim.run_until(SimTime::from_secs(40.0));
        let times = tb.deploy_times_ms();
        assert_eq!(times.len(), 1, "service must reach Running");
        assert!(times[0] > 100.0 && times[0] < 5_000.0, "t={}", times[0]);

        // The root's DB agrees.
        let root = tb
            .sim
            .actor_as::<crate::coordinator::RootOrchestrator>(tb.root)
            .unwrap();
        let rec = root.db.services().next().unwrap();
        assert!(rec.fully_running());
        assert_eq!(rec.instances[0].state, ServiceState::Running);
    }

    #[test]
    fn multi_cluster_testbed_spreads_registration() {
        let mut tb = build_oakestra(OakTestbedConfig {
            clusters: 3,
            workers_per_cluster: 2,
            ..OakTestbedConfig::default()
        });
        tb.warm_up();
        let root = tb
            .sim
            .actor_as::<crate::coordinator::RootOrchestrator>(tb.root)
            .unwrap();
        assert_eq!(root.tree.len(), 3);
        for (_, orch) in &tb.clusters {
            let c = tb
                .sim
                .actor_as::<crate::coordinator::ClusterOrchestrator>(*orch)
                .unwrap();
            assert_eq!(c.workers.len(), 2);
        }
    }

    #[test]
    fn flat_testbed_deploys_end_to_end() {
        let mut tb = build_flat(
            FrameworkProfile::k3s(),
            7,
            4,
            NodeClass::S,
            false,
            2_000.0,
        );
        tb.warm_up();
        tb.submit_pod(crate::util::ServiceId(1), None, SimTime::from_secs(13.0));
        tb.sim.run_until(SimTime::from_secs(40.0));
        assert_eq!(tb.deploy_times_ms().len(), 1);
    }
}
