//! Dynamic-workload churn engine: drives the northbound API v1 with the
//! three storm generators the paper's "absorbs dynamic variations at the
//! edge" claim needs but no static bench exercises (ROADMAP: API-driven
//! dynamic workloads):
//!
//! 1. **Submit/undeploy churn** — a seeded arrival/departure process over
//!    a catalog of Schema-1 SLAs (service lifetimes are exponential, like
//!    the continuously redeployed smart-city services of
//!    arXiv:2407.17314).
//! 2. **Closed-loop autoscaler** — an actor that polls `ServiceStatus`,
//!    tracks a seeded offered-load walk per service and issues
//!    `ScaleService` against hysteresis thresholds.
//! 3. **Failover drills** — `MigrateInstance` calls raced against
//!    injected crash-stop worker failures (mobility-induced migration
//!    pressure, arXiv:2110.07808).
//!
//! The engine measures what the steady-state benches cannot: lifecycle-op
//! latency under churn (submit→Running, scale→converged, migrate→cutover,
//! undeploy→drained — [`crate::metrics::lifecycle`]) and the control
//! plane's per-op message/CPU cost. Everything is seed-deterministic: the
//! same [`ChurnConfig`] yields an identical op log and an identical final
//! placement census, which the integration tests assert.

use std::any::Any;
use std::collections::{BTreeMap, BTreeSet};

use crate::api::{ApiClient, ApiError, ApiRequest, ApiResponse};
use crate::coordinator::{ClusterOrchestrator, RootOrchestrator, SchedulerKind, WorkerEngine};
use crate::metrics::{fmt_stat, lifecycle, Histogram, Table};
use crate::model::ServiceState;
use crate::sim::{Actor, ActorId, Ctx, OakMsg, SimMsg, TimerKind};
use crate::sla::{simple_sla, ServiceSla};
use crate::util::{InstanceId, NodeId, Rng, ServiceId, SimTime};

use super::testbed::{build_oakestra, OakTestbed, OakTestbedConfig};

/// Which storm generators run (they compose; `All` is the full mix).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ChurnScenario {
    /// Arrival/departure churn only.
    Submit,
    /// Fixed fleet + closed-loop autoscaler only.
    Scale,
    /// Fixed fleet + failover drills only.
    Failover,
    /// Arrival/departure churn over the *heavy* catalog
    /// ([`spill_catalog_sla`]): footprints sized so undersized clusters
    /// saturate between aggregate reports and the root's priority-list
    /// spill (`DelegationResult{None}` → next cluster) fires under
    /// sustained load. Pair with a many-small-clusters `--shape`
    /// (e.g. 16x6).
    Spill,
    /// Arrival churn + migration drills under a seeded schedule of
    /// cluster-uplink cuts and flaps (the partition-tolerance bench):
    /// root↔cluster links go down mid-storm, clusters run autonomously,
    /// and each heal triggers the anti-entropy resync whose convergence
    /// latency the report gates. Pair with `partition_clusters`/
    /// `partition_cycles` > 0 (the [`ChurnConfig::partition_storm`]
    /// preset) or no link ever actually drops.
    Partition,
    /// Arrival churn + migration drills under a seeded schedule of
    /// cluster-orchestrator *crash-stops* (the crash-recovery bench):
    /// the orchestrator actor is killed outright — state discarded,
    /// in-flight messages dropped — and later restarted cold under a
    /// higher incarnation epoch. The restarted cluster rebuilds its
    /// tables bottom-up from worker re-register censuses, re-attaches
    /// to the root, and the report gates the crash-to-converged
    /// latency and lost-replica count. Pair with `crash_clusters`/
    /// `crash_cycles` > 0 (the [`ChurnConfig::crash_storm`] preset)
    /// or no orchestrator ever actually dies.
    Crash,
    /// Submit + autoscale + failover composed.
    All,
}

impl ChurnScenario {
    pub fn parse(s: &str) -> Option<ChurnScenario> {
        Some(match s.to_ascii_lowercase().as_str() {
            "submit" | "churn" => ChurnScenario::Submit,
            "scale" | "autoscale" => ChurnScenario::Scale,
            "failover" | "migrate" => ChurnScenario::Failover,
            "spill" => ChurnScenario::Spill,
            "partition" => ChurnScenario::Partition,
            "crash" => ChurnScenario::Crash,
            "all" => ChurnScenario::All,
            _ => return None,
        })
    }
    fn arrivals(self) -> bool {
        matches!(
            self,
            ChurnScenario::Submit
                | ChurnScenario::Spill
                | ChurnScenario::Partition
                | ChurnScenario::Crash
                | ChurnScenario::All
        )
    }
    fn autoscale(self) -> bool {
        matches!(self, ChurnScenario::Scale | ChurnScenario::All)
    }
    fn drills(self) -> bool {
        // Partition keeps the migration drills: a cut racing an
        // in-flight cutover is exactly the reconciliation case the
        // heal-time resync must settle. Crash keeps them for the same
        // reason — a migration mid-cutover when the orchestrator dies
        // is exactly what the census-seeded recovery must finish.
        matches!(
            self,
            ChurnScenario::Failover
                | ChurnScenario::Partition
                | ChurnScenario::Crash
                | ChurnScenario::All
        )
    }
    /// Does this scenario install the seeded uplink-cut schedule?
    fn partitions(self) -> bool {
        matches!(self, ChurnScenario::Partition)
    }
    /// Does this scenario install the seeded orchestrator-crash schedule?
    fn crashes(self) -> bool {
        matches!(self, ChurnScenario::Crash)
    }
    /// Spill storms draw from the deliberately heavy SLA catalog.
    fn heavy_catalog(self) -> bool {
        matches!(self, ChurnScenario::Spill)
    }
}

/// Knobs of the churn engine. Defaults describe a small storm that a
/// 2×4 S-VM testbed absorbs; scale `duration_s`/`arrival_period_s` up
/// for the real bench.
#[derive(Clone, Debug)]
pub struct ChurnConfig {
    pub seed: u64,
    pub scenario: ChurnScenario,
    pub clusters: usize,
    pub workers_per_cluster: usize,
    pub scheduler: SchedulerKind,
    /// Virtual seconds of active churn (after warm-up).
    pub duration_s: f64,
    /// Virtual seconds of settle time after the final undeploy wave.
    pub settle_s: f64,
    /// Driver tick period (arrivals/polls/decisions), virtual seconds.
    pub tick_s: f64,
    /// Mean service inter-arrival time (exponential), seconds.
    pub arrival_period_s: f64,
    /// Mean service lifetime (exponential), seconds.
    pub mean_lifetime_s: f64,
    /// Cap on concurrently live churn services.
    pub max_live: usize,
    /// Distinct Schema-1 SLA shapes in the catalog.
    pub catalog: usize,
    /// Fleet size for the fixed-fleet scenarios (Scale/Failover), and the
    /// number of arrival-churn services the autoscaler adopts under All.
    pub autoscaled: usize,
    /// Autoscaler decision period, in ticks.
    pub autoscale_every: u64,
    /// Offered load consumed by one replica (abstract units).
    pub load_per_replica: f64,
    /// Per-tick std-dev of the offered-load random walk.
    pub load_step: f64,
    /// Hysteresis: scale up when load/replica exceeds `load_hi`…
    pub load_hi: f64,
    /// …and down only when it falls below `load_lo`.
    pub load_lo: f64,
    pub max_replicas: usize,
    /// Failover drill period, in ticks.
    pub drill_every: u64,
    /// Max drills per run.
    pub drills: usize,
    /// Probability that a drill also crash-stops the hosting worker,
    /// racing the migration against the failure.
    pub fail_worker_chance: f64,
    /// Probability that a drill-killed worker later *rejoins*: the
    /// hardware comes back as a fresh node id with an empty instance set
    /// and re-registers with its cluster (ROADMAP: worker recovery).
    pub rejoin_chance: f64,
    /// Seconds between a kill and its scheduled rejoin.
    pub rejoin_delay_s: f64,
    /// Autoscaler signal source: when true, decisions key off the *real*
    /// per-service observed CPU exposed by `ServiceStatus`
    /// (`observed_cpu_mc`, fed by worker telemetry through the clusters'
    /// coalesced aggregate reports) instead of the synthetic offered-load
    /// walk. The walk still advances either way, so flipping the knob
    /// never shifts the RNG stream.
    pub cpu_autoscale: bool,
    /// Observed-CPU budget one replica is expected to absorb (mc), the
    /// `load_per_replica` analogue of the CPU-keyed autoscaler.
    pub cpu_per_replica_mc: f64,
    /// Quiet window between the end of the storms and the final drain.
    /// With no new ops in flight the control plane converges, and the
    /// harness snapshots the root-vs-census consistency check here —
    /// while replacements are still alive, so invisible ones would show.
    pub pre_drain_hold_s: f64,
    /// Abandon convergence watches after this long (an instance that
    /// failed placement can legitimately never converge; the watch must
    /// not pin its service forever).
    pub watch_timeout_s: f64,
    /// Partition scenario: how many cluster uplinks (a prefix of the
    /// cluster list) the seeded fault schedule cuts. 0 = no partitions.
    pub partition_clusters: usize,
    /// Cut/heal cycles per affected cluster. The middle cycle of each
    /// schedule is a short *flap* ([`Self::partition_flap_s`]) instead
    /// of a full cut.
    pub partition_cycles: usize,
    /// Length of one full cut window, seconds. Must exceed the WsLink
    /// `partitioned_after` lease (30 s) or the root never detects it.
    pub partition_s: f64,
    /// Length of one flap window, seconds: long enough to trip the
    /// lease into Suspect (> 12 s), short enough never to reach
    /// Partitioned — exercising outbox buffering without a resync.
    pub partition_flap_s: f64,
    /// Healed gap between consecutive windows of one cluster, seconds.
    pub partition_gap_s: f64,
    /// Quiet lead-in before the first cut, seconds after storm start.
    pub partition_lead_s: f64,
    /// Crash scenario: how many cluster orchestrators (a prefix of the
    /// cluster list) the seeded crash schedule kills. 0 = no crashes.
    pub crash_clusters: usize,
    /// Kill/restart cycles per affected cluster. Odd-numbered cycles
    /// (the second, fourth, …) are *long* outages
    /// ([`Self::crash_down_long_s`]); the rest are short.
    pub crash_cycles: usize,
    /// Orchestrator downtime of a short outage, seconds. Sized inside
    /// the root's Suspect window (> 12 s lease silence, < 30 s
    /// Partitioned escalation): the higher-epoch re-register must
    /// cancel the escalation, not double-count a detection.
    pub crash_down_s: f64,
    /// Downtime of a long outage, seconds. Must exceed the WsLink
    /// `partitioned_after` lease (30 s) so the root escalates to
    /// Partitioned *before* the restart re-registers — the crash is
    /// then absorbed through the same Degraded-overlay path as a
    /// healed partition.
    pub crash_down_long_s: f64,
    /// Gap between one cluster's restart and its next kill, seconds.
    pub crash_gap_s: f64,
    /// Quiet lead-in before the first kill, seconds after storm start.
    pub crash_lead_s: f64,
    /// Lane-sharded sim: `0` = classic single-lane sequential loop,
    /// `N >= 1` = one event lane per cluster (plus the root lane)
    /// drained by up to `N` threads. Any `N >= 1` yields the identical
    /// report for a given seed; `0` matches the pre-lane golden.
    pub threads: usize,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        ChurnConfig {
            seed: 42,
            scenario: ChurnScenario::All,
            clusters: 2,
            workers_per_cluster: 4,
            scheduler: SchedulerKind::RomBestFit,
            duration_s: 180.0,
            settle_s: 40.0,
            tick_s: 1.0,
            arrival_period_s: 4.0,
            mean_lifetime_s: 45.0,
            max_live: 20,
            catalog: 6,
            autoscaled: 3,
            autoscale_every: 5,
            load_per_replica: 1.0,
            load_step: 0.45,
            load_hi: 1.2,
            load_lo: 0.6,
            max_replicas: 5,
            drill_every: 20,
            drills: 3,
            fail_worker_chance: 0.5,
            rejoin_chance: 0.25,
            rejoin_delay_s: 15.0,
            cpu_autoscale: false,
            cpu_per_replica_mc: 70.0,
            pre_drain_hold_s: 8.0,
            watch_timeout_s: 30.0,
            partition_clusters: 0,
            partition_cycles: 0,
            partition_s: 42.0,
            partition_flap_s: 15.0,
            partition_gap_s: 18.0,
            partition_lead_s: 15.0,
            crash_clusters: 0,
            crash_cycles: 0,
            crash_down_s: 15.0,
            crash_down_long_s: 35.0,
            crash_gap_s: 25.0,
            crash_lead_s: 12.0,
            threads: 0,
        }
    }
}

impl ChurnConfig {
    /// A small fast storm for CI smoke runs and the integration tests.
    pub fn quick(seed: u64) -> Self {
        ChurnConfig {
            seed,
            duration_s: 90.0,
            settle_s: 35.0,
            arrival_period_s: 5.0,
            mean_lifetime_s: 30.0,
            max_live: 10,
            drills: 2,
            drill_every: 15,
            ..ChurnConfig::default()
        }
    }

    /// The many-cluster spill storm (ROADMAP: multi-cluster spill under
    /// churn): 16 deliberately undersized clusters of 6 S workers and the
    /// heavy catalog, with arrivals fast enough that the root's (stale,
    /// delta-coalesced) aggregates keep over-targeting the current best
    /// cluster — forcing `DelegationResult{None}` spill down the
    /// priority list, and occasional full exhaustion.
    pub fn spill_storm(seed: u64) -> Self {
        ChurnConfig {
            seed,
            scenario: ChurnScenario::Spill,
            clusters: 16,
            workers_per_cluster: 6,
            duration_s: 90.0,
            settle_s: 40.0,
            arrival_period_s: 0.6,
            mean_lifetime_s: 25.0,
            max_live: 64,
            catalog: 8,
            ..ChurnConfig::default()
        }
    }

    /// The partition-tolerance storm: 16 clusters × 12 workers on the
    /// lane engine, arrival churn + migration drills while a seeded
    /// schedule cuts and flaps 4 of the 16 cluster uplinks (two full
    /// >30 s cuts and one Suspect-only flap each). The storm window is
    /// sized so the last heal lands well before the storm ends — the
    /// heal-to-convergence latency is measured against live churn, not
    /// against the final drain.
    pub fn partition_storm(seed: u64) -> Self {
        ChurnConfig {
            seed,
            scenario: ChurnScenario::Partition,
            clusters: 16,
            workers_per_cluster: 12,
            threads: 4,
            duration_s: 170.0,
            settle_s: 45.0,
            arrival_period_s: 1.0,
            mean_lifetime_s: 40.0,
            max_live: 96,
            catalog: 8,
            drills: 12,
            drill_every: 8,
            fail_worker_chance: 0.25,
            partition_clusters: 4,
            partition_cycles: 3,
            ..ChurnConfig::default()
        }
    }

    /// The coordinator crash-recovery storm: 16 clusters × 12 workers on
    /// the lane engine, arrival churn + migration drills while a seeded
    /// schedule crash-stops and cold-restarts 4 of the 16 cluster
    /// orchestrators (one short Suspect-window outage and one long
    /// escalated outage each). The storm window is sized so the last
    /// restart lands ≥ 20 s before the storm ends — crash-to-converged
    /// latency is measured against live churn, not the final drain.
    pub fn crash_storm(seed: u64) -> Self {
        ChurnConfig {
            seed,
            scenario: ChurnScenario::Crash,
            clusters: 16,
            workers_per_cluster: 12,
            threads: 4,
            duration_s: 130.0,
            settle_s: 45.0,
            arrival_period_s: 1.0,
            mean_lifetime_s: 40.0,
            max_live: 96,
            catalog: 8,
            drills: 12,
            drill_every: 8,
            fail_worker_chance: 0.25,
            crash_clusters: 4,
            crash_cycles: 2,
            ..ChurnConfig::default()
        }
    }

    /// The 10k-worker storm (ROADMAP: raw-speed substrate): 64 clusters
    /// × 160 workers under the full scenario mix, on the lane-sharded
    /// engine with 4 worker threads. Arrivals are fast and the live cap
    /// high so the control plane stays under sustained mutation pressure
    /// across the whole fleet, but the storm window is short enough to
    /// fit the CI wall-clock budget.
    pub fn storm_10k(seed: u64) -> Self {
        ChurnConfig {
            seed,
            scenario: ChurnScenario::All,
            clusters: 64,
            workers_per_cluster: 160,
            threads: 4,
            duration_s: 60.0,
            settle_s: 40.0,
            arrival_period_s: 0.25,
            mean_lifetime_s: 25.0,
            max_live: 256,
            catalog: 8,
            autoscaled: 6,
            drills: 8,
            drill_every: 10,
            ..ChurnConfig::default()
        }
    }
}

/// Parse a `CxW` topology shape (e.g. `16x6` = 16 clusters × 6 workers).
pub fn parse_shape(s: &str) -> Option<(usize, usize)> {
    let (c, w) = s.split_once(|ch| ch == 'x' || ch == 'X')?;
    let c: usize = c.trim().parse().ok()?;
    let w: usize = w.trim().parse().ok()?;
    (c > 0 && w > 0).then_some((c, w))
}

/// One SLA shape of the churn catalog: small footprints with varied
/// cpu/mem and an occasional two-task service, all within an S VM.
pub fn catalog_sla(i: usize) -> ServiceSla {
    let cpu = 50 + 25 * (i % 4) as u32;
    let mem = 24 + 16 * (i % 3) as u32;
    let mut sla = simple_sla(&format!("churn-{i}"), cpu, mem);
    if i % 3 == 2 {
        sla.constraints.push(sla.constraints[0].clone());
    }
    sla
}

/// One SLA shape of the *spill* catalog: heavy single-task footprints
/// (400–850 mc) sized so an S worker (1000 mc) hosts one — at most two —
/// instances. Sustained arrivals then overrun whole clusters between
/// aggregate reports, forcing the root's priority-list spill.
pub fn spill_catalog_sla(i: usize) -> ServiceSla {
    let cpu = 400 + 150 * (i % 4) as u32;
    let mem = 96 + 64 * (i % 3) as u32;
    simple_sla(&format!("spill-{i}"), cpu, mem)
}

/// Driver-side view of one live service.
#[derive(Clone, Debug)]
struct LiveService {
    catalog: usize,
    autoscaled: bool,
    /// Offered-load walk (autoscaled services only).
    load: f64,
}

/// The churn driver actor: issues all northbound calls through an
/// embedded [`ApiClient`] (batched issue + completion tracking) and keeps
/// a deterministic op log.
pub struct ChurnDriver {
    cfg: ChurnConfig,
    root: ActorId,
    rng: Rng,
    pub client: ApiClient,
    /// Chronological, seed-deterministic log of every lifecycle decision
    /// and observed completion.
    pub ops: Vec<String>,
    live: BTreeMap<ServiceId, LiveService>,
    departures: BTreeMap<ServiceId, SimTime>,
    pending_submit: BTreeMap<u64, (usize, SimTime)>,
    scale_req: BTreeMap<u64, (ServiceId, usize, SimTime)>,
    scale_watch: BTreeMap<ServiceId, (usize, SimTime)>,
    migrate_req: BTreeMap<u64, (ServiceId, InstanceId, SimTime)>,
    migrate_watch: BTreeMap<InstanceId, (ServiceId, SimTime)>,
    undeploy_req: BTreeMap<u64, (ServiceId, SimTime)>,
    undeploy_watch: BTreeMap<ServiceId, SimTime>,
    /// service → running (instance, worker) pairs from the last status.
    running_cache: BTreeMap<ServiceId, Vec<(InstanceId, NodeId)>>,
    /// service → min per-task running count from the last status.
    replica_cache: BTreeMap<ServiceId, usize>,
    /// service → aggregated observed CPU (mc) from the last status — the
    /// real-telemetry signal of the CPU-keyed autoscaler.
    cpu_cache: BTreeMap<ServiceId, u64>,
    pub failed_workers: BTreeSet<NodeId>,
    pub api_errors: BTreeMap<&'static str, u64>,
    /// Kills whose hardware is scheduled to rejoin: (dead node, when).
    /// The driver cannot spawn sim nodes itself; [`run_churn`] applies
    /// due entries between slices via [`OakTestbed::revive_worker`].
    pending_rejoin: Vec<(NodeId, SimTime)>,
    /// Every abandoned convergence watch: (expired at, service, the
    /// workers its instances were last seen running on). [`run_churn`]
    /// cross-checks each entry against the partition schedule — an
    /// abandonment is only excusable when the service had a foot in a
    /// cluster whose uplink was cut during the watch window.
    pub expired_watches: Vec<(SimTime, ServiceId, Vec<NodeId>)>,
    // Counters for the report.
    pub submits: u64,
    pub undeploys: u64,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub migrations: u64,
    pub drills_done: u64,
    pub rejoins: u64,
    next_arrival: SimTime,
    ticks: u64,
    end: SimTime,
    drain_at: SimTime,
    settle_end: SimTime,
    started: bool,
}

impl ChurnDriver {
    /// The SLA shape arrivals draw from: spill storms use the heavy
    /// catalog, everything else the small one.
    fn sla_for(cfg: &ChurnConfig, i: usize) -> crate::sla::ServiceSla {
        if cfg.scenario.heavy_catalog() {
            spill_catalog_sla(i)
        } else {
            catalog_sla(i)
        }
    }

    pub fn new(cfg: ChurnConfig, root: ActorId) -> Self {
        for i in 0..cfg.catalog {
            Self::sla_for(&cfg, i)
                .validate()
                .expect("churn catalog SLA must validate");
        }
        let rng = Rng::seeded(cfg.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xC0FFEE);
        ChurnDriver {
            cfg,
            root,
            rng,
            client: ApiClient::new(),
            ops: Vec::new(),
            live: BTreeMap::new(),
            departures: BTreeMap::new(),
            pending_submit: BTreeMap::new(),
            scale_req: BTreeMap::new(),
            scale_watch: BTreeMap::new(),
            migrate_req: BTreeMap::new(),
            migrate_watch: BTreeMap::new(),
            undeploy_req: BTreeMap::new(),
            undeploy_watch: BTreeMap::new(),
            running_cache: BTreeMap::new(),
            replica_cache: BTreeMap::new(),
            cpu_cache: BTreeMap::new(),
            failed_workers: BTreeSet::new(),
            api_errors: BTreeMap::new(),
            pending_rejoin: Vec::new(),
            expired_watches: Vec::new(),
            submits: 0,
            undeploys: 0,
            scale_ups: 0,
            scale_downs: 0,
            migrations: 0,
            drills_done: 0,
            rejoins: 0,
            next_arrival: SimTime::ZERO,
            ticks: 0,
            end: SimTime::ZERO,
            drain_at: SimTime::ZERO,
            settle_end: SimTime::ZERO,
            started: false,
        }
    }

    /// Rejoins that have come due by `now`, removed from the pending
    /// list (called by [`run_churn`] between simulation slices).
    pub fn take_due_rejoins(&mut self, now: SimTime) -> Vec<NodeId> {
        let (due, later): (Vec<_>, Vec<_>) =
            self.pending_rejoin.drain(..).partition(|(_, at)| *at <= now);
        self.pending_rejoin = later;
        due.into_iter().map(|(node, _)| node).collect()
    }

    /// Record a completed rejoin (the testbed revived `old` as `fresh`).
    pub fn note_rejoined(&mut self, at: SimTime, old: NodeId, fresh: NodeId) {
        self.rejoins += 1;
        self.log(at, format!("worker-rejoined {old} as {fresh}"));
    }

    /// Record a scheduled orchestrator crash-stop the testbed applied
    /// (`dropped` = in-flight messages that died with the actor).
    pub fn note_cluster_crashed(&mut self, at: SimTime, cluster: usize, dropped: usize) {
        self.log(
            at,
            format!("cluster-crashed idx={cluster} inflight_dropped={dropped}"),
        );
    }

    /// Record a cold restart under a fresh incarnation epoch.
    pub fn note_cluster_restarted(&mut self, at: SimTime, cluster: usize, epoch: u64) {
        self.log(at, format!("cluster-restarted idx={cluster} epoch={epoch}"));
    }

    fn log(&mut self, now: SimTime, line: String) {
        self.ops.push(format!("t={:>10.3}ms {line}", now.as_millis()));
    }

    /// Issue one northbound call (same-node delivery to the root; ids and
    /// responses tracked by the embedded [`ApiClient`]).
    fn call(&mut self, ctx: &mut Ctx<'_>, request: ApiRequest) -> u64 {
        let env = self.client.envelope(request, ctx.self_id);
        let id = env.request_id;
        // lint: route(root, northbound call addressed to the root orchestrator)
        ctx.send_local(self.root, SimMsg::Oak(OakMsg::ApiCall(Box::new(env))));
        id
    }

    fn submit_from_catalog(&mut self, ctx: &mut Ctx<'_>, idx: usize) {
        let sla = Self::sla_for(&self.cfg, idx);
        let req = self.call(ctx, ApiRequest::SubmitService { sla });
        self.pending_submit.insert(req, (idx, ctx.now));
        self.submits += 1;
        self.log(ctx.now, format!("submit catalog={idx} req={req}"));
    }

    fn undeploy_service(&mut self, ctx: &mut Ctx<'_>, service: ServiceId) {
        let req = self.call(ctx, ApiRequest::UndeployService { service });
        self.undeploy_req.insert(req, (service, ctx.now));
        self.undeploys += 1;
        self.live.remove(&service);
        self.departures.remove(&service);
        self.scale_watch.remove(&service);
        // A migration of a doomed service can no longer cut over.
        self.migrate_watch.retain(|_, (s, _)| *s != service);
        self.log(ctx.now, format!("undeploy {service} req={req}"));
    }

    fn arrivals(&mut self, ctx: &mut Ctx<'_>) {
        while ctx.now >= self.next_arrival {
            let gap = self.rng.exponential(self.cfg.arrival_period_s);
            self.next_arrival = self.next_arrival + SimTime::from_secs(gap.max(0.05));
            if self.live.len() + self.pending_submit.len() >= self.cfg.max_live {
                ctx.metrics().inc("churn.arrival_capped");
                continue;
            }
            let idx = self.rng.below(self.cfg.catalog);
            self.submit_from_catalog(ctx, idx);
        }
    }

    fn departures_due(&mut self, ctx: &mut Ctx<'_>) {
        let due: Vec<ServiceId> = self
            .departures
            .iter()
            .filter(|(_, at)| **at <= ctx.now)
            .map(|(s, _)| *s)
            .collect();
        for s in due {
            self.undeploy_service(ctx, s);
        }
    }

    fn autoscale(&mut self, ctx: &mut Ctx<'_>) {
        let targets: Vec<ServiceId> = self
            .live
            .iter()
            .filter(|(_, l)| l.autoscaled)
            .map(|(s, _)| *s)
            .collect();
        for service in targets {
            // Advance the offered-load walk for every autoscaled service
            // (even while a scale is converging — load does not wait).
            let (load, in_flight) = {
                let l = self.live.get_mut(&service).unwrap();
                let step = self.rng.normal(0.0, self.cfg.load_step);
                let max_load = self.cfg.max_replicas as f64 * self.cfg.load_per_replica;
                l.load = (l.load + step).clamp(0.3, max_load);
                (l.load, self.scale_watch.contains_key(&service))
            };
            if in_flight
                || self.undeploy_watch.contains_key(&service)
                || self.migrate_watch.values().any(|(s, _)| *s == service)
            {
                // A mid-cutover migration transiently double-counts the
                // task (original + adopted replacement both live); let it
                // settle before acting on the replica count.
                continue;
            }
            let Some(&replicas) = self.replica_cache.get(&service) else {
                continue; // no status observed yet
            };
            if replicas == 0 {
                continue;
            }
            // Signal source: the synthetic offered-load walk, or — when
            // `cpu_autoscale` is on — the real per-service observed CPU
            // that `ServiceStatus` now aggregates from worker telemetry
            // (first step on the QoS-telemetry roadmap item).
            let (desired, ratio) = if self.cfg.cpu_autoscale {
                let observed = self.cpu_cache.get(&service).copied().unwrap_or(0) as f64;
                // Observed CPU grows with the replica count (each replica
                // draws run_util × its reservation), so a proportional
                // target `ceil(observed / per_replica)` has positive
                // feedback. Step at most ±1 replica per decision: the
                // controller stays bounded per poll even on a signal
                // proportional to its own actuation.
                (
                    ((observed / self.cfg.cpu_per_replica_mc).ceil() as usize)
                        .clamp(replicas.saturating_sub(1), replicas + 1)
                        .clamp(1, self.cfg.max_replicas),
                    observed / (replicas as f64 * self.cfg.cpu_per_replica_mc),
                )
            } else {
                (
                    ((load / self.cfg.load_per_replica).ceil() as usize)
                        .clamp(1, self.cfg.max_replicas),
                    load / (replicas as f64 * self.cfg.load_per_replica),
                )
            };
            let (scale, dir) = if ratio > self.cfg.load_hi && desired > replicas {
                (true, "up")
            } else if ratio < self.cfg.load_lo && desired < replicas {
                (true, "down")
            } else {
                (false, "")
            };
            if scale {
                let req = self.call(
                    ctx,
                    ApiRequest::ScaleService {
                        service,
                        task: None,
                        replicas: desired,
                    },
                );
                self.scale_req.insert(req, (service, desired, ctx.now));
                if dir == "up" {
                    self.scale_ups += 1;
                } else {
                    self.scale_downs += 1;
                }
                self.log(
                    ctx.now,
                    format!(
                        "scale-{dir} {service} {replicas}->{desired} \
                         load={load:.2} req={req}"
                    ),
                );
            }
        }
    }

    fn drill(&mut self, ctx: &mut Ctx<'_>) {
        if self.drills_done >= self.cfg.drills as u64 {
            return;
        }
        // Candidates: running instances of live services, excluding
        // failed workers and anything already migrating. Autoscaled
        // services are fair game since root-visible replacement tracking
        // landed: migration successors are registered with the root, so
        // its replica count stays authoritative through a drill.
        let candidates: Vec<(ServiceId, InstanceId, NodeId)> = self
            .running_cache
            .iter()
            .filter(|(s, _)| self.live.contains_key(s))
            .flat_map(|(s, insts)| insts.iter().map(move |(i, n)| (*s, *i, *n)))
            .filter(|(_, i, n)| {
                !self.migrate_watch.contains_key(i) && !self.failed_workers.contains(n)
            })
            .collect();
        if candidates.is_empty() {
            return;
        }
        let (service, instance, node) = candidates[self.rng.below(candidates.len())];
        let req = self.call(ctx, ApiRequest::MigrateInstance { service, instance });
        self.migrate_req.insert(req, (service, instance, ctx.now));
        self.migrations += 1;
        self.drills_done += 1;
        // Race the migration against a crash-stop of the source worker
        // (never more than half the fleet).
        let total_workers = self.cfg.clusters * self.cfg.workers_per_cluster;
        let kill = self.rng.chance(self.cfg.fail_worker_chance)
            && self.failed_workers.len() < total_workers / 2;
        if kill {
            ctx.set_node_failed(node, true);
            self.failed_workers.insert(node);
            ctx.metrics().inc("churn.worker_killed");
            // The hardware may come back: schedule a rejoin under a
            // fresh node id (applied by run_churn between slices).
            if self.rng.chance(self.cfg.rejoin_chance) {
                let at = ctx.now + SimTime::from_secs(self.cfg.rejoin_delay_s);
                self.pending_rejoin.push((node, at));
                self.log(ctx.now, format!("rejoin-scheduled {node}"));
            }
        }
        self.log(
            ctx.now,
            format!(
                "drill migrate {service}/{instance} from {node} \
                 kill_worker={kill} req={req}"
            ),
        );
    }

    fn poll(&mut self, ctx: &mut Ctx<'_>) {
        let mut targets: BTreeSet<ServiceId> = BTreeSet::new();
        targets.extend(self.scale_watch.keys().copied());
        targets.extend(self.undeploy_watch.keys().copied());
        targets.extend(self.migrate_watch.values().map(|(s, _)| *s));
        targets.extend(
            self.live
                .iter()
                .filter(|(_, l)| l.autoscaled)
                .map(|(s, _)| *s),
        );
        if self.cfg.scenario.drills() && self.drills_done < self.cfg.drills as u64 {
            // Drills pick victims from the status cache: keep it fresh
            // for every live service — but only while drills remain, or
            // the polling itself would inflate the control-plane cost
            // this bench reports.
            targets.extend(self.live.keys().copied());
        }
        for service in targets {
            self.call(ctx, ApiRequest::ServiceStatus { service });
        }
    }

    /// Abandon watches that outlived their timeout: an instance that
    /// failed placement (or a drill racing an undeploy) may legitimately
    /// never converge, and a stuck watch would pin its service out of the
    /// autoscaler forever.
    fn expire_watches(&mut self, ctx: &mut Ctx<'_>) {
        let cutoff = SimTime::from_secs(self.cfg.watch_timeout_s);
        let now = ctx.now;
        let mut expired: Vec<(String, ServiceId)> = Vec::new();
        self.scale_watch.retain(|s, (_, t0)| {
            let keep = now.saturating_sub(*t0) < cutoff;
            if !keep {
                expired.push((format!("scale-watch-expired {s}"), *s));
            }
            keep
        });
        self.migrate_watch.retain(|i, (s, t0)| {
            let keep = now.saturating_sub(*t0) < cutoff;
            if !keep {
                expired.push((format!("migrate-watch-expired {s}/{i}"), *s));
            }
            keep
        });
        self.undeploy_watch.retain(|s, t0| {
            let keep = now.saturating_sub(*t0) < cutoff;
            if !keep {
                expired.push((format!("undeploy-watch-expired {s}"), *s));
            }
            keep
        });
        for (line, service) in expired {
            ctx.metrics().inc("churn.watch_expired");
            let nodes: Vec<NodeId> = self
                .running_cache
                .get(&service)
                .map(|insts| insts.iter().map(|(_, n)| *n).collect())
                .unwrap_or_default();
            self.expired_watches.push((now, service, nodes));
            self.log(now, line);
        }
    }

    fn on_status(&mut self, ctx: &mut Ctx<'_>, s: &crate::api::ServiceStatusInfo) {
        let service = s.service;
        // Per-task running / live counts.
        let mut running: BTreeMap<u16, usize> = BTreeMap::new();
        let mut alive: BTreeMap<u16, usize> = BTreeMap::new();
        for t in 0..s.tasks as u16 {
            running.insert(t, 0);
            alive.insert(t, 0);
        }
        let mut running_insts = Vec::new();
        for i in &s.instances {
            if i.state == ServiceState::Running {
                *running.entry(i.task.index).or_insert(0) += 1;
                if let Some(w) = i.worker {
                    running_insts.push((i.instance, w));
                }
            }
            if !i.state.is_terminal() {
                *alive.entry(i.task.index).or_insert(0) += 1;
            }
        }
        self.replica_cache
            .insert(service, running.values().copied().min().unwrap_or(0));
        self.running_cache.insert(service, running_insts);
        self.cpu_cache.insert(service, s.observed_cpu_mc);

        // Scale convergence: every task at the target, all running.
        if let Some(&(target, t0)) = self.scale_watch.get(&service) {
            let converged = running.values().all(|&r| r == target)
                && alive.values().all(|&a| a == target);
            if converged {
                self.scale_watch.remove(&service);
                let ms = ctx.now.saturating_sub(t0).as_millis();
                ctx.metrics().observe(lifecycle::SCALE_TO_CONVERGED_MS, ms);
                self.log(
                    ctx.now,
                    format!("scale-converged {service} replicas={target}"),
                );
            }
        }

        // Migration cutover: the original instance reached a terminal
        // state (replacement operational, old container gone).
        let watched: Vec<InstanceId> = self
            .migrate_watch
            .iter()
            .filter(|(_, (svc, _))| *svc == service)
            .map(|(i, _)| *i)
            .collect();
        for iid in watched {
            let Some(inst) = s.instances.iter().find(|i| i.instance == iid) else {
                continue;
            };
            if inst.state.is_terminal() {
                if let Some((_, t0)) = self.migrate_watch.remove(&iid) {
                    let ms = ctx.now.saturating_sub(t0).as_millis();
                    ctx.metrics().observe(lifecycle::MIGRATE_TO_CUTOVER_MS, ms);
                    self.log(ctx.now, format!("migrate-cutover {service}/{iid}"));
                }
            }
        }

        // Undeploy drain: no live instances remain.
        if let Some(&t0) = self.undeploy_watch.get(&service) {
            if s.live() == 0 {
                self.undeploy_watch.remove(&service);
                let ms = ctx.now.saturating_sub(t0).as_millis();
                ctx.metrics().observe(lifecycle::UNDEPLOY_TO_DRAINED_MS, ms);
                self.log(ctx.now, format!("undeploy-drained {service}"));
            }
        }
    }

    fn error_kind(e: &ApiError) -> &'static str {
        match e {
            ApiError::UnsupportedVersion { .. } => "unsupported_version",
            ApiError::InvalidSla(_) => "invalid_sla",
            ApiError::UnknownService(_) => "unknown_service",
            ApiError::ServiceRetired(_) => "service_retired",
            ApiError::UnknownTask(_) => "unknown_task",
            ApiError::UnknownInstance(_) => "unknown_instance",
            ApiError::NotRunning(_) => "not_running",
            ApiError::AlreadyReplaced { .. } => "already_replaced",
            ApiError::InvalidReplicas { .. } => "invalid_replicas",
            ApiError::NoFeasiblePlacement { .. } => "no_feasible_placement",
        }
    }

    fn on_return(&mut self, ctx: &mut Ctx<'_>, request_id: u64, response: ApiResponse) {
        match &response {
            ApiResponse::Status(s) => {
                self.on_status(ctx, s);
            }
            ApiResponse::Submitted { service, .. } => {
                if let Some((catalog, _t0)) = self.pending_submit.remove(&request_id) {
                    let autoscaled = self.cfg.scenario.autoscale()
                        && self
                            .live
                            .values()
                            .filter(|l| l.autoscaled)
                            .count()
                            < self.cfg.autoscaled;
                    self.live.insert(
                        *service,
                        LiveService {
                            catalog,
                            autoscaled,
                            load: 1.0,
                        },
                    );
                    if self.cfg.scenario.arrivals() && !self.is_fixed_fleet() {
                        let life = self.rng.exponential(self.cfg.mean_lifetime_s);
                        self.departures.insert(
                            *service,
                            ctx.now + SimTime::from_secs(life.max(2.0)),
                        );
                    }
                    self.log(
                        ctx.now,
                        format!(
                            "submitted {service} catalog={catalog} \
                             autoscaled={autoscaled} req={request_id}"
                        ),
                    );
                    if ctx.now >= self.end {
                        // Acked after the final wave: tear it down too.
                        self.undeploy_service(ctx, *service);
                    }
                }
            }
            ApiResponse::ScaleStarted {
                service,
                added,
                removed,
            } => {
                if let Some((svc, target, t0)) = self.scale_req.remove(&request_id) {
                    debug_assert_eq!(svc, *service);
                    self.scale_watch.insert(svc, (target, t0));
                    self.log(
                        ctx.now,
                        format!(
                            "scale-started {service} +{} -{} req={request_id}",
                            added.len(),
                            removed.len()
                        ),
                    );
                }
            }
            ApiResponse::MigrationStarted { instance } => {
                if let Some((svc, iid, t0)) = self.migrate_req.remove(&request_id) {
                    debug_assert_eq!(iid, *instance);
                    self.migrate_watch.insert(iid, (svc, t0));
                    self.log(
                        ctx.now,
                        format!("migration-started {svc}/{iid} req={request_id}"),
                    );
                }
            }
            ApiResponse::UndeployStarted { service, instances } => {
                if let Some((svc, t0)) = self.undeploy_req.remove(&request_id) {
                    debug_assert_eq!(svc, *service);
                    self.undeploy_watch.insert(svc, t0);
                    self.log(
                        ctx.now,
                        format!(
                            "undeploy-started {service} live={instances} \
                             req={request_id}"
                        ),
                    );
                }
            }
            ApiResponse::Error(e) => {
                let kind = Self::error_kind(e);
                *self.api_errors.entry(kind).or_insert(0) += 1;
                // Clear any op bookkeeping tied to the failed request so
                // watches are only ever created from success acks.
                self.pending_submit.remove(&request_id);
                self.scale_req.remove(&request_id);
                self.migrate_req.remove(&request_id);
                self.undeploy_req.remove(&request_id);
                self.log(ctx.now, format!("api-error {kind} req={request_id}"));
            }
            _ => {}
        }
        self.client.record(request_id, response);
    }

    fn is_fixed_fleet(&self) -> bool {
        matches!(
            self.cfg.scenario,
            ChurnScenario::Scale | ChurnScenario::Failover
        )
    }

    fn tick(&mut self, ctx: &mut Ctx<'_>) {
        self.ticks += 1;
        let churning = ctx.now < self.end;
        if churning {
            if self.cfg.scenario.arrivals() {
                self.arrivals(ctx);
                self.departures_due(ctx);
            }
            if self.cfg.scenario.autoscale() && self.ticks % self.cfg.autoscale_every == 0
            {
                self.autoscale(ctx);
            }
            if self.cfg.scenario.drills() && self.ticks % self.cfg.drill_every == 0 {
                self.drill(ctx);
            }
        } else if ctx.now >= self.drain_at && !self.live.is_empty() {
            // Final wave (after the pre-drain hold, which gives the
            // consistency snapshot a quiet converged control plane):
            // drain everything that is still live.
            let remaining: Vec<ServiceId> = self.live.keys().copied().collect();
            self.log(ctx.now, format!("final-drain services={}", remaining.len()));
            for s in remaining {
                self.undeploy_service(ctx, s);
            }
        }
        self.expire_watches(ctx);
        self.poll(ctx);
        if ctx.now < self.settle_end {
            ctx.schedule(
                SimTime::from_secs(self.cfg.tick_s),
                SimMsg::Timer(TimerKind::Custom(1)),
            );
        }
    }
}

impl Actor for ChurnDriver {
    fn handle(&mut self, ctx: &mut Ctx<'_>, msg: SimMsg) {
        match msg {
            SimMsg::Timer(TimerKind::Custom(0)) => {
                if self.started {
                    return;
                }
                self.started = true;
                self.end = ctx.now + SimTime::from_secs(self.cfg.duration_s);
                self.drain_at =
                    self.end + SimTime::from_secs(self.cfg.pre_drain_hold_s);
                self.settle_end = self.drain_at + SimTime::from_secs(self.cfg.settle_s);
                self.next_arrival = ctx.now;
                self.log(
                    ctx.now,
                    format!(
                        "churn-start scenario={:?} seed={}",
                        self.cfg.scenario, self.cfg.seed
                    ),
                );
                if self.is_fixed_fleet() {
                    for i in 0..self.cfg.autoscaled {
                        let idx = i % self.cfg.catalog;
                        self.submit_from_catalog(ctx, idx);
                    }
                }
                self.tick(ctx);
            }
            SimMsg::Timer(TimerKind::Custom(1)) => {
                self.tick(ctx);
            }
            SimMsg::Oak(OakMsg::ApiReturn {
                request_id,
                response,
            }) => {
                self.on_return(ctx, request_id, *response);
            }
            SimMsg::Oak(OakMsg::ServiceDeployed { service, elapsed }) => {
                ctx.metrics()
                    .observe(lifecycle::SUBMIT_TO_RUNNING_MS, elapsed.as_millis());
                self.client.deployed.insert(service, elapsed);
                self.log(
                    ctx.now,
                    format!("deployed {service} after {:.1}ms", elapsed.as_millis()),
                );
            }
            _ => {}
        }
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

/// Latency summary of one lifecycle-op histogram.
#[derive(Clone, Debug, Default)]
pub struct OpStats {
    pub count: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

impl OpStats {
    fn from(h: Option<&Histogram>) -> OpStats {
        match h {
            Some(h) => OpStats {
                count: h.count(),
                p50_ms: h.p50(),
                p95_ms: h.p95(),
            },
            None => OpStats::default(),
        }
    }
}

/// Partition-tolerance accounting of one churn run: the seeded fault
/// schedule, what the root and clusters observed of it, and how fast the
/// anti-entropy resync reconverged after each heal. Present only when
/// the scenario installed uplink cuts.
#[derive(Clone, Debug, Default)]
pub struct PartitionStats {
    /// Scheduled full-cut windows (lease trips Partitioned).
    pub cuts: u64,
    /// Scheduled flap windows (Suspect only — must NOT trip the lease).
    pub flaps: u64,
    /// Root-side detections / heals (`root.partition_*`).
    pub detected: u64,
    pub healed: u64,
    /// `ResyncRequest`s sent by the root / snapshots answered.
    pub resyncs: u64,
    pub snapshots: u64,
    /// Service rows marked Degraded on detection / cleared on heal.
    pub services_degraded: u64,
    pub services_restored: u64,
    /// Detection→heal window per partition (root clock).
    pub degraded_window: OpStats,
    /// Heal→(root census == cluster census) latency, measured by the
    /// harness polling [`census_diff`] at slice boundaries.
    pub heal_to_convergence: OpStats,
    /// Heals whose census never drained before the run ended (gate: 0).
    pub unconverged_heals: usize,
    /// Resync reconciliation outcomes: replayed adoptions, benign
    /// duplicates, lineage conflicts (double adoptions — gate: 0),
    /// true orphans torn down, lost instances re-minted, and
    /// delegations the census settled.
    pub resync_adopted: u64,
    pub resync_duplicates: u64,
    pub resync_conflicts: u64,
    pub resync_orphans: u64,
    pub resync_lost: u64,
    pub resync_settled: u64,
    /// Cluster-side uplink lease + critical-message outbox traffic.
    pub uplink_partitioned: u64,
    pub uplink_healed: u64,
    pub outbox_buffered: u64,
    pub outbox_replayed: u64,
    pub outbox_retry: u64,
    pub outbox_dropped: u64,
    /// Transport-level fault accounting (`net.*`).
    pub retransmits: u64,
    pub dropped_after_retry: u64,
    pub net_lost: u64,
}

/// Crash-recovery accounting of one churn run: the seeded orchestrator
/// kill/restart schedule, the epoch-fenced re-registration traffic it
/// produced, and how fast each cold restart rebuilt a census the root
/// agrees with. Present only when the scenario installed crashes.
#[derive(Clone, Debug, Default)]
pub struct CrashStats {
    /// Scheduled orchestrator crash-stops applied / cold restarts.
    pub kills: u64,
    pub restarts: u64,
    /// Short (Suspect-window) vs long (escalated past the 30 s lease)
    /// outages in the schedule.
    pub short_outages: u64,
    pub long_outages: u64,
    /// In-flight messages dropped on the floor by the kills.
    pub inflight_dropped: u64,
    /// Outages the root escalated to Partitioned before the restart
    /// re-registered (`root.partition_detected` — long outages only;
    /// a short outage's higher-epoch re-register inside the Suspect
    /// window must cancel the escalation, never double-count it).
    pub escalated: u64,
    /// Higher-epoch re-registrations accepted (`root.cluster_restarted`)
    /// and stale-epoch registrations fenced (`root.register_stale_epoch`).
    pub restart_registers: u64,
    pub stale_registers: u64,
    /// Worker-side recovery traffic: solicited re-register handshakes
    /// and messages fenced for carrying a dead incarnation's epoch.
    pub worker_reregistered: u64,
    pub epoch_fenced: u64,
    /// Bottom-up state rebuild: census rows seeded from re-register
    /// handshakes, recoveries declared complete, census-seeded
    /// migration replacements cut over, resyncs deferred until
    /// Recovering ended, and delegations refused while recovering.
    pub census_seeded: u64,
    pub recovery_completed: u64,
    pub recovery_cutover: u64,
    pub resync_deferred: u64,
    pub delegations_refused: u64,
    /// Root-side reconciliation through the crash-resync: standard
    /// anti-entropy outcomes plus delegations that died with the
    /// crashed outbox and were re-driven (`root.resync_redelegated`).
    pub resync_adopted: u64,
    pub resync_duplicates: u64,
    pub resync_conflicts: u64,
    pub resync_orphans: u64,
    pub resync_lost: u64,
    pub resync_settled: u64,
    pub redelegated: u64,
    /// Kill→(root census == cluster census) latency per outage,
    /// measured by the harness polling [`census_diff`] at slice
    /// boundaries after each restart.
    pub crash_to_converged: OpStats,
    /// Restarts whose census never drained before the run ended (gate: 0).
    pub unconverged_crashes: usize,
    /// `root-only` rows of the quiet-hold census snapshot: replicas the
    /// root still believes in that no cluster hosts — capacity lost to
    /// the crashes (gate: 0).
    pub lost_replicas: usize,
}

/// Everything `oakestra churn` emits: latency + cost under churn, the
/// deterministic op log and the final placement census (the determinism
/// and leak assertions of the integration suite run on these).
#[derive(Clone, Debug)]
pub struct ChurnReport {
    pub seed: u64,
    pub scenario: String,
    /// Topology shape the storm ran against (`CxW`), so trajectory points
    /// from different shapes are never compared apples-to-oranges.
    pub clusters: usize,
    pub workers_per_cluster: usize,
    pub duration_s: f64,
    pub ops_issued: u64,
    pub unanswered_requests: usize,
    pub submits: u64,
    pub undeploys: u64,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub migrations: u64,
    pub workers_killed: usize,
    pub rejoins: u64,
    pub submit: OpStats,
    pub scale: OpStats,
    pub migrate: OpStats,
    pub undeploy: OpStats,
    pub api_errors: BTreeMap<String, u64>,
    /// Oakestra control-plane messages/bytes during the churn window.
    pub ctrl_msgs: u64,
    pub ctrl_bytes: u64,
    /// Messages per lifecycle mutation (submit+scale+migrate+undeploy).
    pub msgs_per_op: f64,
    /// Root-node control-plane CPU over the window, ms, and per mutation.
    pub root_cpu_ms: f64,
    pub root_cpu_ms_per_op: f64,
    /// Root API handler invocations per operation kind (`root.op.*`),
    /// the per-op cost attribution the perf trajectory tracks.
    pub root_ops: BTreeMap<String, u64>,
    /// Mean cluster-orchestrator-node CPU over the window, ms.
    pub cluster_cpu_ms_mean: f64,
    /// Cluster scheduler invocations and their cost distribution.
    pub sched_runs: usize,
    pub sched_ms_mean: f64,
    pub sched_ms_p95: f64,
    /// Root federation hot-path accounting: every `DelegateTask` sent,
    /// how many were priority-list spill continuations (attempt > 0),
    /// and how many top-K selections the root actually ran — under a
    /// spill storm `rank_ops` must stay ≈ delegations (one rank per
    /// instance, O(1) per spill step), NOT ≈ sends.
    pub delegation_sends: u64,
    pub spill_sends: u64,
    /// O(1) spill continuations (popped the precomputed priority list —
    /// no rank ran). The structural invariant `rank_ops ≤
    /// delegation_sends + placement_failed` holds because every top-K
    /// selection either produces a send or ends its delegation in
    /// failure; spill steps produce sends without ranking.
    pub spill_steps: u64,
    pub rank_ops: u64,
    pub placement_failed: u64,
    /// spill_sends / delegation_sends.
    pub spill_rate: f64,
    /// p95 of DelegateTask sends per delegation (1.0 = no spill).
    pub delegation_attempts_p95: f64,
    /// Cluster→root aggregate delta-coalescing: reports pushed vs ticks
    /// suppressed below the threshold.
    pub aggregate_sent: u64,
    pub aggregate_suppressed: u64,
    /// Event-loop lanes the storm ran on (1 = the classic sequential
    /// sim; `clusters + 1` when lane-sharded).
    pub lanes: usize,
    /// Same-tick delivery batching across all lanes: events drained and
    /// drain rounds — their ratio is the batching factor the raw-speed
    /// ROADMAP item gates on.
    pub lane_batch_events: u64,
    pub lane_batch_drains: u64,
    /// Host wall-clock seconds the whole run took (build + storm +
    /// drain) — the raw speed axis of the per-PR perf trajectory.
    /// Varies machine to machine; excluded from determinism checks.
    pub wall_clock_s: f64,
    /// Sim-queue state after the post-storm quiescence drain: total
    /// queued events (timers included) and in-flight messages. The
    /// latter must be 0 — a non-timer leftover means a message chain
    /// never converged.
    pub pending_events: usize,
    pub pending_non_timer: usize,
    pub leaked_instances: usize,
    pub leaked_capacity_mc: u64,
    /// Root-vs-placement consistency snapshot, taken during the quiet
    /// pre-drain hold (storms over, replacements still alive): every
    /// live instance id the root and the clusters disagree about. Must
    /// be empty — a non-empty diff means cluster-minted successors are
    /// invisible (or phantom records survive) at the root.
    pub census_mismatch: usize,
    pub census_diff: Vec<String>,
    /// Virtual ms (since sim start) at which the snapshot was taken.
    pub census_checked_at_ms: f64,
    /// Convergence watches abandoned at `watch_timeout_s`, and how many
    /// of those belonged to a service with *no* foot in a partitioned
    /// cluster during the watch window (`--strict` gates these at 0 —
    /// only a partition excuses an abandonment).
    pub watch_expired: u64,
    pub watch_expired_unexcused: u64,
    /// Partition-tolerance accounting; `None` unless the scenario
    /// installed uplink cuts.
    pub partition: Option<PartitionStats>,
    /// Crash-recovery accounting; `None` unless the scenario installed
    /// orchestrator crashes.
    pub crash: Option<CrashStats>,
    pub op_log: Vec<String>,
    pub census: Vec<String>,
}

/// Live-instance disagreements between the root database and the actual
/// cluster placement: the symmetric difference of the two live-id sets.
/// `root-only` rows are phantom records (the root believes in an
/// instance no cluster holds); `cluster-only` rows are invisible
/// replacements (placed capacity the root cannot see — the bug class
/// root-visible replacement tracking closes).
pub fn census_diff(tb: &OakTestbed) -> Vec<String> {
    let root = tb
        .sim
        .actor_as::<RootOrchestrator>(tb.root)
        .expect("root actor");
    let mut root_live: BTreeSet<InstanceId> = BTreeSet::new();
    for rec in root.db.services() {
        for i in &rec.instances {
            if !i.state.is_terminal() {
                root_live.insert(i.instance);
            }
        }
    }
    let mut cluster_live: BTreeSet<InstanceId> = BTreeSet::new();
    for (_, orch) in &tb.clusters {
        // A crash-stopped orchestrator has no state at all: every
        // instance the root still tracks there shows up `root-only`
        // until the restarted incarnation's census rebuild converges.
        let Some(c) = tb.sim.actor_as::<ClusterOrchestrator>(*orch) else {
            continue;
        };
        for (iid, _, _, _) in c.live_instances() {
            cluster_live.insert(iid);
        }
    }
    let mut out = Vec::new();
    for i in root_live.difference(&cluster_live) {
        out.push(format!("root-only {i}"));
    }
    for i in cluster_live.difference(&root_live) {
        out.push(format!("cluster-only {i}"));
    }
    out
}

/// Sorted snapshot of every instance the control plane still knows about,
/// across all three tiers. Two same-seed runs must produce identical
/// censuses; after a full drain it must contain no live rows.
pub fn placement_census(tb: &OakTestbed) -> Vec<String> {
    let mut out = Vec::new();
    let root = tb
        .sim
        .actor_as::<RootOrchestrator>(tb.root)
        .expect("root actor");
    for rec in root.db.services() {
        for i in &rec.instances {
            out.push(format!(
                "root {} {} task{} {:?} worker={} gen={}",
                rec.spec.id,
                i.instance,
                i.task.index,
                i.state,
                i.worker.map(|w| w.to_string()).unwrap_or_else(|| "-".into()),
                i.generation
            ));
        }
    }
    for (cnode, orch) in &tb.clusters {
        let c = tb
            .sim
            .actor_as::<ClusterOrchestrator>(*orch)
            .expect("cluster actor");
        for (iid, task, node, state) in c.live_instances() {
            out.push(format!(
                "cluster@{cnode} {} {} on {} {:?}",
                task.service, iid, node, state
            ));
        }
        let r = c.reserved();
        out.push(format!(
            "cluster@{cnode} reserved cpu={} mem={}",
            r.cpu_millicores, r.mem_mb
        ));
    }
    for (wnode, engine) in &tb.workers {
        let w = tb
            .sim
            .actor_as::<WorkerEngine>(*engine)
            .expect("worker actor");
        let ids: Vec<String> = w.hosted_ids().iter().map(|i| i.to_string()).collect();
        out.push(format!(
            "worker {wnode} hosted=[{}] used_cpu={}",
            ids.join(","),
            w.used.cpu_millicores
        ));
    }
    out
}

/// Count leaked instances / reserved capacity after a full drain: live
/// root records, cluster records, cluster reservations and containers
/// hosted by live (non-failed) workers all must be gone.
pub fn count_leaks(tb: &OakTestbed, failed: &BTreeSet<NodeId>) -> (usize, u64) {
    let mut instances = 0usize;
    let mut capacity_mc = 0u64;
    let root = tb
        .sim
        .actor_as::<RootOrchestrator>(tb.root)
        .expect("root actor");
    for rec in root.db.services() {
        instances += rec
            .instances
            .iter()
            .filter(|i| !i.state.is_terminal())
            .count();
    }
    for (_, orch) in &tb.clusters {
        let c = tb
            .sim
            .actor_as::<ClusterOrchestrator>(*orch)
            .expect("cluster actor");
        instances += c.live_instances().len();
        capacity_mc += c.reserved().cpu_millicores as u64;
    }
    for (wnode, engine) in &tb.workers {
        if failed.contains(wnode) {
            continue; // crashed hardware: its containers died with it
        }
        let w = tb
            .sim
            .actor_as::<WorkerEngine>(*engine)
            .expect("worker actor");
        instances += w.hosted_count();
        capacity_mc += w.used.cpu_millicores as u64;
    }
    (instances, capacity_mc)
}

/// Build the testbed, run the configured churn storm to completion and
/// collect the report. Fully deterministic in `cfg.seed` (wall-clock
/// aside, which measures the host, not the simulation).
pub fn run_churn(cfg: &ChurnConfig) -> ChurnReport {
    // lint: allow(ambient-time, measures host wall-clock; never feeds the simulation)
    let wall_start = std::time::Instant::now();
    let mut tb = build_oakestra(OakTestbedConfig {
        seed: cfg.seed,
        clusters: cfg.clusters,
        workers_per_cluster: cfg.workers_per_cluster,
        scheduler: cfg.scheduler,
        threads: cfg.threads,
        ..OakTestbedConfig::default()
    });
    tb.warm_up();

    let oak_labels = [
        crate::messaging::labels::WORKER_TO_CLUSTER,
        crate::messaging::labels::CLUSTER_TO_WORKER,
        crate::messaging::labels::CLUSTER_TO_ROOT,
        crate::messaging::labels::ROOT_TO_CLUSTER,
    ];
    let m0 = tb.sim.metrics();
    let msgs0: u64 = oak_labels.iter().map(|l| m0.msgs(l)).sum();
    let bytes0: u64 = oak_labels.iter().map(|l| m0.bytes(l)).sum();
    drop(m0);

    let start = tb.sim.now() + SimTime::from_secs(1.0);
    let driver_id = tb
        .sim
        .add_actor(tb.root_node, Box::new(ChurnDriver::new(cfg.clone(), tb.root)));
    tb.sim
        .inject(start, driver_id, SimMsg::Timer(TimerKind::Custom(0)));

    // Seeded partition schedule: a prefix of the cluster uplinks gets a
    // series of cut/heal windows with per-cluster jitter. Installed now,
    // before events drain past the first `from` — the schedule is part
    // of the run's seed-determined identity, never mutated mid-storm.
    // Rows: (cluster index, from, until, is_flap).
    let mut partition_windows: Vec<(usize, SimTime, SimTime, bool)> = Vec::new();
    if cfg.scenario.partitions() && cfg.partition_clusters > 0 && cfg.partition_cycles > 0 {
        let mut prng = Rng::seeded(cfg.seed ^ 0x9A12_7C0F_FEE0_DD01);
        for ci in 0..cfg.partition_clusters.min(cfg.clusters) {
            let mut at = start
                + SimTime::from_secs(cfg.partition_lead_s)
                + SimTime::from_millis(prng.below(5_000) as f64);
            for cycle in 0..cfg.partition_cycles {
                // The middle window of each cluster's schedule is a
                // flap: Suspect-only, so it exercises outbox buffering
                // and the lease's false-trip resistance without a
                // detection/resync round.
                let flap = cfg.partition_cycles >= 3 && cycle == cfg.partition_cycles / 2;
                let len = if flap {
                    cfg.partition_flap_s
                } else {
                    cfg.partition_s
                };
                let until = at + SimTime::from_secs(len);
                tb.cut_cluster_uplink(ci, at, until);
                partition_windows.push((ci, at, until, flap));
                at = until
                    + SimTime::from_secs(cfg.partition_gap_s)
                    + SimTime::from_millis(prng.below(3_000) as f64);
            }
        }
    }
    // Heal times of the full cuts, in order: after each one the harness
    // polls the census until root and clusters agree again.
    let mut pending_heals: Vec<SimTime> = partition_windows
        .iter()
        .filter(|w| !w.3)
        .map(|w| w.2)
        .collect();
    pending_heals.sort();
    let mut heal_convergence = Histogram::default();

    // Seeded orchestrator-crash schedule: a prefix of the clusters gets
    // kill/restart cycles with per-cluster jitter. Like the partition
    // schedule it is fixed up-front — part of the run's seed-determined
    // identity — but unlike uplink cuts the kills cannot be installed
    // into the network: crash/restart mutate the actor table, which only
    // the testbed (not an in-sim actor) may touch, so the events are
    // applied at slice boundaries below. Windows: (cluster index, kill
    // at, restart at, is_long).
    let mut crash_windows: Vec<(usize, SimTime, SimTime, bool)> = Vec::new();
    if cfg.scenario.crashes() && cfg.crash_clusters > 0 && cfg.crash_cycles > 0 {
        let mut crng = Rng::seeded(cfg.seed ^ 0xC4A5_4ED0_0B5E_55ED);
        for ci in 0..cfg.crash_clusters.min(cfg.clusters) {
            let mut at = start
                + SimTime::from_secs(cfg.crash_lead_s)
                + SimTime::from_millis(crng.below(4_000) as f64);
            for cycle in 0..cfg.crash_cycles {
                // Every second cycle is a long outage: downtime past the
                // 30 s Partitioned lease, so the root escalates and the
                // restart is absorbed like a healed partition. The rest
                // are short: the restart re-registers inside the Suspect
                // window and must *cancel* the escalation.
                let long = cycle % 2 == 1;
                let down = if long {
                    cfg.crash_down_long_s
                } else {
                    cfg.crash_down_s
                };
                let back = at + SimTime::from_secs(down);
                crash_windows.push((ci, at, back, long));
                at = back
                    + SimTime::from_secs(cfg.crash_gap_s)
                    + SimTime::from_millis(crng.below(3_000) as f64);
            }
        }
    }
    // The schedule flattened to (time, cluster, is_restart) events in
    // application order, and the per-outage convergence watch list
    // (kill at, restart at) ordered by restart time.
    let mut crash_events: Vec<(SimTime, usize, bool)> = crash_windows
        .iter()
        .flat_map(|&(ci, at, back, _)| [(at, ci, false), (back, ci, true)])
        .collect();
    crash_events.sort();
    let mut pending_crashes: Vec<(SimTime, SimTime)> = crash_windows
        .iter()
        .map(|&(_, at, back, _)| (at, back))
        .collect();
    pending_crashes.sort_by_key(|&(_, back)| back);
    let mut crash_convergence = Histogram::default();
    let mut crash_kills = 0u64;
    let mut crash_restarts = 0u64;
    let mut crash_inflight_dropped = 0u64;

    let horizon = start
        + SimTime::from_secs(
            cfg.duration_s + cfg.pre_drain_hold_s + cfg.settle_s + 5.0,
        );
    // Consistency snapshot late in the quiet hold: storms are over and
    // in-flight lifecycle ops have converged, but nothing has been
    // drained yet — invisible replacements (or phantom root records)
    // would show here.
    let census_at =
        start + SimTime::from_secs(cfg.duration_s + cfg.pre_drain_hold_s * 0.75);
    // Run in one-virtual-second slices: worker *rejoins* need new sim
    // nodes/actors, which only the testbed (not an in-sim actor) can
    // create, so due rejoins are applied between slices. Slice
    // boundaries are fixed virtual times — fully seed-deterministic.
    let slice = SimTime::from_secs(1.0);
    let mut census_diff_rows: Option<(SimTime, Vec<String>)> = None;
    let mut next = start;
    while next < horizon {
        next = std::cmp::min(next + slice, horizon);
        tb.sim.run_until(next);
        // Apply due orchestrator kills/restarts. Slice boundaries are
        // fixed virtual times, so the quantized apply instants — and
        // everything downstream of them — are seed-deterministic and
        // identical for every `--threads` count.
        while let Some(&(at, ci, is_restart)) = crash_events.first() {
            if at > next {
                break;
            }
            crash_events.remove(0);
            if is_restart {
                let epoch = tb.restart_cluster(ci);
                crash_restarts += 1;
                if let Some(d) = tb.sim.actor_as_mut::<ChurnDriver>(driver_id) {
                    d.note_cluster_restarted(next, ci, epoch);
                }
            } else {
                let dropped = tb.crash_cluster(ci);
                crash_kills += 1;
                crash_inflight_dropped += dropped as u64;
                if let Some(d) = tb.sim.actor_as_mut::<ChurnDriver>(driver_id) {
                    d.note_cluster_crashed(next, ci, dropped);
                }
            }
        }
        let due = tb
            .sim
            .actor_as_mut::<ChurnDriver>(driver_id)
            .map(|d| d.take_due_rejoins(next))
            .unwrap_or_default();
        for old in due {
            let fresh = tb.revive_worker(old);
            if let Some(d) = tb.sim.actor_as_mut::<ChurnDriver>(driver_id) {
                // Stamped with the slice boundary — the moment the
                // revival is actually applied — so the op log stays
                // chronological.
                d.note_rejoined(next, old, fresh);
            }
        }
        if census_diff_rows.is_none() && next >= census_at {
            census_diff_rows = Some((next, census_diff(&tb)));
        }
        // Heal-to-convergence: once a heal has elapsed, the root's
        // records and the clusters' placements must re-agree. The first
        // slice boundary where the census diff is empty closes every
        // elapsed heal (storm-transient delegation rows keep the diff
        // non-empty for a boundary or two — that latency is real and
        // belongs in the measurement).
        while let Some(&healed_at) = pending_heals.first() {
            if healed_at > next || !census_diff(&tb).is_empty() {
                break;
            }
            heal_convergence.record(next.saturating_sub(healed_at).as_millis());
            pending_heals.remove(0);
        }
        // Crash-to-converged: once a restart has elapsed, the rebuilt
        // census must re-agree with the root. The first slice boundary
        // where the diff is empty closes every elapsed outage, measured
        // from the *kill* — downtime plus the whole recover/resync tail
        // is the latency a crashed coordinator actually costs.
        while let Some(&(killed_at, back_at)) = pending_crashes.first() {
            if back_at > next || !census_diff(&tb).is_empty() {
                break;
            }
            crash_convergence.record(next.saturating_sub(killed_at).as_millis());
            pending_crashes.remove(0);
        }
    }
    let (census_checked_at, census_gap) =
        census_diff_rows.unwrap_or((horizon, Vec::new()));

    // Drain every in-flight message (timers keep ticking, but a message
    // still queued after the settle window is a convergence failure the
    // leak audit must see as state, not as something about to happen).
    tb.sim.run_to_quiescence(horizon + SimTime::from_secs(5.0));
    let pending_events = tb.sim.pending_events();
    let pending_non_timer = tb.sim.pending_non_timer_events();

    let m = tb.sim.metrics();
    let msgs1: u64 = oak_labels.iter().map(|l| m.msgs(l)).sum();
    let bytes1: u64 = oak_labels.iter().map(|l| m.bytes(l)).sum();
    let elapsed_ms = horizon.saturating_sub(start).as_millis();
    let root_cpu_ms = m
        .usage(tb.root_node)
        .map(|u| u.cpu_util(start, horizon) * elapsed_ms)
        .unwrap_or(0.0);
    let cluster_cpu: Vec<f64> = tb
        .clusters
        .iter()
        .map(|(n, _)| {
            m.usage(*n)
                .map(|u| u.cpu_util(start, horizon) * elapsed_ms)
                .unwrap_or(0.0)
        })
        .collect();

    let submit = OpStats::from(m.histogram(lifecycle::SUBMIT_TO_RUNNING_MS));
    let scale = OpStats::from(m.histogram(lifecycle::SCALE_TO_CONVERGED_MS));
    let migrate = OpStats::from(m.histogram(lifecycle::MIGRATE_TO_CUTOVER_MS));
    let undeploy = OpStats::from(m.histogram(lifecycle::UNDEPLOY_TO_DRAINED_MS));
    let sched = m.histogram("cluster.sched_ms");
    let (sched_runs, sched_ms_mean, sched_ms_p95) = sched
        .map(|h| (h.count(), h.mean(), h.p95()))
        .unwrap_or((0, 0.0, 0.0));
    let root_ops: BTreeMap<String, u64> = m
        .counters_with_prefix("root.op.")
        .into_iter()
        .map(|(k, v)| (k.trim_start_matches("root.op.").to_string(), v))
        .collect();
    let delegation_sends = m.counter("root.op.delegate_send");
    let spill_sends = m.counter("root.op.spill_send");
    let spill_steps = m.counter("root.op.spill_step");
    let rank_ops = m.counter("root.op.rank");
    let placement_failed = m.counter("root.placement_failed");
    let delegation_attempts_p95 = m
        .histogram("root.delegation_attempts")
        .map(|h| h.p95())
        .unwrap_or(0.0);
    let aggregate_sent = m.counter("cluster.report_sent");
    let aggregate_suppressed = m.counter("cluster.report_suppressed");
    let lanes = tb.sim.lane_count();
    let lane_batch_events = m.counter(crate::sim::lane::BATCH_EVENTS_KEY);
    let lane_batch_drains = m.counter(crate::sim::lane::BATCH_DRAINS_KEY);

    let d = tb
        .sim
        .actor_as::<ChurnDriver>(driver_id)
        .expect("churn driver actor");
    let mutations =
        (d.submits + d.scale_ups + d.scale_downs + d.migrations + d.undeploys).max(1);
    let (leaked_instances, leaked_capacity_mc) = count_leaks(&tb, &d.failed_workers);

    // Watch-abandonment audit: an expired watch is excused only when its
    // service had an instance in a cluster whose uplink was cut — or
    // whose orchestrator was crashed/recovering — at some point during
    // the watch window (both legitimately stall convergence past any
    // timeout). Everything else is a real convergence failure `--strict`
    // must surface. Crash windows are padded past the restart instant:
    // a restarted orchestrator is still census-rebuilding and resyncing
    // for a few seconds after it comes back.
    let crash_excuse_pad = SimTime::from_secs(10.0);
    let excuse_windows: Vec<(usize, SimTime, SimTime)> = partition_windows
        .iter()
        .map(|&(ci, from, until, _)| (ci, from, until))
        .chain(
            crash_windows
                .iter()
                .map(|&(ci, from, until, _)| (ci, from, until + crash_excuse_pad)),
        )
        .collect();
    let watch_cutoff = SimTime::from_secs(cfg.watch_timeout_s);
    let watch_expired = d.expired_watches.len() as u64;
    let watch_expired_unexcused = d
        .expired_watches
        .iter()
        .filter(|(at, _, nodes)| {
            let w0 = at.saturating_sub(watch_cutoff);
            let overlapping: Vec<usize> = excuse_windows
                .iter()
                .filter(|(_, from, until)| *from < *at && *until > w0)
                .map(|(ci, _, _)| *ci)
                .collect();
            let excused = !overlapping.is_empty()
                && (nodes.is_empty()
                    || nodes.iter().any(|n| {
                        tb.worker_cluster
                            .get(n)
                            .is_some_and(|ci| overlapping.contains(ci))
                    }));
            !excused
        })
        .count() as u64;

    let partition = if partition_windows.is_empty() {
        None
    } else {
        let ops = |h: Option<&Histogram>| OpStats::from(h);
        Some(PartitionStats {
            cuts: partition_windows.iter().filter(|w| !w.3).count() as u64,
            flaps: partition_windows.iter().filter(|w| w.3).count() as u64,
            detected: m.counter("root.partition_detected"),
            healed: m.counter("root.partition_healed"),
            resyncs: m.counter("root.resync_requested"),
            snapshots: m.counter("cluster.resync_sent"),
            services_degraded: m.counter("root.services_degraded"),
            services_restored: m.counter("root.services_restored"),
            degraded_window: ops(m.histogram("root.degraded_window_ms")),
            heal_to_convergence: ops(Some(&heal_convergence)),
            unconverged_heals: pending_heals.len(),
            resync_adopted: m.counter("root.resync_adopted"),
            resync_duplicates: m.counter("root.resync_adopt_duplicate"),
            resync_conflicts: m.counter("root.resync_adopt_conflict"),
            resync_orphans: m.counter("root.resync_orphans"),
            resync_lost: m.counter("root.resync_lost"),
            resync_settled: m.counter("root.resync_settled_delegations"),
            uplink_partitioned: m.counter("cluster.uplink_partitioned"),
            uplink_healed: m.counter("cluster.uplink_healed"),
            outbox_buffered: m.counter("cluster.outbox_buffered"),
            outbox_replayed: m.counter("cluster.outbox_replayed"),
            outbox_retry: m.counter("cluster.outbox_retry"),
            outbox_dropped: m.counter("cluster.outbox_dropped"),
            retransmits: m.counter("net.retransmit"),
            dropped_after_retry: m.counter("net.dropped_after_retry"),
            net_lost: m.counter("net.lost"),
        })
    };

    let crash = if crash_windows.is_empty() {
        None
    } else {
        Some(CrashStats {
            kills: crash_kills,
            restarts: crash_restarts,
            short_outages: crash_windows.iter().filter(|w| !w.3).count() as u64,
            long_outages: crash_windows.iter().filter(|w| w.3).count() as u64,
            inflight_dropped: crash_inflight_dropped,
            escalated: m.counter("root.partition_detected"),
            restart_registers: m.counter("root.cluster_restarted"),
            stale_registers: m.counter("root.register_stale_epoch"),
            worker_reregistered: m.counter("worker.reregistered"),
            epoch_fenced: m.counter("worker.epoch_fenced"),
            census_seeded: m.counter("cluster.census_seeded"),
            recovery_completed: m.counter("cluster.recovery_completed"),
            recovery_cutover: m.counter("cluster.recovery_cutover"),
            resync_deferred: m.counter("cluster.resync_deferred"),
            delegations_refused: m.counter("cluster.delegation_while_recovering"),
            resync_adopted: m.counter("root.resync_adopted"),
            resync_duplicates: m.counter("root.resync_adopt_duplicate"),
            resync_conflicts: m.counter("root.resync_adopt_conflict"),
            resync_orphans: m.counter("root.resync_orphans"),
            resync_lost: m.counter("root.resync_lost"),
            resync_settled: m.counter("root.resync_settled_delegations"),
            redelegated: m.counter("root.resync_redelegated"),
            crash_to_converged: OpStats::from(Some(&crash_convergence)),
            unconverged_crashes: pending_crashes.len(),
            lost_replicas: census_gap
                .iter()
                .filter(|r| r.starts_with("root-only"))
                .count(),
        })
    };

    ChurnReport {
        seed: cfg.seed,
        scenario: format!("{:?}", cfg.scenario).to_ascii_lowercase(),
        clusters: cfg.clusters,
        workers_per_cluster: cfg.workers_per_cluster,
        duration_s: cfg.duration_s,
        ops_issued: d.client.issued(),
        unanswered_requests: d.client.outstanding().len(),
        submits: d.submits,
        undeploys: d.undeploys,
        scale_ups: d.scale_ups,
        scale_downs: d.scale_downs,
        migrations: d.migrations,
        workers_killed: d.failed_workers.len(),
        rejoins: d.rejoins,
        submit,
        scale,
        migrate,
        undeploy,
        api_errors: d
            .api_errors
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect(),
        ctrl_msgs: msgs1 - msgs0,
        ctrl_bytes: bytes1 - bytes0,
        msgs_per_op: (msgs1 - msgs0) as f64 / mutations as f64,
        root_cpu_ms,
        root_cpu_ms_per_op: root_cpu_ms / mutations as f64,
        root_ops,
        cluster_cpu_ms_mean: crate::util::mean(&cluster_cpu),
        sched_runs,
        sched_ms_mean,
        sched_ms_p95,
        delegation_sends,
        spill_sends,
        spill_steps,
        rank_ops,
        placement_failed,
        spill_rate: spill_sends as f64 / delegation_sends.max(1) as f64,
        delegation_attempts_p95,
        aggregate_sent,
        aggregate_suppressed,
        lanes,
        lane_batch_events,
        lane_batch_drains,
        wall_clock_s: wall_start.elapsed().as_secs_f64(),
        pending_events,
        pending_non_timer,
        leaked_instances,
        leaked_capacity_mc,
        census_mismatch: census_gap.len(),
        census_diff: census_gap,
        census_checked_at_ms: census_checked_at.as_millis(),
        watch_expired,
        watch_expired_unexcused,
        partition,
        crash,
        op_log: d.ops.clone(),
        census: placement_census(&tb),
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

impl ChurnReport {
    /// Render as the `BENCH_churn.json` artifact (hand-rolled — the
    /// offline crate set has no serde).
    pub fn to_json(&self) -> String {
        let stats = |s: &OpStats| {
            format!(
                "{{\"count\": {}, \"p50_ms\": {:.3}, \"p95_ms\": {:.3}}}",
                s.count, s.p50_ms, s.p95_ms
            )
        };
        let errors: Vec<String> = self
            .api_errors
            .iter()
            .map(|(k, v)| format!("\"{}\": {v}", json_escape(k)))
            .collect();
        let root_ops: Vec<String> = self
            .root_ops
            .iter()
            .map(|(k, v)| format!("\"{}\": {v}", json_escape(k)))
            .collect();
        let strings = |xs: &[String]| {
            let rows: Vec<String> = xs
                .iter()
                .map(|l| format!("    \"{}\"", json_escape(l)))
                .collect();
            if rows.is_empty() {
                "[]".to_string()
            } else {
                format!("[\n{}\n  ]", rows.join(",\n"))
            }
        };
        // Partition runs carry an extra "partition" object; every other
        // scenario omits it entirely (same pattern as "sim" below).
        let partition_json = match &self.partition {
            None => String::new(),
            Some(p) => format!(
                "\"partition\": {{\"cuts\": {}, \"flaps\": {}, \"detected\": {}, \
                 \"healed\": {}, \"resyncs\": {}, \"snapshots\": {}, \
                 \"services_degraded\": {}, \"services_restored\": {},\n    \
                 \"degraded_window_ms\": {},\n    \
                 \"heal_to_convergence_ms\": {},\n    \
                 \"unconverged_heals\": {},\n    \
                 \"resync\": {{\"adopted\": {}, \"duplicates\": {}, \"conflicts\": {}, \
                 \"orphans\": {}, \"lost\": {}, \"settled\": {}}},\n    \
                 \"uplink\": {{\"partitioned\": {}, \"healed\": {}, \
                 \"outbox_buffered\": {}, \"outbox_replayed\": {}, \
                 \"outbox_retry\": {}, \"outbox_dropped\": {}}},\n    \
                 \"net\": {{\"retransmits\": {}, \"dropped_after_retry\": {}, \
                 \"lost\": {}}}}},\n  ",
                p.cuts,
                p.flaps,
                p.detected,
                p.healed,
                p.resyncs,
                p.snapshots,
                p.services_degraded,
                p.services_restored,
                stats(&p.degraded_window),
                stats(&p.heal_to_convergence),
                p.unconverged_heals,
                p.resync_adopted,
                p.resync_duplicates,
                p.resync_conflicts,
                p.resync_orphans,
                p.resync_lost,
                p.resync_settled,
                p.uplink_partitioned,
                p.uplink_healed,
                p.outbox_buffered,
                p.outbox_replayed,
                p.outbox_retry,
                p.outbox_dropped,
                p.retransmits,
                p.dropped_after_retry,
                p.net_lost,
            ),
        };
        // Crash runs carry an extra "crash" object; every other scenario
        // omits it entirely (same pattern as "partition" above).
        let crash_json = match &self.crash {
            None => String::new(),
            Some(c) => format!(
                "\"crash\": {{\"kills\": {}, \"restarts\": {}, \
                 \"short_outages\": {}, \"long_outages\": {}, \
                 \"inflight_dropped\": {}, \"escalated\": {},\n    \
                 \"registers\": {{\"restart\": {}, \"stale\": {}}},\n    \
                 \"workers\": {{\"reregistered\": {}, \"epoch_fenced\": {}}},\n    \
                 \"recovery\": {{\"census_seeded\": {}, \"completed\": {}, \
                 \"cutover\": {}, \"resync_deferred\": {}, \
                 \"delegations_refused\": {}}},\n    \
                 \"resync\": {{\"adopted\": {}, \"duplicates\": {}, \"conflicts\": {}, \
                 \"orphans\": {}, \"lost\": {}, \"settled\": {}, \
                 \"redelegated\": {}}},\n    \
                 \"crash_to_converged_ms\": {},\n    \
                 \"unconverged_crashes\": {}, \"lost_replicas\": {}}},\n  ",
                c.kills,
                c.restarts,
                c.short_outages,
                c.long_outages,
                c.inflight_dropped,
                c.escalated,
                c.restart_registers,
                c.stale_registers,
                c.worker_reregistered,
                c.epoch_fenced,
                c.census_seeded,
                c.recovery_completed,
                c.recovery_cutover,
                c.resync_deferred,
                c.delegations_refused,
                c.resync_adopted,
                c.resync_duplicates,
                c.resync_conflicts,
                c.resync_orphans,
                c.resync_lost,
                c.resync_settled,
                c.redelegated,
                stats(&c.crash_to_converged),
                c.unconverged_crashes,
                c.lost_replicas,
            ),
        };
        // Lane-sharded runs carry an extra "sim" object; the classic
        // single-lane sim omits it entirely so legacy reports stay
        // byte-identical to the pre-lane golden fixture.
        let sim_json = if self.lanes > 1 {
            format!(
                "\"sim\": {{\"lanes\": {}, \"lane\": {{\"batch\": {:.2}, \
                 \"batch_events\": {}, \"batch_drains\": {}}}}},\n  ",
                self.lanes,
                self.lane_batch_events as f64 / self.lane_batch_drains.max(1) as f64,
                self.lane_batch_events,
                self.lane_batch_drains,
            )
        } else {
            String::new()
        };
        format!(
            "{{\n  \"bench\": \"churn\",\n  \"seed\": {},\n  \"scenario\": \"{}\",\n  \
             \"topology\": {{\"clusters\": {}, \"workers_per_cluster\": {}, \
             \"shape\": \"{}x{}\"}},\n  {}\
             \"duration_s\": {},\n  \"wall_clock_s\": {:.3},\n  \
             \"ops_issued\": {},\n  \"unanswered_requests\": {},\n  \
             \"counts\": {{\"submit\": {}, \"undeploy\": {}, \"scale_up\": {}, \
             \"scale_down\": {}, \"migrate\": {}, \"workers_killed\": {}, \
             \"rejoins\": {}}},\n  \
             \"latency_ms\": {{\n    \"submit_to_running\": {},\n    \
             \"scale_to_converged\": {},\n    \"migrate_to_cutover\": {},\n    \
             \"undeploy_to_drained\": {}\n  }},\n  \
             \"control_plane\": {{\"msgs\": {}, \"bytes\": {}, \"msgs_per_op\": {:.2}, \
             \"root_cpu_ms\": {:.1}, \"root_cpu_ms_per_op\": {:.3}, \
             \"cluster_cpu_ms_mean\": {:.1}, \"sched_runs\": {}, \
             \"sched_ms_mean\": {:.3}, \"sched_ms_p95\": {:.3}}},\n  \
             \"federation\": {{\"delegation_sends\": {}, \"spill_sends\": {}, \
             \"spill_steps\": {}, \"spill_rate\": {:.4}, \"rank_ops\": {}, \
             \"placement_failed\": {}, \
             \"delegation_attempts_p95\": {:.3}, \"aggregate_sent\": {}, \
             \"aggregate_suppressed\": {}}},\n  \
             \"root_ops\": {{{}}},\n  \
             \"quiescence\": {{\"pending_events\": {}, \"pending_non_timer\": {}}},\n  \
             \"api_errors\": {{{}}},\n  \
             \"leaks\": {{\"instances\": {}, \"capacity_mc\": {}}},\n  \
             \"census_consistency\": {{\"checked_at_ms\": {:.1}, \
             \"mismatch\": {}, \"diff\": {}}},\n  \
             \"watches\": {{\"expired\": {}, \"unexcused\": {}}},\n  {}{}\
             \"op_log\": {},\n  \"census\": {}\n}}\n",
            self.seed,
            self.scenario,
            self.clusters,
            self.workers_per_cluster,
            self.clusters,
            self.workers_per_cluster,
            sim_json,
            self.duration_s,
            self.wall_clock_s,
            self.ops_issued,
            self.unanswered_requests,
            self.submits,
            self.undeploys,
            self.scale_ups,
            self.scale_downs,
            self.migrations,
            self.workers_killed,
            self.rejoins,
            stats(&self.submit),
            stats(&self.scale),
            stats(&self.migrate),
            stats(&self.undeploy),
            self.ctrl_msgs,
            self.ctrl_bytes,
            self.msgs_per_op,
            self.root_cpu_ms,
            self.root_cpu_ms_per_op,
            self.cluster_cpu_ms_mean,
            self.sched_runs,
            self.sched_ms_mean,
            self.sched_ms_p95,
            self.delegation_sends,
            self.spill_sends,
            self.spill_steps,
            self.spill_rate,
            self.rank_ops,
            self.placement_failed,
            self.delegation_attempts_p95,
            self.aggregate_sent,
            self.aggregate_suppressed,
            root_ops.join(", "),
            self.pending_events,
            self.pending_non_timer,
            errors.join(", "),
            self.leaked_instances,
            self.leaked_capacity_mc,
            self.census_checked_at_ms,
            self.census_mismatch,
            strings(&self.census_diff),
            self.watch_expired,
            self.watch_expired_unexcused,
            partition_json,
            crash_json,
            strings(&self.op_log),
            strings(&self.census),
        )
    }

    /// Human-readable tables for the CLI. Empty histograms render as
    /// `n/a`, never as NaN or a misleading 0.0.
    pub fn tables(&self) -> Vec<Table> {
        let mut lat = Table::new(
            "Churn — lifecycle-op latency (ms)",
            &["op", "count", "p50", "p95"],
        );
        for (name, s) in [
            ("submit->running", &self.submit),
            ("scale->converged", &self.scale),
            ("migrate->cutover", &self.migrate),
            ("undeploy->drained", &self.undeploy),
        ] {
            lat.row(vec![
                name.to_string(),
                s.count.to_string(),
                fmt_stat(s.count, s.p50_ms),
                fmt_stat(s.count, s.p95_ms),
            ]);
        }
        let mut cost = Table::new(
            "Churn — control-plane cost",
            &["metric", "value"],
        );
        cost.row(vec!["ops_issued".into(), self.ops_issued.to_string()]);
        cost.row(vec![
            "mutations".into(),
            (self.submits + self.scale_ups + self.scale_downs + self.migrations
                + self.undeploys)
                .to_string(),
        ]);
        cost.row(vec!["ctrl_msgs".into(), self.ctrl_msgs.to_string()]);
        cost.row(vec!["msgs_per_op".into(), format!("{:.2}", self.msgs_per_op)]);
        cost.row(vec![
            "root_cpu_ms_per_op".into(),
            format!("{:.3}", self.root_cpu_ms_per_op),
        ]);
        cost.row(vec![
            "cluster_cpu_ms_mean".into(),
            format!("{:.1}", self.cluster_cpu_ms_mean),
        ]);
        cost.row(vec![
            "sched_runs".into(),
            self.sched_runs.to_string(),
        ]);
        cost.row(vec![
            "sched_ms_mean".into(),
            fmt_stat(self.sched_runs, self.sched_ms_mean),
        ]);
        cost.row(vec![
            "delegation_sends".into(),
            self.delegation_sends.to_string(),
        ]);
        cost.row(vec![
            "spill_rate".into(),
            format!("{:.3}", self.spill_rate),
        ]);
        cost.row(vec!["rank_ops".into(), self.rank_ops.to_string()]);
        cost.row(vec![
            "delegation_attempts_p95".into(),
            format!("{:.2}", self.delegation_attempts_p95),
        ]);
        cost.row(vec![
            "aggregate_coalescing".into(),
            format!(
                "{} sent / {} suppressed",
                self.aggregate_sent, self.aggregate_suppressed
            ),
        ]);
        cost.row(vec![
            "wall_clock_s".into(),
            format!("{:.2}", self.wall_clock_s),
        ]);
        if self.lanes > 1 {
            cost.row(vec!["sim_lanes".into(), self.lanes.to_string()]);
            cost.row(vec![
                "lane_batch".into(),
                format!(
                    "{:.2} ({} events / {} drains)",
                    self.lane_batch_events as f64 / self.lane_batch_drains.max(1) as f64,
                    self.lane_batch_events,
                    self.lane_batch_drains
                ),
            ]);
        }
        cost.row(vec![
            "pending_non_timer".into(),
            self.pending_non_timer.to_string(),
        ]);
        cost.row(vec![
            "workers_killed".into(),
            self.workers_killed.to_string(),
        ]);
        cost.row(vec!["rejoins".into(), self.rejoins.to_string()]);
        cost.row(vec![
            "census_mismatch".into(),
            self.census_mismatch.to_string(),
        ]);
        cost.row(vec![
            "leaked_instances".into(),
            self.leaked_instances.to_string(),
        ]);
        cost.row(vec![
            "leaked_capacity_mc".into(),
            self.leaked_capacity_mc.to_string(),
        ]);
        cost.row(vec![
            "watch_expired".into(),
            format!(
                "{} ({} unexcused)",
                self.watch_expired, self.watch_expired_unexcused
            ),
        ]);
        let mut out = vec![lat, cost];
        if let Some(p) = &self.partition {
            out.push(self.partition_table(p));
        }
        if let Some(c) = &self.crash {
            out.push(self.crash_table(c));
        }
        out
    }

    fn partition_table(&self, p: &PartitionStats) -> Table {
        let mut part = Table::new(
            "Churn — partition tolerance",
            &["metric", "value"],
        );
        part.row(vec![
            "windows".into(),
            format!("{} cuts / {} flaps", p.cuts, p.flaps),
        ]);
        part.row(vec![
            "detected/healed".into(),
            format!("{} / {}", p.detected, p.healed),
        ]);
        part.row(vec![
            "resyncs".into(),
            format!("{} requested / {} snapshots", p.resyncs, p.snapshots),
        ]);
        part.row(vec![
            "services degraded/restored".into(),
            format!("{} / {}", p.services_degraded, p.services_restored),
        ]);
        part.row(vec![
            "degraded_window_ms p50/p95".into(),
            format!(
                "{} / {}",
                fmt_stat(p.degraded_window.count, p.degraded_window.p50_ms),
                fmt_stat(p.degraded_window.count, p.degraded_window.p95_ms)
            ),
        ]);
        part.row(vec![
            "heal_to_convergence_ms p50/p95".into(),
            format!(
                "{} / {}",
                fmt_stat(p.heal_to_convergence.count, p.heal_to_convergence.p50_ms),
                fmt_stat(p.heal_to_convergence.count, p.heal_to_convergence.p95_ms)
            ),
        ]);
        part.row(vec![
            "unconverged_heals".into(),
            p.unconverged_heals.to_string(),
        ]);
        part.row(vec![
            "resync adopted/dup/conflict".into(),
            format!(
                "{} / {} / {}",
                p.resync_adopted, p.resync_duplicates, p.resync_conflicts
            ),
        ]);
        part.row(vec![
            "resync orphans/lost/settled".into(),
            format!("{} / {} / {}", p.resync_orphans, p.resync_lost, p.resync_settled),
        ]);
        part.row(vec![
            "outbox buffered/replayed/dropped".into(),
            format!(
                "{} / {} / {}",
                p.outbox_buffered, p.outbox_replayed, p.outbox_dropped
            ),
        ]);
        part.row(vec![
            "net retransmit/dropped/lost".into(),
            format!(
                "{} / {} / {}",
                p.retransmits, p.dropped_after_retry, p.net_lost
            ),
        ]);
        part
    }

    fn crash_table(&self, c: &CrashStats) -> Table {
        let mut t = Table::new(
            "Churn — coordinator crash recovery",
            &["metric", "value"],
        );
        t.row(vec![
            "outages".into(),
            format!(
                "{} kills / {} restarts ({} short, {} long)",
                c.kills, c.restarts, c.short_outages, c.long_outages
            ),
        ]);
        t.row(vec![
            "inflight_dropped".into(),
            c.inflight_dropped.to_string(),
        ]);
        t.row(vec![
            "escalated (long outages only)".into(),
            c.escalated.to_string(),
        ]);
        t.row(vec![
            "registers restart/stale".into(),
            format!("{} / {}", c.restart_registers, c.stale_registers),
        ]);
        t.row(vec![
            "workers reregistered/fenced".into(),
            format!("{} / {}", c.worker_reregistered, c.epoch_fenced),
        ]);
        t.row(vec![
            "census_seeded".into(),
            c.census_seeded.to_string(),
        ]);
        t.row(vec![
            "recovery completed/cutover".into(),
            format!("{} / {}", c.recovery_completed, c.recovery_cutover),
        ]);
        t.row(vec![
            "resync deferred / delegations refused".into(),
            format!("{} / {}", c.resync_deferred, c.delegations_refused),
        ]);
        t.row(vec![
            "resync adopted/dup/conflict".into(),
            format!(
                "{} / {} / {}",
                c.resync_adopted, c.resync_duplicates, c.resync_conflicts
            ),
        ]);
        t.row(vec![
            "resync orphans/lost/settled/redelegated".into(),
            format!(
                "{} / {} / {} / {}",
                c.resync_orphans, c.resync_lost, c.resync_settled, c.redelegated
            ),
        ]);
        t.row(vec![
            "crash_to_converged_ms p50/p95".into(),
            format!(
                "{} / {}",
                fmt_stat(c.crash_to_converged.count, c.crash_to_converged.p50_ms),
                fmt_stat(c.crash_to_converged.count, c.crash_to_converged.p95_ms)
            ),
        ]);
        t.row(vec![
            "unconverged_crashes".into(),
            c.unconverged_crashes.to_string(),
        ]);
        t.row(vec![
            "lost_replicas".into(),
            c.lost_replicas.to_string(),
        ]);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_slas_validate() {
        for i in 0..12 {
            let sla = catalog_sla(i);
            sla.validate().unwrap();
            assert!(sla.constraints[0].vcpus_millicores <= 150);
        }
        // Every third shape is a two-task service.
        assert_eq!(catalog_sla(2).constraints.len(), 2);
        assert_eq!(catalog_sla(0).constraints.len(), 1);
    }

    #[test]
    fn scenario_parsing_and_composition() {
        assert_eq!(ChurnScenario::parse("all"), Some(ChurnScenario::All));
        assert_eq!(ChurnScenario::parse("SCALE"), Some(ChurnScenario::Scale));
        assert_eq!(ChurnScenario::parse("spill"), Some(ChurnScenario::Spill));
        assert_eq!(ChurnScenario::parse("bogus"), None);
        assert!(ChurnScenario::All.arrivals());
        assert!(ChurnScenario::All.autoscale());
        assert!(ChurnScenario::All.drills());
        assert!(!ChurnScenario::Submit.drills());
        assert!(!ChurnScenario::Failover.autoscale());
        // Spill is arrival churn over the heavy catalog — no autoscaler
        // or drills muddying the delegation signal.
        assert!(ChurnScenario::Spill.arrivals());
        assert!(!ChurnScenario::Spill.autoscale());
        assert!(!ChurnScenario::Spill.drills());
        assert!(ChurnScenario::Spill.heavy_catalog());
        assert!(!ChurnScenario::All.heavy_catalog());
        // Partition: arrival churn + migration drills racing the seeded
        // uplink cuts; only this scenario installs the fault schedule.
        assert_eq!(
            ChurnScenario::parse("partition"),
            Some(ChurnScenario::Partition)
        );
        assert!(ChurnScenario::Partition.arrivals());
        assert!(ChurnScenario::Partition.drills());
        assert!(!ChurnScenario::Partition.autoscale());
        assert!(ChurnScenario::Partition.partitions());
        assert!(!ChurnScenario::All.partitions());
        // Crash: arrival churn + migration drills racing the seeded
        // orchestrator kills; only this scenario installs the crash
        // schedule, and it never cuts uplinks.
        assert_eq!(ChurnScenario::parse("crash"), Some(ChurnScenario::Crash));
        assert!(ChurnScenario::Crash.arrivals());
        assert!(ChurnScenario::Crash.drills());
        assert!(!ChurnScenario::Crash.autoscale());
        assert!(ChurnScenario::Crash.crashes());
        assert!(!ChurnScenario::Crash.partitions());
        assert!(!ChurnScenario::Partition.crashes());
        assert!(!ChurnScenario::All.crashes());
    }

    #[test]
    fn shape_parses_and_rejects_junk() {
        assert_eq!(parse_shape("16x6"), Some((16, 6)));
        assert_eq!(parse_shape("4X50"), Some((4, 50)));
        assert_eq!(parse_shape(" 2 x 3 "), Some((2, 3)));
        assert_eq!(parse_shape("0x5"), None);
        assert_eq!(parse_shape("5"), None);
        assert_eq!(parse_shape("axb"), None);
    }

    #[test]
    fn spill_catalog_is_heavy_but_hostable() {
        for i in 0..12 {
            let sla = spill_catalog_sla(i);
            sla.validate().unwrap();
            let cpu = sla.constraints[0].vcpus_millicores;
            // Heavy enough that an S worker (1000 mc) hosts at most two,
            // small enough that every shape always fits somewhere.
            assert!((400..=850).contains(&cpu), "cpu={cpu}");
            assert_eq!(sla.constraints.len(), 1);
        }
    }

    #[test]
    fn report_json_is_parseable() {
        let cfg = ChurnConfig {
            duration_s: 30.0,
            settle_s: 25.0,
            scenario: ChurnScenario::Submit,
            arrival_period_s: 4.0,
            mean_lifetime_s: 15.0,
            clusters: 1,
            workers_per_cluster: 4,
            ..ChurnConfig::default()
        };
        let report = run_churn(&cfg);
        assert!(report.submits > 0, "arrival process must submit services");
        let v = crate::json::parse(&report.to_json()).expect("emitted JSON parses");
        assert_eq!(v.get("bench").as_str(), Some("churn"));
        assert_eq!(v.get("seed").as_u64(), Some(cfg.seed));
        assert!(v.get("latency_ms").get("submit_to_running").get("count").as_u64()
            .is_some());
        assert!(v
            .get("census_consistency")
            .get("mismatch")
            .as_u64()
            .is_some());
        assert!(v.get("counts").get("rejoins").as_u64().is_some());
        // Perf-trajectory fields: wall clock, per-op root costs and the
        // post-drain quiescence audit.
        assert!(v.get("wall_clock_s").as_f64().unwrap_or(-1.0) >= 0.0);
        assert!(v.get("root_ops").get("submit").as_u64().unwrap_or(0) > 0);
        // Federation hot-path fields: topology shape, delegation/spill
        // accounting and the aggregate delta-coalescing counters.
        assert_eq!(v.get("topology").get("clusters").as_u64(), Some(1));
        assert_eq!(
            v.get("topology").get("shape").as_str(),
            Some("1x4"),
            "shape must mirror the storm topology"
        );
        assert!(
            v.get("federation").get("delegation_sends").as_u64().unwrap_or(0) > 0,
            "submit churn must delegate"
        );
        assert!(v.get("federation").get("rank_ops").as_u64().unwrap_or(0) > 0);
        assert!(v.get("federation").get("spill_rate").as_f64().is_some());
        assert!(v.get("federation").get("aggregate_sent").as_u64().unwrap_or(0) > 0);
        assert_eq!(
            v.get("quiescence").get("pending_non_timer").as_u64(),
            Some(0),
            "post-drain quiescence must leave no message in flight"
        );
        assert!(v.get("control_plane").get("sched_ms_p95").as_f64().is_some());
        // Single-lane runs must NOT carry the "sim" object — its absence
        // is what keeps legacy reports byte-identical to the pre-lane
        // golden fixture.
        assert!(v.get("sim").get("lanes").as_u64().is_none());
        // Watch-abandonment accounting is always present; the partition
        // and crash objects only appear when the scenario installed
        // uplink cuts / orchestrator kills respectively.
        assert!(v.get("watches").get("expired").as_u64().is_some());
        assert!(v.get("watches").get("unexcused").as_u64().is_some());
        assert!(v.get("partition").get("cuts").as_u64().is_none());
        assert!(v.get("crash").get("kills").as_u64().is_none());
    }

    /// Same seed, same storm, different `--threads`: the lane engine must
    /// emit byte-identical reports (op log, census, metrics and all) for
    /// every thread count — the merge-order determinism contract.
    #[test]
    fn sharded_storm_is_thread_count_invariant() {
        let run = |threads: usize| {
            let cfg = ChurnConfig {
                scenario: ChurnScenario::Submit,
                duration_s: 30.0,
                settle_s: 25.0,
                arrival_period_s: 4.0,
                mean_lifetime_s: 15.0,
                clusters: 2,
                workers_per_cluster: 4,
                threads,
                ..ChurnConfig::default()
            };
            let mut report = run_churn(&cfg);
            report.wall_clock_s = 0.0;
            report.to_json()
        };
        let one = run(1);
        assert_eq!(one, run(2), "lane engine must be thread-count invariant");
        let v = crate::json::parse(&one).unwrap();
        assert_eq!(v.get("sim").get("lanes").as_u64(), Some(3));
        let batch = v.get("sim").get("lane").get("batch").as_f64().unwrap_or(0.0);
        assert!(batch >= 1.0, "batch={batch}");
    }

    /// The partition storm must (a) be thread-count invariant like every
    /// other scenario — this byte-equality doubles as the retransmit
    /// determinism regression, since `net.retransmit` and
    /// `net.dropped_after_retry` are embedded in the report JSON — and
    /// (b) actually reconcile: every scheduled cut is detected, healed
    /// and resynced, the census reconverges after every heal, and no
    /// adoption conflicts, leaks or unexcused watch abandonments remain.
    #[test]
    fn partition_storm_reconciles_and_is_thread_invariant() {
        let run = |threads: usize| {
            let cfg = ChurnConfig {
                threads,
                clusters: 3,
                workers_per_cluster: 4,
                partition_clusters: 2,
                // Last heal lands by ~144s (10s lead + two 42s cuts +
                // one 15s flap + jittered 12s gaps); 150s keeps the
                // census snapshot (duration + 0.75*hold) comfortably
                // past the post-heal resync.
                duration_s: 150.0,
                settle_s: 40.0,
                arrival_period_s: 2.0,
                mean_lifetime_s: 30.0,
                max_live: 24,
                drills: 4,
                drill_every: 10,
                partition_gap_s: 12.0,
                partition_lead_s: 10.0,
                ..ChurnConfig::partition_storm(7)
            };
            let mut report = run_churn(&cfg);
            report.wall_clock_s = 0.0;
            report
        };
        let one = run(1);
        assert_eq!(
            one.to_json(),
            run(4).to_json(),
            "partition storm must be thread-count invariant"
        );
        let p = one.partition.as_ref().expect("partition stats present");
        assert_eq!(p.cuts, 4, "2 clusters x 2 full cuts each");
        assert_eq!(p.flaps, 2, "1 Suspect-only flap per partitioned cluster");
        assert_eq!(p.detected, p.cuts, "every >30s cut must trip the lease");
        assert_eq!(p.healed, p.detected, "every detection must heal");
        assert_eq!(p.resyncs, p.healed, "every heal must trigger a resync");
        assert!(p.snapshots >= p.resyncs, "clusters must answer resyncs");
        assert_eq!(p.resync_conflicts, 0, "no double adoptions");
        assert_eq!(p.unconverged_heals, 0, "census must drain after each heal");
        assert_eq!(p.heal_to_convergence.count as u64, p.cuts);
        assert!(
            p.retransmits > 0,
            "cuts must force reliable-transport retries"
        );
        assert_eq!(one.census_mismatch, 0, "{:?}", one.census_diff);
        assert_eq!(one.leaked_instances, 0);
        assert_eq!(one.watch_expired_unexcused, 0);
    }

    /// The crash storm must (a) be thread-count invariant — the epoch
    /// handshakes, census seeding and redelegation sweeps are all
    /// embedded in the report JSON, so byte-equality doubles as the
    /// crash-recovery determinism regression — and (b) actually
    /// recover: every kill is restarted under a higher epoch the root
    /// accepts, short outages never escalate to Partitioned, the census
    /// reconverges after every outage, and no replicas are lost, no
    /// adoptions conflict, no leaks or unexcused abandonments remain.
    #[test]
    fn crash_storm_recovers_and_is_thread_invariant() {
        let run = |threads: usize| {
            let cfg = ChurnConfig {
                threads,
                clusters: 3,
                workers_per_cluster: 4,
                crash_clusters: 2,
                // Last restart lands by ~95s (12s lead + 15s short cut +
                // jittered 25s gap + 35s long cut); 120s keeps it ≥ 20s
                // of live churn before the storm ends, and the census
                // snapshot (duration + 0.75*hold) well past the final
                // recovery resync.
                duration_s: 120.0,
                settle_s: 40.0,
                arrival_period_s: 2.0,
                mean_lifetime_s: 30.0,
                max_live: 24,
                drills: 4,
                drill_every: 10,
                ..ChurnConfig::crash_storm(7)
            };
            let mut report = run_churn(&cfg);
            report.wall_clock_s = 0.0;
            report
        };
        let one = run(1);
        assert_eq!(
            one.to_json(),
            run(4).to_json(),
            "crash storm must be thread-count invariant"
        );
        let c = one.crash.as_ref().expect("crash stats present");
        assert_eq!(c.kills, 4, "2 clusters x 2 kills each");
        assert_eq!(c.restarts, 4, "every kill must cold-restart");
        assert_eq!(c.short_outages, 2);
        assert_eq!(c.long_outages, 2);
        assert_eq!(
            c.restart_registers, 4,
            "every restart must re-register under a higher epoch"
        );
        assert_eq!(
            c.escalated, c.long_outages,
            "only >30s outages may trip Partitioned — a Suspect-window \
             re-register must cancel the escalation"
        );
        assert_eq!(c.recovery_completed, 4, "every restart must finish recovery");
        assert!(
            c.worker_reregistered >= 4 * 4,
            "every worker of a crashed cluster re-registers per outage \
             (got {})",
            c.worker_reregistered
        );
        assert!(
            c.census_seeded > 0,
            "recovering clusters must rebuild state from worker censuses"
        );
        assert_eq!(c.resync_conflicts, 0, "no double adoptions");
        assert_eq!(c.unconverged_crashes, 0, "census must drain after each outage");
        assert_eq!(c.crash_to_converged.count as u64, c.kills);
        assert_eq!(c.lost_replicas, 0, "no replica may be lost to a crash");
        assert_eq!(one.census_mismatch, 0, "{:?}", one.census_diff);
        assert_eq!(one.leaked_instances, 0);
        assert_eq!(one.watch_expired_unexcused, 0);
        assert_eq!(one.pending_non_timer, 0);
    }
}
