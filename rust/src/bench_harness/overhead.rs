//! Fig. 4b/4c (idle CPU & memory at worker and master vs cluster size),
//! Fig. 7a (control-message volume vs deployed services) and Fig. 7b
//! (worker/orchestrator utilization during the Nginx stress deploy).

use crate::baselines::FrameworkProfile;
use crate::messaging::labels;
use crate::metrics::Table;
use crate::sla::simple_sla;
use crate::util::{NodeId, ServiceId, SimTime};

use super::testbed::{build_flat, build_oakestra, OakTestbedConfig};

/// Measure idle (cpu%, mem MB) at one worker and the master over a
/// window, after warm-up.
fn idle_sample(
    sim: &crate::sim::Sim,
    worker: NodeId,
    master: NodeId,
    from: SimTime,
    to: SimTime,
) -> (f64, f64, f64, f64) {
    let m = sim.metrics();
    let u = |n: NodeId| {
        m.usage(n)
            .map(|u| (u.cpu_util(from, to) * 100.0, u.mem_mb))
            .unwrap_or((0.0, 0.0))
    };
    let (wc, wm) = u(worker);
    let (mc, mm) = u(master);
    (wc, wm, mc, mm)
}

/// Fig. 4b/4c: idle overheads vs cluster size for every framework.
/// Returns (cpu table, memory table).
pub fn fig4bc_idle_overhead(sizes: &[usize], window_s: f64) -> (Table, Table) {
    let mut cpu = Table::new(
        "Fig 4b — idle CPU (% of one core): worker / master vs cluster size",
        &[
            "workers",
            "oak_worker",
            "oak_master",
            "k3s_worker",
            "k3s_master",
            "k8s_worker",
            "k8s_master",
            "mk8s_worker",
            "mk8s_master",
        ],
    );
    let mut mem = Table::new(
        "Fig 4c — idle memory (MB): worker / master vs cluster size",
        &[
            "workers",
            "oak_worker",
            "oak_master",
            "k3s_worker",
            "k3s_master",
            "k8s_worker",
            "k8s_master",
            "mk8s_worker",
            "mk8s_master",
        ],
    );
    let from = SimTime::from_secs(15.0);
    for &n in sizes {
        let to = SimTime::from_secs(15.0 + window_s);

        let mut oak = build_oakestra(OakTestbedConfig {
            seed: 60,
            workers_per_cluster: n,
            ..OakTestbedConfig::default()
        });
        oak.sim.run_until(to);
        let w = oak.workers[0].0;
        let m = oak.clusters[0].0;
        let (owc, owm, omc, omm) = idle_sample(&oak.sim, w, m, from, to);

        let flat = |p: FrameworkProfile, seed: u64| {
            let mut tb = build_flat(p, seed, n, crate::model::NodeClass::S, false, 2_000.0);
            tb.sim.run_until(to);
            idle_sample(&tb.sim, tb.kubelets[0].0, tb.master_node, from, to)
        };
        let (k3wc, k3wm, k3mc, k3mm) = flat(FrameworkProfile::k3s(), 61);
        let (k8wc, k8wm, k8mc, k8mm) = flat(FrameworkProfile::kubernetes(), 62);
        let (mkwc, mkwm, mkmc, mkmm) = flat(FrameworkProfile::microk8s(), 63);

        cpu.row(vec![
            n.to_string(),
            format!("{owc:.2}"),
            format!("{omc:.2}"),
            format!("{k3wc:.2}"),
            format!("{k3mc:.2}"),
            format!("{k8wc:.2}"),
            format!("{k8mc:.2}"),
            format!("{mkwc:.2}"),
            format!("{mkmc:.2}"),
        ]);
        mem.row(vec![
            n.to_string(),
            format!("{owm:.0}"),
            format!("{omm:.0}"),
            format!("{k3wm:.0}"),
            format!("{k3mm:.0}"),
            format!("{k8wm:.0}"),
            format!("{k8mm:.0}"),
            format!("{mkwm:.0}"),
            format!("{mkmm:.0}"),
        ]);
    }
    (cpu, mem)
}

/// Fig. 7a: total control-plane messages vs number of deployed services
/// (10-worker cluster), Oakestra vs K3s.
pub fn fig7a_control_messages(service_counts: &[usize]) -> Table {
    let mut t = Table::new(
        "Fig 7a — control messages (count) during deploy+steady state",
        &["services", "oakestra_msgs", "k3s_msgs", "k3s/oakestra"],
    );
    for &s in service_counts {
        // Oakestra.
        let mut oak = build_oakestra(OakTestbedConfig {
            seed: 70,
            workers_per_cluster: 10,
            ..OakTestbedConfig::default()
        });
        oak.warm_up();
        let m = oak.sim.metrics();
        let m0: u64 = [
            labels::WORKER_TO_CLUSTER,
            labels::CLUSTER_TO_WORKER,
            labels::CLUSTER_TO_ROOT,
            labels::ROOT_TO_CLUSTER,
        ]
        .iter()
        .map(|l| m.msgs(l))
        .sum();
        for r in 0..s {
            oak.submit(
                simple_sla(&format!("ng-{r}"), 5, 4),
                SimTime::from_secs(13.0 + 0.2 * r as f64),
            );
        }
        let end = SimTime::from_secs(13.0 + 0.2 * s as f64 + 60.0);
        oak.sim.run_until(end);
        let m = oak.sim.metrics();
        let oak_msgs: u64 = [
            labels::WORKER_TO_CLUSTER,
            labels::CLUSTER_TO_WORKER,
            labels::CLUSTER_TO_ROOT,
            labels::ROOT_TO_CLUSTER,
        ]
        .iter()
        .map(|l| m.msgs(l))
        .sum::<u64>()
            - m0;

        // K3s.
        let mut k3s = build_flat(
            FrameworkProfile::k3s(),
            71,
            10,
            crate::model::NodeClass::S,
            false,
            2_000.0,
        );
        k3s.warm_up();
        let m = k3s.sim.metrics();
        let k0: u64 = [labels::KUBE_NODE_TO_MASTER, labels::KUBE_MASTER_TO_NODE]
            .iter()
            .map(|l| m.msgs(l))
            .sum();
        for r in 0..s {
            k3s.submit_pod(
                ServiceId(1 + r as u32),
                Some(crate::model::Capacity::new(5, 4, 0)),
                SimTime::from_secs(13.0 + 0.2 * r as f64),
            );
        }
        k3s.sim.run_until(end);
        let m = k3s.sim.metrics();
        let k3s_msgs: u64 = [labels::KUBE_NODE_TO_MASTER, labels::KUBE_MASTER_TO_NODE]
            .iter()
            .map(|l| m.msgs(l))
            .sum::<u64>()
            - k0;

        t.row(vec![
            s.to_string(),
            oak_msgs.to_string(),
            k3s_msgs.to_string(),
            format!("{:.2}", k3s_msgs as f64 / oak_msgs.max(1) as f64),
        ]);
    }
    t
}

/// Fig. 7b: worker & orchestrator CPU as up to `max_per_worker` Nginx
/// containers are deployed on each of 10 workers. Samples utilization at
/// several container counts.
pub fn fig7b_stress(checkpoints: &[usize]) -> Table {
    let mut t = Table::new(
        "Fig 7b — CPU (% core) under increasing containers per worker",
        &[
            "containers/worker",
            "oak_worker",
            "oak_orch",
            "k3s_worker",
            "k3s_master",
        ],
    );
    for &per_worker in checkpoints {
        let total = per_worker * 10;

        let mut oak = build_oakestra(OakTestbedConfig {
            seed: 75,
            workers_per_cluster: 10,
            worker_class: crate::model::NodeClass::S,
            ..OakTestbedConfig::default()
        });
        oak.warm_up();
        for r in 0..total {
            oak.submit(
                simple_sla(&format!("ng-{r}"), 5, 4),
                SimTime::from_secs(13.0 + 0.1 * r as f64),
            );
        }
        let settle = SimTime::from_secs(13.0 + 0.1 * total as f64 + 30.0);
        let end = settle + SimTime::from_secs(30.0);
        oak.sim.run_until(end);
        let (owc, _, _, _) = idle_sample(&oak.sim, oak.workers[0].0, oak.clusters[0].0, settle, end);
        let (_, _, omc, _) = idle_sample(&oak.sim, oak.workers[0].0, oak.clusters[0].0, settle, end);

        let mut k3s = build_flat(
            FrameworkProfile::k3s(),
            76,
            10,
            crate::model::NodeClass::S,
            false,
            2_000.0,
        );
        k3s.warm_up();
        for r in 0..total {
            k3s.submit_pod(
                ServiceId(1 + r as u32),
                Some(crate::model::Capacity::new(5, 4, 0)),
                SimTime::from_secs(13.0 + 0.1 * r as f64),
            );
        }
        k3s.sim.run_until(end);
        let (kwc, _, kmc, _) =
            idle_sample(&k3s.sim, k3s.kubelets[0].0, k3s.master_node, settle, end);

        t.row(vec![
            per_worker.to_string(),
            format!("{:.1}", owc.min(100.0)),
            format!("{omc:.1}"),
            format!("{:.1}", kwc.min(100.0)),
            format!("{kmc:.1}"),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_overhead_ratios_match_paper_claims() {
        let (cpu, mem) = fig4bc_idle_overhead(&[4], 45.0);
        let row = &cpu.rows[0];
        let v = |i: usize| row[i].parse::<f64>().unwrap();
        let (oak_w, oak_m, k3s_w, k3s_m, k8s_w, k8s_m) =
            (v(1), v(2), v(3), v(4), v(5), v(6));
        // Paper: ≈6× less worker CPU, ≈11× less master CPU vs best rival.
        assert!(k3s_w / oak_w > 3.0, "worker: k3s={k3s_w} oak={oak_w}");
        assert!(k3s_m / oak_m > 5.0, "master: k3s={k3s_m} oak={oak_m}");
        assert!(k8s_w > k3s_w && k8s_m > k3s_m);
        // Memory: ≈18% (worker) / ≈33% (master) lighter than K3s.
        let m = &mem.rows[0];
        let mv = |i: usize| m[i].parse::<f64>().unwrap();
        let (omw, omm, kmw, kmm) = (mv(1), mv(2), mv(3), mv(4));
        assert!(omw < kmw && omw / kmw > 0.6, "worker mem {omw} vs {kmw}");
        assert!(omm < kmm && omm / kmm > 0.5, "master mem {omm} vs {kmm}");
    }

    #[test]
    fn k3s_sends_about_twice_the_messages() {
        let t = fig7a_control_messages(&[20]);
        let ratio: f64 = t.rows[0][3].parse().unwrap();
        assert!(ratio > 1.4, "k3s/oakestra message ratio {ratio} too small");
    }

    #[test]
    fn stress_exhausts_k3s_before_oakestra() {
        let t = fig7b_stress(&[60]);
        let oak: f64 = t.rows[0][1].parse().unwrap();
        let k3s: f64 = t.rows[0][3].parse().unwrap();
        assert!(k3s > oak, "k3s {k3s}% should exceed oakestra {oak}%");
        assert!(k3s > 70.0, "k3s should be near exhaustion at 60/worker: {k3s}");
        assert!(oak < 80.0, "oakestra should have headroom at 60/worker: {oak}");
    }
}
