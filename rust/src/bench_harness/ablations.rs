//! Ablation benches for the design choices DESIGN.md calls out:
//! telemetry update policies, delegated vs flat scheduling, and the
//! tunnel LRU cap.

use crate::metrics::Table;
use crate::model::Capacity;
use crate::netmanager::ProxyTun;
use crate::scheduler::{PlacementInput, RomScheduler, RomStrategy, TaskScheduler};
use crate::telemetry::{TelemetryGovernor, UpdatePolicy};
use crate::util::{mean, NodeId, Rng, ServiceId, SimTime};

use super::sched::{paper_sla, synthetic_fabric};

/// Telemetry policy ablation: messages published for the same utilization
/// trace under Periodic / Δ-threshold / age-adaptive policies.
pub fn ablate_telemetry(duration_s: u64, churn: f64) -> Table {
    let mut t = Table::new(
        "Ablation — telemetry messages vs update policy",
        &["policy", "published", "suppressed", "mean_staleness_s"],
    );
    let total = Capacity::new(4000, 4096, 0);
    let policies: Vec<(&str, UpdatePolicy)> = vec![
        (
            "periodic_2s",
            UpdatePolicy::Periodic {
                interval: SimTime::from_secs(2.0),
            },
        ),
        (
            "delta_10pct",
            UpdatePolicy::DeltaThreshold {
                interval: SimTime::from_secs(2.0),
                threshold: 0.10,
                max_age: SimTime::from_secs(30.0),
            },
        ),
        (
            "age_adaptive",
            UpdatePolicy::AgeAdaptive {
                min_interval: SimTime::from_secs(2.0),
                max_interval: SimTime::from_secs(16.0),
            },
        ),
    ];
    for (name, policy) in policies {
        let mut gov = TelemetryGovernor::new(policy);
        let mut rng = Rng::seeded(7);
        let mut used = Capacity::new(1000, 1024, 0);
        let mut now = SimTime::ZERO;
        let mut last_pub = SimTime::ZERO;
        let mut staleness = Vec::new();
        while now.as_secs() < duration_s as f64 {
            // Utilization random walk; `churn` controls movement rate.
            if rng.chance(churn) {
                let delta = rng.range(-400.0, 400.0);
                used.cpu_millicores =
                    (used.cpu_millicores as f64 + delta).clamp(0.0, 4000.0) as u32;
            }
            if gov.should_publish(now, used, total) {
                last_pub = now;
            }
            staleness.push(now.saturating_sub(last_pub).as_secs());
            now += gov.tick_interval();
        }
        t.row(vec![
            name.to_string(),
            gov.published.to_string(),
            gov.suppressed.to_string(),
            format!("{:.2}", mean(&staleness)),
        ]);
    }
    t
}

/// Delegation ablation: scheduling cost of the 2-step hierarchy vs one
/// flat scheduler scanning every worker (per placement, at scale).
pub fn ablate_delegation(total_workers: usize, clusters: usize, reps: usize) -> Table {
    let mut t = Table::new(
        "Ablation — delegated vs flat scheduling cost (ms per placement)",
        &["shape", "flat_ms", "delegated_ms", "speedup"],
    );
    let sla = paper_sla();
    let per = total_workers / clusters;
    let mut flat_ms = Vec::new();
    let mut del_ms = Vec::new();
    for r in 0..reps {
        // Flat: one scheduler over everything.
        let fabric = synthetic_fabric(total_workers, 400 + r as u64);
        let input = PlacementInput {
            sla: &sla.constraints[0],
            workers: &fabric.workers,
            service_hint: ServiceId(0),
            exclude: None,
        };
        // lint: allow(ambient-time, wall-clock timing is the measurement itself)
        let t0 = std::time::Instant::now();
        let mut s = RomScheduler {
            strategy: RomStrategy::BestFit,
        };
        let _ = s.place(&input);
        flat_ms.push(t0.elapsed().as_secs_f64() * 1000.0);

        // Delegated: rank aggregates, then scan one cluster.
        let fabrics: Vec<_> = (0..clusters)
            .map(|c| synthetic_fabric(per, 500 + (r * 64 + c) as u64))
            .collect();
        // lint: allow(ambient-time, wall-clock timing is the measurement itself)
        let t0 = std::time::Instant::now();
        let aggs: Vec<crate::hierarchy::AggregateStats> = fabrics
            .iter()
            .map(|f| {
                let avail: Vec<_> = f
                    .workers
                    .iter()
                    .map(|w| (w.available(), w.spec.virtualization()))
                    .collect();
                crate::hierarchy::AggregateStats::from_workers(
                    avail.iter().map(|(c, v)| (c, *v)),
                    None,
                )
            })
            .collect();
        let pairs: Vec<_> = aggs
            .iter()
            .enumerate()
            .map(|(i, a)| (crate::util::ClusterId(i as u32 + 1), a))
            .collect();
        let ranked = crate::scheduler::rank_clusters(&sla.constraints[0], &pairs);
        if let Some(best) = ranked.first() {
            let f = &fabrics[(best.cluster.0 - 1) as usize];
            let input = PlacementInput {
                sla: &sla.constraints[0],
                workers: &f.workers,
                service_hint: ServiceId(0),
            exclude: None,
            };
            let mut s = RomScheduler {
                strategy: RomStrategy::BestFit,
            };
            let _ = s.place(&input);
        }
        del_ms.push(t0.elapsed().as_secs_f64() * 1000.0);
    }
    t.row(vec![
        format!("{clusters}x{per}"),
        format!("{:.4}", mean(&flat_ms)),
        format!("{:.4}", mean(&del_ms)),
        format!("{:.2}x", mean(&flat_ms) / mean(&del_ms).max(1e-9)),
    ]);
    t
}

/// Tunnel LRU ablation: handshakes and evictions as the active-tunnel cap
/// k varies against a zipf-ish peer access trace.
pub fn ablate_tunnel_lru(caps: &[usize], peers: usize, accesses: usize) -> Table {
    let mut t = Table::new(
        "Ablation — ProxyTUN LRU cap k vs handshakes/evictions",
        &["k", "handshakes", "evictions", "handshake_rate"],
    );
    for &k in caps {
        let mut tun = ProxyTun::with_cap(k);
        let mut rng = Rng::seeded(11);
        for a in 0..accesses {
            // Zipf-ish: favor low peer ids.
            let r = rng.f64();
            let peer = ((r * r) * peers as f64) as usize % peers;
            tun.activate(NodeId(peer as u32), SimTime::from_millis(a as f64 * 10.0));
            tun.check_invariants().unwrap();
        }
        t.row(vec![
            k.to_string(),
            tun.handshakes.to_string(),
            tun.evictions.to_string(),
            format!("{:.3}", tun.handshakes as f64 / accesses as f64),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_policy_publishes_fewer_messages() {
        let t = ablate_telemetry(600, 0.1);
        let published: Vec<u64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        assert!(published[1] < published[0], "delta < periodic: {published:?}");
    }

    #[test]
    fn delegation_is_cheaper_per_placement() {
        let t = ablate_delegation(500, 10, 5);
        let speedup: f64 = t.rows[0][3].trim_end_matches('x').parse().unwrap();
        assert!(speedup > 1.0, "speedup {speedup}");
    }

    #[test]
    fn bigger_cap_fewer_handshakes() {
        let t = ablate_tunnel_lru(&[4, 64], 64, 2000);
        let h: Vec<u64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        assert!(h[1] <= h[0], "handshakes {h:?}");
    }
}
