//! Experiment drivers: one function per figure of the paper's evaluation
//! (§7), shared between `cargo bench` targets, the CLI (`oakestra bench`)
//! and the examples. Every driver returns a [`crate::metrics::Table`]
//! whose rows mirror the series the paper plots; EXPERIMENTS.md records
//! paper-vs-measured per figure.

pub mod churn;
mod deploy;
mod net;
mod overhead;
mod sched;
mod testbed;
mod video;

pub use churn::{
    census_diff, count_leaks, parse_shape, placement_census, run_churn, ChurnConfig,
    ChurnDriver, ChurnReport, ChurnScenario, CrashStats, PartitionStats,
};
pub use deploy::{fig4a_deploy_time, fig5_network_degradation};
pub use net::{fig9_left_closest_rtt, fig9_right_tunnel_transfer};
pub use overhead::{fig4bc_idle_overhead, fig7a_control_messages, fig7b_stress};
pub use sched::{
    fig6_cluster_ratio, fig8a_schedulers_hpc, fig8b_schedulers_scale,
    paper_sla as sched_paper_sla, run_host as sched_run_host,
    synthetic_fabric as sched_fabric, SyntheticFabric,
};
pub use testbed::{
    build_flat, build_oakestra, FlatTestbed, Framework, OakTestbed, OakTestbedConfig,
};
pub use video::fig10_video_analytics;

pub mod ablations;

/// Render a set of tables as one markdown document section.
pub fn tables_to_markdown(tables: &[crate::metrics::Table]) -> String {
    tables
        .iter()
        .map(|t| t.to_markdown())
        .collect::<Vec<_>>()
        .join("\n")
}
