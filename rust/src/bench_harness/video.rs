//! Fig. 10: the live video-analytics pipeline (Fig. 3) on four S-VM
//! workers, one stage per worker, under native / Oakestra / K3s. The
//! per-stage slowdown comes from the co-resident platform agent's CPU
//! share (measured by the Fig. 4b experiment); detection cost is anchored
//! to real execution of the AOT detector artifact when available.

use crate::metrics::Table;
use crate::model::NodeClass;
use crate::sim::{ActorId, Sim, SimMsg, TimerKind};
use crate::util::{NodeId, SimTime};
use crate::workload::{VideoSourceDriver, VideoStage, VideoStageCosts};

/// Agent CPU share stolen per platform on an S VM running the pipeline
/// (one busy container + monitoring; consistent with Fig. 4b/7b).
pub fn agent_overhead(platform: &str) -> f64 {
    match platform {
        "native" => 0.0,
        "oakestra" => 0.022, // NodeEngine tick + per-instance monitoring
        "k3s" => 0.12,       // kubelet tick + cAdvisor on a busy node
        _ => 0.25,           // k8s/microk8s (fail to run reliably — §7.4)
    }
}

/// Run the pipeline on one platform; returns per-stage means + e2e mean.
pub fn run_pipeline(
    platform: &str,
    costs: VideoStageCosts,
    frames: u64,
    fps: f64,
    seed: u64,
) -> (Vec<f64>, f64) {
    let mut sim = Sim::new(seed);
    for i in 0..5 {
        sim.add_node(NodeId(i), NodeClass::S);
    }
    let ov = agent_overhead(platform);
    let mk = |stage: u8, next: Option<ActorId>, sim: &mut Sim| {
        let mut vs = VideoStage::new(stage, costs, next);
        vs.agent_overhead = ov;
        sim.add_actor(NodeId(stage as u32 + 1), Box::new(vs))
    };
    let s3 = mk(3, None, &mut sim);
    let s2 = mk(2, Some(s3), &mut sim);
    let s1 = mk(1, Some(s2), &mut sim);
    let s0 = mk(0, Some(s1), &mut sim);
    let drv = sim.add_actor(NodeId(0), Box::new(VideoSourceDriver::new(s0, fps, frames)));
    sim.inject(SimTime::ZERO, drv, SimMsg::Timer(TimerKind::Workload));
    sim.run_until(SimTime::from_secs(frames as f64 / fps + 60.0));

    let stage_mean = |key: &'static str| {
        sim.core
            .metrics
            .histogram(key)
            .map(|h| h.mean())
            .unwrap_or(0.0)
    };
    let stages = vec![
        stage_mean("video.source_ms"),
        stage_mean("video.aggregation_ms"),
        stage_mean("video.detection_ms"),
        stage_mean("video.tracking_ms"),
    ];
    let e2e = stage_mean("video.e2e_ms");
    (stages, e2e)
}

/// Fig. 10 driver. Uses PJRT-anchored detection cost when artifacts are
/// built, the calibrated default otherwise.
pub fn fig10_video_analytics(frames: u64) -> Table {
    let costs = crate::workload::video_stage_costs_real()
        .unwrap_or_else(|_| VideoStageCosts::default());
    let mut t = Table::new(
        "Fig 10 — video analytics per-stage latency (ms)",
        &[
            "platform",
            "source",
            "aggregation",
            "detection",
            "tracking",
            "e2e",
            "vs_native",
        ],
    );
    let (native_stages, native_e2e) = run_pipeline("native", costs, frames, 5.0, 1);
    for platform in ["native", "oakestra", "k3s"] {
        let (stages, e2e) = if platform == "native" {
            (native_stages.clone(), native_e2e)
        } else {
            run_pipeline(platform, costs, frames, 5.0, 1)
        };
        t.row(vec![
            platform.to_string(),
            format!("{:.0}", stages[0]),
            format!("{:.0}", stages[1]),
            format!("{:.0}", stages[2]),
            format!("{:.0}", stages[3]),
            format!("{e2e:.0}"),
            format!("{:+.1}%", (e2e / native_e2e - 1.0) * 100.0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oakestra_close_to_native_k3s_behind() {
        let costs = VideoStageCosts::default();
        let (_, native) = run_pipeline("native", costs, 30, 5.0, 2);
        let (_, oak) = run_pipeline("oakestra", costs, 30, 5.0, 2);
        let (_, k3s) = run_pipeline("k3s", costs, 30, 5.0, 2);
        assert!(oak > native && oak < 1.1 * native, "oak={oak} native={native}");
        assert!(k3s > 1.05 * oak, "k3s={k3s} oak={oak}");
        // Paper: ~10% overall advantage for Oakestra over K3s.
        let adv = k3s / oak - 1.0;
        assert!(adv > 0.05 && adv < 0.30, "advantage {adv}");
    }

    #[test]
    fn detection_dominates_all_platforms() {
        let costs = VideoStageCosts::default();
        let (stages, _) = run_pipeline("oakestra", costs, 20, 5.0, 3);
        assert!(stages[2] > stages[0] + stages[1] + stages[3]);
        // Object tracking lands in the paper's 300–400 ms? No — tracking
        // is ~60 ms here; the 300–400 ms paper figure is detection+track
        // on S VMs. Shape check only: tracking < detection.
        assert!(stages[3] < stages[2]);
    }
}
