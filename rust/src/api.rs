//! Northbound service lifecycle API **v1** (paper §3.2.1 System/Service
//! Manager, §4.2): the single typed front door through which developers —
//! the CLI, the testbed, the examples and the integration tests — drive
//! the hierarchy. Every lifecycle operation of the paper's service
//! manager is covered: intake ([`ApiRequest::SubmitService`], full
//! Schema 1 JSON via [`crate::sla::ServiceSla::parse_json`]), horizontal
//! scaling ([`ApiRequest::ScaleService`]), explicit migration
//! ([`ApiRequest::MigrateInstance`]), teardown
//! ([`ApiRequest::UndeployService`]) and observation
//! ([`ApiRequest::ServiceStatus`], [`ApiRequest::ListServices`]).
//!
//! ## Protocol
//!
//! Requests travel to the root orchestrator as
//! [`crate::sim::OakMsg::ApiCall`] carrying an [`ApiEnvelope`]; every
//! call is answered with at least one
//! [`crate::sim::OakMsg::ApiReturn`] tagged with the envelope's
//! `request_id`. The first return is synchronous from the root handler
//! (acknowledgement or a structured [`ApiError`]); operations with
//! asynchronous outcomes additionally emit **events** under the same
//! `request_id` — today a placement failure anywhere down the delegation
//! chain surfaces as [`ApiError::NoFeasiblePlacement`]. Full-service
//! deployment completion keeps its dedicated
//! [`crate::sim::OakMsg::ServiceDeployed`] callback (the Fig. 4a timer).
//!
//! Versioning: envelopes carry [`API_VERSION`]; the root rejects any
//! other version with [`ApiError::UnsupportedVersion`] so future schema
//! revisions can coexist with v1 clients.

use std::any::Any;
use std::collections::{BTreeMap, HashMap};

use crate::coordinator::{ServiceDb, ServiceRecord};
use crate::model::ServiceState;
use crate::sim::{Actor, ActorId, Ctx, OakMsg, SimMsg};
use crate::sla::{ServiceSla, SlaError};
use crate::util::{ClusterId, InstanceId, NodeId, ServiceId, SimTime, TaskId};

/// Current northbound API version (carried in every [`ApiEnvelope`]).
pub const API_VERSION: u32 = 1;

/// Upper bound on per-task replicas accepted by [`ApiRequest::ScaleService`]
/// (guards the control plane against runaway fan-out requests).
pub const MAX_REPLICAS: usize = 64;

/// One northbound call: version + correlation id + operation + reply
/// address. Built by [`ApiClient::envelope`] or directly by drivers.
#[derive(Clone, Debug)]
pub struct ApiEnvelope {
    pub version: u32,
    /// Caller-chosen correlation id echoed on every [`ApiResponse`].
    pub request_id: u64,
    pub request: ApiRequest,
    /// Where `ApiReturn`s (and the `ServiceDeployed` callback for
    /// submissions) are delivered. `None` = fire-and-forget.
    pub reply_to: Option<ActorId>,
}

/// The v1 operation set (paper §3.2.1: "deployment, migration, scaling
/// and teardown of services" plus status observation).
#[derive(Clone, Debug)]
pub enum ApiRequest {
    /// Submit a validated SLA (paper step ①). Use
    /// [`ServiceSla::parse_json`] to build one from a Schema 1 document.
    SubmitService { sla: ServiceSla },
    /// Set the replica count of one task (or every task) of a service.
    /// Scale-up mints fresh instances through the ROM/LDP schedulers;
    /// scale-down tears surplus instances down via `UndeployInstance`.
    ScaleService {
        service: ServiceId,
        /// `None` scales every task of the service to `replicas`.
        task: Option<u16>,
        replicas: usize,
    },
    /// Explicitly migrate one running instance away from its current
    /// worker (paper §6: rescheduling + deferred teardown).
    MigrateInstance {
        service: ServiceId,
        instance: InstanceId,
    },
    /// Tear down every live instance of a service.
    UndeployService { service: ServiceId },
    /// Read the full lifecycle state of one service.
    ServiceStatus { service: ServiceId },
    /// Enumerate all submitted services with summary state.
    ListServices,
}

/// Structured failure modes of the v1 API.
#[derive(Clone, Debug, PartialEq)]
pub enum ApiError {
    /// Envelope version is not [`API_VERSION`].
    UnsupportedVersion { requested: u32, supported: u32 },
    /// SLA failed the root service manager's structural validation.
    InvalidSla(SlaError),
    UnknownService(ServiceId),
    /// The service was undeployed: mutating operations (scale, migrate)
    /// are refused so a teardown can never race back into growth.
    ServiceRetired(ServiceId),
    UnknownTask(TaskId),
    UnknownInstance(InstanceId),
    /// Migration requires a Running instance.
    NotRunning(InstanceId),
    /// The instance was already superseded by a registered replacement
    /// (migration or local recovery): the error names the successor so
    /// the caller can retarget its operation at the live lineage head.
    AlreadyReplaced {
        instance: InstanceId,
        successor: InstanceId,
    },
    /// Replica count out of the accepted (1..=[`MAX_REPLICAS`]) range.
    InvalidReplicas { requested: usize, max: usize },
    /// Asynchronous event: the delegation chain exhausted the cluster
    /// priority list without a feasible placement (paper §4.2).
    NoFeasiblePlacement { service: ServiceId, task: TaskId },
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ApiError::UnsupportedVersion {
                requested,
                supported,
            } => write!(f, "unsupported API version {requested} (supported: {supported})"),
            ApiError::InvalidSla(e) => write!(f, "invalid SLA: {e}"),
            ApiError::UnknownService(s) => write!(f, "unknown service {s}"),
            ApiError::ServiceRetired(s) => {
                write!(f, "service {s} is undeployed (retired)")
            }
            ApiError::UnknownTask(t) => write!(f, "unknown task {t}"),
            ApiError::UnknownInstance(i) => write!(f, "unknown instance {i}"),
            ApiError::NotRunning(i) => write!(f, "instance {i} is not running"),
            ApiError::AlreadyReplaced {
                instance,
                successor,
            } => {
                write!(f, "instance {instance} was replaced by {successor}")
            }
            ApiError::InvalidReplicas { requested, max } => {
                write!(f, "replica count {requested} outside 1..={max}")
            }
            ApiError::NoFeasiblePlacement { service, task } => {
                write!(f, "no feasible placement for {service} task {task}")
            }
        }
    }
}
impl std::error::Error for ApiError {}

/// Lifecycle state of one instance as reported by [`ApiResponse::Status`].
#[derive(Clone, Debug)]
pub struct InstanceStatusInfo {
    pub instance: InstanceId,
    pub task: TaskId,
    pub state: ServiceState,
    pub worker: Option<NodeId>,
    /// Cluster the instance runs in (delegation target, or inherited
    /// from the lineage for adopted successors).
    pub cluster: Option<ClusterId>,
    pub generation: u32,
    /// Successor lineage: the instance this one replaced, if any.
    pub predecessor: Option<InstanceId>,
    /// The registered replacement that superseded this instance, if any.
    pub successor: Option<InstanceId>,
}

/// Full status of one service (paper's database view, §3.2.1).
#[derive(Clone, Debug)]
pub struct ServiceStatusInfo {
    pub service: ServiceId,
    pub name: String,
    pub submitted_at: SimTime,
    pub fully_running: bool,
    pub tasks: usize,
    /// Aggregated observed CPU draw (mc) across the service's Running
    /// instances, from worker telemetry rolled up through the clusters'
    /// (delta-coalesced) aggregate reports — real QoS telemetry an
    /// autoscaler can key off, not the reservation.
    pub observed_cpu_mc: u64,
    /// Clusters holding placements of this service whose rows are a
    /// last-known-good view, not live truth: the cluster's federation
    /// lease is currently partitioned, or its orchestrator
    /// crash-restarted and is still rebuilding its census (degraded-mode
    /// staleness; cleared by the anti-entropy resync once the census
    /// converges).
    pub stale_clusters: Vec<ClusterId>,
    pub instances: Vec<InstanceStatusInfo>,
}

impl ServiceStatusInfo {
    /// Instances currently in a given state.
    pub fn count(&self, state: ServiceState) -> usize {
        self.instances.iter().filter(|i| i.state == state).count()
    }
    /// Live (non-terminal) instances.
    pub fn live(&self) -> usize {
        self.instances
            .iter()
            .filter(|i| !i.state.is_terminal())
            .count()
    }
}

/// One row of [`ApiResponse::Services`].
#[derive(Clone, Debug)]
pub struct ServiceSummary {
    pub service: ServiceId,
    pub name: String,
    pub tasks: usize,
    pub running_instances: usize,
    pub fully_running: bool,
}

/// Every answer the root can give; each is tagged with the originating
/// `request_id` by [`crate::sim::OakMsg::ApiReturn`].
#[derive(Clone, Debug)]
pub enum ApiResponse {
    /// Submission accepted; instances are being delegated.
    Submitted {
        service: ServiceId,
        instances: Vec<InstanceId>,
    },
    /// Scaling accepted: `added` instances entered the delegation
    /// pipeline, `removed` instances entered teardown.
    ScaleStarted {
        service: ServiceId,
        added: Vec<InstanceId>,
        removed: Vec<InstanceId>,
    },
    /// Migration accepted and forwarded to the owning cluster. The
    /// cluster may still reject it (no alternative worker fits —
    /// `cluster.migration_rejected` metric); observe progress via
    /// [`ApiRequest::ServiceStatus`].
    MigrationStarted { instance: InstanceId },
    /// Teardown accepted for `instances` live instances.
    UndeployStarted {
        service: ServiceId,
        instances: usize,
    },
    Status(ServiceStatusInfo),
    Services(Vec<ServiceSummary>),
    Error(ApiError),
}

impl ApiResponse {
    pub fn is_error(&self) -> bool {
        matches!(self, ApiResponse::Error(_))
    }
}

/// Build the status view of one service record (shared by the root's
/// `ServiceStatus` handler and by tests inspecting the DB directly).
pub fn status_of(rec: &ServiceRecord) -> ServiceStatusInfo {
    ServiceStatusInfo {
        service: rec.spec.id,
        name: rec.spec.name.clone(),
        submitted_at: rec.submitted_at,
        fully_running: rec.fully_running(),
        tasks: rec.spec.tasks.len(),
        observed_cpu_mc: rec.observed_cpu_mc(),
        stale_clusters: rec.degraded.keys().copied().collect(),
        instances: rec
            .instances
            .iter()
            .map(|i| InstanceStatusInfo {
                instance: i.instance,
                task: i.task,
                state: i.state,
                worker: i.worker,
                cluster: rec.placement.get(&i.instance).copied(),
                generation: i.generation,
                predecessor: i.predecessor,
                successor: i.successor,
            })
            .collect(),
    }
}

/// Summarize every service in the database ([`ApiRequest::ListServices`]).
pub fn summarize(db: &ServiceDb) -> Vec<ServiceSummary> {
    let mut rows: Vec<ServiceSummary> = db
        .services()
        .map(|rec| ServiceSummary {
            service: rec.spec.id,
            name: rec.spec.name.clone(),
            tasks: rec.spec.tasks.len(),
            running_instances: rec
                .instances
                .iter()
                .filter(|i| i.state == ServiceState::Running)
                .count(),
            fully_running: rec.fully_running(),
        })
        .collect();
    rows.sort_by_key(|r| r.service);
    rows
}

/// Render a status view as a human-readable block (CLI `status` output).
pub fn format_status(s: &ServiceStatusInfo) -> String {
    let mut out = format!(
        "service {} '{}': {} task(s), {} instance record(s), fully_running={}, \
         observed_cpu={}mc\n",
        s.service,
        s.name,
        s.tasks,
        s.instances.len(),
        s.fully_running,
        s.observed_cpu_mc
    );
    if !s.stale_clusters.is_empty() {
        let list: Vec<String> = s.stale_clusters.iter().map(|c| c.to_string()).collect();
        out.push_str(&format!(
            "  ! DEGRADED: cluster(s) {} partitioned/recovering — their rows are last-known-good\n",
            list.join(", ")
        ));
    }
    for i in &s.instances {
        let mut lineage = String::new();
        if let Some(p) = i.predecessor {
            lineage.push_str(&format!(" replaces {p}"));
        }
        if let Some(n) = i.successor {
            lineage.push_str(&format!(" superseded-by {n}"));
        }
        out.push_str(&format!(
            "  {} task {} gen {}: {:?} on {} (cluster {}){lineage}\n",
            i.instance,
            i.task,
            i.generation,
            i.state,
            i.worker.map(|w| w.to_string()).unwrap_or_else(|| "-".into()),
            i.cluster
                .map(|c| c.to_string())
                .unwrap_or_else(|| "-".into()),
        ));
    }
    out
}

/// Northbound client actor: mints correlation ids, keeps every response
/// keyed by `request_id`, and mirrors `ServiceDeployed` callbacks (the
/// deployment-time tracker role of [`crate::workload::DeployDriver`] for
/// the API-driven path).
#[derive(Default)]
pub struct ApiClient {
    next_id: u64,
    /// Every response received, in arrival order.
    pub responses: Vec<(u64, ApiResponse)>,
    /// request_id → indices into `responses` (churn workloads issue
    /// thousands of requests; lookups must not scan the full history).
    by_request: HashMap<u64, Vec<usize>>,
    /// submit→fully-Running latency per service (Fig. 4a metric).
    /// Ordered map: churn reports iterate it into emitted artifacts, and
    /// that order must be seed-deterministic.
    pub deployed: BTreeMap<ServiceId, SimTime>,
}

impl ApiClient {
    pub fn new() -> Self {
        ApiClient::default()
    }

    /// Build a v1 envelope around `request`, minting a fresh
    /// `request_id`. `reply_to` should be this client's actor id.
    pub fn envelope(&mut self, request: ApiRequest, reply_to: ActorId) -> ApiEnvelope {
        let request_id = self.next_id;
        self.next_id += 1;
        ApiEnvelope {
            version: API_VERSION,
            request_id,
            request,
            reply_to: Some(reply_to),
        }
    }

    /// Batched issue: one envelope per request, ids minted contiguously.
    /// Churn storms submit whole waves of lifecycle calls at one virtual
    /// instant; building them in a batch keeps the id block contiguous so
    /// completion tracking can reason about the wave as a unit.
    pub fn envelopes(
        &mut self,
        requests: Vec<ApiRequest>,
        reply_to: ActorId,
    ) -> Vec<ApiEnvelope> {
        requests
            .into_iter()
            .map(|r| self.envelope(r, reply_to))
            .collect()
    }

    /// Number of request ids minted so far.
    pub fn issued(&self) -> u64 {
        self.next_id
    }

    /// Minted request ids that have not received any response yet. Empty
    /// after a settled run: every v1 call is answered with at least a
    /// synchronous ack, so leftovers indicate lost replies.
    pub fn outstanding(&self) -> Vec<u64> {
        (0..self.next_id)
            .filter(|id| !self.by_request.contains_key(id))
            .collect()
    }

    /// Record one response (the actor's receive path; also usable by
    /// tests injecting responses directly).
    pub fn record(&mut self, request_id: u64, response: ApiResponse) {
        self.by_request
            .entry(request_id)
            .or_default()
            .push(self.responses.len());
        self.responses.push((request_id, response));
    }

    /// All responses recorded for one request id (first is the
    /// synchronous ack; later entries are asynchronous events).
    pub fn responses_for(&self, request_id: u64) -> Vec<&ApiResponse> {
        self.by_request
            .get(&request_id)
            .map(|idxs| idxs.iter().map(|&i| &self.responses[i].1).collect())
            .unwrap_or_default()
    }

    /// The synchronous ack for a request id, if it arrived.
    pub fn ack(&self, request_id: u64) -> Option<&ApiResponse> {
        self.responses_for(request_id).first().copied()
    }

    /// Errors observed across all requests (sync and async).
    pub fn errors(&self) -> Vec<&ApiError> {
        self.responses
            .iter()
            .filter_map(|(_, r)| match r {
                ApiResponse::Error(e) => Some(e),
                _ => None,
            })
            .collect()
    }
}

impl Actor for ApiClient {
    fn handle(&mut self, ctx: &mut Ctx<'_>, msg: SimMsg) {
        match msg {
            SimMsg::Oak(OakMsg::ApiReturn {
                request_id,
                response,
            }) => {
                if response.is_error() {
                    ctx.metrics().inc("api.client_errors");
                }
                self.record(request_id, *response);
            }
            SimMsg::Oak(OakMsg::ServiceDeployed { service, elapsed }) => {
                self.deployed.insert(service, elapsed);
                ctx.metrics()
                    .observe("driver.deploy_ms", elapsed.as_millis());
            }
            _ => {}
        }
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sla::simple_sla;

    #[test]
    fn status_of_reflects_record_state() {
        let mut db = ServiceDb::default();
        let mut sla = simple_sla("app", 1000, 100);
        sla.constraints.push(sla.constraints[0].clone());
        let (id, ids) = db.register(sla, SimTime::from_secs(1.0));
        {
            let rec = db.service_mut(id).unwrap();
            let inst = rec.instance_mut(ids[0]).unwrap();
            inst.transition(ServiceState::Scheduled).unwrap();
            inst.worker = Some(NodeId(3));
            inst.transition(ServiceState::Running).unwrap();
            inst.successor = Some(InstanceId(42));
            rec.placement.insert(ids[0], ClusterId(1));
            rec.observed_cpu.insert(ClusterId(1), 123);
        }
        let s = status_of(db.service(id).unwrap());
        assert_eq!(s.observed_cpu_mc, 123);
        assert!(s.stale_clusters.is_empty());
        assert_eq!(s.tasks, 2);
        assert_eq!(s.instances.len(), 2);
        assert_eq!(s.count(ServiceState::Running), 1);
        assert_eq!(s.count(ServiceState::Requested), 1);
        assert_eq!(s.live(), 2);
        assert!(!s.fully_running);
        assert_eq!(s.instances[0].cluster, Some(ClusterId(1)));
        assert_eq!(s.instances[0].worker, Some(NodeId(3)));
        assert_eq!(s.instances[0].successor, Some(InstanceId(42)));
        assert_eq!(s.instances[0].predecessor, None);
        let rendered = format_status(&s);
        assert!(rendered.contains("Running"));
        assert!(rendered.contains("superseded-by i42"));
        assert!(rendered.contains("observed_cpu=123mc"));
    }

    #[test]
    fn status_surfaces_degraded_clusters() {
        let mut db = ServiceDb::default();
        let (id, ids) = db.register(simple_sla("edge", 500, 64), SimTime::ZERO);
        {
            let rec = db.service_mut(id).unwrap();
            rec.placement.insert(ids[0], ClusterId(3));
        }
        db.mark_cluster_degraded(ClusterId(3), SimTime::from_secs(40.0));
        let s = status_of(db.service(id).unwrap());
        assert_eq!(s.stale_clusters, vec![ClusterId(3)]);
        let rendered = format_status(&s);
        assert!(rendered.contains("DEGRADED"));
        assert!(rendered.contains("last-known-good"));
    }

    #[test]
    fn summarize_orders_by_service_id() {
        let mut db = ServiceDb::default();
        db.register(simple_sla("a", 100, 10), SimTime::ZERO);
        db.register(simple_sla("b", 100, 10), SimTime::ZERO);
        let rows = summarize(&db);
        assert_eq!(rows.len(), 2);
        assert!(rows[0].service < rows[1].service);
        assert_eq!(rows[0].name, "a");
        assert!(!rows[0].fully_running);
    }

    #[test]
    fn client_mints_sequential_request_ids() {
        let mut c = ApiClient::new();
        let e0 = c.envelope(ApiRequest::ListServices, ActorId(0));
        let e1 = c.envelope(ApiRequest::ListServices, ActorId(0));
        assert_eq!(e0.version, API_VERSION);
        assert_eq!(e0.request_id, 0);
        assert_eq!(e1.request_id, 1);
        assert_eq!(e0.reply_to, Some(ActorId(0)));
    }

    #[test]
    fn client_groups_responses_by_request() {
        let mut c = ApiClient::new();
        c.record(
            7,
            ApiResponse::Submitted {
                service: ServiceId(0),
                instances: vec![InstanceId(0)],
            },
        );
        c.record(
            7,
            ApiResponse::Error(ApiError::NoFeasiblePlacement {
                service: ServiceId(0),
                task: TaskId::default(),
            }),
        );
        assert_eq!(c.responses_for(7).len(), 2);
        assert!(matches!(c.ack(7), Some(ApiResponse::Submitted { .. })));
        assert_eq!(c.errors().len(), 1);
        assert!(c.ack(9).is_none());
    }

    #[test]
    fn client_batches_and_tracks_completion() {
        let mut c = ApiClient::new();
        let envs = c.envelopes(
            vec![
                ApiRequest::ListServices,
                ApiRequest::UndeployService {
                    service: ServiceId(1),
                },
                ApiRequest::ListServices,
            ],
            ActorId(2),
        );
        assert_eq!(envs.len(), 3);
        assert_eq!(
            envs.iter().map(|e| e.request_id).collect::<Vec<_>>(),
            vec![0, 1, 2],
            "batch ids are contiguous"
        );
        assert_eq!(c.issued(), 3);
        assert_eq!(c.outstanding(), vec![0, 1, 2]);
        c.record(1, ApiResponse::Services(vec![]));
        assert_eq!(c.outstanding(), vec![0, 2]);
        c.record(0, ApiResponse::Services(vec![]));
        c.record(2, ApiResponse::Services(vec![]));
        assert!(c.outstanding().is_empty());
    }

    #[test]
    fn api_errors_display() {
        let e = ApiError::UnsupportedVersion {
            requested: 2,
            supported: 1,
        };
        assert!(e.to_string().contains("version 2"));
        assert!(ApiError::UnknownService(ServiceId(4))
            .to_string()
            .contains("s4"));
        assert!(ApiError::InvalidReplicas {
            requested: 900,
            max: MAX_REPLICAS
        }
        .to_string()
        .contains("900"));
        let replaced = ApiError::AlreadyReplaced {
            instance: InstanceId(3),
            successor: InstanceId(9),
        };
        assert!(replaced.to_string().contains("i3"));
        assert!(replaced.to_string().contains("i9"));
    }
}
