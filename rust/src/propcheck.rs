//! Lightweight property-based testing harness (the vendored offline crate
//! set has no proptest, so invariant tests use this instead — see
//! Cargo.toml). Runs a property over many deterministic random cases,
//! reporting the failing case seed so a failure reproduces exactly.

use crate::util::Rng;

/// Run `property` over `cases` seeded RNG streams. Panics with the
/// offending case seed on the first failure (re-run with
/// `check_one(seed, property)` to reproduce).
pub fn check<F>(name: &str, cases: u32, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x9E37_0000_u64 + case as u64;
        let mut rng = Rng::seeded(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run one failing case by seed.
pub fn check_one<F>(seed: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::seeded(seed);
    if let Err(msg) = property(&mut rng) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

/// Assertion helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut runs = 0;
        check("trivial", 50, |rng| {
            runs += 1;
            let x = rng.f64();
            prop_assert!((0.0..1.0).contains(&x));
            Ok(())
        });
        assert_eq!(runs, 50);
    }

    #[test]
    #[should_panic(expected = "property 'bad'")]
    fn failing_property_panics_with_seed() {
        check("bad", 10, |rng| {
            let x = rng.f64();
            prop_assert!(x < 0.5, "x={x} too big");
            Ok(())
        });
    }

    #[test]
    fn check_one_reproduces() {
        // Same seed must behave identically.
        let probe = |rng: &mut crate::util::Rng| rng.next_u64();
        let mut r1 = crate::util::Rng::seeded(0x9E37_0000);
        let mut r2 = crate::util::Rng::seeded(0x9E37_0000);
        assert_eq!(probe(&mut r1), probe(&mut r2));
        check_one(12345, |_| Ok(()));
    }
}
