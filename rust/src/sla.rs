//! Service Level Agreement descriptors (paper Schema 1, §4.2).
//!
//! Application providers submit a JSON SLA alongside their code; the root
//! service manager validates it and derives task requirements
//! `Q_{τ_{p,i}}`. In addition to cloud-style capacity fields the schema
//! carries edge-specific constraints: geographic `area`/`location`,
//! end-to-end `latency`, service-to-service (S2S) and service-to-user
//! (S2U) link constraints (Alg. 2), plus the scheduling-heuristic tuning
//! knobs `convergence_time` and `rigidness`.

use crate::geo::GeoPoint;
use crate::model::{Capacity, Virtualization};
use crate::util::TaskId;

/// Constraint on a service-to-service link (`Q^{s2s}` in Alg. 2): this
/// task must sit within both thresholds of the *target* task's placement.
#[derive(Clone, Debug, PartialEq)]
pub struct S2sConstraint {
    /// Index of the target microservice within the same service.
    pub target_task: u16,
    /// Max great-circle distance to the target instance, km (`geo_thr`).
    pub geo_threshold_km: f64,
    /// Max Vivaldi (≈RTT) distance to the target instance, ms (`viv_thr`).
    pub latency_threshold_ms: f64,
}

/// Constraint on a service-to-user link (`Q^{s2u}` in Alg. 2).
#[derive(Clone, Debug, PartialEq)]
pub struct S2uConstraint {
    /// Where the users are expected (degrees in the JSON form).
    pub user_location: GeoPoint,
    /// Max great-circle distance to `user_location`, km (`geo_thr`).
    pub geo_threshold_km: f64,
    /// Max RTT to the (trilaterated) user position, ms (`lat_thr`).
    pub latency_threshold_ms: f64,
    /// How many random workers ping the user for trilateration (Alg. 2
    /// line 11, `rnd(W)`).
    pub probe_count: usize,
}

/// Per-task SLA row (one entry of Schema 1's `constraints` list).
#[derive(Clone, Debug, Default)]
pub struct TaskSla {
    pub memory_mb: u32,
    pub vcpus_millicores: u32,
    pub vgpus: u8,
    pub vtpus: u8,
    pub disk_mb: u32,
    pub bandwidth_in_mbps: u32,
    pub bandwidth_out_mbps: u32,
    /// Target operational area name (resolved against the registry).
    pub area: Option<String>,
    /// Explicit location pin, degrees.
    pub location: Option<GeoPoint>,
    /// Scheduler sensitivity to SLA violations before re-scheduling is
    /// triggered (0.0 = never re-schedule, 1.0 = immediately; §4.2).
    pub rigidness: f64,
    /// Max time the scheduler may spend finding a placement, ms (§4.2).
    pub convergence_time_ms: u64,
    /// Required virtualization technologies (comma-separated names).
    pub virtualization: String,
    pub s2s: Vec<S2sConstraint>,
    pub s2u: Vec<S2uConstraint>,
}

impl TaskSla {
    /// Requested capacity vector `Q_{τ_{p,i}}`.
    pub fn request(&self) -> Capacity {
        Capacity {
            cpu_millicores: self.vcpus_millicores,
            mem_mb: self.memory_mb,
            disk_mb: self.disk_mb,
            gpus: self.vgpus,
            tpus: self.vtpus,
        }
    }

    pub fn virtualization_mask(&self) -> Option<Virtualization> {
        Virtualization::parse(&self.virtualization)
    }
}

/// A full service SLA: the JSON document submitted to the root API.
#[derive(Clone, Debug, Default)]
pub struct ServiceSla {
    pub name: String,
    /// One row per microservice, ordered by microservice id.
    pub constraints: Vec<TaskSla>,
}

/// Validation failure for a submitted SLA.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SlaError {
    NoTasks,
    ZeroResources(usize),
    UnknownVirtualization(usize),
    BadS2sTarget { task: usize, target: u16 },
    SelfS2sTarget(usize),
    BadThreshold(usize),
}

impl std::fmt::Display for SlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SlaError::NoTasks => write!(f, "SLA has no microservice constraints"),
            SlaError::ZeroResources(i) => {
                write!(f, "task {i}: zero cpu and memory request")
            }
            SlaError::UnknownVirtualization(i) => {
                write!(f, "task {i}: unknown virtualization string")
            }
            SlaError::BadS2sTarget { task, target } => {
                write!(f, "task {task}: s2s target {target} out of range")
            }
            SlaError::SelfS2sTarget(i) => write!(f, "task {i}: s2s targets itself"),
            SlaError::BadThreshold(i) => {
                write!(f, "task {i}: non-positive constraint threshold")
            }
        }
    }
}
impl std::error::Error for SlaError {}

impl ServiceSla {
    /// Parse the JSON SLA document (Schema 1 shape). Unknown fields are
    /// ignored; missing numeric fields default to zero, mirroring how the
    /// paper's schema marks most properties optional.
    pub fn parse_json(s: &str) -> anyhow::Result<ServiceSla> {
        let v = crate::json::parse(s)?;
        let name = v.get("name").as_str().unwrap_or("unnamed").to_string();
        let mut constraints = Vec::new();
        for row in v.get("constraints").as_array().unwrap_or(&[]) {
            let num = |k: &str| row.get(k).as_f64().unwrap_or(0.0);
            let geo = |val: &crate::json::Value| -> Option<GeoPoint> {
                if val.is_null() {
                    return None;
                }
                Some(GeoPoint::from_degrees(
                    val.get("lat_deg").as_f64()?,
                    val.get("lon_deg").as_f64()?,
                ))
            };
            let mut t = TaskSla {
                memory_mb: num("memory_mb") as u32,
                vcpus_millicores: num("vcpus_millicores") as u32,
                vgpus: num("vgpus") as u8,
                vtpus: num("vtpus") as u8,
                disk_mb: num("disk_mb") as u32,
                bandwidth_in_mbps: num("bandwidth_in_mbps") as u32,
                bandwidth_out_mbps: num("bandwidth_out_mbps") as u32,
                area: row.get("area").as_str().map(str::to_string),
                location: geo(row.get("location")),
                rigidness: num("rigidness"),
                convergence_time_ms: num("convergence_time_ms") as u64,
                virtualization: row
                    .get("virtualization")
                    .as_str()
                    .unwrap_or("container")
                    .to_string(),
                s2s: Vec::new(),
                s2u: Vec::new(),
            };
            for c in row.get("s2s").as_array().unwrap_or(&[]) {
                t.s2s.push(S2sConstraint {
                    target_task: c.get("target_task").as_u64().unwrap_or(0) as u16,
                    geo_threshold_km: c.get("geo_threshold_km").as_f64().unwrap_or(0.0),
                    latency_threshold_ms: c
                        .get("latency_threshold_ms")
                        .as_f64()
                        .unwrap_or(0.0),
                });
            }
            for c in row.get("s2u").as_array().unwrap_or(&[]) {
                t.s2u.push(S2uConstraint {
                    user_location: geo(c.get("user_location")).unwrap_or_default(),
                    geo_threshold_km: c.get("geo_threshold_km").as_f64().unwrap_or(0.0),
                    latency_threshold_ms: c
                        .get("latency_threshold_ms")
                        .as_f64()
                        .unwrap_or(0.0),
                    probe_count: c.get("probe_count").as_u64().unwrap_or(3) as usize,
                });
            }
            constraints.push(t);
        }
        Ok(ServiceSla { name, constraints })
    }

    /// Structural validation performed by the root service manager before
    /// a deployment request is accepted (paper step ①).
    pub fn validate(&self) -> Result<(), SlaError> {
        if self.constraints.is_empty() {
            return Err(SlaError::NoTasks);
        }
        let n = self.constraints.len() as u16;
        for (i, t) in self.constraints.iter().enumerate() {
            if t.vcpus_millicores == 0 && t.memory_mb == 0 {
                return Err(SlaError::ZeroResources(i));
            }
            if t.virtualization_mask().is_none() {
                return Err(SlaError::UnknownVirtualization(i));
            }
            for s in &t.s2s {
                if s.target_task >= n {
                    return Err(SlaError::BadS2sTarget {
                        task: i,
                        target: s.target_task,
                    });
                }
                if s.target_task as usize == i {
                    return Err(SlaError::SelfS2sTarget(i));
                }
                if s.geo_threshold_km <= 0.0 || s.latency_threshold_ms <= 0.0 {
                    return Err(SlaError::BadThreshold(i));
                }
            }
            for u in &t.s2u {
                if u.geo_threshold_km <= 0.0 || u.latency_threshold_ms <= 0.0 {
                    return Err(SlaError::BadThreshold(i));
                }
            }
        }
        Ok(())
    }

    /// Task ids this SLA will create under a given service id.
    pub fn task_ids(&self, service: crate::util::ServiceId) -> Vec<TaskId> {
        (0..self.constraints.len() as u16)
            .map(|index| TaskId { service, index })
            .collect()
    }
}

/// Convenience builder for the common "1 CPU, 100 MB" style test SLAs
/// used throughout the paper's evaluation (§7.3).
pub fn simple_sla(name: &str, cpu_millicores: u32, mem_mb: u32) -> ServiceSla {
    ServiceSla {
        name: name.to_string(),
        constraints: vec![TaskSla {
            memory_mb: mem_mb,
            vcpus_millicores: cpu_millicores,
            virtualization: "container".into(),
            rigidness: 0.5,
            convergence_time_ms: 5_000,
            ..TaskSla::default()
        }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ServiceId;

    #[test]
    fn parse_schema1_style_json() {
        let json = r#"{
            "name": "video-analytics",
            "constraints": [
                {
                    "memory_mb": 100, "vcpus_millicores": 1000,
                    "vgpus": 0, "vtpus": 0, "disk_mb": 50,
                    "bandwidth_in_mbps": 10, "bandwidth_out_mbps": 5,
                    "area": "munich", "location": null,
                    "rigidness": 0.5, "convergence_time_ms": 5000,
                    "virtualization": "container",
                    "s2s": [{"target_task": 1, "geo_threshold_km": 120.0,
                             "latency_threshold_ms": 20.0}],
                    "s2u": []
                },
                {
                    "memory_mb": 200, "vcpus_millicores": 500,
                    "vgpus": 0, "vtpus": 0, "disk_mb": 0,
                    "bandwidth_in_mbps": 0, "bandwidth_out_mbps": 0,
                    "area": null, "location": null,
                    "rigidness": 0.1, "convergence_time_ms": 5000,
                    "virtualization": "container,wasm",
                    "s2s": [], "s2u": []
                }
            ]
        }"#;
        let sla = ServiceSla::parse_json(json).unwrap();
        assert_eq!(sla.constraints.len(), 2);
        sla.validate().unwrap();
        assert_eq!(sla.constraints[0].request().cpu_millicores, 1000);
        assert_eq!(
            sla.constraints[1].virtualization_mask().unwrap(),
            Virtualization::CONTAINER.union(Virtualization::WASM)
        );
    }

    #[test]
    fn validation_catches_structural_errors() {
        let mut sla = simple_sla("x", 1000, 100);
        sla.constraints[0].s2s.push(S2sConstraint {
            target_task: 5,
            geo_threshold_km: 10.0,
            latency_threshold_ms: 10.0,
        });
        assert_eq!(
            sla.validate(),
            Err(SlaError::BadS2sTarget { task: 0, target: 5 })
        );

        let mut sla = simple_sla("x", 1000, 100);
        sla.constraints[0].s2s.push(S2sConstraint {
            target_task: 0,
            geo_threshold_km: 10.0,
            latency_threshold_ms: 10.0,
        });
        assert_eq!(sla.validate(), Err(SlaError::SelfS2sTarget(0)));

        let empty = ServiceSla {
            name: "e".into(),
            constraints: vec![],
        };
        assert_eq!(empty.validate(), Err(SlaError::NoTasks));

        let mut sla = simple_sla("x", 0, 0);
        sla.constraints[0].memory_mb = 0;
        assert_eq!(sla.validate(), Err(SlaError::ZeroResources(0)));

        let mut sla = simple_sla("x", 1000, 100);
        sla.constraints[0].virtualization = "quantum".into();
        assert_eq!(sla.validate(), Err(SlaError::UnknownVirtualization(0)));
    }

    #[test]
    fn task_ids_are_sequential() {
        let mut sla = simple_sla("x", 1000, 100);
        sla.constraints.push(sla.constraints[0].clone());
        let ids = sla.task_ids(ServiceId(7));
        assert_eq!(ids.len(), 2);
        assert_eq!(ids[0].index, 0);
        assert_eq!(ids[1].index, 1);
        assert!(ids.iter().all(|t| t.service == ServiceId(7)));
    }
}
