//! Semantic overlay networking (paper §5): logical service addressing that
//! stays stable across migrations/failures, balancing-policy ServiceIPs,
//! per-worker address conversion tables, and the ProxyTUN tunnel manager
//! with configured/active link distinction and LRU eviction.

mod balancer;
mod mdns;
mod subnet;
mod table;
mod tunnel;

pub use balancer::{pick_instance, BalancePolicy};
pub use mdns::Mdns;
pub use subnet::SubnetAllocator;
pub use table::{ConversionTable, TableEntry};
pub use tunnel::{
    tunnel_transfer_time, ProxyTun, TunnelState, HANDSHAKE_MS, OAK_PKT_OVERHEAD_MS,
    WG_PKT_OVERHEAD_MS,
};

use crate::util::{InstanceId, NodeId, TaskId};

/// A semantic service address (paper §5): either a concrete instance's
/// logical IP, or a policy address that resolves to "the instance that
/// best suits that policy" at connection time.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum ServiceIp {
    /// Logical address of one specific instance (stable across node moves).
    Instance(InstanceId),
    /// `serviceX.round_robin` — rotate over live instances.
    RoundRobin(TaskId),
    /// `serviceX.closest` — lowest-latency live instance (Vivaldi).
    Closest(TaskId),
}

impl ServiceIp {
    /// The task this address belongs to, if policy-addressed.
    pub fn task(&self) -> Option<TaskId> {
        match self {
            ServiceIp::Instance(_) => None,
            ServiceIp::RoundRobin(t) | ServiceIp::Closest(t) => Some(*t),
        }
    }
}

/// Where one live instance of a task currently is: the value side of the
/// conversion table.
#[derive(Clone, Copy, PartialEq, Debug)]
pub struct InstanceLocation {
    pub instance: InstanceId,
    pub task: TaskId,
    pub node: NodeId,
    /// RTT estimate from the table owner to this instance, ms (Vivaldi).
    pub rtt_ms: f64,
}
