//! ProxyTUN (paper §5): UDP-based, end-to-end encrypted L4 tunnels between
//! workers. Tracks the *configured* (known endpoint) vs *active* (carrying
//! traffic) link distinction, enforces the per-node active cap `k` with
//! LRU eviction, and models the per-packet tunneling overhead the paper
//! measures against WireGuard (Fig. 9 right).

use std::collections::BTreeMap;

use crate::util::{NodeId, SimTime};

/// Lifecycle of one outbound tunnel.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TunnelState {
    /// Endpoint known, no recent traffic; candidate for GC.
    Configured,
    /// Currently carrying data.
    Active,
}

#[derive(Clone, Debug)]
struct Tunnel {
    state: TunnelState,
    last_used: SimTime,
}

/// Per-worker tunnel manager.
#[derive(Clone, Debug)]
pub struct ProxyTun {
    tunnels: BTreeMap<NodeId, Tunnel>,
    /// Max simultaneously *active* tunnels (paper: `k`, LRU beyond).
    pub max_active: usize,
    /// Tunnels become Configured after this idle time.
    pub idle_timeout: SimTime,
    /// Count of LRU evictions (ablation metric).
    pub evictions: u64,
    /// Handshakes performed (each activation of a non-active tunnel).
    pub handshakes: u64,
}

/// Per-packet overhead of Oakestra's L4 per-packet tunneling, ms. The
/// paper finds WireGuard ~10% faster at low RTT (kernel path vs userspace
/// proxy); these constants encode that gap and feed Fig. 9 (right).
pub const OAK_PKT_OVERHEAD_MS: f64 = 0.035;
/// WireGuard's kernel-path per-packet cost, ms.
pub const WG_PKT_OVERHEAD_MS: f64 = 0.012;
/// Tunnel handshake cost (endpoint setup / key exchange), ms.
pub const HANDSHAKE_MS: f64 = 1.5;

impl Default for ProxyTun {
    fn default() -> Self {
        ProxyTun {
            tunnels: BTreeMap::new(),
            max_active: 64,
            idle_timeout: SimTime::from_secs(30.0),
            evictions: 0,
            handshakes: 0,
        }
    }
}

impl ProxyTun {
    pub fn with_cap(max_active: usize) -> Self {
        ProxyTun {
            max_active,
            ..ProxyTun::default()
        }
    }

    /// Ensure an active tunnel to `peer`, returning the setup latency this
    /// use incurs (0 for an already-active tunnel). Activating beyond the
    /// cap evicts the least-recently-used active tunnel (paper §5).
    pub fn activate(&mut self, peer: NodeId, now: SimTime) -> SimTime {
        let needs_handshake = match self.tunnels.get(&peer) {
            Some(t) if t.state == TunnelState::Active => {
                self.tunnels.get_mut(&peer).unwrap().last_used = now;
                return SimTime::ZERO;
            }
            Some(_) => false, // configured: endpoint known, re-activate cheap
            None => true,     // brand new: full handshake
        };

        // Enforce the active cap.
        let active: Vec<(NodeId, SimTime)> = self
            .tunnels
            .iter()
            .filter(|(_, t)| t.state == TunnelState::Active)
            .map(|(n, t)| (*n, t.last_used))
            .collect();
        if active.len() >= self.max_active {
            if let Some((lru, _)) = active.iter().min_by_key(|(_, t)| *t) {
                self.tunnels.get_mut(lru).unwrap().state = TunnelState::Configured;
                self.evictions += 1;
            }
        }

        self.tunnels.insert(
            peer,
            Tunnel {
                state: TunnelState::Active,
                last_used: now,
            },
        );
        if needs_handshake {
            self.handshakes += 1;
            SimTime::from_millis(HANDSHAKE_MS)
        } else {
            SimTime::from_millis(HANDSHAKE_MS * 0.2) // warm re-activation
        }
    }

    /// Record traffic on an (assumed active) tunnel.
    pub fn touch(&mut self, peer: NodeId, now: SimTime) {
        if let Some(t) = self.tunnels.get_mut(&peer) {
            t.last_used = now;
        }
    }

    /// Periodic GC sweep: demote idle active tunnels to Configured.
    pub fn gc(&mut self, now: SimTime) {
        let timeout = self.idle_timeout;
        for t in self.tunnels.values_mut() {
            if t.state == TunnelState::Active
                && now.saturating_sub(t.last_used) >= timeout
            {
                t.state = TunnelState::Configured;
            }
        }
    }

    pub fn active_count(&self) -> usize {
        self.tunnels
            .values()
            .filter(|t| t.state == TunnelState::Active)
            .count()
    }

    /// Tunnels currently in the `Configured` state (endpoint known, no
    /// recent traffic). This used to return *all* known tunnels — use
    /// [`ProxyTun::known_count`] for that total.
    pub fn configured_count(&self) -> usize {
        self.tunnels
            .values()
            .filter(|t| t.state == TunnelState::Configured)
            .count()
    }

    /// All tunnels with a known endpoint, whatever their state
    /// (`Configured` + `Active`).
    pub fn known_count(&self) -> usize {
        self.tunnels.len()
    }

    pub fn state_of(&self, peer: NodeId) -> Option<TunnelState> {
        self.tunnels.get(&peer).map(|t| t.state)
    }

    /// Invariant for the proptest suite: active count never exceeds the
    /// cap (+1 transient during activation is not observable from here).
    pub fn check_invariants(&self) -> Result<(), String> {
        let a = self.active_count();
        if a > self.max_active {
            return Err(format!("{a} active tunnels exceed cap {}", self.max_active));
        }
        Ok(())
    }
}

/// Time to push `bytes` through a tunnel whose underlying link sustains
/// `link_mbps`, for a per-packet overhead model with 1400-byte MTU. Used
/// by both the Oakestra and WireGuard sides of Fig. 9 (right).
pub fn tunnel_transfer_time(bytes: u64, link_mbps: f64, per_pkt_ms: f64) -> SimTime {
    let pkts = (bytes as f64 / 1400.0).ceil();
    let wire = bytes as f64 * 8.0 / (link_mbps * 1e6);
    SimTime::from_secs(wire + pkts * per_pkt_ms / 1000.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activation_lifecycle() {
        let mut p = ProxyTun::with_cap(4);
        let t0 = SimTime::ZERO;
        let cost = p.activate(NodeId(1), t0);
        assert_eq!(cost, SimTime::from_millis(HANDSHAKE_MS));
        assert_eq!(p.state_of(NodeId(1)), Some(TunnelState::Active));
        // Re-activating an active tunnel is free.
        assert_eq!(p.activate(NodeId(1), t0), SimTime::ZERO);
        assert_eq!(p.handshakes, 1);
    }

    #[test]
    fn counts_distinguish_configured_from_known() {
        let mut p = ProxyTun::default();
        p.idle_timeout = SimTime::from_secs(10.0);
        p.activate(NodeId(1), SimTime::ZERO);
        p.activate(NodeId(2), SimTime::from_secs(9.0));
        // Both active, none configured; both known.
        assert_eq!(p.active_count(), 2);
        assert_eq!(p.configured_count(), 0);
        assert_eq!(p.known_count(), 2);
        p.gc(SimTime::from_secs(12.0));
        // Tunnel 1 demoted: counted as configured, still known.
        assert_eq!(p.active_count(), 1);
        assert_eq!(p.configured_count(), 1);
        assert_eq!(p.known_count(), 2);
    }

    #[test]
    fn gc_demotes_idle_tunnels() {
        let mut p = ProxyTun::default();
        p.idle_timeout = SimTime::from_secs(10.0);
        p.activate(NodeId(1), SimTime::ZERO);
        p.activate(NodeId(2), SimTime::from_secs(9.0));
        p.gc(SimTime::from_secs(12.0));
        assert_eq!(p.state_of(NodeId(1)), Some(TunnelState::Configured));
        assert_eq!(p.state_of(NodeId(2)), Some(TunnelState::Active));
        // Re-activation of a configured tunnel is cheaper than a handshake.
        let cost = p.activate(NodeId(1), SimTime::from_secs(13.0));
        assert!(cost < SimTime::from_millis(HANDSHAKE_MS));
        assert_eq!(p.handshakes, 2);
    }

    #[test]
    fn lru_eviction_at_cap() {
        let mut p = ProxyTun::with_cap(2);
        p.activate(NodeId(1), SimTime::from_secs(1.0));
        p.activate(NodeId(2), SimTime::from_secs(2.0));
        p.touch(NodeId(1), SimTime::from_secs(3.0)); // 2 is now LRU
        p.activate(NodeId(3), SimTime::from_secs(4.0));
        assert_eq!(p.active_count(), 2);
        assert_eq!(p.state_of(NodeId(2)), Some(TunnelState::Configured));
        assert_eq!(p.state_of(NodeId(1)), Some(TunnelState::Active));
        assert_eq!(p.evictions, 1);
        p.check_invariants().unwrap();
    }

    #[test]
    fn transfer_time_orders_oak_vs_wireguard() {
        // 100 MB over a 100 Mbps link (Fig. 9 right setup).
        let oak = tunnel_transfer_time(100 << 20, 100.0, OAK_PKT_OVERHEAD_MS);
        let wg = tunnel_transfer_time(100 << 20, 100.0, WG_PKT_OVERHEAD_MS);
        assert!(wg < oak);
        // Gap is ~10% territory, not 2x.
        let ratio = oak.as_secs() / wg.as_secs();
        assert!(ratio > 1.05 && ratio < 1.35, "ratio={ratio}");
    }
}
