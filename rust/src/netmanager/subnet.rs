//! Per-worker overlay subnet allocation: each worker obtains a unique
//! subnetwork during the registration handshake (paper §6 Networking) and
//! maps each deployed instance to a logical address inside it.

use std::collections::BTreeMap;

use crate::util::{InstanceId, NodeId};

/// Allocates `/24`-style index ranges out of a flat u32 space; subnet `s`
/// spans logical addresses `[s << 8, (s+1) << 8)`.
#[derive(Clone, Debug, Default)]
pub struct SubnetAllocator {
    next: u32,
    by_node: BTreeMap<NodeId, u32>,
    /// next host index within each subnet
    host_next: BTreeMap<u32, u32>,
    freed: Vec<u32>,
}

impl SubnetAllocator {
    /// Assign (or return the existing) subnet for a worker.
    pub fn subnet_for(&mut self, node: NodeId) -> u32 {
        if let Some(s) = self.by_node.get(&node) {
            return *s;
        }
        let s = self.freed.pop().unwrap_or_else(|| {
            let s = self.next;
            self.next += 1;
            s
        });
        self.by_node.insert(node, s);
        self.host_next.insert(s, 1);
        s
    }

    /// Mint a logical address for an instance inside the worker's subnet.
    pub fn logical_addr(&mut self, node: NodeId, _instance: InstanceId) -> u32 {
        let s = self.subnet_for(node);
        let h = self.host_next.entry(s).or_insert(1);
        let addr = (s << 8) | (*h & 0xFF);
        *h += 1;
        addr
    }

    /// Release a departed worker's subnet for reuse.
    pub fn release(&mut self, node: NodeId) {
        if let Some(s) = self.by_node.remove(&node) {
            self.host_next.remove(&s);
            self.freed.push(s);
        }
    }

    pub fn subnet_of_addr(addr: u32) -> u32 {
        addr >> 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subnets_are_unique_per_node() {
        let mut a = SubnetAllocator::default();
        let s1 = a.subnet_for(NodeId(1));
        let s2 = a.subnet_for(NodeId(2));
        assert_ne!(s1, s2);
        assert_eq!(a.subnet_for(NodeId(1)), s1); // stable
    }

    #[test]
    fn logical_addrs_stay_inside_subnet() {
        let mut a = SubnetAllocator::default();
        let s = a.subnet_for(NodeId(9));
        for i in 0..10 {
            let addr = a.logical_addr(NodeId(9), InstanceId(i));
            assert_eq!(SubnetAllocator::subnet_of_addr(addr), s);
        }
    }

    #[test]
    fn release_recycles() {
        let mut a = SubnetAllocator::default();
        let s1 = a.subnet_for(NodeId(1));
        a.release(NodeId(1));
        let s2 = a.subnet_for(NodeId(2));
        assert_eq!(s1, s2);
    }

    #[test]
    fn addrs_unique_within_node() {
        let mut a = SubnetAllocator::default();
        let x = a.logical_addr(NodeId(1), InstanceId(1));
        let y = a.logical_addr(NodeId(1), InstanceId(2));
        assert_ne!(x, y);
    }
}
