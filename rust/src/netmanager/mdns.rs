//! Local mDNS-style naming (paper §5): services address peers by
//! `name.policy` names (e.g. `serviceB.closest`) instead of raw IPs; the
//! worker-local resolver maps names to semantic ServiceIPs.

use std::collections::BTreeMap;

use crate::util::TaskId;

use super::ServiceIp;

/// Worker-local name resolver.
#[derive(Clone, Debug, Default)]
pub struct Mdns {
    names: BTreeMap<String, TaskId>,
}

impl Mdns {
    /// Register a service name (done by the NodeEngine at deploy time from
    /// the orchestrator-provided service metadata).
    pub fn register(&mut self, name: &str, task: TaskId) {
        self.names.insert(name.to_ascii_lowercase(), task);
    }

    pub fn unregister(&mut self, name: &str) {
        self.names.remove(&name.to_ascii_lowercase());
    }

    /// Resolve `service.policy` → ServiceIP. Bare names default to the
    /// round-robin policy. Unknown names or policies resolve to `None`.
    pub fn resolve(&self, qname: &str) -> Option<ServiceIp> {
        let q = qname.to_ascii_lowercase();
        let (name, policy) = match q.rsplit_once('.') {
            Some((n, p)) => (n, p),
            None => (q.as_str(), "round_robin"),
        };
        // A dot that isn't a known policy is part of the name itself.
        let (name, policy) = match policy {
            "closest" | "round_robin" | "rr" => (name, policy),
            _ => (q.as_str(), "round_robin"),
        };
        let task = *self.names.get(name)?;
        Some(match policy {
            "closest" => ServiceIp::Closest(task),
            _ => ServiceIp::RoundRobin(task),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ServiceId;

    fn tid(i: u16) -> TaskId {
        TaskId {
            service: ServiceId(3),
            index: i,
        }
    }

    #[test]
    fn resolve_policies() {
        let mut m = Mdns::default();
        m.register("serviceB", tid(1));
        assert_eq!(m.resolve("serviceB.closest"), Some(ServiceIp::Closest(tid(1))));
        assert_eq!(
            m.resolve("serviceb.round_robin"),
            Some(ServiceIp::RoundRobin(tid(1)))
        );
        assert_eq!(m.resolve("serviceB"), Some(ServiceIp::RoundRobin(tid(1))));
        assert_eq!(m.resolve("unknown.closest"), None);
    }

    #[test]
    fn dotted_names_without_policy() {
        let mut m = Mdns::default();
        m.register("video.detector", tid(2));
        assert_eq!(
            m.resolve("video.detector"),
            Some(ServiceIp::RoundRobin(tid(2)))
        );
    }

    #[test]
    fn unregister_removes() {
        let mut m = Mdns::default();
        m.register("x", tid(0));
        m.unregister("X");
        assert_eq!(m.resolve("x"), None);
    }
}
