//! Edge-oriented load balancing (paper §5): clients want not only a
//! lightly-loaded instance but the one deployed *closest* to them. Policy
//! resolution happens in the worker's ProxyTUN at connection time.

use super::{ConversionTable, InstanceLocation, ServiceIp};

/// Balancing policy carried by a semantic ServiceIP.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BalancePolicy {
    RoundRobin,
    Closest,
}

/// Resolve a ServiceIP to one concrete instance using the worker's table.
/// Returns `None` when the table has no live locations (caller then asks
/// the cluster service manager and retries).
pub fn pick_instance(
    table: &mut ConversionTable,
    ip: &ServiceIp,
) -> Option<InstanceLocation> {
    match ip {
        ServiceIp::Instance(inst) => {
            let locs = table.lookup(ip)?;
            locs.iter().find(|l| l.instance == *inst).copied()
        }
        ServiceIp::RoundRobin(task) => {
            let locs = table.lookup(ip)?.to_vec();
            let i = table.rr_next(*task, locs.len());
            locs.get(i).copied()
        }
        ServiceIp::Closest(_) => {
            // `total_cmp` keeps the pick total when an RTT estimate is NaN
            // (stale Vivaldi coordinate): NaN sorts last, so any location
            // with a real estimate wins and the connection path never
            // panics.
            let locs = table.lookup(ip)?;
            locs.iter()
                .min_by(|a, b| a.rtt_ms.total_cmp(&b.rtt_ms))
                .copied()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netmanager::TableEntry;
    use crate::util::{InstanceId, NodeId, ServiceId, TaskId};

    fn tid() -> TaskId {
        TaskId {
            service: ServiceId(1),
            index: 0,
        }
    }

    fn table() -> ConversionTable {
        let mut t = ConversionTable::default();
        t.apply(TableEntry {
            task: tid(),
            locations: vec![
                InstanceLocation {
                    instance: InstanceId(1),
                    task: tid(),
                    node: NodeId(10),
                    rtt_ms: 25.0,
                },
                InstanceLocation {
                    instance: InstanceId(2),
                    task: tid(),
                    node: NodeId(11),
                    rtt_ms: 5.0,
                },
                InstanceLocation {
                    instance: InstanceId(3),
                    task: tid(),
                    node: NodeId(12),
                    rtt_ms: 90.0,
                },
            ],
        });
        t
    }

    #[test]
    fn closest_picks_min_rtt() {
        let mut t = table();
        let got = pick_instance(&mut t, &ServiceIp::Closest(tid())).unwrap();
        assert_eq!(got.instance, InstanceId(2));
    }

    #[test]
    fn round_robin_rotates_over_all() {
        let mut t = table();
        let picks: Vec<u64> = (0..6)
            .map(|_| {
                pick_instance(&mut t, &ServiceIp::RoundRobin(tid()))
                    .unwrap()
                    .instance
                    .0
            })
            .collect();
        assert_eq!(picks, vec![1, 2, 3, 1, 2, 3]);
    }

    #[test]
    fn instance_address_is_exact() {
        let mut t = table();
        let got = pick_instance(&mut t, &ServiceIp::Instance(InstanceId(3))).unwrap();
        assert_eq!(got.node, NodeId(12));
    }

    #[test]
    fn closest_tolerates_nan_rtt_estimates() {
        // A location with a NaN RTT (stale Vivaldi estimate) must neither
        // panic the pick nor win it while finite estimates exist.
        let mut t = ConversionTable::default();
        t.apply(TableEntry {
            task: tid(),
            locations: vec![
                InstanceLocation {
                    instance: InstanceId(1),
                    task: tid(),
                    node: NodeId(10),
                    rtt_ms: f64::NAN,
                },
                InstanceLocation {
                    instance: InstanceId(2),
                    task: tid(),
                    node: NodeId(11),
                    rtt_ms: 30.0,
                },
            ],
        });
        let got = pick_instance(&mut t, &ServiceIp::Closest(tid())).unwrap();
        assert_eq!(got.instance, InstanceId(2));

        // All-NaN degenerates to a deterministic pick (first entry).
        let mut t = ConversionTable::default();
        t.apply(TableEntry {
            task: tid(),
            locations: vec![
                InstanceLocation {
                    instance: InstanceId(7),
                    task: tid(),
                    node: NodeId(12),
                    rtt_ms: f64::NAN,
                },
                InstanceLocation {
                    instance: InstanceId(8),
                    task: tid(),
                    node: NodeId(13),
                    rtt_ms: f64::NAN,
                },
            ],
        });
        let got = pick_instance(&mut t, &ServiceIp::Closest(tid())).unwrap();
        assert_eq!(got.instance, InstanceId(7));
    }

    #[test]
    fn empty_table_returns_none() {
        let mut t = ConversionTable::default();
        assert!(pick_instance(&mut t, &ServiceIp::Closest(tid())).is_none());
        assert_eq!(t.misses, 1);
    }
}
