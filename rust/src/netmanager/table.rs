//! The per-worker address conversion table (paper §5, Fig. 2): maps
//! semantic ServiceIPs to the current set of instance locations. Entries
//! start `null` at worker boot (t=0), fill on demand via cluster
//! resolution, and are invalidated/refreshed by push updates from the
//! orchestrator on migrations, scaling and undeployment.

use std::collections::BTreeMap;

use crate::util::TaskId;

use super::{InstanceLocation, ServiceIp};

/// One pushed/resolved table row: all live locations for one task.
#[derive(Clone, Debug, PartialEq)]
pub struct TableEntry {
    pub task: TaskId,
    pub locations: Vec<InstanceLocation>,
}

/// The conversion table held by each worker's NetManager.
#[derive(Clone, Debug, Default)]
pub struct ConversionTable {
    entries: BTreeMap<TaskId, Vec<InstanceLocation>>,
    /// Round-robin cursors per task.
    rr_cursor: BTreeMap<TaskId, usize>,
    /// Resolution misses observed (each triggers a ResolveIp round-trip).
    pub misses: u64,
    /// Push updates applied (one per table row replaced).
    pub updates: u64,
    /// Batched pushes received (one per `TableUpdate` message — the
    /// orchestrator coalesces row deltas per destination, so
    /// `updates / batches` is the achieved coalescing factor).
    pub batches: u64,
}

impl ConversionTable {
    /// Look up the instances backing a ServiceIP. `None` means unknown
    /// task — the caller must ask the cluster service manager (step ⑩).
    pub fn lookup(&mut self, ip: &ServiceIp) -> Option<&[InstanceLocation]> {
        let task = match ip {
            ServiceIp::Instance(inst) => {
                // Instance addresses resolve by scanning known rows.
                let hit = self
                    .entries
                    .values()
                    .flatten()
                    .any(|l| l.instance == *inst);
                if !hit {
                    self.misses += 1;
                    return None;
                }
                return self
                    .entries
                    .values()
                    .find(|locs| locs.iter().any(|l| l.instance == *inst))
                    .map(|v| v.as_slice());
            }
            ServiceIp::RoundRobin(t) | ServiceIp::Closest(t) => *t,
        };
        match self.entries.get(&task) {
            Some(v) if !v.is_empty() => Some(v.as_slice()),
            _ => {
                self.misses += 1;
                None
            }
        }
    }

    /// Apply a pushed/resolved entry (replaces the task's full row —
    /// updates are authoritative snapshots from the orchestrator).
    pub fn apply(&mut self, entry: TableEntry) {
        self.updates += 1;
        if entry.locations.is_empty() {
            self.entries.remove(&entry.task);
        } else {
            self.entries.insert(entry.task, entry.locations);
        }
    }

    /// Apply one coalesced `TableUpdate` batch.
    pub fn apply_all(&mut self, entries: Vec<TableEntry>) {
        self.batches += 1;
        for e in entries {
            self.apply(e);
        }
    }

    /// Drop every location on a given node (local failure observation —
    /// the authoritative update will follow from the orchestrator).
    pub fn invalidate_node(&mut self, node: crate::util::NodeId) {
        for locs in self.entries.values_mut() {
            locs.retain(|l| l.node != node);
        }
        self.entries.retain(|_, v| !v.is_empty());
    }

    /// Advance and return the round-robin cursor for a task.
    pub fn rr_next(&mut self, task: TaskId, len: usize) -> usize {
        let c = self.rr_cursor.entry(task).or_insert(0);
        let i = *c % len.max(1);
        *c = c.wrapping_add(1);
        i
    }

    pub fn known_tasks(&self) -> usize {
        self.entries.len()
    }

    pub fn locations(&self, task: TaskId) -> Option<&[InstanceLocation]> {
        self.entries.get(&task).map(Vec::as_slice)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{InstanceId, NodeId, ServiceId};

    fn tid(i: u16) -> TaskId {
        TaskId {
            service: ServiceId(1),
            index: i,
        }
    }
    fn loc(inst: u64, node: u32, rtt: f64) -> InstanceLocation {
        InstanceLocation {
            instance: InstanceId(inst),
            task: tid(0),
            node: NodeId(node),
            rtt_ms: rtt,
        }
    }

    #[test]
    fn starts_empty_and_counts_misses() {
        let mut t = ConversionTable::default();
        assert!(t.lookup(&ServiceIp::Closest(tid(0))).is_none());
        assert!(t.lookup(&ServiceIp::Instance(InstanceId(1))).is_none());
        assert_eq!(t.misses, 2);
    }

    #[test]
    fn apply_then_lookup() {
        let mut t = ConversionTable::default();
        t.apply(TableEntry {
            task: tid(0),
            locations: vec![loc(1, 10, 5.0), loc(2, 11, 9.0)],
        });
        let got = t.lookup(&ServiceIp::RoundRobin(tid(0))).unwrap();
        assert_eq!(got.len(), 2);
        assert!(t.lookup(&ServiceIp::Instance(InstanceId(2))).is_some());
        assert_eq!(t.misses, 0);
    }

    #[test]
    fn empty_update_removes_row() {
        let mut t = ConversionTable::default();
        t.apply(TableEntry {
            task: tid(0),
            locations: vec![loc(1, 10, 5.0)],
        });
        t.apply(TableEntry {
            task: tid(0),
            locations: vec![],
        });
        assert!(t.lookup(&ServiceIp::Closest(tid(0))).is_none());
    }

    #[test]
    fn invalidate_node_prunes() {
        let mut t = ConversionTable::default();
        t.apply(TableEntry {
            task: tid(0),
            locations: vec![loc(1, 10, 5.0), loc(2, 11, 9.0)],
        });
        t.invalidate_node(NodeId(10));
        let got = t.locations(tid(0)).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].node, NodeId(11));
        t.invalidate_node(NodeId(11));
        assert!(t.locations(tid(0)).is_none());
    }

    #[test]
    fn batched_apply_counts_batches_and_rows() {
        let mut t = ConversionTable::default();
        t.apply_all(vec![
            TableEntry {
                task: tid(0),
                locations: vec![loc(1, 10, 5.0)],
            },
            TableEntry {
                task: tid(1),
                locations: vec![loc(2, 11, 9.0)],
            },
        ]);
        t.apply_all(vec![TableEntry {
            task: tid(0),
            locations: vec![],
        }]);
        assert_eq!(t.batches, 2);
        assert_eq!(t.updates, 3);
        assert!(t.locations(tid(0)).is_none());
        assert!(t.locations(tid(1)).is_some());
    }

    #[test]
    fn rr_cursor_cycles() {
        let mut t = ConversionTable::default();
        let seq: Vec<usize> = (0..6).map(|_| t.rr_next(tid(0), 3)).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }
}
