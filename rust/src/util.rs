//! Foundation types: identifiers, simulated time, deterministic RNG and
//! small statistics helpers shared across the crate.

use std::fmt;

/// Identifier of a physical node (worker, orchestrator host, user device).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug, Default)]
pub struct NodeId(pub u32);

/// Identifier of a cluster (or sub-cluster) in the hierarchy tree.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug, Default)]
pub struct ClusterId(pub u32);

/// Identifier of an application service `s_p` (a set of microservices).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug, Default)]
pub struct ServiceId(pub u32);

/// Identifier of a microservice/task `τ_{p,i}` within a service.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug, Default)]
pub struct TaskId {
    pub service: ServiceId,
    pub index: u16,
}

/// Identifier of a *deployed instance* of a task (replicas/migrations mint
/// fresh instance ids; the old instance keeps its id until terminated).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug, Default)]
pub struct InstanceId(pub u64);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}
impl fmt::Display for ClusterId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "c{}", self.0)
    }
}
impl fmt::Display for ServiceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "s{}", self.0)
    }
}
impl fmt::Display for TaskId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}τ{}", self.service, self.index)
    }
}
impl fmt::Display for InstanceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "i{}", self.0)
    }
}

/// Simulated time in **microseconds** since experiment start.
///
/// Microsecond resolution keeps sub-millisecond control-plane costs exact
/// while `u64` still covers ~584k years of virtual time.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_micros(us: u64) -> Self {
        SimTime(us)
    }
    pub fn from_millis(ms: f64) -> Self {
        SimTime((ms * 1_000.0).round().max(0.0) as u64)
    }
    pub fn from_secs(s: f64) -> Self {
        SimTime((s * 1_000_000.0).round().max(0.0) as u64)
    }
    pub fn as_micros(self) -> u64 {
        self.0
    }
    pub fn as_millis(self) -> f64 {
        self.0 as f64 / 1_000.0
    }
    pub fn as_secs(self) -> f64 {
        self.0 as f64 / 1_000_000.0
    }
    #[must_use]
    pub fn saturating_sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }
}

impl std::ops::Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}
impl std::ops::AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}
impl std::ops::Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 - rhs.0)
    }
}
impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}ms", self.as_millis())
    }
}

/// Deterministic, dependency-free RNG (splitmix64 seeded xoshiro256**).
///
/// Every stochastic decision in the simulator draws from one of these,
/// seeded from the experiment config, so traces are exactly reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seeded(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the xoshiro state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        mean + std * (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponentially distributed sample with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * self.f64().max(1e-12).ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample up to `k` distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }

    /// Fork an independent stream (for per-actor RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::seeded(self.next_u64())
    }
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation (0.0 for empty input).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0 <= p <= 100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simtime_roundtrip() {
        assert_eq!(SimTime::from_millis(1.5).as_micros(), 1500);
        assert_eq!(SimTime::from_secs(2.0).as_millis(), 2000.0);
        assert_eq!(
            (SimTime::from_millis(3.0) + SimTime::from_millis(4.0)).as_millis(),
            7.0
        );
        assert_eq!(
            SimTime::from_millis(1.0).saturating_sub(SimTime::from_millis(5.0)),
            SimTime::ZERO
        );
    }

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn rng_uniform_bounds() {
        let mut r = Rng::seeded(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.range(5.0, 10.0);
            assert!((5.0..10.0).contains(&y));
            assert!(r.below(3) < 3);
        }
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng::seeded(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal(10.0, 2.0)).collect();
        assert!((mean(&xs) - 10.0).abs() < 0.1);
        assert!((std_dev(&xs) - 2.0).abs() < 0.1);
    }

    #[test]
    fn rng_exponential_mean() {
        let mut r = Rng::seeded(13);
        let xs: Vec<f64> = (0..20_000).map(|_| r.exponential(5.0)).collect();
        assert!((mean(&xs) - 5.0).abs() < 0.2);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seeded(3);
        let s = r.sample_indices(10, 4);
        assert_eq!(s.len(), 4);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 4);
        assert_eq!(r.sample_indices(3, 10).len(), 3);
    }

    #[test]
    fn stats_helpers() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.118_033_988).abs() < 1e-6);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
