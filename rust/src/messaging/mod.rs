//! Control-plane transports (paper §6 "Orchestration"): an MQTT-style
//! topic broker for *intra*-cluster traffic (lightweight pub/sub between
//! workers and their cluster orchestrator) and a WebSocket-style duplex
//! link with liveness monitoring for *inter*-cluster traffic (cluster ↔
//! root). Byte overheads differ deliberately — that asymmetry is part of
//! the paper's design argument and shows up in Figs. 5/7a.

mod broker;
mod wslink;

pub use broker::{MqttBroker, Topic};
pub use wslink::{LinkHealth, Outbox, OutboxEntry, WsLink};

/// Fixed per-message framing overhead in bytes.
///
/// MQTT's minimal header is 2 bytes + topic; WebSocket frames carry a
/// few bytes but each HTTP(S)-upgraded connection and its TLS record
/// layer amortize to tens of bytes per message in practice.
pub const MQTT_FRAME_OVERHEAD: usize = 2 + 16;
pub const WS_FRAME_OVERHEAD: usize = 6 + 48;

/// Canonical accounting labels for control-plane message directions,
/// used consistently so Fig. 7a can split traffic by link.
pub mod labels {
    pub const WORKER_TO_CLUSTER: &str = "oak.worker->cluster";
    pub const CLUSTER_TO_WORKER: &str = "oak.cluster->worker";
    pub const CLUSTER_TO_ROOT: &str = "oak.cluster->root";
    pub const ROOT_TO_CLUSTER: &str = "oak.root->cluster";
    pub const KUBE_NODE_TO_MASTER: &str = "kube.node->master";
    pub const KUBE_MASTER_TO_NODE: &str = "kube.master->node";
    pub const DATA_PLANE: &str = "data";
}
