//! In-process MQTT-style broker: hierarchical topics with `+`/`#`
//! wildcards, QoS-0 fan-out. The cluster orchestrator embeds one; workers
//! publish telemetry to `cluster/<id>/worker/<n>/report` and subscribe to
//! their command topics — mirroring Oakestra's real MQTT usage.

use std::collections::HashMap;

use crate::sim::ActorId;

/// A parsed MQTT topic (or subscription filter).
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct Topic(Vec<String>);

impl Topic {
    pub fn parse(s: &str) -> Topic {
        Topic(s.split('/').map(str::to_string).collect())
    }

    /// MQTT matching: `+` matches one level, `#` matches the rest.
    pub fn matches(filter: &Topic, topic: &Topic) -> bool {
        let f = &filter.0;
        let t = &t_ref(topic).0;
        let mut i = 0;
        while i < f.len() {
            if f[i] == "#" {
                return true;
            }
            if i >= t.len() {
                return false;
            }
            if f[i] != "+" && f[i] != t[i] {
                return false;
            }
            i += 1;
        }
        i == t.len()
    }

    pub fn as_string(&self) -> String {
        self.0.join("/")
    }

    /// Wire length of the topic name (feeds framing overhead accounting).
    pub fn wire_len(&self) -> usize {
        self.as_string().len()
    }
}

fn t_ref(t: &Topic) -> &Topic {
    t
}

/// QoS-0 broker: subscriptions are (filter → subscriber actor) pairs.
#[derive(Clone, Debug, Default)]
pub struct MqttBroker {
    subs: Vec<(Topic, ActorId)>,
    /// Retained per-topic statistics (messages, bytes).
    stats: HashMap<String, (u64, u64)>,
}

impl MqttBroker {
    pub fn subscribe(&mut self, filter: &str, subscriber: ActorId) {
        self.subs.push((Topic::parse(filter), subscriber));
    }

    pub fn unsubscribe_actor(&mut self, subscriber: ActorId) {
        self.subs.retain(|(_, a)| *a != subscriber);
    }

    /// Resolve a publish to its subscriber set (delivery is the caller's
    /// job — in the simulator the orchestrator actor forwards through
    /// `Ctx::send`; dedups so one actor gets one copy).
    pub fn route(&mut self, topic: &str, payload_bytes: usize) -> Vec<ActorId> {
        let t = Topic::parse(topic);
        let e = self.stats.entry(t.as_string()).or_insert((0, 0));
        e.0 += 1;
        e.1 += payload_bytes as u64;
        let mut out: Vec<ActorId> = self
            .subs
            .iter()
            .filter(|(f, _)| Topic::matches(f, &t))
            .map(|(_, a)| *a)
            .collect();
        out.sort();
        out.dedup();
        out
    }

    pub fn topic_stats(&self, topic: &str) -> (u64, u64) {
        self.stats.get(topic).copied().unwrap_or((0, 0))
    }

    pub fn subscription_count(&self) -> usize {
        self.subs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_and_wildcard_matching() {
        let m = |f: &str, t: &str| Topic::matches(&Topic::parse(f), &Topic::parse(t));
        assert!(m("a/b/c", "a/b/c"));
        assert!(!m("a/b/c", "a/b"));
        assert!(!m("a/b", "a/b/c"));
        assert!(m("a/+/c", "a/b/c"));
        assert!(!m("a/+/c", "a/b/d"));
        assert!(m("a/#", "a/b/c/d"));
        assert!(m("#", "anything/at/all"));
        assert!(m("a/+/+", "a/b/c"));
        assert!(!m("+", "a/b"));
    }

    #[test]
    fn routing_fans_out_and_dedups() {
        let mut b = MqttBroker::default();
        b.subscribe("cluster/1/worker/+/report", ActorId(1));
        b.subscribe("cluster/1/#", ActorId(1)); // overlapping sub, same actor
        b.subscribe("cluster/1/worker/7/report", ActorId(2));
        b.subscribe("cluster/2/#", ActorId(3));
        let got = b.route("cluster/1/worker/7/report", 180);
        assert_eq!(got, vec![ActorId(1), ActorId(2)]);
        assert_eq!(b.topic_stats("cluster/1/worker/7/report"), (1, 180));
    }

    #[test]
    fn unsubscribe_removes_all_filters() {
        let mut b = MqttBroker::default();
        b.subscribe("a/#", ActorId(1));
        b.subscribe("b/#", ActorId(1));
        b.unsubscribe_actor(ActorId(1));
        assert!(b.route("a/x", 1).is_empty());
        assert_eq!(b.subscription_count(), 0);
    }
}
