//! WebSocket-style duplex link state machine with liveness pings (paper
//! §6: HTTP(S) WebSockets between cluster and root "implicitly allows us
//! to monitor the liveness of both orchestrator endpoints and trigger
//! remedial actions in case of failures").
//!
//! The link is a **lease**: `Healthy → Suspect → Partitioned`, driven by
//! pong silence. Both federation endpoints hold one — the root per
//! cluster link, the cluster for its uplink — and the coordinator tiers
//! key degraded-mode autonomy and the anti-entropy resync off the
//! `Partitioned` edge. A bounded-retry [`Outbox`] buffers critical
//! messages while the lease is unhealthy so a heal replays them instead
//! of losing them silently; receiver-side idempotency (adoption lineage,
//! pending-delegation maps) makes the replays safe to double-deliver.

use std::collections::VecDeque;

use crate::util::SimTime;

/// Liveness verdict for one direction of a root↔cluster link.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum LinkHealth {
    Healthy,
    /// No pong for > `suspect_after` — degrade gracefully.
    Suspect,
    /// No pong for > `partitioned_after` — the lease is lost: the peer
    /// is unreachable (crashed or partitioned; the difference is
    /// invisible from here) and remedial action is warranted.
    Partitioned,
}

/// One endpoint's view of the link.
#[derive(Clone, Debug)]
pub struct WsLink {
    pub ping_interval: SimTime,
    pub suspect_after: SimTime,
    pub partitioned_after: SimTime,
    last_pong: SimTime,
    pub pings_sent: u64,
    pub pongs_received: u64,
}

impl WsLink {
    pub fn new(now: SimTime) -> Self {
        WsLink {
            ping_interval: SimTime::from_secs(5.0),
            suspect_after: SimTime::from_secs(12.0),
            partitioned_after: SimTime::from_secs(30.0),
            last_pong: now,
            pings_sent: 0,
            pongs_received: 0,
        }
    }

    pub fn on_ping_sent(&mut self) {
        self.pings_sent += 1;
    }

    pub fn on_pong(&mut self, now: SimTime) {
        self.pongs_received += 1;
        self.last_pong = now;
    }

    /// Any inbound application message also proves liveness.
    pub fn on_activity(&mut self, now: SimTime) {
        self.last_pong = now;
    }

    pub fn health(&self, now: SimTime) -> LinkHealth {
        let silence = now.saturating_sub(self.last_pong);
        if silence >= self.partitioned_after {
            LinkHealth::Partitioned
        } else if silence >= self.suspect_after {
            LinkHealth::Suspect
        } else {
            LinkHealth::Healthy
        }
    }
}

/// One buffered critical message awaiting delivery confirmation (or
/// supersession, or retry exhaustion).
#[derive(Clone, Debug)]
pub struct OutboxEntry<M> {
    pub seq: u64,
    pub msg: M,
    /// Resends burned so far (0 = only the original send went out).
    pub retries: u32,
    /// Don't resend before this instant.
    pub next_retry: SimTime,
}

/// Bounded-retry send buffer for critical messages over an unhealthy
/// lease. Generic over the message type so the messaging tier stays
/// decoupled from the protocol enum; the cluster orchestrator
/// instantiates it with `OakMsg`.
///
/// Replay is **at-least-once**: entries stay buffered until explicitly
/// acked ([`Outbox::ack`]), superseded (caller removes stale seqs), or
/// `max_retries` resends are exhausted — after which the entry is
/// dropped and counted, and the anti-entropy resync is the recovery
/// path of last resort. Receivers must be idempotent.
#[derive(Clone, Debug)]
pub struct Outbox<M> {
    next_seq: u64,
    pub max_retries: u32,
    /// Base pacing between resends of one entry (doubles per retry).
    pub retry_backoff: SimTime,
    entries: VecDeque<OutboxEntry<M>>,
    /// Entries that exhausted their retry budget and were dropped.
    pub dropped: u64,
}

impl<M: Clone> Outbox<M> {
    pub fn new(max_retries: u32, retry_backoff: SimTime) -> Self {
        Outbox {
            next_seq: 0,
            max_retries,
            retry_backoff,
            entries: VecDeque::new(),
            dropped: 0,
        }
    }

    /// Buffer a message (already sent once by the caller); returns its
    /// seq for later [`Outbox::ack`]/supersession.
    pub fn enqueue(&mut self, msg: M, now: SimTime) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.entries.push_back(OutboxEntry {
            seq,
            msg,
            retries: 0,
            next_retry: now + self.retry_backoff,
        });
        seq
    }

    /// Entries due for a resend at `now`: each returned entry has its
    /// retry budget decremented and its next attempt pushed out on an
    /// exponential backoff. Entries whose budget is exhausted are
    /// dropped (counted in `dropped`) instead of returned.
    pub fn due(&mut self, now: SimTime) -> Vec<(u64, M)> {
        let mut out = Vec::new();
        let mut kept = VecDeque::with_capacity(self.entries.len());
        while let Some(mut e) = self.entries.pop_front() {
            if e.next_retry > now {
                kept.push_back(e);
                continue;
            }
            if e.retries >= self.max_retries {
                self.dropped += 1;
                continue;
            }
            e.retries += 1;
            let backoff = SimTime(self.retry_backoff.0 << e.retries.min(10));
            e.next_retry = now + backoff;
            out.push((e.seq, e.msg.clone()));
            kept.push_back(e);
        }
        self.entries = kept;
        out
    }

    /// Confirm delivery of `seq` (peer ack, or the caller observed the
    /// effect). Returns whether the entry was still buffered.
    pub fn ack(&mut self, seq: u64) -> bool {
        let before = self.entries.len();
        self.entries.retain(|e| e.seq != seq);
        self.entries.len() != before
    }

    /// Drop every buffered entry matching the predicate (supersession:
    /// e.g. a fresher `ClusterReport` makes older ones meaningless).
    pub fn retain(&mut self, keep: impl FnMut(&OutboxEntry<M>) -> bool) {
        self.entries.retain(keep);
    }

    /// Everything still buffered, for an on-heal replay. Entries stay
    /// buffered (the replay itself may be lost); each burns one retry.
    pub fn replay_all(&mut self, now: SimTime) -> Vec<(u64, M)> {
        for e in &mut self.entries {
            e.next_retry = now; // due immediately
        }
        self.due(now)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_degrades_with_silence() {
        let mut l = WsLink::new(SimTime::ZERO);
        assert_eq!(l.health(SimTime::from_secs(1.0)), LinkHealth::Healthy);
        assert_eq!(l.health(SimTime::from_secs(15.0)), LinkHealth::Suspect);
        assert_eq!(l.health(SimTime::from_secs(31.0)), LinkHealth::Partitioned);
        l.on_pong(SimTime::from_secs(31.0));
        assert_eq!(l.health(SimTime::from_secs(32.0)), LinkHealth::Healthy);
    }

    #[test]
    fn silence_past_suspect_then_pong_recovers() {
        let mut l = WsLink::new(SimTime::ZERO);
        // Exactly at the suspect threshold the lease degrades…
        assert_eq!(l.health(l.suspect_after), LinkHealth::Suspect);
        // …one pong restores it instantly (no hysteresis on recovery:
        // the wire demonstrably works).
        l.on_pong(SimTime::from_secs(13.0));
        assert_eq!(l.health(SimTime::from_secs(14.0)), LinkHealth::Healthy);
        assert_eq!(l.pongs_received, 1);
    }

    #[test]
    fn partitioned_edge_is_reached_through_suspect() {
        let l = WsLink::new(SimTime::ZERO);
        let mut edges = Vec::new();
        let mut last = l.health(SimTime::ZERO);
        for s in 0..40 {
            let h = l.health(SimTime::from_secs(s as f64));
            if h != last {
                edges.push(h);
                last = h;
            }
        }
        assert_eq!(edges, vec![LinkHealth::Suspect, LinkHealth::Partitioned]);
    }

    #[test]
    fn activity_counts_as_liveness() {
        let mut l = WsLink::new(SimTime::ZERO);
        l.on_activity(SimTime::from_secs(29.0));
        assert_eq!(l.health(SimTime::from_secs(35.0)), LinkHealth::Healthy);
    }

    /// A delta-coalesced aggregate quiet period (no `ClusterReport` for
    /// far longer than `partitioned_after`) must never trip the lease:
    /// liveness rides the ping/pong exchange, which keeps flowing while
    /// reports are suppressed.
    #[test]
    fn coalesced_report_quiet_period_never_trips_lease() {
        let mut l = WsLink::new(SimTime::ZERO);
        // 120 virtual seconds of report silence, but pongs arrive on
        // every 5s ping tick.
        for tick in 1..=24u64 {
            let now = SimTime::from_secs(5.0 * tick as f64);
            l.on_ping_sent();
            assert_eq!(
                l.health(now),
                LinkHealth::Healthy,
                "lease must not degrade at t={now} on pong cadence alone"
            );
            l.on_pong(now);
        }
        assert_eq!(l.pongs_received, 24);
    }

    #[test]
    fn counters_track() {
        let mut l = WsLink::new(SimTime::ZERO);
        l.on_ping_sent();
        l.on_ping_sent();
        l.on_pong(SimTime::from_secs(1.0));
        assert_eq!(l.pings_sent, 2);
        assert_eq!(l.pongs_received, 1);
    }

    #[test]
    fn outbox_retries_then_drops_after_budget() {
        let mut ob: Outbox<&'static str> =
            Outbox::new(2, SimTime::from_secs(1.0));
        let seq = ob.enqueue("report", SimTime::ZERO);
        assert_eq!(ob.len(), 1);
        // Not due before the backoff elapses.
        assert!(ob.due(SimTime::from_secs(0.5)).is_empty());
        // First retry at +1s; second pushed out on doubled backoff.
        let due = ob.due(SimTime::from_secs(1.0));
        assert_eq!(due, vec![(seq, "report")]);
        assert!(ob.due(SimTime::from_secs(2.0)).is_empty(), "2^1 backoff");
        let due = ob.due(SimTime::from_secs(3.0));
        assert_eq!(due.len(), 1);
        // Budget (2) exhausted: the next due scan drops it.
        assert!(ob.due(SimTime::from_secs(60.0)).is_empty());
        assert_eq!(ob.dropped, 1);
        assert!(ob.is_empty());
    }

    #[test]
    fn outbox_ack_and_supersession_remove_entries() {
        let mut ob: Outbox<u32> = Outbox::new(5, SimTime::from_secs(1.0));
        let a = ob.enqueue(1, SimTime::ZERO);
        let _b = ob.enqueue(2, SimTime::ZERO);
        let c = ob.enqueue(3, SimTime::ZERO);
        assert!(ob.ack(a));
        assert!(!ob.ack(a), "double-ack is a no-op");
        // Supersede everything but seq c.
        ob.retain(|e| e.seq == c);
        assert_eq!(ob.len(), 1);
        let due = ob.replay_all(SimTime::from_secs(10.0));
        assert_eq!(due, vec![(c, 3)]);
        assert_eq!(ob.dropped, 0);
    }

    #[test]
    fn outbox_replay_burns_retries_and_is_idempotent_on_ack() {
        let mut ob: Outbox<&'static str> =
            Outbox::new(1, SimTime::from_secs(1.0));
        let seq = ob.enqueue("delegation", SimTime::ZERO);
        // Heal replay: entry goes out once more…
        assert_eq!(ob.replay_all(SimTime::from_secs(5.0)).len(), 1);
        // …and the peer's ack clears it before the budget drops it.
        assert!(ob.ack(seq));
        assert!(ob.is_empty());
        assert_eq!(ob.dropped, 0);
    }

    /// Acks for seqs the outbox never issued — or issued and already
    /// resolved — must be pure no-ops: `false` back, nothing disturbed.
    /// A crash-restarted peer can ack seqs from the dead incarnation's
    /// outbox, which this incarnation has never minted.
    #[test]
    fn outbox_unknown_and_stale_seq_acks_are_idempotent_noops() {
        let mut ob: Outbox<&'static str> = Outbox::new(3, SimTime::from_secs(1.0));
        let a = ob.enqueue("a", SimTime::ZERO);
        let b = ob.enqueue("b", SimTime::ZERO);
        // Unknown seq: never minted by this outbox.
        assert!(!ob.ack(9_999));
        assert_eq!(ob.len(), 2, "unknown ack must not disturb live entries");
        // Stale seq: minted, resolved, acked again.
        assert!(ob.ack(a));
        assert!(!ob.ack(a), "second ack of a resolved seq is a no-op");
        assert!(!ob.ack(9_999));
        assert_eq!(ob.len(), 1);
        // The survivor is untouched — same seq, same payload, full
        // retry budget still available.
        let due = ob.replay_all(SimTime::from_secs(10.0));
        assert_eq!(due, vec![(b, "b")]);
        assert_eq!(ob.dropped, 0);
    }

    /// Once an entry exhausts its retry budget and is dropped, no later
    /// heal replay may resurrect it: the drop is final and the
    /// anti-entropy resync is the only remaining recovery path.
    #[test]
    fn outbox_replay_after_retry_cap_drop_does_not_resurrect() {
        let mut ob: Outbox<&'static str> = Outbox::new(1, SimTime::from_secs(1.0));
        ob.enqueue("doomed", SimTime::ZERO);
        // Burn the single retry, then let the next scan drop it.
        assert_eq!(ob.due(SimTime::from_secs(1.0)).len(), 1);
        assert!(ob.due(SimTime::from_secs(60.0)).is_empty());
        assert_eq!(ob.dropped, 1);
        assert!(ob.is_empty());
        // A heal replay long after must find nothing — and must not
        // double-count the drop either.
        assert!(ob.replay_all(SimTime::from_secs(120.0)).is_empty());
        assert_eq!(ob.dropped, 1);
        // New traffic keeps minting fresh, monotonically later seqs.
        let fresh = ob.enqueue("fresh", SimTime::from_secs(121.0));
        assert_eq!(fresh, 1, "seqs continue past dropped entries");
    }

    /// Replay order is enqueue order (seq order), no matter how acks
    /// and fresh enqueues interleave: receivers rely on replayed
    /// critical messages arriving in their original causal order.
    #[test]
    fn outbox_replay_ordering_is_stable_across_interleaved_enqueues() {
        let mut ob: Outbox<&'static str> = Outbox::new(5, SimTime::from_secs(1.0));
        let a = ob.enqueue("a", SimTime::ZERO);
        let b = ob.enqueue("b", SimTime::from_secs(1.0));
        assert!(ob.ack(a));
        let c = ob.enqueue("c", SimTime::from_secs(2.0));
        let d = ob.enqueue("d", SimTime::from_secs(3.0));
        assert!(ob.ack(c));
        let e = ob.enqueue("e", SimTime::from_secs(4.0));
        // Survivors replay as b, d, e — original enqueue order, with the
        // acked entries excised but never reordering their neighbours.
        let due = ob.replay_all(SimTime::from_secs(30.0));
        assert_eq!(due, vec![(b, "b"), (d, "d"), (e, "e")]);
        // A second replay keeps the same order (backoff pushed each
        // entry out uniformly — relative order is preserved).
        let due = ob.replay_all(SimTime::from_secs(60.0));
        assert_eq!(due, vec![(b, "b"), (d, "d"), (e, "e")]);
    }
}
