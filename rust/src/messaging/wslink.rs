//! WebSocket-style duplex link state machine with liveness pings (paper
//! §6: HTTP(S) WebSockets between cluster and root "implicitly allows us
//! to monitor the liveness of both orchestrator endpoints and trigger
//! remedial actions in case of failures").

use crate::util::SimTime;

/// Liveness verdict for one direction of a root↔cluster link.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum LinkHealth {
    Healthy,
    /// No pong for > `suspect_after` — degrade gracefully.
    Suspect,
    /// No pong for > `dead_after` — peer considered failed.
    Dead,
}

/// One endpoint's view of the link.
#[derive(Clone, Debug)]
pub struct WsLink {
    pub ping_interval: SimTime,
    pub suspect_after: SimTime,
    pub dead_after: SimTime,
    last_pong: SimTime,
    pub pings_sent: u64,
    pub pongs_received: u64,
}

impl WsLink {
    pub fn new(now: SimTime) -> Self {
        WsLink {
            ping_interval: SimTime::from_secs(5.0),
            suspect_after: SimTime::from_secs(12.0),
            dead_after: SimTime::from_secs(30.0),
            last_pong: now,
            pings_sent: 0,
            pongs_received: 0,
        }
    }

    pub fn on_ping_sent(&mut self) {
        self.pings_sent += 1;
    }

    pub fn on_pong(&mut self, now: SimTime) {
        self.pongs_received += 1;
        self.last_pong = now;
    }

    /// Any inbound application message also proves liveness.
    pub fn on_activity(&mut self, now: SimTime) {
        self.last_pong = now;
    }

    pub fn health(&self, now: SimTime) -> LinkHealth {
        let silence = now.saturating_sub(self.last_pong);
        if silence >= self.dead_after {
            LinkHealth::Dead
        } else if silence >= self.suspect_after {
            LinkHealth::Suspect
        } else {
            LinkHealth::Healthy
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_degrades_with_silence() {
        let mut l = WsLink::new(SimTime::ZERO);
        assert_eq!(l.health(SimTime::from_secs(1.0)), LinkHealth::Healthy);
        assert_eq!(l.health(SimTime::from_secs(15.0)), LinkHealth::Suspect);
        assert_eq!(l.health(SimTime::from_secs(31.0)), LinkHealth::Dead);
        l.on_pong(SimTime::from_secs(31.0));
        assert_eq!(l.health(SimTime::from_secs(32.0)), LinkHealth::Healthy);
    }

    #[test]
    fn activity_counts_as_liveness() {
        let mut l = WsLink::new(SimTime::ZERO);
        l.on_activity(SimTime::from_secs(29.0));
        assert_eq!(l.health(SimTime::from_secs(35.0)), LinkHealth::Healthy);
    }

    #[test]
    fn counters_track() {
        let mut l = WsLink::new(SimTime::ZERO);
        l.on_ping_sent();
        l.on_ping_sent();
        l.on_pong(SimTime::from_secs(1.0));
        assert_eq!(l.pings_sent, 2);
        assert_eq!(l.pongs_received, 1);
    }
}
