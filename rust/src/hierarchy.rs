//! The federated cluster hierarchy *I = ⟨C, E⟩* (paper §4.1): an oriented
//! tree of clusters rooted at the root orchestrator `C₀ = {RO}`, plus the
//! aggregate statistics `∪(Aⁱ) = ⟨Σ(Aⁱ), μ(Aⁱ), σ(Aⁱ)⟩` each cluster
//! pushes to its parent — the only resource information that crosses
//! cluster boundaries (administrative-control preservation).

use std::collections::BTreeMap;

use crate::geo::Area;
use crate::model::{Capacity, Virtualization};
use crate::util::ClusterId;

/// Root pseudo-cluster id (`C₀`).
pub const ROOT: ClusterId = ClusterId(0);

/// Aggregated capacity distribution a cluster advertises upward:
/// `⟨Σ, μ, σ⟩` over available worker (+ sub-cluster) capacities, per
/// resource dimension, plus coarse metadata the root scheduler filters on.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AggregateStats {
    pub total: Capacity,
    pub mean_cpu_millicores: f64,
    pub mean_mem_mb: f64,
    pub std_cpu_millicores: f64,
    pub std_mem_mb: f64,
    /// Largest single-worker available capacity — the root must not pick a
    /// cluster whose *sum* fits but where no single worker does.
    pub max_worker: Capacity,
    pub worker_count: usize,
    /// Union of virtualization technologies available in the cluster.
    pub virtualization: Virtualization,
    /// Approximate operation zone (for SLA `area`/`location` filters).
    pub area: Option<Area>,
}

impl AggregateStats {
    /// Aggregate a set of per-worker available capacities (§4.1:
    /// `Aⁱ = {A₁ⁱ…Aₙⁱ} ∪ {Aʲ | (Cᵢ,Cⱼ) ∈ E}`; sub-cluster aggregates are
    /// folded in by treating their max-worker/total like member entries).
    pub fn from_workers<'a>(
        workers: impl Iterator<Item = (&'a Capacity, Virtualization)>,
        area: Option<Area>,
    ) -> AggregateStats {
        let mut agg = AggregateStats {
            area,
            ..AggregateStats::default()
        };
        let mut cpus = Vec::new();
        let mut mems = Vec::new();
        for (cap, virt) in workers {
            agg.total += *cap;
            cpus.push(cap.cpu_millicores as f64);
            mems.push(cap.mem_mb as f64);
            if cap.cpu_millicores >= agg.max_worker.cpu_millicores {
                // Track the componentwise max to stay conservative.
                agg.max_worker.cpu_millicores =
                    agg.max_worker.cpu_millicores.max(cap.cpu_millicores);
            }
            agg.max_worker.mem_mb = agg.max_worker.mem_mb.max(cap.mem_mb);
            agg.max_worker.disk_mb = agg.max_worker.disk_mb.max(cap.disk_mb);
            agg.max_worker.gpus = agg.max_worker.gpus.max(cap.gpus);
            agg.max_worker.tpus = agg.max_worker.tpus.max(cap.tpus);
            agg.virtualization = agg.virtualization.union(virt);
            agg.worker_count += 1;
        }
        agg.mean_cpu_millicores = crate::util::mean(&cpus);
        agg.mean_mem_mb = crate::util::mean(&mems);
        agg.std_cpu_millicores = crate::util::std_dev(&cpus);
        agg.std_mem_mb = crate::util::std_dev(&mems);
        agg
    }

    /// Has this aggregate moved enough since `last` to be worth a report?
    /// The delta-coalescing predicate of cluster→root pushes: any change
    /// to a feasibility-relevant field (worker count, best single worker,
    /// virtualization union, area) forces a send — the root's pre-filters
    /// key on those — while mean/total drifts only count once they exceed
    /// `frac` relatively. σ drifts alone never force a send: they only
    /// shade the ranking score, which the threshold semantics accept as
    /// approximate between reports.
    pub fn delta_exceeds(&self, last: &AggregateStats, frac: f64) -> bool {
        fn rel(a: f64, b: f64) -> f64 {
            (a - b).abs() / b.abs().max(1.0)
        }
        self.worker_count != last.worker_count
            || self.max_worker != last.max_worker
            || self.virtualization != last.virtualization
            || self.area != last.area
            || rel(self.mean_cpu_millicores, last.mean_cpu_millicores) > frac
            || rel(self.mean_mem_mb, last.mean_mem_mb) > frac
            || rel(
                self.total.cpu_millicores as f64,
                last.total.cpu_millicores as f64,
            ) > frac
            || rel(self.total.mem_mb as f64, last.total.mem_mb as f64) > frac
    }

    /// Merge a sub-cluster's aggregate into this one (multi-tier roll-up).
    pub fn absorb(&mut self, child: &AggregateStats) {
        let n1 = self.worker_count as f64;
        let n2 = child.worker_count as f64;
        if n2 == 0.0 {
            return;
        }
        let merge_mean_std = |m1: f64, s1: f64, m2: f64, s2: f64| {
            let n = n1 + n2;
            let m = (n1 * m1 + n2 * m2) / n;
            // Pooled variance with mean shift.
            let v = (n1 * (s1 * s1 + (m1 - m) * (m1 - m))
                + n2 * (s2 * s2 + (m2 - m) * (m2 - m)))
                / n;
            (m, v.sqrt())
        };
        let (mc, sc) = merge_mean_std(
            self.mean_cpu_millicores,
            self.std_cpu_millicores,
            child.mean_cpu_millicores,
            child.std_cpu_millicores,
        );
        let (mm, sm) = merge_mean_std(
            self.mean_mem_mb,
            self.std_mem_mb,
            child.mean_mem_mb,
            child.std_mem_mb,
        );
        self.mean_cpu_millicores = mc;
        self.std_cpu_millicores = sc;
        self.mean_mem_mb = mm;
        self.std_mem_mb = sm;
        self.total += child.total;
        self.max_worker.cpu_millicores = self
            .max_worker
            .cpu_millicores
            .max(child.max_worker.cpu_millicores);
        self.max_worker.mem_mb = self.max_worker.mem_mb.max(child.max_worker.mem_mb);
        self.max_worker.disk_mb = self.max_worker.disk_mb.max(child.max_worker.disk_mb);
        self.max_worker.gpus = self.max_worker.gpus.max(child.max_worker.gpus);
        self.max_worker.tpus = self.max_worker.tpus.max(child.max_worker.tpus);
        self.virtualization = self.virtualization.union(child.virtualization);
        self.worker_count += child.worker_count;
    }
}

/// The oriented cluster tree. Parent links define the inter-cluster
/// control edges `E`; every non-root cluster has exactly one parent and
/// the structure is cycle-free by construction. **Topology only**: the
/// per-cluster aggregates live in the root's indexed
/// [`crate::coordinator::ClusterTable`] (`RootOrchestrator::fed`), which
/// maintains the scheduling pre-filters on ingest — storing them here
/// too would be a silent-staleness trap.
#[derive(Clone, Debug, Default)]
pub struct ClusterTree {
    parent: BTreeMap<ClusterId, ClusterId>,
    children: BTreeMap<ClusterId, Vec<ClusterId>>,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TreeError {
    AlreadyRegistered(ClusterId),
    UnknownParent(ClusterId),
    UnknownCluster(ClusterId),
    RootImmutable,
}

impl ClusterTree {
    pub fn new() -> Self {
        let mut t = ClusterTree::default();
        t.children.insert(ROOT, Vec::new());
        t
    }

    /// Register a cluster under `parent` (paper: operators register via
    /// the root API; sub-clusters attach to their parent orchestrator).
    pub fn attach(&mut self, id: ClusterId, parent: ClusterId) -> Result<(), TreeError> {
        if id == ROOT {
            return Err(TreeError::RootImmutable);
        }
        if self.parent.contains_key(&id) {
            return Err(TreeError::AlreadyRegistered(id));
        }
        if parent != ROOT && !self.parent.contains_key(&parent) {
            return Err(TreeError::UnknownParent(parent));
        }
        self.parent.insert(id, parent);
        self.children.entry(parent).or_default().push(id);
        self.children.entry(id).or_default();
        Ok(())
    }

    /// Remove a leaf cluster (operators may scale down freely, §4.1).
    pub fn detach(&mut self, id: ClusterId) -> Result<(), TreeError> {
        if id == ROOT {
            return Err(TreeError::RootImmutable);
        }
        let parent = *self
            .parent
            .get(&id)
            .ok_or(TreeError::UnknownCluster(id))?;
        if !self.children.get(&id).map(Vec::is_empty).unwrap_or(true) {
            // Only leaves detach; callers must detach children first.
            return Err(TreeError::UnknownCluster(id));
        }
        self.parent.remove(&id);
        self.children.remove(&id);
        if let Some(sibs) = self.children.get_mut(&parent) {
            sibs.retain(|c| *c != id);
        }
        Ok(())
    }

    pub fn parent_of(&self, id: ClusterId) -> Option<ClusterId> {
        self.parent.get(&id).copied()
    }

    pub fn children_of(&self, id: ClusterId) -> &[ClusterId] {
        self.children.get(&id).map(Vec::as_slice).unwrap_or(&[])
    }

    pub fn contains(&self, id: ClusterId) -> bool {
        id == ROOT || self.parent.contains_key(&id)
    }

    pub fn clusters(&self) -> impl Iterator<Item = ClusterId> + '_ {
        self.parent.keys().copied()
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }
    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Depth of a cluster (root children = 1). The paper's `t`-tier
    /// scheduling descends `depth` steps.
    pub fn depth(&self, id: ClusterId) -> usize {
        let mut d = 0;
        let mut cur = id;
        while let Some(p) = self.parent.get(&cur) {
            d += 1;
            cur = *p;
        }
        d
    }

    /// Invariant check used by the proptest suite: parent/children maps
    /// mirror each other and the structure is acyclic.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (c, p) in &self.parent {
            if !self
                .children
                .get(p)
                .map(|v| v.contains(c))
                .unwrap_or(false)
            {
                return Err(format!("{c} missing from children of {p}"));
            }
            // Acyclicity: walking up must terminate at ROOT.
            let mut seen = 0;
            let mut cur = *c;
            while let Some(next) = self.parent.get(&cur) {
                cur = *next;
                seen += 1;
                if seen > self.parent.len() + 1 {
                    return Err(format!("cycle through {c}"));
                }
            }
            if cur != ROOT {
                return Err(format!("{c} does not reach root"));
            }
        }
        for (p, kids) in &self.children {
            for k in kids {
                if self.parent.get(k) != Some(p) {
                    return Err(format!("child {k} of {p} lacks back-edge"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cap(cpu: u32, mem: u32) -> Capacity {
        Capacity::new(cpu, mem, 0)
    }

    #[test]
    fn attach_detach_roundtrip() {
        let mut t = ClusterTree::new();
        t.attach(ClusterId(1), ROOT).unwrap();
        t.attach(ClusterId(2), ROOT).unwrap();
        t.attach(ClusterId(3), ClusterId(2)).unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t.depth(ClusterId(3)), 2);
        assert_eq!(t.parent_of(ClusterId(3)), Some(ClusterId(2)));
        t.check_invariants().unwrap();

        // Can't detach a non-leaf.
        assert!(t.detach(ClusterId(2)).is_err());
        t.detach(ClusterId(3)).unwrap();
        t.detach(ClusterId(2)).unwrap();
        assert_eq!(t.len(), 1);
        t.check_invariants().unwrap();
    }

    #[test]
    fn rejects_bad_edges() {
        let mut t = ClusterTree::new();
        t.attach(ClusterId(1), ROOT).unwrap();
        assert_eq!(
            t.attach(ClusterId(1), ROOT),
            Err(TreeError::AlreadyRegistered(ClusterId(1)))
        );
        assert_eq!(
            t.attach(ClusterId(5), ClusterId(9)),
            Err(TreeError::UnknownParent(ClusterId(9)))
        );
        assert_eq!(t.attach(ROOT, ClusterId(1)), Err(TreeError::RootImmutable));
    }

    #[test]
    fn aggregate_from_workers() {
        let caps = [cap(1000, 1024), cap(3000, 2048), cap(2000, 4096)];
        let agg = AggregateStats::from_workers(
            caps.iter().map(|c| (c, Virtualization::CONTAINER)),
            None,
        );
        assert_eq!(agg.worker_count, 3);
        assert_eq!(agg.total.cpu_millicores, 6000);
        assert!((agg.mean_cpu_millicores - 2000.0).abs() < 1e-9);
        assert_eq!(agg.max_worker.cpu_millicores, 3000);
        assert_eq!(agg.max_worker.mem_mb, 4096);
        assert!((agg.std_cpu_millicores - 816.4965809).abs() < 1e-3);
    }

    #[test]
    fn absorb_matches_flat_aggregation() {
        let a = [cap(1000, 1000), cap(2000, 2000)];
        let b = [cap(3000, 3000), cap(4000, 4000), cap(5000, 5000)];
        let mut agg_a = AggregateStats::from_workers(
            a.iter().map(|c| (c, Virtualization::CONTAINER)),
            None,
        );
        let agg_b = AggregateStats::from_workers(
            b.iter().map(|c| (c, Virtualization::WASM)),
            None,
        );
        agg_a.absorb(&agg_b);

        let flat: Vec<Capacity> = a.iter().chain(b.iter()).copied().collect();
        let agg_flat = AggregateStats::from_workers(
            flat.iter().map(|c| (c, Virtualization::CONTAINER)),
            None,
        );
        assert_eq!(agg_a.worker_count, 5);
        assert_eq!(agg_a.total, agg_flat.total);
        assert!((agg_a.mean_cpu_millicores - agg_flat.mean_cpu_millicores).abs() < 1e-6);
        assert!((agg_a.std_cpu_millicores - agg_flat.std_cpu_millicores).abs() < 1e-6);
        assert!(agg_a.virtualization.supports(Virtualization::WASM));
    }

    #[test]
    fn delta_threshold_coalesces_small_moves() {
        let caps = [cap(1000, 1024), cap(3000, 2048)];
        let base = AggregateStats::from_workers(
            caps.iter().map(|c| (c, Virtualization::CONTAINER)),
            None,
        );
        // Identical aggregate: below any threshold.
        assert!(!base.delta_exceeds(&base, 0.05));
        // A small mean drift stays coalesced; a big one does not.
        let mut drift = base.clone();
        drift.mean_cpu_millicores *= 1.02;
        assert!(!drift.delta_exceeds(&base, 0.05));
        drift.mean_cpu_millicores = base.mean_cpu_millicores * 1.10;
        assert!(drift.delta_exceeds(&base, 0.05));
        // Feasibility-relevant fields always force a send.
        let mut fewer = base.clone();
        fewer.worker_count -= 1;
        assert!(fewer.delta_exceeds(&base, 0.5));
        let mut shrunk = base.clone();
        shrunk.max_worker.cpu_millicores -= 1;
        assert!(shrunk.delta_exceeds(&base, 0.5));
        let mut virt = base.clone();
        virt.virtualization = Virtualization::all();
        assert!(virt.delta_exceeds(&base, 0.5));
    }

}
