//! Push-based resource telemetry (paper §4.1): each worker pushes its
//! utilization `U_n` to the cluster orchestrator at frequency `λ(R_n)`,
//! which may differ per resource and adapt dynamically — the paper
//! sketches Δ-threshold suppression and age-of-information adaptation;
//! both are implemented here (and ablated in `benches/ablations.rs`).

use crate::model::Capacity;
use crate::util::SimTime;

/// Update-rate policy for one worker's telemetry stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UpdatePolicy {
    /// Fixed period λ.
    Periodic { interval: SimTime },
    /// Publish only when utilization moved more than `threshold` (fraction
    /// of total capacity) since the last published value, with a hard
    /// max-age bound so the orchestrator never sees stale-forever state.
    DeltaThreshold {
        interval: SimTime,
        threshold: f64,
        max_age: SimTime,
    },
    /// Age-of-information adaptation: busy workers (high churn) publish at
    /// `min_interval`; quiet ones back off exponentially to `max_interval`.
    AgeAdaptive {
        min_interval: SimTime,
        max_interval: SimTime,
    },
}

/// Per-worker telemetry governor: decides at each tick whether to publish.
#[derive(Clone, Debug)]
pub struct TelemetryGovernor {
    pub policy: UpdatePolicy,
    last_published: Option<(SimTime, Capacity)>,
    /// Current backoff (AgeAdaptive only).
    current_interval: SimTime,
    /// Published / suppressed counters (ablation metrics).
    pub published: u64,
    pub suppressed: u64,
}

impl TelemetryGovernor {
    pub fn new(policy: UpdatePolicy) -> Self {
        let current_interval = match policy {
            UpdatePolicy::Periodic { interval } => interval,
            UpdatePolicy::DeltaThreshold { interval, .. } => interval,
            UpdatePolicy::AgeAdaptive { min_interval, .. } => min_interval,
        };
        TelemetryGovernor {
            policy,
            last_published: None,
            current_interval,
            published: 0,
            suppressed: 0,
        }
    }

    /// The tick period the worker should schedule next.
    pub fn tick_interval(&self) -> SimTime {
        self.current_interval
    }

    /// Decide whether `used` (capacity in use, against `total`) should be
    /// published at `now`. Updates internal state accordingly.
    pub fn should_publish(&mut self, now: SimTime, used: Capacity, total: Capacity) -> bool {
        let decision = match self.policy {
            UpdatePolicy::Periodic { .. } => true,
            UpdatePolicy::DeltaThreshold {
                threshold, max_age, ..
            } => match self.last_published {
                None => true,
                Some((at, last)) => {
                    let age = now.saturating_sub(at);
                    let d_cpu = (used.cpu_millicores as f64
                        - last.cpu_millicores as f64)
                        .abs()
                        / total.cpu_millicores.max(1) as f64;
                    let d_mem = (used.mem_mb as f64 - last.mem_mb as f64).abs()
                        / total.mem_mb.max(1) as f64;
                    age >= max_age || d_cpu > threshold || d_mem > threshold
                }
            },
            UpdatePolicy::AgeAdaptive {
                min_interval,
                max_interval,
            } => {
                // Publish every tick, but stretch the tick when nothing
                // changes (snap back to fast cadence on movement).
                let changed = match self.last_published {
                    None => true,
                    Some((_, last)) => last != used,
                };
                self.current_interval = if changed {
                    min_interval
                } else {
                    SimTime::from_micros(
                        (self.current_interval.as_micros() * 2)
                            .min(max_interval.as_micros()),
                    )
                };
                true
            }
        };
        if decision {
            self.published += 1;
            self.last_published = Some((now, used));
        } else {
            self.suppressed += 1;
        }
        decision
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cap(cpu: u32) -> Capacity {
        Capacity::new(cpu, 1024, 0)
    }

    const TOTAL: Capacity = Capacity {
        cpu_millicores: 1000,
        mem_mb: 1024,
        disk_mb: 0,
        gpus: 0,
        tpus: 0,
    };

    #[test]
    fn periodic_always_publishes() {
        let mut g = TelemetryGovernor::new(UpdatePolicy::Periodic {
            interval: SimTime::from_secs(1.0),
        });
        for i in 0..5 {
            assert!(g.should_publish(SimTime::from_secs(i as f64), cap(100), TOTAL));
        }
        assert_eq!(g.published, 5);
        assert_eq!(g.suppressed, 0);
    }

    #[test]
    fn delta_threshold_suppresses_small_changes() {
        let mut g = TelemetryGovernor::new(UpdatePolicy::DeltaThreshold {
            interval: SimTime::from_secs(1.0),
            threshold: 0.10,
            max_age: SimTime::from_secs(30.0),
        });
        assert!(g.should_publish(SimTime::from_secs(0.0), cap(100), TOTAL)); // first
        assert!(!g.should_publish(SimTime::from_secs(1.0), cap(150), TOTAL)); // 5% move
        assert!(g.should_publish(SimTime::from_secs(2.0), cap(260), TOTAL)); // 16% move
        assert_eq!(g.published, 2);
        assert_eq!(g.suppressed, 1);
    }

    #[test]
    fn delta_threshold_max_age_forces_publish() {
        let mut g = TelemetryGovernor::new(UpdatePolicy::DeltaThreshold {
            interval: SimTime::from_secs(1.0),
            threshold: 0.5,
            max_age: SimTime::from_secs(10.0),
        });
        assert!(g.should_publish(SimTime::from_secs(0.0), cap(100), TOTAL));
        assert!(!g.should_publish(SimTime::from_secs(5.0), cap(100), TOTAL));
        assert!(g.should_publish(SimTime::from_secs(11.0), cap(100), TOTAL));
    }

    #[test]
    fn age_adaptive_backs_off_when_quiet() {
        let mut g = TelemetryGovernor::new(UpdatePolicy::AgeAdaptive {
            min_interval: SimTime::from_secs(1.0),
            max_interval: SimTime::from_secs(8.0),
        });
        g.should_publish(SimTime::from_secs(0.0), cap(100), TOTAL);
        g.should_publish(SimTime::from_secs(1.0), cap(100), TOTAL);
        assert_eq!(g.tick_interval(), SimTime::from_secs(2.0));
        g.should_publish(SimTime::from_secs(3.0), cap(100), TOTAL);
        assert_eq!(g.tick_interval(), SimTime::from_secs(4.0));
        g.should_publish(SimTime::from_secs(7.0), cap(100), TOTAL);
        g.should_publish(SimTime::from_secs(15.0), cap(100), TOTAL);
        assert_eq!(g.tick_interval(), SimTime::from_secs(8.0)); // capped
        // Movement snaps back to fast cadence.
        g.should_publish(SimTime::from_secs(23.0), cap(500), TOTAL);
        assert_eq!(g.tick_interval(), SimTime::from_secs(1.0));
    }
}
