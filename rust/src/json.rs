//! Minimal JSON parser (objects, arrays, strings, numbers, bools, null).
//!
//! This build is fully offline (no serde available in the vendored crate
//! set), so SLA documents and the AOT artifact manifest are parsed with
//! this ~200-line recursive-descent parser instead. It accepts strict
//! JSON; errors carry byte offsets for debuggability.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|f| *f >= 0.0).map(|f| f as u64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `Null` for anything missing/mistyped.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        self.as_object().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}
impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, m: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            message: m.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs unsupported (not needed for
                            // SLA/manifest docs); map to replacement char.
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(c) => {
                    // Copy the full UTF-8 sequence.
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    if self.pos + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[self.pos..self.pos + len])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                    self.pos += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse(r#""hi\nthere""#).unwrap().as_str(), Some("hi\nthere"));
        assert_eq!(parse(r#""é""#).unwrap().as_str(), Some("é"));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, {"b": "c"}, null], "d": {"e": false}}"#).unwrap();
        assert_eq!(v.get("a").as_array().unwrap().len(), 3);
        assert_eq!(v.get("a").as_array().unwrap()[1].get("b").as_str(), Some("c"));
        assert_eq!(v.get("d").get("e").as_bool(), Some(false));
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""τ₁→τ₂ 日本語""#).unwrap();
        assert_eq!(v.as_str(), Some("τ₁→τ₂ 日本語"));
    }
}
