//! Lane-isolation analysis: each tier's dispatcher may touch only its
//! own lane's state — tiers interact exclusively through `OakMsg`.
//!
//! Every stateful control-plane type is assigned an owning tier below;
//! a dispatcher that names a type owned by another tier (or reaches
//! into the sim core directly instead of going through `Ctx`) gets a
//! `lane-isolation` finding. Message *payload* types (`TableEntry`,
//! `InstanceLocation`, `ServiceIp`, `AggregateStats`, `VivaldiState`)
//! are deliberately unowned: they cross tiers by design, on the wire.
//!
//! The same pass computes the per-arm isolation certificate — the set
//! of `self.<field>` touches over the handler's call closure — which
//! `oakestra lint --graph` embeds in `PROTOCOL.json`. That certificate
//! is the machine-checked precondition for sharding the event loop
//! per-cluster lane (ROADMAP: parallel sim core).
//!
//! Since the sharded engine landed, the pass also polices the lane
//! containers themselves: a `struct Lane*` under `/sim/` may not embed
//! a tier-owned type unless that type is defined under `/sim/` (the
//! simulated runtime the lane legitimately owns, e.g.
//! `ContainerRuntime`). Anything else would let one lane reach another
//! lane's state without going through the window merge.

use super::flow::{closure_ranges, dispatcher_tier, fn_table, FlowAnalysis};
use super::lexer::{is_ident, is_punct, Scan, Tok, Token};
use super::rules::FileAllows;
use super::{SourceFile, Violation};

pub const LANE_ISOLATION: &str = "lane-isolation";

/// Stateful type → the only tier whose dispatcher may name it.
/// `coordinator/state.rs` and the cluster's transport/subnet state are
/// cluster-lane; `db.rs`/`fedstate.rs`/`hierarchy.rs` trees are
/// root-lane; the node-local runtime/table/tunnel machinery is
/// worker-lane.
const OWNERS: &[(&str, &str)] = &[
    ("ClusterEntry", "root"),
    ("ClusterTable", "root"),
    ("ClusterTree", "root"),
    ("ServiceDb", "root"),
    ("ServiceRecord", "root"),
    ("InstanceTable", "cluster"),
    ("LocalInstance", "cluster"),
    ("MqttBroker", "cluster"),
    ("SubnetAllocator", "cluster"),
    ("WorkerTable", "cluster"),
    ("ContainerRuntime", "worker"),
    ("ConversionTable", "worker"),
    ("Mdns", "worker"),
    ("ProxyTun", "worker"),
    ("TelemetryGovernor", "worker"),
    ("TunnelState", "worker"),
];

/// Flag cross-lane state references and direct sim-core access in the
/// three dispatcher files, and tier-owned types embedded in `/sim/`
/// lane structs.
pub fn check(
    sources: &[SourceFile],
    scans: &[Scan],
    allows: &mut [FileAllows],
    out: &mut Vec<Violation>,
) {
    for (fi, (file, scan)) in sources.iter().zip(scans).enumerate() {
        let Some(tier) = dispatcher_tier(&file.path) else {
            continue;
        };
        for (i, t) in scan.tokens.iter().enumerate() {
            if scan.in_test[i] {
                continue;
            }
            let Tok::Ident(name) = &t.tok else { continue };
            let message = if name == "core" && is_punct(&scan.tokens, i.wrapping_sub(1), '.') {
                Some(
                    "direct sim-core access from a dispatcher; go through a \
                     Ctx method so the lane boundary stays rerouteable"
                        .to_string(),
                )
            } else {
                OWNERS
                    .iter()
                    .find(|(ty, owner)| ty == name && *owner != tier)
                    .map(|(ty, owner)| {
                        format!(
                            "{ty} is {owner}-lane state; the {tier} dispatcher may \
                             not touch it — tiers interact only through OakMsg"
                        )
                    })
            };
            if let Some(message) = message {
                if allows[fi].covers(LANE_ISOLATION, t.line) {
                    continue;
                }
                out.push(Violation {
                    rule: LANE_ISOLATION,
                    file: file.path.clone(),
                    line: t.line,
                    col: t.col,
                    message,
                });
            }
        }
    }
    check_lane_structs(sources, scans, allows, out);
}

/// The lane containers of the sharded sim core: a `struct Lane*` in a
/// `/sim/` file may hold only sim-defined and std types — never a type
/// the OWNERS table assigns to a tier, because that would hand one lane
/// a mutable alias of another lane's state outside the window merge.
fn check_lane_structs(
    sources: &[SourceFile],
    scans: &[Scan],
    allows: &mut [FileAllows],
    out: &mut Vec<Violation>,
) {
    let sim_defined = sim_defined_types(sources, scans);
    for (fi, (file, scan)) in sources.iter().zip(scans).enumerate() {
        if !file.path.contains("/sim/") {
            continue;
        }
        let mut i = 0;
        while i < scan.tokens.len() {
            if scan.in_test[i] || !is_ident(&scan.tokens, i, "struct") {
                i += 1;
                continue;
            }
            let lane_name = match scan.tokens.get(i + 1).map(|t| &t.tok) {
                Some(Tok::Ident(n)) if n.starts_with("Lane") => n,
                _ => {
                    i += 1;
                    continue;
                }
            };
            let (start, end) = struct_body(&scan.tokens, i + 2);
            for k in start..end {
                let t = &scan.tokens[k];
                let Tok::Ident(name) = &t.tok else { continue };
                if sim_defined.iter().any(|d| d == name) {
                    continue;
                }
                let Some((ty, owner)) = OWNERS.iter().find(|(ty, _)| ty == name) else {
                    continue;
                };
                if allows[fi].covers(LANE_ISOLATION, t.line) {
                    continue;
                }
                out.push(Violation {
                    rule: LANE_ISOLATION,
                    file: file.path.clone(),
                    line: t.line,
                    col: t.col,
                    message: format!(
                        "{ty} is {owner}-lane state; lane struct {lane_name} may \
                         not embed another lane's owned types — cross-lane effects \
                         travel only through the window merge"
                    ),
                });
            }
            i = end.max(i + 1);
        }
    }
}

/// Names of every type declared in a `/sim/` source file (outside test
/// modules) — the set a lane struct may legitimately own.
fn sim_defined_types(sources: &[SourceFile], scans: &[Scan]) -> Vec<String> {
    let mut out = Vec::new();
    for (file, scan) in sources.iter().zip(scans) {
        if !file.path.contains("/sim/") {
            continue;
        }
        for (i, t) in scan.tokens.iter().enumerate() {
            let Tok::Ident(kw) = &t.tok else { continue };
            if scan.in_test[i] || (kw != "struct" && kw != "enum") {
                continue;
            }
            if let Some(Tok::Ident(name)) = scan.tokens.get(i + 1).map(|t| &t.tok) {
                if !out.contains(name) {
                    out.push(name.clone());
                }
            }
        }
    }
    out
}

/// Token range of a struct's body, searching just past its name: the
/// contents of `{ … }` (field struct) or `( … )` (tuple struct), both
/// exclusive of the delimiters; a unit struct yields an empty range.
fn struct_body(tokens: &[Token], mut i: usize) -> (usize, usize) {
    while i < tokens.len() {
        let open = match &tokens[i].tok {
            Tok::Punct(';') => return (i, i),
            Tok::Punct(c) if *c == '{' || *c == '(' => *c,
            _ => {
                i += 1;
                continue;
            }
        };
        let close = if open == '{' { '}' } else { ')' };
        let mut depth = 1;
        let mut j = i + 1;
        while j < tokens.len() && depth > 0 {
            match &tokens[j].tok {
                Tok::Punct(c) if *c == open => depth += 1,
                Tok::Punct(c) if *c == close => depth -= 1,
                _ => {}
            }
            j += 1;
        }
        return (i + 1, j.saturating_sub(1));
    }
    (i, i)
}

/// Per-arm isolation certificates, parallel to `fa.arms`: the sorted
/// set of `self.<field>` accesses over each handler's call closure.
pub fn certificates(sources: &[SourceFile], scans: &[Scan], fa: &FlowAnalysis) -> Vec<Vec<String>> {
    let mut out = Vec::with_capacity(fa.arms.len());
    // fn tables are per-file; arms of one file are contiguous enough
    // that a one-slot cache avoids recomputation.
    let mut cached: Option<(usize, super::flow::FnTable)> = None;
    for arm in &fa.arms {
        let Some(fi) = sources.iter().position(|f| f.path == arm.file) else {
            out.push(Vec::new());
            continue;
        };
        let scan = &scans[fi];
        if cached.as_ref().map(|(i, _)| *i) != Some(fi) {
            cached = Some((fi, fn_table(scan)));
        }
        let table = &cached.as_ref().unwrap().1;
        let mut touches: Vec<String> = Vec::new();
        for (start, end) in closure_ranges(scan, table, arm.body) {
            for k in start..end.min(scan.tokens.len()) {
                let Tok::Ident(s) = &scan.tokens[k].tok else {
                    continue;
                };
                if s != "self" || !is_punct(&scan.tokens, k + 1, '.') {
                    continue;
                }
                if let Some(Tok::Ident(field)) = scan.tokens.get(k + 2).map(|t| &t.tok) {
                    // A following `(` is a method call, not a field.
                    if !is_punct(&scan.tokens, k + 3, '(') && !touches.contains(field) {
                        touches.push(field.clone());
                    }
                }
            }
        }
        touches.sort();
        out.push(touches);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::lexer::scan;
    use super::*;

    fn run(files: &[(&str, &str)]) -> Vec<Violation> {
        let sources: Vec<SourceFile> = files
            .iter()
            .map(|(p, t)| SourceFile {
                path: (*p).into(),
                text: (*t).into(),
            })
            .collect();
        let scans: Vec<Scan> = sources.iter().map(|f| scan(&f.text)).collect();
        let mut allows: Vec<FileAllows> = scans.iter().map(FileAllows::new).collect();
        let mut out = Vec::new();
        check(&sources, &scans, &mut allows, &mut out);
        out
    }

    #[test]
    fn lane_struct_may_not_embed_foreign_lane_state() {
        let v = run(&[(
            "rust/src/sim/lane.rs",
            "pub(crate) struct LaneCore { table: WorkerTable }",
        )]);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, LANE_ISOLATION);
        assert!(v[0].message.contains("WorkerTable"));
        assert!(v[0].message.contains("LaneCore"));
    }

    #[test]
    fn sim_defined_types_are_lane_local() {
        // ContainerRuntime is tier-owned *and* defined under /sim/ — the
        // per-lane copy of the simulated runtime is exactly the point.
        let v = run(&[
            (
                "rust/src/sim/container.rs",
                "pub struct ContainerRuntime { pub registry_mbps: f64 }",
            ),
            (
                "rust/src/sim/lane.rs",
                "pub(crate) struct LaneCore { containers: ContainerRuntime }",
            ),
        ]);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn lane_rule_scopes_to_lane_structs_outside_tests() {
        // Non-Lane structs in /sim/ are out of scope (the dispatcher
        // rule, not this one, polices real cross-lane use)...
        let harness = run(&[(
            "rust/src/sim/mod.rs",
            "struct Harness { t: WorkerTable }",
        )]);
        assert!(harness.is_empty(), "{harness:?}");
        // ...as are lane structs declared inside #[cfg(test)] modules.
        let fixture = run(&[(
            "rust/src/sim/lane.rs",
            "#[cfg(test)]\nmod tests {\n    struct LaneFixture {\n        t: WorkerTable,\n    }\n}\n",
        )]);
        assert!(fixture.is_empty(), "{fixture:?}");
    }

    #[test]
    fn allow_pragma_suppresses_lane_struct_finding() {
        let v = run(&[(
            "rust/src/sim/lane.rs",
            "pub(crate) struct LaneOutbox {\n    \
             // lint: allow(lane-isolation, read-only census mirror)\n    \
             table: WorkerTable,\n}\n",
        )]);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn tuple_and_unit_lane_structs_are_covered() {
        let v = run(&[(
            "rust/src/sim/lane.rs",
            "struct LaneTag;\nstruct LaneRef(ClusterTable);\n",
        )]);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("ClusterTable"));
        assert!(v[0].message.contains("LaneRef"));
    }
}
