//! Lane-isolation analysis: each tier's dispatcher may touch only its
//! own lane's state — tiers interact exclusively through `OakMsg`.
//!
//! Every stateful control-plane type is assigned an owning tier below;
//! a dispatcher that names a type owned by another tier (or reaches
//! into the sim core directly instead of going through `Ctx`) gets a
//! `lane-isolation` finding. Message *payload* types (`TableEntry`,
//! `InstanceLocation`, `ServiceIp`, `AggregateStats`, `VivaldiState`)
//! are deliberately unowned: they cross tiers by design, on the wire.
//!
//! The same pass computes the per-arm isolation certificate — the set
//! of `self.<field>` touches over the handler's call closure — which
//! `oakestra lint --graph` embeds in `PROTOCOL.json`. That certificate
//! is the machine-checked precondition for sharding the event loop
//! per-cluster lane (ROADMAP: parallel sim core).

use super::flow::{closure_ranges, dispatcher_tier, fn_table, FlowAnalysis};
use super::lexer::{is_punct, Scan, Tok};
use super::rules::FileAllows;
use super::{SourceFile, Violation};

pub const LANE_ISOLATION: &str = "lane-isolation";

/// Stateful type → the only tier whose dispatcher may name it.
/// `coordinator/state.rs` and the cluster's transport/subnet state are
/// cluster-lane; `db.rs`/`fedstate.rs`/`hierarchy.rs` trees are
/// root-lane; the node-local runtime/table/tunnel machinery is
/// worker-lane.
const OWNERS: &[(&str, &str)] = &[
    ("ClusterEntry", "root"),
    ("ClusterTable", "root"),
    ("ClusterTree", "root"),
    ("ServiceDb", "root"),
    ("ServiceRecord", "root"),
    ("InstanceTable", "cluster"),
    ("LocalInstance", "cluster"),
    ("MqttBroker", "cluster"),
    ("SubnetAllocator", "cluster"),
    ("WorkerTable", "cluster"),
    ("ContainerRuntime", "worker"),
    ("ConversionTable", "worker"),
    ("Mdns", "worker"),
    ("ProxyTun", "worker"),
    ("TelemetryGovernor", "worker"),
    ("TunnelState", "worker"),
];

/// Flag cross-lane state references and direct sim-core access in the
/// three dispatcher files.
pub fn check(
    sources: &[SourceFile],
    scans: &[Scan],
    allows: &mut [FileAllows],
    out: &mut Vec<Violation>,
) {
    for (fi, (file, scan)) in sources.iter().zip(scans).enumerate() {
        let Some(tier) = dispatcher_tier(&file.path) else {
            continue;
        };
        for (i, t) in scan.tokens.iter().enumerate() {
            if scan.in_test[i] {
                continue;
            }
            let Tok::Ident(name) = &t.tok else { continue };
            let message = if name == "core" && is_punct(&scan.tokens, i.wrapping_sub(1), '.') {
                Some(
                    "direct sim-core access from a dispatcher; go through a \
                     Ctx method so the lane boundary stays rerouteable"
                        .to_string(),
                )
            } else {
                OWNERS
                    .iter()
                    .find(|(ty, owner)| ty == name && *owner != tier)
                    .map(|(ty, owner)| {
                        format!(
                            "{ty} is {owner}-lane state; the {tier} dispatcher may \
                             not touch it — tiers interact only through OakMsg"
                        )
                    })
            };
            if let Some(message) = message {
                if allows[fi].covers(LANE_ISOLATION, t.line) {
                    continue;
                }
                out.push(Violation {
                    rule: LANE_ISOLATION,
                    file: file.path.clone(),
                    line: t.line,
                    col: t.col,
                    message,
                });
            }
        }
    }
}

/// Per-arm isolation certificates, parallel to `fa.arms`: the sorted
/// set of `self.<field>` accesses over each handler's call closure.
pub fn certificates(sources: &[SourceFile], scans: &[Scan], fa: &FlowAnalysis) -> Vec<Vec<String>> {
    let mut out = Vec::with_capacity(fa.arms.len());
    // fn tables are per-file; arms of one file are contiguous enough
    // that a one-slot cache avoids recomputation.
    let mut cached: Option<(usize, super::flow::FnTable)> = None;
    for arm in &fa.arms {
        let Some(fi) = sources.iter().position(|f| f.path == arm.file) else {
            out.push(Vec::new());
            continue;
        };
        let scan = &scans[fi];
        if cached.as_ref().map(|(i, _)| *i) != Some(fi) {
            cached = Some((fi, fn_table(scan)));
        }
        let table = &cached.as_ref().unwrap().1;
        let mut touches: Vec<String> = Vec::new();
        for (start, end) in closure_ranges(scan, table, arm.body) {
            for k in start..end.min(scan.tokens.len()) {
                let Tok::Ident(s) = &scan.tokens[k].tok else {
                    continue;
                };
                if s != "self" || !is_punct(&scan.tokens, k + 1, '.') {
                    continue;
                }
                if let Some(Tok::Ident(field)) = scan.tokens.get(k + 2).map(|t| &t.tok) {
                    // A following `(` is a method call, not a field.
                    if !is_punct(&scan.tokens, k + 3, '(') && !touches.contains(field) {
                        touches.push(field.clone());
                    }
                }
            }
        }
        touches.sort();
        out.push(touches);
    }
    out
}
