//! Protocol flow-graph analysis: who sends which `OakMsg` variant to
//! which tier, and which dispatcher arm handles it.
//!
//! Send sites are every `send` / `send_unreliable` / `send_local` /
//! `schedule` / `schedule_for` / `inject` call whose message resolves to
//! an `OakMsg` variant (inline `SimMsg::Oak(OakMsg::V …)` or through the
//! nearest `let msg = …` binding). The destination tier comes from the
//! wire label (`labels::CLUSTER_TO_ROOT` ⇒ root), from a self-addressed
//! `send_local(ctx.self_id, …)` / `schedule`, or — for dynamic
//! addressees — from a `route(tier, why)` pragma comment.
//!
//! Dispatcher arms are the `OakMsg::V … =>` match arms of the three
//! coordinator files. The graph closes when every (variant, dest-tier)
//! edge lands on a real arm (`flow-handled`), every arm has at least one
//! sender (`flow-dead-arm`), and every declared request/reply pair sends
//! its reply somewhere in the handler's call closure (`reply-pairing`,
//! deferrable with a `defer(Reply, why)` pragma comment inside the arm).

use std::collections::BTreeMap;

use super::lexer::{is_ident, is_punct, skip_attr, Pragma, Scan, Tok};
use super::rules::{FileAllows, PRAGMA};
use super::{SourceFile, Violation};

pub const FLOW_HANDLED: &str = "flow-handled";
pub const FLOW_DEAD_ARM: &str = "flow-dead-arm";
pub const REPLY_PAIRING: &str = "reply-pairing";

/// Declared request/reply obligations: (request, reply, handling tier).
/// The tier's handler for the request must send the reply on some path
/// of its call closure or carry a defer pragma.
pub const REPLY_PAIRS: &[(&str, &str, &str)] = &[
    ("ApiCall", "ApiReturn", "root"),
    ("DelegateTask", "DelegationResult", "cluster"),
    ("InstanceReplaced", "InstanceReplacedAck", "root"),
    ("Ping", "Pong", "cluster"),
    ("RegisterCluster", "RegisterClusterAck", "root"),
    ("RegisterWorker", "RegisterWorkerAck", "cluster"),
    ("ResyncRequest", "ResyncSnapshot", "cluster"),
];

/// Which tier a dispatcher file implements, if any.
pub fn dispatcher_tier(path: &str) -> Option<&'static str> {
    if path.ends_with("coordinator/root.rs") {
        Some("root")
    } else if path.ends_with("coordinator/cluster.rs") {
        Some("cluster")
    } else if path.ends_with("coordinator/worker.rs") {
        Some("worker")
    } else {
        None
    }
}

/// Tier a file *sends from*: its dispatcher tier, or `client` for
/// drivers, benches and the API layer (environment actors).
fn file_tier(path: &str) -> &'static str {
    dispatcher_tier(path).unwrap_or("client")
}

/// Files that are the transport/analysis substrate itself, not protocol
/// participants: their internal `push`/`send` plumbing is not a flow
/// edge.
fn is_transport(path: &str) -> bool {
    path.contains("/sim/") || path.contains("/lint/")
}

fn label_dest(label: &str) -> Option<&'static str> {
    match label {
        "ROOT_TO_CLUSTER" => Some("cluster"),
        "CLUSTER_TO_ROOT" => Some("root"),
        "CLUSTER_TO_WORKER" => Some("worker"),
        "WORKER_TO_CLUSTER" => Some("cluster"),
        _ => None,
    }
}

/// `(message-arg index, addressee-arg index, label-arg index)` for each
/// transmit-path method (see `sim::Ctx` / `Sim::inject` signatures).
fn trigger(name: &str) -> Option<(usize, Option<usize>, Option<usize>)> {
    match name {
        "send" | "send_unreliable" => Some((1, Some(0), Some(3))),
        "send_local" => Some((1, Some(0), None)),
        "schedule" => Some((1, None, None)),
        "schedule_for" => Some((2, Some(0), None)),
        "inject" => Some((2, Some(1), None)),
        _ => None,
    }
}

/// One send of an `OakMsg` variant (or a send the analyzer gave up on:
/// `variant`/`to` of `None` become `flow-handled` findings).
#[derive(Clone, Debug)]
pub struct SendSite {
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub variant: Option<String>,
    pub from: &'static str,
    pub to: Option<String>,
    /// Token index of the method-name ident (closure membership tests).
    pub(crate) idx: usize,
}

/// One `OakMsg::V … =>` dispatcher match arm.
#[derive(Clone, Debug)]
pub struct Arm {
    pub tier: &'static str,
    pub file: String,
    pub variant: String,
    pub line: u32,
    pub col: u32,
    /// Token range of the handler body (after `=>`), exclusive end.
    pub(crate) body: (usize, usize),
    /// Last source line of the body — the defer-pragma window.
    pub(crate) end_line: u32,
    /// OakMsg variants sent anywhere in the arm's call closure (sorted,
    /// deduped) — the reply certificate.
    pub replies: Vec<String>,
}

/// The extracted tier-aware send→handle graph for the whole tree.
#[derive(Debug, Default)]
pub struct FlowAnalysis {
    pub sites: Vec<SendSite>,
    pub arms: Vec<Arm>,
    /// tier → variants its dispatcher deliberately leaves to `_`.
    pub wildcards: BTreeMap<String, Vec<String>>,
    /// Unused `route(...)` pragmas: (file, line, col, tier).
    unused_routes: Vec<(String, u32, u32, String)>,
    /// Defer pragmas per dispatcher tier: (variant, line, col, used).
    defers: BTreeMap<String, Vec<(String, u32, u32, bool)>>,
    /// Per-dispatcher-file scan index into the caller's slices, so the
    /// isolation pass can reuse arm bodies against the right scan.
    pub(crate) dispatcher_files: Vec<(usize, &'static str)>,
}

/// A named function's body token range — the unit of the call-closure
/// walk shared by reply-pairing and the isolation certificate.
pub(crate) struct FnTable {
    fns: Vec<(String, (usize, usize))>,
}

pub(crate) fn fn_table(scan: &Scan) -> FnTable {
    let toks = &scan.tokens;
    let mut fns = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if is_ident(toks, i, "fn") {
            if let Some(Tok::Ident(name)) = toks.get(i + 1).map(|t| &t.tok) {
                // First `{` past the signature opens the body (types in
                // signatures never contain braces).
                let mut j = i + 2;
                while j < toks.len() && !is_punct(toks, j, '{') {
                    // A signature-less decl (trait method `fn f();`)
                    // has no body.
                    if is_punct(toks, j, ';') {
                        break;
                    }
                    j += 1;
                }
                if is_punct(toks, j, '{') {
                    let end = skip_balanced(toks, j, '{', '}');
                    fns.push((name.clone(), (j, end)));
                }
            }
        }
        i += 1;
    }
    FnTable { fns }
}

/// Token ranges reachable from `body` by following same-file calls
/// (`self.helper(…)` or bare `helper(…)`) transitively.
pub(crate) fn closure_ranges(scan: &Scan, table: &FnTable, body: (usize, usize)) -> Vec<(usize, usize)> {
    let toks = &scan.tokens;
    let mut ranges = vec![body];
    let mut seen: Vec<String> = Vec::new();
    let mut work = vec![body];
    while let Some((start, end)) = work.pop() {
        for k in start..end.min(toks.len()) {
            let Tok::Ident(name) = &toks[k].tok else {
                continue;
            };
            if !is_punct(toks, k + 1, '(') || is_punct(toks, k.wrapping_sub(1), ':') {
                continue;
            }
            if seen.contains(name) {
                continue;
            }
            if let Some((_, range)) = table.fns.iter().find(|(n, _)| n == name) {
                seen.push(name.clone());
                ranges.push(*range);
                work.push(*range);
            }
        }
    }
    ranges
}

/// Index just past the token matching the opener at `i`.
fn skip_balanced(toks: &[super::lexer::Token], i: usize, open: char, close: char) -> usize {
    let mut depth = 1;
    let mut j = i + 1;
    while j < toks.len() && depth > 0 {
        match &toks[j].tok {
            Tok::Punct(c) if *c == open => depth += 1,
            Tok::Punct(c) if *c == close => depth -= 1,
            _ => {}
        }
        j += 1;
    }
    j
}

/// The `_ => …` wildcard arm's anchor token, if the file has one.
pub(crate) fn wildcard_arm_anchor(scan: &Scan) -> Option<(u32, u32)> {
    let toks = &scan.tokens;
    for (i, t) in toks.iter().enumerate() {
        if scan.in_test[i] {
            continue;
        }
        if matches!(&t.tok, Tok::Ident(n) if n == "_")
            && is_punct(toks, i + 1, '=')
            && is_punct(toks, i + 2, '>')
        {
            return Some((t.line, t.col));
        }
    }
    None
}

/// Split the balanced argument list opening at `open_idx` (a `(`) into
/// top-level comma-separated token ranges. Returns `None` when the list
/// never closes.
fn split_args(toks: &[super::lexer::Token], open_idx: usize) -> Option<Vec<(usize, usize)>> {
    let mut args = Vec::new();
    let mut depth = 1;
    let mut start = open_idx + 1;
    let mut j = open_idx + 1;
    while j < toks.len() {
        match &toks[j].tok {
            Tok::Punct('(') | Tok::Punct('[') | Tok::Punct('{') => depth += 1,
            Tok::Punct(')') | Tok::Punct(']') | Tok::Punct('}') => {
                depth -= 1;
                if depth == 0 {
                    if j > start {
                        args.push((start, j));
                    }
                    return Some(args);
                }
            }
            Tok::Punct(',') if depth == 1 => {
                args.push((start, j));
                start = j + 1;
            }
            _ => {}
        }
        j += 1;
    }
    None
}

/// What a message-argument token range resolves to.
enum MsgKind {
    Oak(String),
    NonProtocol,
    Unknown,
}

fn classify_msg(toks: &[super::lexer::Token], range: (usize, usize)) -> MsgKind {
    let (start, end) = range;
    for k in start..end.min(toks.len()) {
        if is_ident(toks, k, "OakMsg") && is_punct(toks, k + 1, ':') && is_punct(toks, k + 2, ':') {
            if let Some(Tok::Ident(v)) = toks.get(k + 3).map(|t| &t.tok) {
                return MsgKind::Oak(v.clone());
            }
        }
        if is_ident(toks, k, "SimMsg") && is_punct(toks, k + 1, ':') && is_punct(toks, k + 2, ':') {
            match toks.get(k + 3).map(|t| &t.tok) {
                Some(Tok::Ident(tag)) if tag == "Data" || tag == "Timer" || tag == "Kube" => {
                    return MsgKind::NonProtocol;
                }
                _ => {}
            }
        }
    }
    MsgKind::Unknown
}

/// Resolve a single-identifier message argument through its nearest
/// preceding `let <var> = …;` binding.
fn resolve_binding(toks: &[super::lexer::Token], var: &str, before: usize) -> MsgKind {
    for k in (0..before).rev() {
        let Tok::Ident(name) = &toks[k].tok else {
            continue;
        };
        if name != var || !is_punct(toks, k + 1, '=') || is_punct(toks, k + 2, '=') {
            continue;
        }
        // `var ==`, `var =` as comparison rhs, and `var.method()` are
        // excluded above / by the '=' requirement; scan the initializer
        // up to its terminating `;`.
        let mut end = k + 2;
        let mut depth = 0i32;
        while end < toks.len() {
            match &toks[end].tok {
                Tok::Punct('(') | Tok::Punct('[') | Tok::Punct('{') => depth += 1,
                Tok::Punct(')') | Tok::Punct(']') | Tok::Punct('}') => depth -= 1,
                Tok::Punct(';') if depth <= 0 => break,
                _ => {}
            }
            end += 1;
        }
        return classify_msg(toks, (k + 2, end));
    }
    MsgKind::Unknown
}

/// Extract the full flow graph (send sites, arms, reply closures,
/// wildcard declarations) from the scanned tree. `scans` parallels
/// `sources`.
pub fn extract(sources: &[SourceFile], scans: &[Scan]) -> FlowAnalysis {
    let mut fa = FlowAnalysis::default();

    for (fi, (file, scan)) in sources.iter().zip(scans).enumerate() {
        if is_transport(&file.path) || !file.path.ends_with(".rs") {
            continue;
        }
        let from = file_tier(&file.path);

        // Route pragmas with their coverage windows.
        let mut routes: Vec<(Vec<u32>, String, u32, u32, bool)> = scan
            .pragmas
            .iter()
            .filter_map(|p| match p {
                Pragma::Route {
                    line, col, tier, ..
                } => Some((scan.allow_window(*line), tier.clone(), *line, *col, false)),
                _ => None,
            })
            .collect();

        let toks = &scan.tokens;
        for i in 0..toks.len() {
            if scan.in_test[i] {
                continue;
            }
            let Tok::Ident(name) = &toks[i].tok else {
                continue;
            };
            let Some((msg_idx, dest_idx, label_idx)) = trigger(name) else {
                continue;
            };
            if !is_punct(toks, i.wrapping_sub(1), '.') || !is_punct(toks, i + 1, '(') {
                continue;
            }
            let Some(args) = split_args(toks, i + 1) else {
                continue;
            };
            let Some(&msg_range) = args.get(msg_idx) else {
                continue;
            };

            let kind = match classify_msg(toks, msg_range) {
                MsgKind::Unknown if msg_range.1 == msg_range.0 + 1 => {
                    match &toks[msg_range.0].tok {
                        Tok::Ident(var) => resolve_binding(toks, var, i),
                        _ => MsgKind::Unknown,
                    }
                }
                k => k,
            };
            let variant = match kind {
                MsgKind::Oak(v) => Some(v),
                MsgKind::NonProtocol => continue,
                MsgKind::Unknown => None,
            };

            // Destination tier: wire label, then self-addressing, then a
            // route pragma covering the call line.
            let mut to: Option<String> = None;
            if let Some(li) = label_idx {
                if let Some(&(ls, le)) = args.get(li) {
                    for k in ls..le.min(toks.len()) {
                        if is_ident(toks, k, "labels") {
                            if let Some(Tok::Ident(l)) = toks.get(k + 3).map(|t| &t.tok) {
                                to = label_dest(l).map(str::to_string);
                            }
                        }
                    }
                }
            }
            if to.is_none() {
                let self_addressed = match dest_idx {
                    None => true, // `schedule` targets self
                    Some(di) => args.get(di).is_some_and(|&(ds, de)| {
                        de == ds + 3
                            && is_ident(toks, ds, "ctx")
                            && is_punct(toks, ds + 1, '.')
                            && is_ident(toks, ds + 2, "self_id")
                    }),
                };
                if self_addressed {
                    to = Some(from.to_string());
                }
            }
            let line = toks[i].line;
            if to.is_none() {
                if let Some(r) = routes
                    .iter_mut()
                    .find(|(window, ..)| window.contains(&line))
                {
                    to = Some(r.1.clone());
                    r.4 = true;
                }
            }

            fa.sites.push(SendSite {
                file: file.path.clone(),
                line,
                col: toks[i].col,
                variant,
                from,
                to,
                idx: i,
            });
        }

        for (_window, tier, line, col, used) in routes {
            if !used {
                fa.unused_routes
                    .push((file.path.clone(), line, col, tier));
            }
        }

        // Dispatcher-only extraction: arms, wildcard manifest, defers.
        let Some(tier) = dispatcher_tier(&file.path) else {
            continue;
        };
        fa.dispatcher_files.push((fi, tier));
        let table = fn_table(scan);

        for p in &scan.pragmas {
            match p {
                Pragma::Wildcard { variants, .. } => {
                    let slot = fa.wildcards.entry(tier.to_string()).or_default();
                    for v in variants {
                        if !slot.contains(v) {
                            slot.push(v.clone());
                        }
                    }
                }
                Pragma::Defer {
                    line, col, variant, ..
                } => {
                    fa.defers.entry(tier.to_string()).or_default().push((
                        variant.clone(),
                        *line,
                        *col,
                        false,
                    ));
                }
                _ => {}
            }
        }

        let mut i = 0;
        while i < toks.len() {
            if scan.in_test[i]
                || !is_ident(toks, i, "OakMsg")
                || !is_punct(toks, i + 1, ':')
                || !is_punct(toks, i + 2, ':')
            {
                i += 1;
                continue;
            }
            let Some(Tok::Ident(variant)) = toks.get(i + 3).map(|t| &t.tok) else {
                i += 1;
                continue;
            };
            let (line, col) = (toks[i + 3].line, toks[i + 3].col);
            let mut j = i + 4;
            if is_punct(toks, j, '{') {
                j = skip_balanced(toks, j, '{', '}');
            } else if is_punct(toks, j, '(') {
                j = skip_balanced(toks, j, '(', ')');
            }
            while is_punct(toks, j, ')') {
                j += 1;
            }
            // Arm if the pattern position continues with `=>`, an
            // alternation `|`, or an `if` guard; otherwise this is a
            // message construction.
            let is_arm = (is_punct(toks, j, '=') && is_punct(toks, j + 1, '>'))
                || is_punct(toks, j, '|')
                || is_ident(toks, j, "if");
            if !is_arm {
                i += 4;
                continue;
            }
            // Find the arm's `=>` (crosses guards and alternations).
            let mut a = j;
            while a < toks.len() && !(is_punct(toks, a, '=') && is_punct(toks, a + 1, '>')) {
                a += 1;
            }
            let body_start = a + 2;
            let body_end = if is_punct(toks, body_start, '{') {
                skip_balanced(toks, body_start, '{', '}')
            } else {
                // Unbraced arm: runs to the top-level `,`.
                let mut depth = 0i32;
                let mut k = body_start;
                while k < toks.len() {
                    match &toks[k].tok {
                        Tok::Punct('(') | Tok::Punct('[') | Tok::Punct('{') => depth += 1,
                        Tok::Punct(')') | Tok::Punct(']') | Tok::Punct('}') => depth -= 1,
                        Tok::Punct(',') if depth <= 0 => break,
                        _ => {}
                    }
                    k += 1;
                }
                k
            };
            let end_line = toks
                .get(body_end.saturating_sub(1))
                .map_or(line, |t| t.line);

            let ranges = closure_ranges(scan, &table, (body_start, body_end));
            let mut replies: Vec<String> = Vec::new();
            for s in &fa.sites {
                if s.file == file.path {
                    if let Some(v) = &s.variant {
                        if ranges.iter().any(|&(rs, re)| s.idx >= rs && s.idx < re)
                            && !replies.contains(v)
                        {
                            replies.push(v.clone());
                        }
                    }
                }
            }
            replies.sort();

            fa.arms.push(Arm {
                tier,
                file: file.path.clone(),
                variant: variant.clone(),
                line,
                col,
                body: (body_start, body_end),
                end_line,
                replies,
            });
            i = j;
        }
    }
    fa
}

/// Status of each declared request/reply pair, in declaration order —
/// the `pairs` section of `PROTOCOL.json`. `paired` means the handler's
/// call closure sends the reply; `deferred` means a defer pragma inside
/// the arm claims it; `open` is a `reply-pairing` finding; `unhandled`
/// means the request has no arm at all (a `flow-handled` finding).
pub fn pair_statuses(
    fa: &FlowAnalysis,
) -> Vec<(&'static str, &'static str, &'static str, &'static str)> {
    REPLY_PAIRS
        .iter()
        .map(|&(req, reply, tier)| {
            let status = match fa.arms.iter().find(|a| a.tier == tier && a.variant == req) {
                None => "unhandled",
                Some(arm) if arm.replies.iter().any(|r| r == reply) => "paired",
                Some(arm) => {
                    let deferred = fa.defers.get(tier).is_some_and(|ds| {
                        ds.iter().any(|(v, line, _, _)| {
                            v == reply && *line >= arm.line && *line <= arm.end_line
                        })
                    });
                    if deferred {
                        "deferred"
                    } else {
                        "open"
                    }
                }
            };
            (req, reply, tier, status)
        })
        .collect()
}

/// Run the three flow rules over the extracted graph.
pub fn check(
    fa: &FlowAnalysis,
    sources: &[SourceFile],
    allows: &mut [FileAllows],
    out: &mut Vec<Violation>,
) {
    let allow_idx = |path: &str| sources.iter().position(|f| f.path == path);
    let mut flag =
        |allows: &mut [FileAllows], rule: &'static str, file: &str, line: u32, col: u32, message: String| {
            if let Some(ai) = allow_idx(file) {
                if allows[ai].covers(rule, line) {
                    return;
                }
            }
            out.push(Violation {
                rule,
                file: file.to_string(),
                line,
                col,
                message,
            });
        };

    // flow-handled: every resolved edge lands on a real arm; unresolved
    // sends are findings too (the analyzer must not silently skip them).
    for s in &fa.sites {
        match (&s.variant, &s.to) {
            (None, _) => flag(
                allows,
                FLOW_HANDLED,
                &s.file,
                s.line,
                s.col,
                "cannot resolve this send's OakMsg variant; construct the message \
                 as `SimMsg::Oak(OakMsg::…)` in a nearby `let` binding"
                    .to_string(),
            ),
            (Some(v), None) => flag(
                allows,
                FLOW_HANDLED,
                &s.file,
                s.line,
                s.col,
                format!(
                    "cannot infer the destination tier of this {v} send; \
                     annotate with `// lint: route(tier, why)`"
                ),
            ),
            (Some(v), Some(to)) => {
                if to == "client" {
                    continue; // environment actors: no dispatcher to land on
                }
                let handled = fa
                    .arms
                    .iter()
                    .any(|a| a.tier == to.as_str() && &a.variant == v);
                if !handled {
                    let wildcarded = fa
                        .wildcards
                        .get(to.as_str())
                        .is_some_and(|ws| ws.contains(v));
                    let hint = if wildcarded {
                        " (the tier wildcard-drops it — a silent discard)"
                    } else {
                        ""
                    };
                    flag(
                        allows,
                        FLOW_HANDLED,
                        &s.file,
                        s.line,
                        s.col,
                        format!("{v} sent to the {to} tier, but its dispatcher has no arm for it{hint}"),
                    );
                }
            }
        }
    }

    // flow-dead-arm: every real arm is reachable from some send site.
    for a in &fa.arms {
        let reached = fa.sites.iter().any(|s| {
            s.variant.as_deref() == Some(a.variant.as_str())
                && s.to.as_deref() == Some(a.tier)
        });
        if !reached {
            flag(
                allows,
                FLOW_DEAD_ARM,
                &a.file,
                a.line,
                a.col,
                format!(
                    "no send site addresses {} to the {} tier; dead arm",
                    a.variant, a.tier
                ),
            );
        }
    }

    // reply-pairing: declared request/reply pairs must answer (or defer).
    let mut defers = fa.defers.clone();
    for &(req, reply, tier) in REPLY_PAIRS {
        let Some(arm) = fa
            .arms
            .iter()
            .find(|a| a.tier == tier && a.variant == req)
        else {
            continue; // missing arm is flow-handled's finding, not ours
        };
        if arm.replies.iter().any(|r| r == reply) {
            continue;
        }
        let deferred = defers.get_mut(tier).is_some_and(|ds| {
            ds.iter_mut()
                .find(|(v, line, _, _)| v == reply && *line >= arm.line && *line <= arm.end_line)
                .map(|d| d.3 = true)
                .is_some()
        });
        if deferred {
            continue;
        }
        flag(
            allows,
            REPLY_PAIRING,
            &arm.file,
            arm.line,
            arm.col,
            format!(
                "{req} handler never sends its declared reply {reply} \
                 (checked through the call closure); reply or declare \
                 `// lint: defer({reply}, why)` inside the arm"
            ),
        );
    }

    // Pragma hygiene for the new verbs: a route pragma that resolved no
    // send, or a defer pragma no pair consulted, is stale.
    for (file, line, col, tier) in &fa.unused_routes {
        out.push(Violation {
            rule: PRAGMA,
            file: file.clone(),
            line: *line,
            col: *col,
            message: format!("route({tier}) pragma covers no unresolved send; delete it"),
        });
    }
    for (tier, ds) in &defers {
        for (variant, line, col, used) in ds {
            if !used {
                let file = sources
                    .iter()
                    .map(|f| f.path.clone())
                    .find(|p| dispatcher_tier(p) == Some(tier.as_str()))
                    .unwrap_or_default();
                out.push(Violation {
                    rule: PRAGMA,
                    file,
                    line: *line,
                    col: *col,
                    message: format!(
                        "defer({variant}) pragma defers nothing (the reply is sent \
                         or no pair requires it); delete it"
                    ),
                });
            }
        }
    }
}
