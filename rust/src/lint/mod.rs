//! `oakestra lint` — a dependency-free, token-level static analyzer over
//! the crate's own sources, enforcing the determinism and protocol
//! invariants every figure in this repo rests on (see README "Static
//! analysis"):
//!
//! - `hash-order` (D1): no `HashMap`/`HashSet` in control-plane modules
//!   unless an allow pragma justifies that iteration order never escapes.
//! - `float-order` (D2): no `partial_cmp`-based ordering; use `total_cmp`.
//! - `ambient-time` (D3): no `Instant`/`SystemTime`/thread RNG outside
//!   the sim clock and `util::Rng`.
//! - `protocol-coverage` (P1): every `OakMsg` variant handled (or
//!   wildcard-declared) in all three tier dispatchers and priced in the
//!   wire-size model.
//! - `metrics-keys` (M1): metric keys cited by README/ci.yml exist in
//!   code.
//! - `pragma`: pragmas must parse, and allow pragmas must suppress
//!   something.
//!
//! Violations are diffed against the committed `LINT_BASELINE.json`
//! ratchet: counts may only shrink.

pub mod baseline;
pub mod lexer;
mod metrics_keys;
mod protocol;
mod rules;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use lexer::Scan;

pub use metrics_keys::METRICS_KEYS;
pub use protocol::{enum_variants, referenced_variants, PROTOCOL};
pub use rules::{AMBIENT_TIME, FLOAT_ORDER, HASH_ORDER, PRAGMA};

/// Every rule id, in report order.
pub const ALL_RULES: [&str; 6] = [
    HASH_ORDER,
    FLOAT_ORDER,
    AMBIENT_TIME,
    PROTOCOL,
    METRICS_KEYS,
    PRAGMA,
];

/// One source (or doc) file: repo-relative path with `/` separators.
#[derive(Clone, Debug)]
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

impl SourceFile {
    /// Modules where hash-iteration order can leak into scheduling,
    /// gossip or output — the D1 scope.
    pub fn control_plane(&self) -> bool {
        self.path.contains("/coordinator/")
            || self.path.contains("/scheduler/")
            || self.path.contains("/netmanager/")
            || self.path.contains("/sim/")
            || self.path.ends_with("hierarchy.rs")
    }
}

/// Everything the analyzer looks at, decoupled from the filesystem so
/// tests can lint fixture inputs.
#[derive(Clone, Debug, Default)]
pub struct LintInput {
    pub sources: Vec<SourceFile>,
    /// README.md / ci.yml — scanned for metric-key references only.
    pub docs: Vec<SourceFile>,
}

#[derive(Clone, Debug)]
pub struct Violation {
    pub rule: &'static str,
    pub file: String,
    /// 1-based; 0 means the finding is file-scoped.
    pub line: u32,
    pub message: String,
}

#[derive(Clone, Debug, Default)]
pub struct LintReport {
    pub violations: Vec<Violation>,
    /// Per-rule totals, zero-filled over [`ALL_RULES`].
    pub counts: BTreeMap<String, u64>,
    pub files_scanned: usize,
}

/// Run every rule over an input set.
pub fn analyze(input: &LintInput) -> LintReport {
    let scans: Vec<Scan> = input.sources.iter().map(|f| lexer::scan(&f.text)).collect();
    let mut violations = Vec::new();
    for (file, scan) in input.sources.iter().zip(&scans) {
        rules::FileRules::new(file, scan).run(scan, &mut violations);
    }
    protocol::check(&input.sources, &scans, &mut violations);
    metrics_keys::check(&input.sources, &scans, &input.docs, &mut violations);

    violations.sort_by(|a, b| {
        (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
    });
    let mut counts: BTreeMap<String, u64> =
        ALL_RULES.iter().map(|r| (r.to_string(), 0)).collect();
    for v in &violations {
        *counts.entry(v.rule.to_string()).or_insert(0) += 1;
    }
    LintReport {
        violations,
        counts,
        files_scanned: input.sources.len(),
    }
}

/// Locate the repo root (the directory holding `rust/src/lib.rs`),
/// starting from `start` and walking up — works from the repo root, from
/// `rust/` (CI's working-directory) and from deeper build dirs.
pub fn find_repo_root(start: &Path) -> Option<PathBuf> {
    let mut dir = Some(start.to_path_buf());
    while let Some(d) = dir {
        if d.join("rust/src/lib.rs").is_file() {
            return Some(d);
        }
        dir = d.parent().map(Path::to_path_buf);
    }
    None
}

/// Read the real tree: every `.rs` under `rust/src` (sorted traversal,
/// so reports and baselines are stable), plus README.md and ci.yml.
pub fn gather(repo_root: &Path) -> Result<LintInput, String> {
    let src_root = repo_root.join("rust/src");
    let mut paths = Vec::new();
    walk(&src_root, &mut paths).map_err(|e| format!("{}: {e}", src_root.display()))?;
    paths.sort();
    let mut sources = Vec::new();
    for p in paths {
        let text =
            std::fs::read_to_string(&p).map_err(|e| format!("{}: {e}", p.display()))?;
        sources.push(SourceFile {
            path: rel_path(repo_root, &p),
            text,
        });
    }
    let mut docs = Vec::new();
    for doc in ["README.md", ".github/workflows/ci.yml"] {
        let p = repo_root.join(doc);
        if let Ok(text) = std::fs::read_to_string(&p) {
            docs.push(SourceFile {
                path: doc.to_string(),
                text,
            });
        }
    }
    Ok(LintInput { sources, docs })
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .map(|e| e.map(|e| e.path()))
        .collect::<std::io::Result<_>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .to_string_lossy()
        .replace('\\', "/")
}

/// Render the machine-readable report (`oakestra lint --json`).
pub fn report_json(report: &LintReport, rows: &[baseline::RatchetRow]) -> String {
    let mut s = String::from("{\n  \"lint\": 1,\n  \"files_scanned\": ");
    s.push_str(&report.files_scanned.to_string());
    s.push_str(",\n  \"counts\": {");
    let counts: Vec<String> = report
        .counts
        .iter()
        .map(|(k, n)| format!("\"{k}\": {n}"))
        .collect();
    s.push_str(&counts.join(", "));
    s.push_str("},\n  \"regressed\": ");
    s.push_str(if rows.iter().any(|r| r.regressed()) {
        "true"
    } else {
        "false"
    });
    s.push_str(",\n  \"violations\": [");
    let rows_json: Vec<String> = report
        .violations
        .iter()
        .map(|v| {
            format!(
                "\n    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"message\": \"{}\"}}",
                v.rule,
                esc(&v.file),
                v.line,
                esc(&v.message)
            )
        })
        .collect();
    s.push_str(&rows_json.join(","));
    if !report.violations.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("]\n}\n");
    s
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_plane_scope() {
        let f = |p: &str| SourceFile {
            path: p.into(),
            text: String::new(),
        };
        assert!(f("rust/src/coordinator/root.rs").control_plane());
        assert!(f("rust/src/scheduler/ldp.rs").control_plane());
        assert!(f("rust/src/netmanager/table.rs").control_plane());
        assert!(f("rust/src/sim/mod.rs").control_plane());
        assert!(f("rust/src/hierarchy.rs").control_plane());
        assert!(!f("rust/src/workload.rs").control_plane());
        assert!(!f("rust/src/metrics.rs").control_plane());
    }

    #[test]
    fn analyze_counts_are_zero_filled() {
        let report = analyze(&LintInput::default());
        assert_eq!(report.counts.len(), ALL_RULES.len());
        assert!(report.counts.values().all(|n| *n == 0));
    }

    #[test]
    fn report_json_is_valid_json() {
        let input = LintInput {
            sources: vec![SourceFile {
                path: "rust/src/sim/bad.rs".into(),
                text: "use std::collections::HashMap;".into(),
            }],
            docs: vec![],
        };
        let report = analyze(&input);
        assert_eq!(report.counts[HASH_ORDER], 1);
        let rows = baseline::ratchet(&report.counts, &baseline::Baseline::zeros());
        let json = report_json(&report, &rows);
        let v = crate::json::parse(&json).expect("report must be parseable");
        assert_eq!(v.get("counts").get(HASH_ORDER).as_u64(), Some(1));
        assert_eq!(v.get("regressed").as_bool(), Some(true));
        assert_eq!(
            v.get("violations").as_array().map(|a| a.len()),
            Some(1)
        );
    }
}
