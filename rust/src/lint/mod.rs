//! `oakestra lint` — a dependency-free, token-level static analyzer over
//! the crate's own sources, enforcing the determinism and protocol
//! invariants every figure in this repo rests on (see README "Static
//! analysis"):
//!
//! - `hash-order` (D1): no `HashMap`/`HashSet` in control-plane modules
//!   unless an allow pragma justifies that iteration order never escapes.
//! - `float-order` (D2): no `partial_cmp`-based ordering; use `total_cmp`.
//! - `ambient-time` (D3): no `Instant`/`SystemTime`/thread RNG outside
//!   the sim clock and `util::Rng`.
//! - `protocol-coverage` (P1): every `OakMsg` variant handled (or
//!   wildcard-declared) in all three tier dispatchers and priced in the
//!   wire-size model.
//! - `flow-handled` (P2): every send site resolves to an `OakMsg`
//!   variant and a destination tier, and that (variant, tier) edge lands
//!   on a real dispatcher arm.
//! - `flow-dead-arm` (P3): every dispatcher arm is reachable from some
//!   send site.
//! - `reply-pairing` (P4): declared request/reply pairs answer within
//!   the handler's call closure or carry a defer pragma.
//! - `lane-isolation` (L1): each tier's dispatcher touches only its own
//!   lane's state; tiers interact exclusively through `OakMsg`.
//! - `metrics-keys` (M1): doc-cited metric keys exist in code, and every
//!   source key is documented in the generated `METRICS.md`.
//! - `pragma`: pragmas must parse, and allow/route/defer pragmas must
//!   suppress or resolve something.
//!
//! Violations are diffed against the committed `LINT_BASELINE.json`
//! ratchet: counts may only shrink. `--graph` additionally emits the
//! extracted protocol flow graph plus per-arm isolation certificates as
//! `PROTOCOL.json`, which CI diffs against the committed artifact.

pub mod baseline;
pub mod flow;
pub mod isolation;
pub mod lexer;
mod metrics_keys;
mod protocol;
mod rules;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use lexer::Scan;

pub use flow::{FLOW_DEAD_ARM, FLOW_HANDLED, REPLY_PAIRING};
pub use isolation::LANE_ISOLATION;
pub use metrics_keys::METRICS_KEYS;
pub use protocol::{enum_variants, referenced_variants, PROTOCOL};
pub use rules::{AMBIENT_TIME, FLOAT_ORDER, HASH_ORDER, PRAGMA};

/// Every rule id, in report order.
pub const ALL_RULES: [&str; 10] = [
    HASH_ORDER,
    FLOAT_ORDER,
    AMBIENT_TIME,
    PROTOCOL,
    FLOW_HANDLED,
    FLOW_DEAD_ARM,
    REPLY_PAIRING,
    LANE_ISOLATION,
    METRICS_KEYS,
    PRAGMA,
];

/// One source (or doc) file: repo-relative path with `/` separators.
#[derive(Clone, Debug)]
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

impl SourceFile {
    /// Modules where hash-iteration order can leak into scheduling,
    /// gossip or output — the D1 scope.
    pub fn control_plane(&self) -> bool {
        self.path.contains("/coordinator/")
            || self.path.contains("/scheduler/")
            || self.path.contains("/netmanager/")
            || self.path.contains("/sim/")
            || self.path.ends_with("hierarchy.rs")
    }
}

/// Everything the analyzer looks at, decoupled from the filesystem so
/// tests can lint fixture inputs.
#[derive(Clone, Debug, Default)]
pub struct LintInput {
    pub sources: Vec<SourceFile>,
    /// README.md / METRICS.md / ci.yml — scanned for metric-key
    /// references (and, for METRICS.md, documentation coverage).
    pub docs: Vec<SourceFile>,
}

#[derive(Clone, Debug)]
pub struct Violation {
    pub rule: &'static str,
    pub file: String,
    /// 1-based; 0 means the finding is file-scoped.
    pub line: u32,
    /// 1-based byte column; 0 means the finding is line- or file-scoped.
    pub col: u32,
    pub message: String,
}

#[derive(Clone, Debug, Default)]
pub struct LintReport {
    pub violations: Vec<Violation>,
    /// Per-rule totals, zero-filled over [`ALL_RULES`].
    pub counts: BTreeMap<String, u64>,
    pub files_scanned: usize,
}

/// Run every rule over an input set.
pub fn analyze(input: &LintInput) -> LintReport {
    let scans: Vec<Scan> = input.sources.iter().map(|f| lexer::scan(&f.text)).collect();
    // Allow pragmas are shared by every pass; "unused allow" is judged
    // only after all of them ran.
    let mut allows: Vec<rules::FileAllows> = scans.iter().map(rules::FileAllows::new).collect();
    let mut violations = Vec::new();
    for (i, (file, scan)) in input.sources.iter().zip(&scans).enumerate() {
        rules::FileRules::new(file).run(scan, &mut allows[i], &mut violations);
    }
    protocol::check(&input.sources, &scans, &mut violations);
    metrics_keys::check(&input.sources, &scans, &input.docs, &mut violations);
    let fa = flow::extract(&input.sources, &scans);
    flow::check(&fa, &input.sources, &mut allows, &mut violations);
    isolation::check(&input.sources, &scans, &mut allows, &mut violations);
    for (file, fa) in input.sources.iter().zip(&allows) {
        for (rule, line, col) in fa.unused() {
            violations.push(Violation {
                rule: PRAGMA,
                file: file.path.clone(),
                line,
                col,
                message: format!("allow({rule}) pragma suppresses nothing; delete it"),
            });
        }
    }

    violations.sort_by(|a, b| {
        (&a.file, a.line, a.col, a.rule).cmp(&(&b.file, b.line, b.col, b.rule))
    });
    let mut counts: BTreeMap<String, u64> =
        ALL_RULES.iter().map(|r| (r.to_string(), 0)).collect();
    for v in &violations {
        *counts.entry(v.rule.to_string()).or_insert(0) += 1;
    }
    LintReport {
        violations,
        counts,
        files_scanned: input.sources.len(),
    }
}

/// Locate the repo root (the directory holding `rust/src/lib.rs`),
/// starting from `start` and walking up — works from the repo root, from
/// `rust/` (CI's working-directory) and from deeper build dirs.
pub fn find_repo_root(start: &Path) -> Option<PathBuf> {
    let mut dir = Some(start.to_path_buf());
    while let Some(d) = dir {
        if d.join("rust/src/lib.rs").is_file() {
            return Some(d);
        }
        dir = d.parent().map(Path::to_path_buf);
    }
    None
}

/// Read the real tree: every `.rs` under `rust/src` (sorted traversal,
/// so reports and baselines are stable), plus the scanned docs.
pub fn gather(repo_root: &Path) -> Result<LintInput, String> {
    let src_root = repo_root.join("rust/src");
    let mut paths = Vec::new();
    walk(&src_root, &mut paths).map_err(|e| format!("{}: {e}", src_root.display()))?;
    paths.sort();
    let mut sources = Vec::new();
    for p in paths {
        let text =
            std::fs::read_to_string(&p).map_err(|e| format!("{}: {e}", p.display()))?;
        sources.push(SourceFile {
            path: rel_path(repo_root, &p),
            text,
        });
    }
    let mut docs = Vec::new();
    for doc in ["README.md", "METRICS.md", ".github/workflows/ci.yml"] {
        let p = repo_root.join(doc);
        if let Ok(text) = std::fs::read_to_string(&p) {
            docs.push(SourceFile {
                path: doc.to_string(),
                text,
            });
        }
    }
    Ok(LintInput { sources, docs })
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .map(|e| e.map(|e| e.path()))
        .collect::<std::io::Result<_>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .to_string_lossy()
        .replace('\\', "/")
}

/// Render the machine-readable report (`oakestra lint --json`).
pub fn report_json(report: &LintReport, rows: &[baseline::RatchetRow]) -> String {
    let mut s = String::from("{\n  \"lint\": 1,\n  \"files_scanned\": ");
    s.push_str(&report.files_scanned.to_string());
    s.push_str(",\n  \"counts\": {");
    let counts: Vec<String> = report
        .counts
        .iter()
        .map(|(k, n)| format!("\"{k}\": {n}"))
        .collect();
    s.push_str(&counts.join(", "));
    s.push_str("},\n  \"regressed\": ");
    s.push_str(if rows.iter().any(|r| r.regressed()) {
        "true"
    } else {
        "false"
    });
    s.push_str(",\n  \"violations\": [");
    let rows_json: Vec<String> = report
        .violations
        .iter()
        .map(|v| {
            format!(
                "\n    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"col\": {}, \"message\": \"{}\"}}",
                v.rule,
                esc(&v.file),
                v.line,
                v.col,
                esc(&v.message)
            )
        })
        .collect();
    s.push_str(&rows_json.join(","));
    if !report.violations.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("]\n}\n");
    s
}

/// Render the protocol flow graph plus per-arm isolation certificates
/// (`oakestra lint --graph`) — the committed, CI-diffed `PROTOCOL.json`.
///
/// Deterministic by construction: variants sorted, edges sorted by
/// (variant, from, to) with sorted `file:line` sites, arms sorted by
/// (tier, variant, line), pairs in declaration order, wildcard manifests
/// sorted per tier.
pub fn protocol_graph_json(input: &LintInput) -> String {
    let scans: Vec<Scan> = input.sources.iter().map(|f| lexer::scan(&f.text)).collect();
    let fa = flow::extract(&input.sources, &scans);
    let touches = isolation::certificates(&input.sources, &scans, &fa);

    let mut variants: Vec<String> = input
        .sources
        .iter()
        .position(|f| f.path.ends_with("sim/msg.rs"))
        .map(|i| {
            enum_variants(&scans[i], "OakMsg")
                .into_iter()
                .map(|(v, _, _)| v)
                .collect()
        })
        .unwrap_or_default();
    variants.sort();

    let mut edges: BTreeMap<(String, String, String), Vec<String>> = BTreeMap::new();
    for s in &fa.sites {
        let (Some(v), Some(to)) = (&s.variant, &s.to) else {
            continue; // unresolved sites are flow-handled findings, not edges
        };
        edges
            .entry((v.clone(), s.from.to_string(), to.clone()))
            .or_default()
            .push(format!("{}:{}", s.file, s.line));
    }

    let mut arm_rows: Vec<(&flow::Arm, &Vec<String>)> = fa.arms.iter().zip(&touches).collect();
    arm_rows.sort_by(|(a, _), (b, _)| {
        (a.tier, &a.variant, a.line).cmp(&(b.tier, &b.variant, b.line))
    });

    let quoted = |xs: &[String]| {
        xs.iter()
            .map(|x| format!("\"{x}\""))
            .collect::<Vec<_>>()
            .join(", ")
    };

    let mut s = String::from(
        "{\n  \"protocol\": 1,\n  \"tiers\": [\"root\", \"cluster\", \"worker\", \"client\"],\n  \"variants\": [",
    );
    s.push_str(&quoted(&variants));
    s.push_str("],\n  \"edges\": [");
    let edge_rows: Vec<String> = edges
        .iter()
        .map(|((v, from, to), sites)| {
            let mut sites = sites.clone();
            sites.sort();
            format!(
                "\n    {{\"variant\": \"{v}\", \"from\": \"{from}\", \"to\": \"{to}\", \"sites\": [{}]}}",
                quoted(&sites)
            )
        })
        .collect();
    s.push_str(&edge_rows.join(","));
    if !edge_rows.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("],\n  \"arms\": [");
    let arm_json: Vec<String> = arm_rows
        .iter()
        .map(|(a, touches)| {
            format!(
                "\n    {{\"tier\": \"{}\", \"variant\": \"{}\", \"line\": {}, \"replies\": [{}], \"touches\": [{}]}}",
                a.tier,
                a.variant,
                a.line,
                quoted(&a.replies),
                quoted(touches)
            )
        })
        .collect();
    s.push_str(&arm_json.join(","));
    if !arm_json.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("],\n  \"pairs\": [");
    let pair_rows: Vec<String> = flow::pair_statuses(&fa)
        .iter()
        .map(|(req, reply, tier, status)| {
            format!(
                "\n    {{\"request\": \"{req}\", \"reply\": \"{reply}\", \"tier\": \"{tier}\", \"status\": \"{status}\"}}"
            )
        })
        .collect();
    s.push_str(&pair_rows.join(","));
    if !pair_rows.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("],\n  \"wildcards\": {");
    let wc_rows: Vec<String> = fa
        .wildcards
        .iter()
        .map(|(tier, vs)| {
            let mut vs = vs.clone();
            vs.sort();
            format!("\n    \"{tier}\": [{}]", quoted(&vs))
        })
        .collect();
    s.push_str(&wc_rows.join(","));
    if !wc_rows.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("}\n}\n");
    s
}

/// Render `METRICS.md` from the source registry
/// (`oakestra lint --metrics-doc`).
pub fn metrics_doc_md(input: &LintInput) -> String {
    let scans: Vec<Scan> = input.sources.iter().map(|f| lexer::scan(&f.text)).collect();
    metrics_keys::metrics_doc(&input.sources, &scans)
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_plane_scope() {
        let f = |p: &str| SourceFile {
            path: p.into(),
            text: String::new(),
        };
        assert!(f("rust/src/coordinator/root.rs").control_plane());
        assert!(f("rust/src/scheduler/ldp.rs").control_plane());
        assert!(f("rust/src/netmanager/table.rs").control_plane());
        assert!(f("rust/src/sim/mod.rs").control_plane());
        assert!(f("rust/src/hierarchy.rs").control_plane());
        assert!(!f("rust/src/workload.rs").control_plane());
        assert!(!f("rust/src/metrics.rs").control_plane());
    }

    #[test]
    fn analyze_counts_are_zero_filled() {
        let report = analyze(&LintInput::default());
        assert_eq!(report.counts.len(), ALL_RULES.len());
        assert!(report.counts.values().all(|n| *n == 0));
    }

    #[test]
    fn report_json_is_valid_json() {
        let input = LintInput {
            sources: vec![SourceFile {
                path: "rust/src/sim/bad.rs".into(),
                text: "use std::collections::HashMap;".into(),
            }],
            docs: vec![],
        };
        let report = analyze(&input);
        assert_eq!(report.counts[HASH_ORDER], 1);
        let rows = baseline::ratchet(&report.counts, &baseline::Baseline::zeros());
        let json = report_json(&report, &rows);
        let v = crate::json::parse(&json).expect("report must be parseable");
        assert_eq!(v.get("counts").get(HASH_ORDER).as_u64(), Some(1));
        assert_eq!(v.get("regressed").as_bool(), Some(true));
        assert_eq!(
            v.get("violations").as_array().map(|a| a.len()),
            Some(1)
        );
        let row = &v.get("violations").as_array().unwrap()[0];
        assert_eq!(row.get("line").as_u64(), Some(1));
        assert_eq!(row.get("col").as_u64(), Some(23));
    }

    #[test]
    fn empty_graph_is_valid_json() {
        let json = protocol_graph_json(&LintInput::default());
        let v = crate::json::parse(&json).expect("graph must be parseable");
        assert_eq!(v.get("protocol").as_u64(), Some(1));
        assert_eq!(v.get("edges").as_array().map(|a| a.len()), Some(0));
    }
}
