//! Minimal Rust token scanner for the determinism linter.
//!
//! Deliberately not a real parser: the lint rules only need identifier
//! and punctuation streams with line/column positions, string literals
//! (for the metrics-key registry), pragma comments, and a conservative
//! marking of `#[cfg(test)] mod … { … }` regions. Comments, string/char
//! literals and raw strings are handled so that rule keywords inside
//! them can never fire.

/// One lexed token.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Tok {
    Ident(String),
    Punct(char),
    /// A string literal's *contents* (escapes left as written).
    Str(String),
}

/// Token plus its 1-based source line and (byte) column.
#[derive(Clone, Debug)]
pub struct Token {
    pub line: u32,
    pub col: u32,
    pub tok: Tok,
}

/// A pragma comment recognized by the linter (see README for syntax).
/// `col` is the column of the `//` that opens the comment.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Pragma {
    /// Suppresses `rule` violations on the lines of its coverage window
    /// (see [`Scan::allow_window`]).
    Allow {
        line: u32,
        col: u32,
        rule: String,
        why: String,
    },
    /// Declares `OakMsg` variants a dispatch loop leaves to its `_` arm.
    Wildcard {
        line: u32,
        col: u32,
        variants: Vec<String>,
    },
    /// Declares the destination tier of a send whose addressee the flow
    /// analyzer cannot infer (dynamic actor expression).
    Route {
        line: u32,
        col: u32,
        tier: String,
        why: String,
    },
    /// Declares that a handler intentionally defers (or omits) the reply
    /// `variant` required by a request/reply pair on some path.
    Defer {
        line: u32,
        col: u32,
        variant: String,
        why: String,
    },
    /// A comment that names the linter but does not parse as a pragma.
    Malformed { line: u32, col: u32, text: String },
}

impl Pragma {
    pub fn line(&self) -> u32 {
        match self {
            Pragma::Allow { line, .. }
            | Pragma::Wildcard { line, .. }
            | Pragma::Route { line, .. }
            | Pragma::Defer { line, .. }
            | Pragma::Malformed { line, .. } => *line,
        }
    }

    pub fn col(&self) -> u32 {
        match self {
            Pragma::Allow { col, .. }
            | Pragma::Wildcard { col, .. }
            | Pragma::Route { col, .. }
            | Pragma::Defer { col, .. }
            | Pragma::Malformed { col, .. } => *col,
        }
    }
}

/// Destination tiers a `route(...)` pragma may name. `client` marks
/// traffic that terminates outside the three dispatchers (API clients,
/// bench drivers) — the flow graph records it but requires no arm.
pub const ROUTE_TIERS: [&str; 4] = ["root", "cluster", "worker", "client"];

/// Scan result for one source file.
#[derive(Clone, Debug, Default)]
pub struct Scan {
    pub tokens: Vec<Token>,
    pub pragmas: Vec<Pragma>,
    /// `in_test[i]` — tokens[i] lies inside a `#[cfg(test)] mod` region.
    pub in_test: Vec<bool>,
}

impl Scan {
    /// First line strictly after `line` that carries any token.
    pub fn next_code_line(&self, line: u32) -> Option<u32> {
        self.tokens
            .iter()
            .map(|t| t.line)
            .filter(|l| *l > line)
            .min()
    }

    /// The lines a pragma on `line` covers: its own line plus the next
    /// code line, looking *through* attribute lines (`#[...]` / `#![...]`)
    /// so a pragma above a derive still reaches the item it annotates —
    /// the attribute lines themselves are covered too. A pragma on the
    /// last line of a file covers exactly that line.
    pub fn allow_window(&self, line: u32) -> Vec<u32> {
        let mut covered = vec![line];
        // First token index past `line` (tokens are in source order).
        let mut idx = match self.tokens.iter().position(|t| t.line > line) {
            Some(i) => i,
            None => return covered,
        };
        // Skip attribute groups: `#` `[` … `]` (and inner `#` `!` `[`).
        loop {
            let mut j = idx;
            if !is_punct(&self.tokens, j, '#') {
                break;
            }
            j += 1;
            if is_punct(&self.tokens, j, '!') {
                j += 1;
            }
            if !is_punct(&self.tokens, j, '[') {
                break;
            }
            let end = skip_attr(&self.tokens, j);
            for t in &self.tokens[idx..end.min(self.tokens.len())] {
                if !covered.contains(&t.line) {
                    covered.push(t.line);
                }
            }
            idx = end;
            if idx >= self.tokens.len() {
                return covered;
            }
        }
        if let Some(t) = self.tokens.get(idx) {
            if !covered.contains(&t.line) {
                covered.push(t.line);
            }
        }
        covered
    }
}

pub fn scan(src: &str) -> Scan {
    let b = src.as_bytes();
    let mut tokens = Vec::new();
    let mut pragmas = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    // Byte offset where the current line starts (columns are 1-based).
    let mut line_start = 0usize;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
                line_start = i;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                let col = (i - line_start + 1) as u32;
                let start = i + 2;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                let text = &src[start.min(i)..i];
                parse_pragma(line, col, text, &mut pragmas);
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                // Nested block comment (pragmas are line-comment only).
                let mut depth = 1;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                        line_start = i;
                    } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            b'"' => {
                let tok_line = line;
                let tok_col = (i - line_start + 1) as u32;
                i += 1;
                let start = i;
                while i < b.len() && b[i] != b'"' {
                    if b[i] == b'\\' {
                        i += 1; // skip escaped char (incl. \")
                    } else if b[i] == b'\n' {
                        line += 1;
                        line_start = i + 1;
                    }
                    i += 1;
                }
                let s = src[start..i.min(b.len())].to_string();
                i = (i + 1).min(b.len());
                tokens.push(Token {
                    line: tok_line,
                    col: tok_col,
                    tok: Tok::Str(s),
                });
            }
            b'r' | b'b' if is_raw_string_start(b, i) => {
                let tok_line = line;
                let tok_col = (i - line_start + 1) as u32;
                // Skip r/br prefix.
                i += 1;
                if b[i] == b'r' {
                    i += 1;
                }
                let mut hashes = 0;
                while i < b.len() && b[i] == b'#' {
                    hashes += 1;
                    i += 1;
                }
                i += 1; // opening quote
                let start = i;
                let mut end = b.len();
                while i < b.len() {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                        line_start = i;
                        continue;
                    }
                    if b[i] == b'"' && closing_hashes(b, i + 1) >= hashes {
                        end = i;
                        i += 1 + hashes;
                        break;
                    }
                    i += 1;
                }
                tokens.push(Token {
                    line: tok_line,
                    col: tok_col,
                    tok: Tok::Str(src[start..end.min(b.len())].to_string()),
                });
            }
            b'\'' => {
                // Char literal or lifetime; neither produces a token.
                if i + 1 < b.len() && b[i + 1] == b'\\' {
                    // Escaped char literal: skip to closing quote.
                    i += 2;
                    while i < b.len() && b[i] != b'\'' {
                        i += 1;
                    }
                    i = (i + 1).min(b.len());
                } else if i + 2 < b.len() && b[i + 2] == b'\'' {
                    i += 3; // plain char literal 'x'
                } else {
                    // Lifetime: skip the quote; the name lexes as an ident.
                    i += 1;
                }
            }
            _ if c == b'_' || c.is_ascii_alphabetic() => {
                let tok_line = line;
                let tok_col = (i - line_start + 1) as u32;
                let start = i;
                while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                    i += 1;
                }
                tokens.push(Token {
                    line: tok_line,
                    col: tok_col,
                    tok: Tok::Ident(src[start..i].to_string()),
                });
            }
            _ if c.is_ascii_digit() => {
                // Numbers produce no token; consume conservatively so
                // `0..n` keeps its dots and `1.0f64` is swallowed whole.
                i += 1;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                if i + 1 < b.len() && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                    i += 1;
                    while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                        i += 1;
                    }
                }
            }
            _ => {
                tokens.push(Token {
                    line,
                    col: (i - line_start + 1) as u32,
                    tok: Tok::Punct(c as char),
                });
                i += 1;
            }
        }
    }
    let in_test = mark_test_regions(&tokens);
    Scan {
        tokens,
        pragmas,
        in_test,
    }
}

fn is_raw_string_start(b: &[u8], i: usize) -> bool {
    // r"..."  r#"..."#  br"..."  br#"..."#
    let mut j = i + 1;
    if b[i] == b'b' {
        if j >= b.len() || b[j] != b'r' {
            return false;
        }
        j += 1;
    }
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    j < b.len() && b[j] == b'"'
}

fn closing_hashes(b: &[u8], mut i: usize) -> usize {
    let mut n = 0;
    while i < b.len() && b[i] == b'#' {
        n += 1;
        i += 1;
    }
    n
}

fn parse_pragma(line: u32, col: u32, comment: &str, out: &mut Vec<Pragma>) {
    let Some(pos) = comment.find("lint:") else {
        return;
    };
    let body = comment[pos + 5..].trim();
    if let Some(rest) = body.strip_prefix("allow(") {
        if let Some(end) = rest.find(')') {
            if let Some((rule, why)) = rest[..end].split_once(',') {
                let (rule, why) = (rule.trim(), why.trim());
                if !rule.is_empty() && !why.is_empty() {
                    out.push(Pragma::Allow {
                        line,
                        col,
                        rule: rule.to_string(),
                        why: why.to_string(),
                    });
                    return;
                }
            }
        }
    } else if let Some(rest) = body.strip_prefix("wildcard(") {
        if let Some(end) = rest.find(')') {
            if let Some((enum_name, list)) = rest[..end].split_once(':') {
                let variants: Vec<String> = list
                    .split(',')
                    .map(|v| v.trim().to_string())
                    .filter(|v| !v.is_empty())
                    .collect();
                if enum_name.trim() == "OakMsg" && !variants.is_empty() {
                    out.push(Pragma::Wildcard {
                        line,
                        col,
                        variants,
                    });
                    return;
                }
            }
        }
    } else if let Some(rest) = body.strip_prefix("route(") {
        if let Some(end) = rest.find(')') {
            if let Some((tier, why)) = rest[..end].split_once(',') {
                let (tier, why) = (tier.trim(), why.trim());
                if ROUTE_TIERS.contains(&tier) && !why.is_empty() {
                    out.push(Pragma::Route {
                        line,
                        col,
                        tier: tier.to_string(),
                        why: why.to_string(),
                    });
                    return;
                }
            }
        }
    } else if let Some(rest) = body.strip_prefix("defer(") {
        if let Some(end) = rest.find(')') {
            if let Some((variant, why)) = rest[..end].split_once(',') {
                let (variant, why) = (variant.trim(), why.trim());
                let valid = !variant.is_empty()
                    && variant.chars().all(|c| c.is_ascii_alphanumeric())
                    && variant.starts_with(|c: char| c.is_ascii_uppercase());
                if valid && !why.is_empty() {
                    out.push(Pragma::Defer {
                        line,
                        col,
                        variant: variant.to_string(),
                        why: why.to_string(),
                    });
                    return;
                }
            }
        }
    }
    out.push(Pragma::Malformed {
        line,
        col,
        text: body.to_string(),
    });
}

/// Mark every token inside a `#[cfg(test)] … mod name { … }` item.
fn mark_test_regions(tokens: &[Token]) -> Vec<bool> {
    let mut marked = vec![false; tokens.len()];
    let mut i = 0;
    while i < tokens.len() {
        if is_punct(tokens, i, '#') && is_cfg_test_attr(tokens, i + 1) {
            // Skip over this and any further attributes to the item.
            let mut j = skip_attr(tokens, i + 1);
            while is_punct(tokens, j, '#') {
                j = skip_attr(tokens, j + 1);
            }
            if is_ident(tokens, j, "mod")
                && matches!(tokens.get(j + 1).map(|t| &t.tok), Some(Tok::Ident(_)))
                && is_punct(tokens, j + 2, '{')
            {
                let mut depth = 1;
                let mut k = j + 3;
                while k < tokens.len() && depth > 0 {
                    match tokens[k].tok {
                        Tok::Punct('{') => depth += 1,
                        Tok::Punct('}') => depth -= 1,
                        _ => {}
                    }
                    k += 1;
                }
                for slot in marked.iter_mut().take(k).skip(i) {
                    *slot = true;
                }
                i = k;
                continue;
            }
        }
        i += 1;
    }
    marked
}

pub(crate) fn is_punct(tokens: &[Token], i: usize, c: char) -> bool {
    matches!(tokens.get(i).map(|t| &t.tok), Some(Tok::Punct(p)) if *p == c)
}

pub(crate) fn is_ident(tokens: &[Token], i: usize, name: &str) -> bool {
    matches!(tokens.get(i).map(|t| &t.tok), Some(Tok::Ident(id)) if id == name)
}

/// `tokens[i]` should be the `[` of an attribute; returns the index just
/// past its matching `]` (or `i` if it isn't an attribute opener).
pub(crate) fn skip_attr(tokens: &[Token], i: usize) -> usize {
    if !is_punct(tokens, i, '[') {
        return i;
    }
    let mut depth = 1;
    let mut j = i + 1;
    while j < tokens.len() && depth > 0 {
        match tokens[j].tok {
            Tok::Punct('[') => depth += 1,
            Tok::Punct(']') => depth -= 1,
            _ => {}
        }
        j += 1;
    }
    j
}

fn is_cfg_test_attr(tokens: &[Token], i: usize) -> bool {
    is_punct(tokens, i, '[')
        && is_ident(tokens, i + 1, "cfg")
        && is_punct(tokens, i + 2, '(')
        && is_ident(tokens, i + 3, "test")
        && is_punct(tokens, i + 4, ')')
        && is_punct(tokens, i + 5, ']')
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(scan: &Scan) -> Vec<&str> {
        scan.tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Ident(s) => Some(s.as_str()),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_keywords() {
        let s = scan("// HashMap here\nlet x = \"HashMap\"; /* HashMap */ y");
        assert_eq!(idents(&s), vec!["let", "x", "y"]);
        assert!(s
            .tokens
            .iter()
            .any(|t| matches!(&t.tok, Tok::Str(v) if v == "HashMap")));
    }

    #[test]
    fn raw_strings_and_chars_are_opaque() {
        let s = scan("let a = r#\"Instant \"quoted\" inside\"#; let c = '\"'; b");
        assert_eq!(idents(&s), vec!["let", "a", "let", "c", "b"]);
    }

    #[test]
    fn numbers_keep_range_dots() {
        let s = scan("for i in 0..n { x = 1.5e3; }");
        let dots = s
            .tokens
            .iter()
            .filter(|t| matches!(t.tok, Tok::Punct('.')))
            .count();
        assert_eq!(dots, 2, "both range dots survive");
        assert_eq!(idents(&s), vec!["for", "i", "in", "n", "x"]);
    }

    #[test]
    fn line_numbers_track_newlines() {
        let s = scan("a\nb\n\nc");
        let lines: Vec<u32> = s.tokens.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
        assert_eq!(s.next_code_line(1), Some(2));
        assert_eq!(s.next_code_line(2), Some(4));
        assert_eq!(s.next_code_line(4), None);
    }

    #[test]
    fn columns_are_one_based_bytes() {
        let s = scan("ab cd\n  ef = \"g\"");
        let pos: Vec<(u32, u32)> = s.tokens.iter().map(|t| (t.line, t.col)).collect();
        assert_eq!(pos, vec![(1, 1), (1, 4), (2, 3), (2, 6), (2, 8)]);
    }

    #[test]
    fn columns_reset_after_multiline_strings() {
        let s = scan("let a = \"x\ny\";\nb");
        let b = s
            .tokens
            .iter()
            .find(|t| matches!(&t.tok, Tok::Ident(n) if n == "b"))
            .unwrap();
        assert_eq!((b.line, b.col), (3, 1));
    }

    #[test]
    fn allow_pragma_parses() {
        let s = scan("// lint: allow(hash-order, lookup only)\nlet m = 1;");
        assert_eq!(
            s.pragmas,
            vec![Pragma::Allow {
                line: 1,
                col: 1,
                rule: "hash-order".into(),
                why: "lookup only".into()
            }]
        );
    }

    #[test]
    fn wildcard_pragma_parses() {
        let s = scan("// lint: wildcard(OakMsg: Ping, Pong)\n_ => {}");
        assert_eq!(
            s.pragmas,
            vec![Pragma::Wildcard {
                line: 1,
                col: 1,
                variants: vec!["Ping".into(), "Pong".into()]
            }]
        );
    }

    #[test]
    fn route_and_defer_pragmas_parse() {
        let s = scan("// lint: route(client, API reply to the caller)\nx");
        assert_eq!(
            s.pragmas,
            vec![Pragma::Route {
                line: 1,
                col: 1,
                tier: "client".into(),
                why: "API reply to the caller".into()
            }]
        );
        let s = scan("  // lint: defer(ApiReturn, replied from respond())\nx");
        assert_eq!(
            s.pragmas,
            vec![Pragma::Defer {
                line: 1,
                col: 3,
                variant: "ApiReturn".into(),
                why: "replied from respond()".into()
            }]
        );
    }

    #[test]
    fn bad_pragmas_are_malformed() {
        for src in [
            "// lint: allow(hash-order)",         // no why
            "// lint: allow(, reason)",           // no rule
            "// lint: wildcard(Other: A)",        // wrong enum
            "// lint: wildcard(OakMsg:)",         // empty list
            "// lint: nonsense",                  // unknown verb
            "// lint: route(nowhere, why)",       // unknown tier
            "// lint: route(root)",               // no why
            "// lint: defer(lowercase, why)",     // not a variant name
            "// lint: defer(ApiReturn)",          // no why
        ] {
            let s = scan(src);
            assert!(
                matches!(s.pragmas.as_slice(), [Pragma::Malformed { .. }]),
                "{src} should be malformed, got {:?}",
                s.pragmas
            );
        }
    }

    #[test]
    fn allow_window_covers_pragma_and_next_code_line() {
        let s = scan("// lint: allow(hash-order, x)\nuse std::collections::HashMap;\nstruct S;");
        let w = s.allow_window(1);
        assert!(w.contains(&1) && w.contains(&2) && !w.contains(&3));
    }

    #[test]
    fn allow_window_sees_through_attribute_lines() {
        // The pragma's target is the item *under* the attributes; both
        // the attribute lines and the item line are covered.
        let src = "// lint: allow(hash-order, keyed by opaque id)\n\
                   #[derive(Clone, Debug)]\n\
                   #[allow(dead_code)]\n\
                   pub struct S { m: HashMap<u32, u32> }\n\
                   fn after() {}\n";
        let s = scan(src);
        let w = s.allow_window(1);
        assert!(w.contains(&1), "pragma line");
        assert!(w.contains(&2) && w.contains(&3), "attribute lines");
        assert!(w.contains(&4), "the annotated item itself");
        assert!(!w.contains(&5), "window must stop at the item");
    }

    #[test]
    fn allow_window_on_last_line_covers_only_itself() {
        // Trailing pragma with and without a final newline: the window
        // is exactly the pragma's own line, never line+1 of a next file.
        for src in [
            "fn f() {}\n// lint: allow(hash-order, trailing)",
            "fn f() {}\n// lint: allow(hash-order, trailing)\n",
        ] {
            let s = scan(src);
            assert_eq!(s.pragmas.len(), 1);
            assert_eq!(s.allow_window(2), vec![2], "{src:?}");
        }
        // Pragma above the last code line still covers both.
        let s = scan("// lint: allow(hash-order, x)\nuse std::collections::HashMap;");
        assert_eq!(s.allow_window(1), vec![1, 2]);
    }

    #[test]
    fn cfg_test_mod_is_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n  fn t() { HashMap }\n}\nfn after() {}";
        let s = scan(src);
        for (i, t) in s.tokens.iter().enumerate() {
            let inside = matches!(&t.tok, Tok::Ident(n) if n == "t" || n == "HashMap" || n == "tests" || n == "mod");
            if inside {
                assert!(s.in_test[i], "{:?} should be in test region", t.tok);
            }
            if matches!(&t.tok, Tok::Ident(n) if n == "live" || n == "after") {
                assert!(!s.in_test[i], "{:?} should be live code", t.tok);
            }
        }
    }
}
