//! Minimal Rust token scanner for the determinism linter.
//!
//! Deliberately not a real parser: the lint rules only need identifier
//! and punctuation streams with line numbers, string literals (for the
//! metrics-key registry), pragma comments, and a conservative marking of
//! `#[cfg(test)] mod … { … }` regions. Comments, string/char literals
//! and raw strings are handled so that rule keywords inside them can
//! never fire.

/// One lexed token.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Tok {
    Ident(String),
    Punct(char),
    /// A string literal's *contents* (escapes left as written).
    Str(String),
}

/// Token plus its 1-based source line.
#[derive(Clone, Debug)]
pub struct Token {
    pub line: u32,
    pub tok: Tok,
}

/// A pragma comment recognized by the linter (see README for syntax).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Pragma {
    /// Suppresses `rule` violations on this line and the next code line.
    Allow { line: u32, rule: String, why: String },
    /// Declares `OakMsg` variants a dispatch loop leaves to its `_` arm.
    Wildcard { line: u32, variants: Vec<String> },
    /// A comment that names the linter but does not parse as a pragma.
    Malformed { line: u32, text: String },
}

impl Pragma {
    pub fn line(&self) -> u32 {
        match self {
            Pragma::Allow { line, .. }
            | Pragma::Wildcard { line, .. }
            | Pragma::Malformed { line, .. } => *line,
        }
    }
}

/// Scan result for one source file.
#[derive(Clone, Debug, Default)]
pub struct Scan {
    pub tokens: Vec<Token>,
    pub pragmas: Vec<Pragma>,
    /// `in_test[i]` — tokens[i] lies inside a `#[cfg(test)] mod` region.
    pub in_test: Vec<bool>,
}

impl Scan {
    /// First line strictly after `line` that carries any token (the
    /// second line an `allow` pragma covers).
    pub fn next_code_line(&self, line: u32) -> Option<u32> {
        self.tokens
            .iter()
            .map(|t| t.line)
            .filter(|l| *l > line)
            .min()
    }
}

pub fn scan(src: &str) -> Scan {
    let b = src.as_bytes();
    let mut tokens = Vec::new();
    let mut pragmas = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                let start = i + 2;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                let text = &src[start.min(i)..i];
                parse_pragma(line, text, &mut pragmas);
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                // Nested block comment (pragmas are line-comment only).
                let mut depth = 1;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            b'"' => {
                let tok_line = line;
                i += 1;
                let start = i;
                while i < b.len() && b[i] != b'"' {
                    if b[i] == b'\\' {
                        i += 1; // skip escaped char (incl. \")
                    } else if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
                let s = src[start..i.min(b.len())].to_string();
                i = (i + 1).min(b.len());
                tokens.push(Token {
                    line: tok_line,
                    tok: Tok::Str(s),
                });
            }
            b'r' | b'b'
                if is_raw_string_start(b, i) =>
            {
                let tok_line = line;
                // Skip r/br prefix.
                i += 1;
                if b[i] == b'r' {
                    i += 1;
                }
                let mut hashes = 0;
                while i < b.len() && b[i] == b'#' {
                    hashes += 1;
                    i += 1;
                }
                i += 1; // opening quote
                let start = i;
                let mut end = b.len();
                while i < b.len() {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                        continue;
                    }
                    if b[i] == b'"' && closing_hashes(b, i + 1) >= hashes {
                        end = i;
                        i += 1 + hashes;
                        break;
                    }
                    i += 1;
                }
                tokens.push(Token {
                    line: tok_line,
                    tok: Tok::Str(src[start..end.min(b.len())].to_string()),
                });
            }
            b'\'' => {
                // Char literal or lifetime; neither produces a token.
                if i + 1 < b.len() && b[i + 1] == b'\\' {
                    // Escaped char literal: skip to closing quote.
                    i += 2;
                    while i < b.len() && b[i] != b'\'' {
                        i += 1;
                    }
                    i = (i + 1).min(b.len());
                } else if i + 2 < b.len() && b[i + 2] == b'\'' {
                    i += 3; // plain char literal 'x'
                } else {
                    // Lifetime: skip the quote; the name lexes as an ident.
                    i += 1;
                }
            }
            _ if c == b'_' || c.is_ascii_alphabetic() => {
                let tok_line = line;
                let start = i;
                while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                    i += 1;
                }
                tokens.push(Token {
                    line: tok_line,
                    tok: Tok::Ident(src[start..i].to_string()),
                });
            }
            _ if c.is_ascii_digit() => {
                // Numbers produce no token; consume conservatively so
                // `0..n` keeps its dots and `1.0f64` is swallowed whole.
                i += 1;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                if i + 1 < b.len() && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                    i += 1;
                    while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                        i += 1;
                    }
                }
            }
            _ => {
                tokens.push(Token {
                    line,
                    tok: Tok::Punct(c as char),
                });
                i += 1;
            }
        }
    }
    let in_test = mark_test_regions(&tokens);
    Scan {
        tokens,
        pragmas,
        in_test,
    }
}

fn is_raw_string_start(b: &[u8], i: usize) -> bool {
    // r"..."  r#"..."#  br"..."  br#"..."#
    let mut j = i + 1;
    if b[i] == b'b' {
        if j >= b.len() || b[j] != b'r' {
            return false;
        }
        j += 1;
    }
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    j < b.len() && b[j] == b'"'
}

fn closing_hashes(b: &[u8], mut i: usize) -> usize {
    let mut n = 0;
    while i < b.len() && b[i] == b'#' {
        n += 1;
        i += 1;
    }
    n
}

fn parse_pragma(line: u32, comment: &str, out: &mut Vec<Pragma>) {
    let Some(pos) = comment.find("lint:") else {
        return;
    };
    let body = comment[pos + 5..].trim();
    if let Some(rest) = body.strip_prefix("allow(") {
        if let Some(end) = rest.find(')') {
            if let Some((rule, why)) = rest[..end].split_once(',') {
                let (rule, why) = (rule.trim(), why.trim());
                if !rule.is_empty() && !why.is_empty() {
                    out.push(Pragma::Allow {
                        line,
                        rule: rule.to_string(),
                        why: why.to_string(),
                    });
                    return;
                }
            }
        }
    } else if let Some(rest) = body.strip_prefix("wildcard(") {
        if let Some(end) = rest.find(')') {
            if let Some((enum_name, list)) = rest[..end].split_once(':') {
                let variants: Vec<String> = list
                    .split(',')
                    .map(|v| v.trim().to_string())
                    .filter(|v| !v.is_empty())
                    .collect();
                if enum_name.trim() == "OakMsg" && !variants.is_empty() {
                    out.push(Pragma::Wildcard { line, variants });
                    return;
                }
            }
        }
    }
    out.push(Pragma::Malformed {
        line,
        text: body.to_string(),
    });
}

/// Mark every token inside a `#[cfg(test)] … mod name { … }` item.
fn mark_test_regions(tokens: &[Token]) -> Vec<bool> {
    let mut marked = vec![false; tokens.len()];
    let mut i = 0;
    while i < tokens.len() {
        if is_punct(tokens, i, '#') && is_cfg_test_attr(tokens, i + 1) {
            // Skip over this and any further attributes to the item.
            let mut j = skip_attr(tokens, i + 1);
            while is_punct(tokens, j, '#') {
                j = skip_attr(tokens, j + 1);
            }
            if is_ident(tokens, j, "mod")
                && matches!(tokens.get(j + 1).map(|t| &t.tok), Some(Tok::Ident(_)))
                && is_punct(tokens, j + 2, '{')
            {
                let mut depth = 1;
                let mut k = j + 3;
                while k < tokens.len() && depth > 0 {
                    match tokens[k].tok {
                        Tok::Punct('{') => depth += 1,
                        Tok::Punct('}') => depth -= 1,
                        _ => {}
                    }
                    k += 1;
                }
                for slot in marked.iter_mut().take(k).skip(i) {
                    *slot = true;
                }
                i = k;
                continue;
            }
        }
        i += 1;
    }
    marked
}

fn is_punct(tokens: &[Token], i: usize, c: char) -> bool {
    matches!(tokens.get(i).map(|t| &t.tok), Some(Tok::Punct(p)) if *p == c)
}

fn is_ident(tokens: &[Token], i: usize, name: &str) -> bool {
    matches!(tokens.get(i).map(|t| &t.tok), Some(Tok::Ident(id)) if id == name)
}

/// `tokens[i]` should be the `[` of an attribute; returns the index just
/// past its matching `]` (or `i` if it isn't an attribute opener).
fn skip_attr(tokens: &[Token], i: usize) -> usize {
    if !is_punct(tokens, i, '[') {
        return i;
    }
    let mut depth = 1;
    let mut j = i + 1;
    while j < tokens.len() && depth > 0 {
        match tokens[j].tok {
            Tok::Punct('[') => depth += 1,
            Tok::Punct(']') => depth -= 1,
            _ => {}
        }
        j += 1;
    }
    j
}

fn is_cfg_test_attr(tokens: &[Token], i: usize) -> bool {
    is_punct(tokens, i, '[')
        && is_ident(tokens, i + 1, "cfg")
        && is_punct(tokens, i + 2, '(')
        && is_ident(tokens, i + 3, "test")
        && is_punct(tokens, i + 4, ')')
        && is_punct(tokens, i + 5, ']')
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(scan: &Scan) -> Vec<&str> {
        scan.tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Ident(s) => Some(s.as_str()),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_keywords() {
        let s = scan("// HashMap here\nlet x = \"HashMap\"; /* HashMap */ y");
        assert_eq!(idents(&s), vec!["let", "x", "y"]);
        assert!(s
            .tokens
            .iter()
            .any(|t| matches!(&t.tok, Tok::Str(v) if v == "HashMap")));
    }

    #[test]
    fn raw_strings_and_chars_are_opaque() {
        let s = scan("let a = r#\"Instant \"quoted\" inside\"#; let c = '\"'; b");
        assert_eq!(idents(&s), vec!["let", "a", "let", "c", "b"]);
    }

    #[test]
    fn numbers_keep_range_dots() {
        let s = scan("for i in 0..n { x = 1.5e3; }");
        let dots = s
            .tokens
            .iter()
            .filter(|t| matches!(t.tok, Tok::Punct('.')))
            .count();
        assert_eq!(dots, 2, "both range dots survive");
        assert_eq!(idents(&s), vec!["for", "i", "in", "n", "x"]);
    }

    #[test]
    fn line_numbers_track_newlines() {
        let s = scan("a\nb\n\nc");
        let lines: Vec<u32> = s.tokens.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
        assert_eq!(s.next_code_line(1), Some(2));
        assert_eq!(s.next_code_line(2), Some(4));
        assert_eq!(s.next_code_line(4), None);
    }

    #[test]
    fn allow_pragma_parses() {
        let s = scan("// lint: allow(hash-order, lookup only)\nlet m = 1;");
        assert_eq!(
            s.pragmas,
            vec![Pragma::Allow {
                line: 1,
                rule: "hash-order".into(),
                why: "lookup only".into()
            }]
        );
    }

    #[test]
    fn wildcard_pragma_parses() {
        let s = scan("// lint: wildcard(OakMsg: Ping, Pong)\n_ => {}");
        assert_eq!(
            s.pragmas,
            vec![Pragma::Wildcard {
                line: 1,
                variants: vec!["Ping".into(), "Pong".into()]
            }]
        );
    }

    #[test]
    fn bad_pragmas_are_malformed() {
        for src in [
            "// lint: allow(hash-order)",     // no why
            "// lint: allow(, reason)",       // no rule
            "// lint: wildcard(Other: A)",    // wrong enum
            "// lint: wildcard(OakMsg:)",     // empty list
            "// lint: nonsense",              // unknown verb
        ] {
            let s = scan(src);
            assert!(
                matches!(s.pragmas.as_slice(), [Pragma::Malformed { .. }]),
                "{src} should be malformed, got {:?}",
                s.pragmas
            );
        }
    }

    #[test]
    fn cfg_test_mod_is_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n  fn t() { HashMap }\n}\nfn after() {}";
        let s = scan(src);
        for (i, t) in s.tokens.iter().enumerate() {
            let inside = matches!(&t.tok, Tok::Ident(n) if n == "t" || n == "HashMap" || n == "tests" || n == "mod");
            if inside {
                assert!(s.in_test[i], "{:?} should be in test region", t.tok);
            }
            if matches!(&t.tok, Tok::Ident(n) if n == "live" || n == "after") {
                assert!(!s.in_test[i], "{:?} should be live code", t.tok);
            }
        }
    }
}
