//! Baseline ratchet: violation counts are diffed against the committed
//! `LINT_BASELINE.json`; a rule's count may shrink (then the baseline
//! should be re-tightened with `--update-baseline`) but never grow.

use std::collections::BTreeMap;
use std::path::Path;

/// Per-rule allowed violation counts.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Baseline {
    pub rules: BTreeMap<String, u64>,
}

impl Baseline {
    /// The empty baseline: zero tolerated violations for every rule.
    pub fn zeros() -> Baseline {
        Baseline {
            rules: super::ALL_RULES
                .iter()
                .map(|r| (r.to_string(), 0))
                .collect(),
        }
    }

    pub fn parse(text: &str) -> Result<Baseline, String> {
        let v = crate::json::parse(text).map_err(|e| e.to_string())?;
        let rules = v
            .get("rules")
            .as_object()
            .ok_or("baseline missing `rules` object")?;
        let mut out = BTreeMap::new();
        for (k, count) in rules {
            let n = count
                .as_f64()
                .ok_or_else(|| format!("rule `{k}` count is not a number"))?;
            out.push_str_checked(k, n)?;
        }
        Ok(Baseline { rules: out })
    }

    /// Missing file ⇒ the strict zero baseline (new checkouts stay green
    /// only when the repo actually is clean).
    pub fn load(path: &Path) -> Result<Baseline, String> {
        match std::fs::read_to_string(path) {
            Ok(text) => Baseline::parse(&text),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Baseline::zeros()),
            Err(e) => Err(format!("{}: {e}", path.display())),
        }
    }

    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"rules\": {\n");
        let rows: Vec<String> = self
            .rules
            .iter()
            .map(|(k, n)| format!("    \"{k}\": {n}"))
            .collect();
        s.push_str(&rows.join(",\n"));
        s.push_str("\n  }\n}\n");
        s
    }
}

/// Helper trait so `parse` can reject non-integer counts inline.
trait PushChecked {
    fn push_str_checked(&mut self, k: &str, n: f64) -> Result<(), String>;
}

impl PushChecked for BTreeMap<String, u64> {
    fn push_str_checked(&mut self, k: &str, n: f64) -> Result<(), String> {
        if n < 0.0 || n.fract() != 0.0 {
            return Err(format!("rule `{k}` count {n} is not a non-negative integer"));
        }
        self.insert(k.to_string(), n as u64);
        Ok(())
    }
}

/// One rule's current-vs-baseline standing.
#[derive(Clone, Debug)]
pub struct RatchetRow {
    pub rule: String,
    pub count: u64,
    pub baseline: u64,
}

impl RatchetRow {
    pub fn regressed(&self) -> bool {
        self.count > self.baseline
    }
    /// The baseline is looser than reality and should be tightened.
    pub fn slack(&self) -> bool {
        self.count < self.baseline
    }
}

/// Compare current counts to the baseline over the union of rule names.
pub fn ratchet(counts: &BTreeMap<String, u64>, base: &Baseline) -> Vec<RatchetRow> {
    let names: std::collections::BTreeSet<&String> =
        counts.keys().chain(base.rules.keys()).collect();
    names
        .into_iter()
        .map(|rule| RatchetRow {
            rule: rule.clone(),
            count: counts.get(rule).copied().unwrap_or(0),
            baseline: base.rules.get(rule).copied().unwrap_or(0),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts(pairs: &[(&str, u64)]) -> BTreeMap<String, u64> {
        pairs.iter().map(|(k, n)| (k.to_string(), *n)).collect()
    }

    #[test]
    fn parse_roundtrip() {
        let b = Baseline::zeros();
        let parsed = Baseline::parse(&b.to_json()).unwrap();
        assert_eq!(parsed, b);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Baseline::parse("{}").is_err());
        assert!(Baseline::parse("{\"rules\": {\"x\": -1}}").is_err());
        assert!(Baseline::parse("{\"rules\": {\"x\": 1.5}}").is_err());
        assert!(Baseline::parse("not json").is_err());
    }

    #[test]
    fn ratchet_semantics() {
        let base = Baseline {
            rules: counts(&[("hash-order", 2), ("float-order", 0)]),
        };
        let rows = ratchet(&counts(&[("hash-order", 3), ("pragma", 1)]), &base);
        let row = |name: &str| rows.iter().find(|r| r.rule == name).unwrap();
        assert!(row("hash-order").regressed()); // 3 > 2
        assert!(!row("float-order").regressed()); // 0 == 0
        assert!(row("pragma").regressed()); // unknown rule defaults to 0
        let rows2 = ratchet(&counts(&[("hash-order", 1)]), &base);
        let r = rows2.iter().find(|r| r.rule == "hash-order").unwrap();
        assert!(!r.regressed() && r.slack()); // 1 < 2: tighten
    }

    #[test]
    fn missing_file_is_zero_baseline() {
        let b = Baseline::load(Path::new("/nonexistent/LINT_BASELINE.json")).unwrap();
        assert_eq!(b, Baseline::zeros());
    }
}
