//! P1 — protocol coverage: every `OakMsg` variant must be referenced (or
//! declared in a wildcard manifest) in each tier dispatcher, and priced in
//! the wire-size model. Token-level "referenced" means the dispatcher
//! mentions `OakMsg::Variant` anywhere outside `#[cfg(test)]`; adding a
//! variant without touching a tier therefore fails the lint, and stale or
//! redundant manifest entries fail it too.

use std::collections::BTreeSet;

use super::lexer::{Pragma, Scan, Tok};
use super::{SourceFile, Violation};

pub const PROTOCOL: &str = "protocol-coverage";

const ENUM_NAME: &str = "OakMsg";
/// Path suffix of the message-definition file (also hosts the size model).
const MSG_FILE: &str = "sim/msg.rs";
/// Path suffixes of the three tier dispatch loops.
const DISPATCHERS: [&str; 3] = [
    "coordinator/root.rs",
    "coordinator/cluster.rs",
    "coordinator/worker.rs",
];

/// `(name, line, col)` of each `enum OakMsg { … }` variant, in
/// declaration order. The span anchors pricing/coverage findings.
pub fn enum_variants(scan: &Scan, enum_name: &str) -> Vec<(String, u32, u32)> {
    let toks = &scan.tokens;
    let mut i = 0;
    while i < toks.len() {
        let is_decl = matches!(&toks[i].tok, Tok::Ident(w) if w == "enum")
            && matches!(&toks.get(i + 1).map(|t| &t.tok), Some(Tok::Ident(n)) if *n == enum_name)
            && matches!(toks.get(i + 2).map(|t| &t.tok), Some(Tok::Punct('{')));
        if !is_decl {
            i += 1;
            continue;
        }
        let mut out = Vec::new();
        let mut depth = 1usize;
        let mut expect_variant = true;
        let mut j = i + 3;
        while j < toks.len() && depth > 0 {
            match &toks[j].tok {
                Tok::Punct('{') | Tok::Punct('(') | Tok::Punct('[') => depth += 1,
                Tok::Punct('}') | Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
                Tok::Punct(',') if depth == 1 => expect_variant = true,
                Tok::Ident(name) if depth == 1 && expect_variant => {
                    out.push((name.clone(), toks[j].line, toks[j].col));
                    expect_variant = false;
                }
                _ => {}
            }
            j += 1;
        }
        return out;
    }
    Vec::new()
}

/// All `Enum::Variant` references outside test regions.
pub fn referenced_variants(scan: &Scan, enum_name: &str) -> BTreeSet<String> {
    let toks = &scan.tokens;
    let mut out = BTreeSet::new();
    for i in 0..toks.len() {
        if scan.in_test[i] {
            continue;
        }
        let is_ref = matches!(&toks[i].tok, Tok::Ident(w) if w == enum_name)
            && matches!(toks.get(i + 1).map(|t| &t.tok), Some(Tok::Punct(':')))
            && matches!(toks.get(i + 2).map(|t| &t.tok), Some(Tok::Punct(':')));
        if is_ref {
            if let Some(Tok::Ident(v)) = toks.get(i + 3).map(|t| &t.tok) {
                out.insert(v.clone());
            }
        }
    }
    out
}

/// Union of a file's wildcard-manifest entries, with each one's span.
fn wildcard_manifest(scan: &Scan) -> Vec<(u32, u32, String)> {
    let mut out = Vec::new();
    for p in &scan.pragmas {
        if let Pragma::Wildcard {
            line,
            col,
            variants,
        } = p
        {
            for v in variants {
                out.push((*line, *col, v.clone()));
            }
        }
    }
    out
}

pub fn check(sources: &[SourceFile], scans: &[Scan], out: &mut Vec<Violation>) {
    let Some(msg_idx) = sources.iter().position(|f| f.path.ends_with(MSG_FILE)) else {
        return; // fixture inputs without a protocol are fine
    };
    let variants = enum_variants(&scans[msg_idx], ENUM_NAME);
    if variants.is_empty() {
        out.push(Violation {
            rule: PROTOCOL,
            file: sources[msg_idx].path.clone(),
            line: 0,
            col: 0,
            message: format!("could not locate `enum {ENUM_NAME}`"),
        });
        return;
    }
    let variant_set: BTreeSet<&str> = variants.iter().map(|(v, _, _)| v.as_str()).collect();

    // Size model: the pricing match lives in msg.rs itself, so "priced"
    // means referenced somewhere in that file beyond the declaration.
    let priced = referenced_variants(&scans[msg_idx], ENUM_NAME);
    for (v, line, col) in &variants {
        if !priced.contains(v) {
            out.push(Violation {
                rule: PROTOCOL,
                file: sources[msg_idx].path.clone(),
                line: *line,
                col: *col,
                message: format!(
                    "{ENUM_NAME}::{v} has no arm in the wire-size model \
                     (default_wire_bytes) — it would ship with zero cost"
                ),
            });
        }
    }

    for suffix in DISPATCHERS {
        let Some(idx) = sources.iter().position(|f| f.path.ends_with(suffix)) else {
            continue;
        };
        let file = &sources[idx];
        let refs = referenced_variants(&scans[idx], ENUM_NAME);
        let manifest = wildcard_manifest(&scans[idx]);
        let declared: BTreeSet<&str> = manifest.iter().map(|(_, _, v)| v.as_str()).collect();
        // An uncovered variant is the `_` arm's fault: anchor there.
        let (wc_line, wc_col) =
            super::flow::wildcard_arm_anchor(&scans[idx]).unwrap_or((0, 0));
        for (v, _, _) in &variants {
            if !refs.contains(v) && !declared.contains(v.as_str()) {
                out.push(Violation {
                    rule: PROTOCOL,
                    file: file.path.clone(),
                    line: wc_line,
                    col: wc_col,
                    message: format!(
                        "{ENUM_NAME}::{v} is neither handled nor declared in a \
                         wildcard manifest in this dispatcher"
                    ),
                });
            }
        }
        for (line, col, v) in &manifest {
            if !variant_set.contains(v.as_str()) {
                out.push(Violation {
                    rule: PROTOCOL,
                    file: file.path.clone(),
                    line: *line,
                    col: *col,
                    message: format!("wildcard manifest names unknown variant `{v}`"),
                });
            } else if refs.contains(v) {
                out.push(Violation {
                    rule: PROTOCOL,
                    file: file.path.clone(),
                    line: *line,
                    col: *col,
                    message: format!(
                        "wildcard manifest entry `{v}` is redundant: the \
                         dispatcher already references it"
                    ),
                });
            }
        }
    }

    // Wildcard manifests only mean something in dispatcher files.
    for (file, scan) in sources.iter().zip(scans) {
        let is_dispatcher = DISPATCHERS.iter().any(|s| file.path.ends_with(s));
        if is_dispatcher {
            continue;
        }
        for p in &scan.pragmas {
            if let Pragma::Wildcard { line, col, .. } = p {
                out.push(Violation {
                    rule: PROTOCOL,
                    file: file.path.clone(),
                    line: *line,
                    col: *col,
                    message: "wildcard manifest outside a tier dispatcher has no effect"
                        .to_string(),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::scan;

    const MSG: &str = "pub enum OakMsg {\n Ping,\n Pong { seq: u64 },\n #[doc = \"x\"]\n Data(Vec<u8>),\n}\nfn price(m: &OakMsg) -> usize { match m {\n OakMsg::Ping => 1,\n OakMsg::Pong { .. } => 2,\n OakMsg::Data(_) => 3,\n} }";

    fn files(dispatcher_src: &str) -> (Vec<SourceFile>, Vec<Scan>) {
        let sources = vec![
            SourceFile {
                path: "rust/src/sim/msg.rs".into(),
                text: MSG.into(),
            },
            SourceFile {
                path: "rust/src/coordinator/root.rs".into(),
                text: dispatcher_src.into(),
            },
        ];
        let scans = sources.iter().map(|f| scan(&f.text)).collect();
        (sources, scans)
    }

    #[test]
    fn variant_extraction_handles_payloads_and_attrs() {
        let s = scan(MSG);
        let names: Vec<String> = enum_variants(&s, "OakMsg")
            .into_iter()
            .map(|(v, _, _)| v)
            .collect();
        assert_eq!(names, vec!["Ping", "Pong", "Data"]);
        assert_eq!(enum_variants(&s, "OakMsg")[0].1, 2, "Ping is on line 2");
        assert!(enum_variants(&s, "Missing").is_empty());
    }

    #[test]
    fn fully_covered_dispatcher_is_clean() {
        let (sources, scans) =
            files("match m { OakMsg::Ping => {}, OakMsg::Pong { .. } => {}, OakMsg::Data(_) => {} }");
        let mut v = Vec::new();
        check(&sources, &scans, &mut v);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn missing_variant_is_flagged_at_the_wildcard_arm() {
        let (sources, scans) = files("match m { OakMsg::Ping => {}, _ => {} }");
        let mut v = Vec::new();
        check(&sources, &scans, &mut v);
        assert_eq!(v.len(), 2, "{v:?}"); // Pong and Data uncovered
        assert!(v.iter().all(|x| x.rule == PROTOCOL));
        assert!(
            v.iter().all(|x| x.line == 1 && x.col > 1),
            "anchored at the `_` arm: {v:?}"
        );
    }

    #[test]
    fn wildcard_manifest_covers_and_validates() {
        let (sources, scans) = files(
            "// lint: wildcard(OakMsg: Pong, Data)\nmatch m { OakMsg::Ping => {}, _ => {} }",
        );
        let mut v = Vec::new();
        check(&sources, &scans, &mut v);
        assert!(v.is_empty(), "{v:?}");

        // Stale entry: names a variant that does not exist.
        let (sources, scans) = files(
            "// lint: wildcard(OakMsg: Pong, Data, Gone)\nmatch m { OakMsg::Ping => {}, _ => {} }",
        );
        let mut v = Vec::new();
        check(&sources, &scans, &mut v);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("Gone"));

        // Redundant entry: also matched above the wildcard.
        let (sources, scans) = files(
            "// lint: wildcard(OakMsg: Ping, Pong, Data)\nmatch m { OakMsg::Ping => {}, _ => {} }",
        );
        let mut v = Vec::new();
        check(&sources, &scans, &mut v);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("redundant"));
    }

    #[test]
    fn unpriced_variant_is_flagged_at_its_declaration() {
        let sources = vec![SourceFile {
            path: "rust/src/sim/msg.rs".into(),
            text: "pub enum OakMsg { Ping, Pong }\nfn price(m: &OakMsg) -> usize { match m { OakMsg::Ping => 1, _ => 0 } }".into(),
        }];
        let scans: Vec<Scan> = sources.iter().map(|f| scan(&f.text)).collect();
        let mut v = Vec::new();
        check(&sources, &scans, &mut v);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("Pong"));
        assert_eq!((v[0].line, v[0].col), (1, 25), "anchored at `Pong` decl");
    }
}
