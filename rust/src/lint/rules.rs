//! Per-file token rules: hash-order (D1), float-order (D2),
//! ambient-time (D3) and pragma hygiene.

use super::lexer::{Pragma, Scan, Tok};
use super::{SourceFile, Violation};

pub const HASH_ORDER: &str = "hash-order";
pub const FLOAT_ORDER: &str = "float-order";
pub const AMBIENT_TIME: &str = "ambient-time";
pub const PRAGMA: &str = "pragma";

/// One `allow` pragma with its coverage window and use tracking.
struct AllowSlot {
    rule: String,
    pragma_line: u32,
    covered: [Option<u32>; 2],
    used: bool,
}

pub struct FileRules<'a> {
    file: &'a SourceFile,
    allows: Vec<AllowSlot>,
}

impl<'a> FileRules<'a> {
    pub fn new(file: &'a SourceFile, scan: &Scan) -> Self {
        let allows = scan
            .pragmas
            .iter()
            .filter_map(|p| match p {
                Pragma::Allow { line, rule, .. } => Some(AllowSlot {
                    rule: rule.clone(),
                    pragma_line: *line,
                    covered: [Some(*line), scan.next_code_line(*line)],
                    used: false,
                }),
                _ => None,
            })
            .collect();
        FileRules { file, allows }
    }

    /// Record a violation at `line` unless an allow pragma covers it.
    fn flag(&mut self, out: &mut Vec<Violation>, rule: &'static str, line: u32, message: String) {
        for slot in &mut self.allows {
            if slot.rule == rule && slot.covered.contains(&Some(line)) {
                slot.used = true;
                return;
            }
        }
        out.push(Violation {
            rule,
            file: self.file.path.clone(),
            line,
            message,
        });
    }

    pub fn run(mut self, scan: &Scan, out: &mut Vec<Violation>) {
        for (i, t) in scan.tokens.iter().enumerate() {
            if scan.in_test[i] {
                continue;
            }
            let Tok::Ident(name) = &t.tok else { continue };
            let line = t.line;
            match name.as_str() {
                "HashMap" | "HashSet" if self.file.control_plane() => {
                    self.flag(
                        out,
                        HASH_ORDER,
                        line,
                        format!(
                            "{name} in a control-plane module; use BTreeMap/BTreeSet \
                             or justify with an allow pragma"
                        ),
                    );
                }
                "partial_cmp" if !prev_ident_is(scan, i, "fn") => {
                    self.flag(
                        out,
                        FLOAT_ORDER,
                        line,
                        "partial_cmp-based ordering; use f64::total_cmp \
                         (NaN-safe, total)"
                            .to_string(),
                    );
                }
                "Instant" | "SystemTime" | "thread_rng" | "ThreadRng" => {
                    self.flag(
                        out,
                        AMBIENT_TIME,
                        line,
                        format!(
                            "{name} is ambient nondeterminism; use the sim clock \
                             or util::Rng"
                        ),
                    );
                }
                _ => {}
            }
        }

        for p in &scan.pragmas {
            if let Pragma::Malformed { line, text } = p {
                out.push(Violation {
                    rule: PRAGMA,
                    file: self.file.path.clone(),
                    line: *line,
                    message: format!("unparseable lint pragma: `{text}`"),
                });
            }
        }
        for slot in &self.allows {
            if !slot.used {
                out.push(Violation {
                    rule: PRAGMA,
                    file: self.file.path.clone(),
                    line: slot.pragma_line,
                    message: format!(
                        "allow({}) pragma suppresses nothing; delete it",
                        slot.rule
                    ),
                });
            }
        }
    }
}

/// Is the nearest preceding token the identifier `name`?
fn prev_ident_is(scan: &Scan, i: usize, name: &str) -> bool {
    i > 0 && matches!(&scan.tokens[i - 1].tok, Tok::Ident(id) if id == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::scan;

    fn check(path: &str, src: &str) -> Vec<Violation> {
        let file = SourceFile {
            path: path.to_string(),
            text: src.to_string(),
        };
        let s = scan(&file.text);
        let mut out = Vec::new();
        FileRules::new(&file, &s).run(&s, &mut out);
        out
    }

    #[test]
    fn hash_order_only_in_control_plane() {
        let src = "use std::collections::HashMap;";
        assert_eq!(check("rust/src/sim/foo.rs", src).len(), 1);
        assert_eq!(check("rust/src/coordinator/root.rs", src).len(), 1);
        assert!(check("rust/src/workload.rs", src).is_empty());
    }

    #[test]
    fn allow_pragma_suppresses_and_counts_as_used() {
        let src = "// lint: allow(hash-order, lookup only)\nuse std::collections::HashMap;";
        assert!(check("rust/src/sim/foo.rs", src).is_empty());
    }

    #[test]
    fn unused_allow_is_flagged() {
        let v = check("rust/src/sim/foo.rs", "// lint: allow(hash-order, stale)\nlet x = 1;");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, PRAGMA);
    }

    #[test]
    fn float_order_skips_trait_impls() {
        let src = "fn partial_cmp(&self, o: &Self) -> Option<Ordering> { Some(self.cmp(o)) }";
        assert!(check("rust/src/any.rs", src).is_empty());
        let v = check("rust/src/any.rs", "xs.sort_by(|a, b| a.partial_cmp(b).unwrap());");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, FLOAT_ORDER);
    }

    #[test]
    fn ambient_time_applies_crate_wide() {
        let v = check("rust/src/workload.rs", "let t = std::time::Instant::now();");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, AMBIENT_TIME);
    }

    #[test]
    fn test_mods_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n use std::collections::HashMap;\n}";
        assert!(check("rust/src/sim/foo.rs", src).is_empty());
    }

    #[test]
    fn strings_and_comments_never_fire() {
        let src = "// HashMap Instant partial_cmp\nlet s = \"HashMap Instant\";";
        assert!(check("rust/src/sim/foo.rs", src).is_empty());
    }
}
