//! Per-file token rules: hash-order (D1), float-order (D2),
//! ambient-time (D3) and pragma hygiene.

use super::lexer::{Pragma, Scan, Tok};
use super::{SourceFile, Violation};

pub const HASH_ORDER: &str = "hash-order";
pub const FLOAT_ORDER: &str = "float-order";
pub const AMBIENT_TIME: &str = "ambient-time";
pub const PRAGMA: &str = "pragma";

/// One `allow` pragma with its coverage window and use tracking.
struct AllowSlot {
    rule: String,
    pragma_line: u32,
    pragma_col: u32,
    covered: Vec<u32>,
    used: bool,
}

/// All `allow` pragmas of one file, shared by every analysis pass (the
/// per-file token rules here plus the cross-file flow/isolation passes)
/// so that "unused allow" is judged only after *all* passes ran.
pub struct FileAllows {
    slots: Vec<AllowSlot>,
}

impl FileAllows {
    pub fn new(scan: &Scan) -> Self {
        let slots = scan
            .pragmas
            .iter()
            .filter_map(|p| match p {
                Pragma::Allow {
                    line, col, rule, ..
                } => Some(AllowSlot {
                    rule: rule.clone(),
                    pragma_line: *line,
                    pragma_col: *col,
                    covered: scan.allow_window(*line),
                    used: false,
                }),
                _ => None,
            })
            .collect();
        FileAllows { slots }
    }

    /// Does an allow pragma for `rule` cover `line`? Marks it used.
    pub fn covers(&mut self, rule: &str, line: u32) -> bool {
        for slot in &mut self.slots {
            if slot.rule == rule && slot.covered.contains(&line) {
                slot.used = true;
                return true;
            }
        }
        false
    }

    /// `(rule, line, col)` of every allow that suppressed nothing.
    pub fn unused(&self) -> Vec<(&str, u32, u32)> {
        self.slots
            .iter()
            .filter(|s| !s.used)
            .map(|s| (s.rule.as_str(), s.pragma_line, s.pragma_col))
            .collect()
    }
}

pub struct FileRules<'a> {
    file: &'a SourceFile,
}

impl<'a> FileRules<'a> {
    pub fn new(file: &'a SourceFile) -> Self {
        FileRules { file }
    }

    /// Record a violation at `line:col` unless an allow pragma covers it.
    fn flag(
        &mut self,
        allows: &mut FileAllows,
        out: &mut Vec<Violation>,
        rule: &'static str,
        line: u32,
        col: u32,
        message: String,
    ) {
        if allows.covers(rule, line) {
            return;
        }
        out.push(Violation {
            rule,
            file: self.file.path.clone(),
            line,
            col,
            message,
        });
    }

    pub fn run(mut self, scan: &Scan, allows: &mut FileAllows, out: &mut Vec<Violation>) {
        for (i, t) in scan.tokens.iter().enumerate() {
            if scan.in_test[i] {
                continue;
            }
            let Tok::Ident(name) = &t.tok else { continue };
            let (line, col) = (t.line, t.col);
            match name.as_str() {
                "HashMap" | "HashSet" if self.file.control_plane() => {
                    self.flag(
                        allows,
                        out,
                        HASH_ORDER,
                        line,
                        col,
                        format!(
                            "{name} in a control-plane module; use BTreeMap/BTreeSet \
                             or justify with an allow pragma"
                        ),
                    );
                }
                "partial_cmp" if !prev_ident_is(scan, i, "fn") => {
                    self.flag(
                        allows,
                        out,
                        FLOAT_ORDER,
                        line,
                        col,
                        "partial_cmp-based ordering; use f64::total_cmp \
                         (NaN-safe, total)"
                            .to_string(),
                    );
                }
                "Instant" | "SystemTime" | "thread_rng" | "ThreadRng" => {
                    self.flag(
                        allows,
                        out,
                        AMBIENT_TIME,
                        line,
                        col,
                        format!(
                            "{name} is ambient nondeterminism; use the sim clock \
                             or util::Rng"
                        ),
                    );
                }
                _ => {}
            }
        }

        for p in &scan.pragmas {
            if let Pragma::Malformed { line, col, text } = p {
                out.push(Violation {
                    rule: PRAGMA,
                    file: self.file.path.clone(),
                    line: *line,
                    col: *col,
                    message: format!("unparseable lint pragma: `{text}`"),
                });
            }
        }
    }
}

/// Is the nearest preceding token the identifier `name`?
fn prev_ident_is(scan: &Scan, i: usize, name: &str) -> bool {
    i > 0 && matches!(&scan.tokens[i - 1].tok, Tok::Ident(id) if id == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::scan;

    fn check(path: &str, src: &str) -> Vec<Violation> {
        let file = SourceFile {
            path: path.to_string(),
            text: src.to_string(),
        };
        let s = scan(&file.text);
        let mut allows = FileAllows::new(&s);
        let mut out = Vec::new();
        FileRules::new(&file).run(&s, &mut allows, &mut out);
        for (rule, line, col) in allows.unused() {
            out.push(Violation {
                rule: PRAGMA,
                file: file.path.clone(),
                line,
                col,
                message: format!("allow({rule}) pragma suppresses nothing; delete it"),
            });
        }
        out
    }

    #[test]
    fn hash_order_only_in_control_plane() {
        let src = "use std::collections::HashMap;";
        assert_eq!(check("rust/src/sim/foo.rs", src).len(), 1);
        assert_eq!(check("rust/src/coordinator/root.rs", src).len(), 1);
        assert!(check("rust/src/workload.rs", src).is_empty());
    }

    #[test]
    fn allow_pragma_suppresses_and_counts_as_used() {
        let src = "// lint: allow(hash-order, lookup only)\nuse std::collections::HashMap;";
        assert!(check("rust/src/sim/foo.rs", src).is_empty());
    }

    #[test]
    fn allow_pragma_reaches_through_attributes() {
        let src = "// lint: allow(hash-order, opaque keys)\n\
                   #[derive(Default)]\n\
                   pub struct C { m: HashMap<u32, u32> }\n";
        assert!(check("rust/src/sim/foo.rs", src).is_empty());
    }

    #[test]
    fn unused_allow_is_flagged() {
        let v = check("rust/src/sim/foo.rs", "// lint: allow(hash-order, stale)\nlet x = 1;");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, PRAGMA);
    }

    #[test]
    fn float_order_skips_trait_impls() {
        let src = "fn partial_cmp(&self, o: &Self) -> Option<Ordering> { Some(self.cmp(o)) }";
        assert!(check("rust/src/any.rs", src).is_empty());
        let v = check("rust/src/any.rs", "xs.sort_by(|a, b| a.partial_cmp(b).unwrap());");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, FLOAT_ORDER);
        assert!(v[0].col > 1, "span must point at the call, not the line start");
    }

    #[test]
    fn ambient_time_applies_crate_wide() {
        let v = check("rust/src/workload.rs", "let t = std::time::Instant::now();");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, AMBIENT_TIME);
    }

    #[test]
    fn test_mods_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n use std::collections::HashMap;\n}";
        assert!(check("rust/src/sim/foo.rs", src).is_empty());
    }

    #[test]
    fn strings_and_comments_never_fire() {
        let src = "// HashMap Instant partial_cmp\nlet s = \"HashMap Instant\";";
        assert!(check("rust/src/sim/foo.rs", src).is_empty());
    }
}
