//! M1 — metrics-key registry: dotted metric keys referenced by README.md
//! or jq-gated in ci.yml must exist as string literals in the sources.
//! The registry is every non-test string literal shaped like a key; doc
//! candidates are only checked when their leading namespace segment is one
//! the code actually uses, which keeps prose ("e.g.", version numbers,
//! file paths) from generating noise.

use std::collections::BTreeSet;

use super::lexer::{Scan, Tok};
use super::{SourceFile, Violation};

pub const METRICS_KEYS: &str = "metrics-keys";

/// File-ish suffixes that disqualify a candidate (and registry entry).
const FILE_SUFFIXES: [&str; 10] = [
    ".rs", ".json", ".yml", ".yaml", ".md", ".toml", ".py", ".txt", ".sh", ".lock",
];

/// Does `s` look like a metric key: lowercase start, at least one dot,
/// charset of the crate's dotted keys (incl. `->` labels and `*` globs).
pub fn is_metric_key(s: &str) -> bool {
    let Some(first) = s.chars().next() else {
        return false;
    };
    if !first.is_ascii_lowercase() {
        return false;
    }
    if !s.contains('.') || s.ends_with('.') || s.contains("..") {
        return false;
    }
    if FILE_SUFFIXES.iter().any(|suf| s.ends_with(suf)) {
        return false;
    }
    s.chars().all(|c| {
        c.is_ascii_lowercase() || c.is_ascii_digit() || matches!(c, '.' | '_' | '-' | '>' | '*')
    })
}

/// All key-shaped string literals outside test regions.
pub fn registry(sources: &[SourceFile], scans: &[Scan]) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    for (_, scan) in sources.iter().zip(scans) {
        for (i, t) in scan.tokens.iter().enumerate() {
            if scan.in_test[i] {
                continue;
            }
            if let Tok::Str(s) = &t.tok {
                if is_metric_key(s) {
                    keys.insert(s.clone());
                }
            }
        }
    }
    keys
}

/// Maximal runs of key characters in a prose/config line.
fn candidate_runs(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for c in line.chars() {
        let key_char = c.is_ascii_alphanumeric()
            || matches!(c, '.' | '_' | '-' | '>' | '*');
        if key_char {
            cur.push(c);
        } else if !cur.is_empty() {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

pub fn check(
    sources: &[SourceFile],
    scans: &[Scan],
    docs: &[SourceFile],
    out: &mut Vec<Violation>,
) {
    let keys = registry(sources, scans);
    let namespaces: BTreeSet<&str> = keys
        .iter()
        .filter_map(|k| k.split('.').next())
        .collect();
    for doc in docs {
        for (lineno, line) in doc.text.lines().enumerate() {
            for run in candidate_runs(line) {
                // A sentence-final dot is punctuation, not part of the key.
                let run = run.trim_end_matches('.');
                if !is_metric_key(run) {
                    continue;
                }
                let ns = run.split('.').next().unwrap_or("");
                if !namespaces.contains(ns) {
                    continue;
                }
                let ok = if let Some(prefix) = run.strip_suffix('*') {
                    keys.iter().any(|k| k.starts_with(prefix))
                } else {
                    keys.contains(run)
                        || keys
                            .iter()
                            .any(|k| k.strip_suffix(".*").is_some_and(|p| run.starts_with(p)))
                };
                if !ok {
                    out.push(Violation {
                        rule: METRICS_KEYS,
                        file: doc.path.clone(),
                        line: lineno as u32 + 1,
                        message: format!(
                            "references metric key `{run}` which no source \
                             string literal defines"
                        ),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::scan;

    fn src(text: &str) -> (Vec<SourceFile>, Vec<Scan>) {
        let sources = vec![SourceFile {
            path: "rust/src/metrics_user.rs".into(),
            text: text.into(),
        }];
        let scans = sources.iter().map(|f| scan(&f.text)).collect();
        (sources, scans)
    }

    fn doc(text: &str) -> SourceFile {
        SourceFile {
            path: "README.md".into(),
            text: text.into(),
        }
    }

    #[test]
    fn key_shape() {
        assert!(is_metric_key("root.op.submit"));
        assert!(is_metric_key("oak.worker->cluster"));
        assert!(is_metric_key("root.op.*"));
        assert!(!is_metric_key("e"));
        assert!(!is_metric_key("Fig.7a"));
        assert!(!is_metric_key("trailing."));
        assert!(!is_metric_key("ci.yml"));
        assert!(!is_metric_key("no_dot"));
    }

    #[test]
    fn documented_existing_key_is_clean() {
        let (sources, scans) = src(r#"fn f(m: &mut M) { m.inc("root.op.submit"); }"#);
        let mut v = Vec::new();
        check(&sources, &scans, &[doc("counts land in `root.op.submit`.")], &mut v);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn unknown_key_in_known_namespace_is_flagged() {
        let (sources, scans) = src(r#"fn f(m: &mut M) { m.inc("root.op.submit"); }"#);
        let mut v = Vec::new();
        check(&sources, &scans, &[doc("see root.op.sumbit for totals")], &mut v);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("root.op.sumbit"));
    }

    #[test]
    fn unknown_namespace_is_ignored() {
        let (sources, scans) = src(r#"fn f(m: &mut M) { m.inc("root.op.submit"); }"#);
        let mut v = Vec::new();
        check(
            &sources,
            &scans,
            &[doc("jq .federation.spill_sends and e.g. v1.2 and a/b.yml")],
            &mut v,
        );
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn glob_suffix_checks_prefix() {
        let (sources, scans) = src(r#"fn f(m: &mut M) { m.inc("root.op.submit"); }"#);
        let mut v = Vec::new();
        check(&sources, &scans, &[doc("all of root.op.* counts")], &mut v);
        assert!(v.is_empty(), "{v:?}");
        let mut v = Vec::new();
        check(&sources, &scans, &[doc("all of root.missing.* counts")], &mut v);
        assert_eq!(v.len(), 1, "{v:?}");
    }

    #[test]
    fn test_only_strings_stay_out_of_registry() {
        let (sources, scans) = src(
            "#[cfg(test)]\nmod tests { fn t(m: &mut M) { m.inc(\"root.only_in_test\"); } }\nfn f(m: &mut M) { m.inc(\"root.live\"); }",
        );
        let mut v = Vec::new();
        check(&sources, &scans, &[doc("root.only_in_test")], &mut v);
        assert_eq!(v.len(), 1, "{v:?}");
    }
}
