//! Services, tasks (microservices) and the instance lifecycle state
//! machine (paper §6: requested → scheduled → running → {terminated,
//! failed}, with migration/replication handled as new scheduling
//! requests).

use crate::model::{Capacity, Virtualization};
use crate::sla::TaskSla;
use crate::util::{InstanceId, NodeId, ServiceId, TaskId};

/// One microservice `τ_{p,i}` of a service: what gets placed on a worker.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub id: TaskId,
    pub name: String,
    /// Requested capacity `Q_{τ_{p,i}}`.
    pub request: Capacity,
    pub virtualization: Virtualization,
    /// Container image size in MB (drives simulated pull time).
    pub image_mb: u32,
    /// Full SLA row for this task (latency/geo constraints etc.).
    pub sla: TaskSla,
}

/// An application service `s_p = {τ_{p,1}, …, τ_{p,n}}` submitted at the
/// root (paper §4.2).
#[derive(Clone, Debug)]
pub struct ServiceSpec {
    pub id: ServiceId,
    pub name: String,
    pub tasks: Vec<TaskSpec>,
}

impl ServiceSpec {
    pub fn task(&self, id: TaskId) -> Option<&TaskSpec> {
        self.tasks.iter().find(|t| t.id == id)
    }
}

/// Lifecycle of one deployed task instance (paper §6 state machine).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ServiceState {
    /// Root scheduler has initiated scheduling.
    Requested,
    /// A cluster found a suitable worker; deployment command in flight.
    Scheduled,
    /// Worker reports the instance operational.
    Running,
    /// Undeployed deliberately (after successful migration, or teardown).
    Terminated,
    /// Unexpected early termination / resource failure / SLA violation.
    Failed,
}

/// Error for illegal state-machine transitions.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct StateError {
    pub from: ServiceState,
    pub to: ServiceState,
}

impl std::fmt::Display for StateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "illegal transition {:?} -> {:?}", self.from, self.to)
    }
}
impl std::error::Error for StateError {}

impl ServiceState {
    /// Legal transitions of the paper's lifecycle. Failures are legal from
    /// every live state (resources can die at any point at the edge), and
    /// deliberate teardown (`Terminated`) may cancel an instance that is
    /// still `Scheduled` — an API-driven undeploy can race the container
    /// start.
    pub fn can_transition(self, to: ServiceState) -> bool {
        use ServiceState::*;
        matches!(
            (self, to),
            (Requested, Scheduled)
                | (Requested, Failed)
                | (Scheduled, Running)
                | (Scheduled, Terminated)
                | (Scheduled, Failed)
                | (Running, Terminated)
                | (Running, Failed)
        )
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, ServiceState::Terminated | ServiceState::Failed)
    }
}

/// A deployed (or deploying) instance of a task, tracked by the service
/// managers at both cluster and root tier.
#[derive(Clone, Debug)]
pub struct InstanceRecord {
    pub instance: InstanceId,
    pub task: TaskId,
    pub state: ServiceState,
    /// Worker hosting the instance (None until scheduled).
    pub worker: Option<NodeId>,
    /// Generation counter: bumped on every migration/replication.
    pub generation: u32,
    /// Successor lineage: the instance this one replaced (set when the
    /// record was minted/adopted as a replacement).
    pub predecessor: Option<InstanceId>,
    /// The replacement that superseded this instance, once registered.
    /// A set successor retires the record from further migration — the
    /// lineage already moved on.
    pub successor: Option<InstanceId>,
}

impl InstanceRecord {
    pub fn new(instance: InstanceId, task: TaskId) -> Self {
        InstanceRecord {
            instance,
            task,
            state: ServiceState::Requested,
            worker: None,
            generation: 0,
            predecessor: None,
            successor: None,
        }
    }

    /// Enforce the legal lifecycle; callers must handle errors (they mean
    /// a protocol bug, not an environmental failure).
    pub fn transition(&mut self, to: ServiceState) -> Result<(), StateError> {
        if self.state.can_transition(to) {
            self.state = to;
            Ok(())
        } else {
            Err(StateError {
                from: self.state,
                to,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use ServiceState::*;

    #[test]
    fn happy_path_lifecycle() {
        let mut r = InstanceRecord::new(InstanceId(1), TaskId::default());
        assert_eq!(r.state, Requested);
        r.transition(Scheduled).unwrap();
        r.transition(Running).unwrap();
        r.transition(Terminated).unwrap();
        assert!(r.state.is_terminal());
    }

    #[test]
    fn failure_possible_from_all_live_states() {
        for (path, expect_ok) in [
            (vec![Failed], true),
            (vec![Scheduled, Failed], true),
            (vec![Scheduled, Running, Failed], true),
        ] {
            let mut r = InstanceRecord::new(InstanceId(1), TaskId::default());
            let mut ok = true;
            for s in path {
                ok &= r.transition(s).is_ok();
            }
            assert_eq!(ok, expect_ok);
        }
    }

    #[test]
    fn illegal_transitions_rejected() {
        let mut r = InstanceRecord::new(InstanceId(1), TaskId::default());
        assert!(r.transition(Running).is_err()); // must schedule first
        r.transition(Scheduled).unwrap();
        assert!(r.transition(Requested).is_err()); // no going back
        r.transition(Running).unwrap();
        r.transition(Terminated).unwrap();
        assert!(r.transition(Running).is_err()); // terminal is terminal
        assert!(r.transition(Failed).is_err());
    }

    #[test]
    fn scheduled_can_be_cancelled() {
        // API-driven undeploy racing a container start: Scheduled →
        // Terminated is a deliberate cancellation, not a failure.
        let mut r = InstanceRecord::new(InstanceId(1), TaskId::default());
        r.transition(Scheduled).unwrap();
        r.transition(Terminated).unwrap();
        assert!(r.state.is_terminal());
    }

    #[test]
    fn terminal_states() {
        assert!(Terminated.is_terminal());
        assert!(Failed.is_terminal());
        assert!(!Running.is_terminal());
        assert!(!Requested.is_terminal());
        assert!(!Scheduled.is_terminal());
    }
}
