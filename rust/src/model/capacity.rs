//! Resource capacity vectors: `C_n` (max), `U_n` (used), `A_n = C_n − U_n`
//! (available) in the paper's notation (§4.1).

use std::ops::{Add, AddAssign, Sub, SubAssign};

/// A resource capacity/usage vector.
///
/// * `cpu_millicores` — 1000 = one vCPU (Kubernetes-style millicores).
/// * `mem_mb` / `disk_mb` — megabytes.
/// * `gpus` / `tpus` — discrete accelerator counts (SLA `vgpus`/`vtpus`).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct Capacity {
    pub cpu_millicores: u32,
    pub mem_mb: u32,
    pub disk_mb: u32,
    pub gpus: u8,
    pub tpus: u8,
}

impl Capacity {
    pub const ZERO: Capacity = Capacity {
        cpu_millicores: 0,
        mem_mb: 0,
        disk_mb: 0,
        gpus: 0,
        tpus: 0,
    };

    pub fn new(cpu_millicores: u32, mem_mb: u32, disk_mb: u32) -> Self {
        Capacity {
            cpu_millicores,
            mem_mb,
            disk_mb,
            gpus: 0,
            tpus: 0,
        }
    }

    /// Component-wise `self >= other` — the feasibility test of Alg. 1/2.
    pub fn fits(&self, req: &Capacity) -> bool {
        self.cpu_millicores >= req.cpu_millicores
            && self.mem_mb >= req.mem_mb
            && self.disk_mb >= req.disk_mb
            && self.gpus >= req.gpus
            && self.tpus >= req.tpus
    }

    /// Saturating component-wise subtraction (A = C − U never underflows).
    #[must_use]
    pub fn saturating_sub(&self, rhs: &Capacity) -> Capacity {
        Capacity {
            cpu_millicores: self.cpu_millicores.saturating_sub(rhs.cpu_millicores),
            mem_mb: self.mem_mb.saturating_sub(rhs.mem_mb),
            disk_mb: self.disk_mb.saturating_sub(rhs.disk_mb),
            gpus: self.gpus.saturating_sub(rhs.gpus),
            tpus: self.tpus.saturating_sub(rhs.tpus),
        }
    }

    /// ROM scoring strategy (paper Alg. 1 example): spare cpu + spare mem
    /// after placing `req`, in comparable units (cores + GB).
    pub fn spare_score(&self, req: &Capacity) -> f64 {
        (self.cpu_millicores as f64 - req.cpu_millicores as f64) / 1000.0
            + (self.mem_mb as f64 - req.mem_mb as f64) / 1024.0
    }
}

impl Add for Capacity {
    type Output = Capacity;
    fn add(self, rhs: Capacity) -> Capacity {
        Capacity {
            cpu_millicores: self.cpu_millicores + rhs.cpu_millicores,
            mem_mb: self.mem_mb + rhs.mem_mb,
            disk_mb: self.disk_mb + rhs.disk_mb,
            gpus: self.gpus + rhs.gpus,
            tpus: self.tpus + rhs.tpus,
        }
    }
}
impl AddAssign for Capacity {
    fn add_assign(&mut self, rhs: Capacity) {
        *self = *self + rhs;
    }
}
impl Sub for Capacity {
    type Output = Capacity;
    fn sub(self, rhs: Capacity) -> Capacity {
        self.saturating_sub(&rhs)
    }
}
impl SubAssign for Capacity {
    fn sub_assign(&mut self, rhs: Capacity) {
        *self = *self - rhs;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_is_componentwise() {
        let cap = Capacity::new(2000, 4096, 10_000);
        assert!(cap.fits(&Capacity::new(2000, 4096, 10_000)));
        assert!(cap.fits(&Capacity::new(1, 1, 1)));
        assert!(!cap.fits(&Capacity::new(2001, 1, 1)));
        assert!(!cap.fits(&Capacity::new(1, 5000, 1)));
        let gpu_req = Capacity {
            gpus: 1,
            ..Capacity::ZERO
        };
        assert!(!cap.fits(&gpu_req));
    }

    #[test]
    fn saturating_sub_never_underflows() {
        let a = Capacity::new(100, 100, 100);
        let b = Capacity::new(200, 50, 300);
        let d = a.saturating_sub(&b);
        assert_eq!(d, Capacity::new(0, 50, 0));
    }

    #[test]
    fn spare_score_matches_kernel_strategy() {
        let a = Capacity::new(4000, 2048, 0);
        let req = Capacity::new(1000, 1024, 0);
        // (4-1) cores + (2-1) GB = 4.0
        assert!((a.spare_score(&req) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn add_sub_roundtrip() {
        let a = Capacity::new(1000, 2000, 3000);
        let b = Capacity::new(10, 20, 30);
        assert_eq!((a + b) - b, a);
    }
}
