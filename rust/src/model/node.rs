//! Worker node descriptions: the paper's testbed VM classes (S/M/L/XL,
//! §7.1) and heterogeneous edge device profiles (HET testbed: Raspberry
//! Pi, Intel NUC, mini-desktop, Jetson AGX Xavier).

use super::{Capacity, Virtualization};
use crate::geo::GeoPoint;
use crate::util::NodeId;
use crate::vivaldi::VivaldiState;

/// HPC testbed VM sizes (paper §7.1): S/M/L/XL with 1/2/4/8 CPUs and
/// 1/2/4/8 GB RAM — plus the HET testbed device profiles.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum NodeClass {
    S,
    M,
    L,
    XL,
    RaspberryPi4,
    IntelNuc,
    MiniDesktop,
    JetsonXavier,
}

impl NodeClass {
    pub fn capacity(self) -> Capacity {
        match self {
            NodeClass::S => Capacity::new(1_000, 1_024, 16_000),
            NodeClass::M => Capacity::new(2_000, 2_048, 32_000),
            NodeClass::L => Capacity::new(4_000, 4_096, 64_000),
            NodeClass::XL => Capacity::new(8_000, 8_192, 128_000),
            NodeClass::RaspberryPi4 => Capacity::new(4_000, 4_096, 32_000),
            NodeClass::IntelNuc => Capacity::new(4_000, 8_192, 256_000),
            NodeClass::MiniDesktop => Capacity::new(8_000, 16_384, 512_000),
            NodeClass::JetsonXavier => {
                let mut c = Capacity::new(8_000, 16_384, 32_000);
                c.gpus = 1;
                c
            }
        }
    }

    /// Relative single-core speed factor (x86 server core = 1.0). Scales
    /// compute costs in the simulator — e.g. the Pi runs the same control
    /// loop slower, which is exactly what the HET experiments show.
    pub fn speed_factor(self) -> f64 {
        match self {
            NodeClass::S | NodeClass::M | NodeClass::L | NodeClass::XL => 1.0,
            NodeClass::RaspberryPi4 => 0.35,
            NodeClass::IntelNuc => 0.9,
            NodeClass::MiniDesktop => 1.1,
            NodeClass::JetsonXavier => 0.7,
        }
    }

    pub fn virtualization(self) -> Virtualization {
        match self {
            NodeClass::RaspberryPi4 => Virtualization::CONTAINER.union(Virtualization::WASM),
            _ => Virtualization::all(),
        }
    }
}

/// Static description of a worker at registration time (paper §3.2.3:
/// capacity, capabilities, runtimes reported to the cluster orchestrator).
#[derive(Clone, Debug)]
pub struct WorkerSpec {
    pub node: NodeId,
    pub class: NodeClass,
    pub location: GeoPoint,
}

impl WorkerSpec {
    pub fn capacity(&self) -> Capacity {
        self.class.capacity()
    }
    pub fn virtualization(&self) -> Virtualization {
        self.class.virtualization()
    }
}

/// Live view the cluster orchestrator keeps per worker (`A_n`, Alg. 1/2
/// input): refreshed by push-based telemetry (§4.1).
#[derive(Clone, Debug)]
pub struct NodeProfile {
    pub spec: WorkerSpec,
    pub used: Capacity,
    pub vivaldi: VivaldiState,
    /// Number of service instances currently placed here.
    pub instances: usize,
}

impl NodeProfile {
    pub fn new(spec: WorkerSpec) -> Self {
        NodeProfile {
            spec,
            used: Capacity::ZERO,
            vivaldi: VivaldiState::default(),
            instances: 0,
        }
    }

    /// Available capacity `A_n = C_n − U_n`.
    pub fn available(&self) -> Capacity {
        self.spec.capacity().saturating_sub(&self.used)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_capacities_follow_paper_table() {
        assert_eq!(NodeClass::S.capacity().cpu_millicores, 1_000);
        assert_eq!(NodeClass::M.capacity().mem_mb, 2_048);
        assert_eq!(NodeClass::L.capacity().cpu_millicores, 4_000);
        assert_eq!(NodeClass::XL.capacity().mem_mb, 8_192);
    }

    #[test]
    fn available_tracks_usage() {
        let spec = WorkerSpec {
            node: NodeId(1),
            class: NodeClass::S,
            location: GeoPoint::default(),
        };
        let mut p = NodeProfile::new(spec);
        assert_eq!(p.available(), NodeClass::S.capacity());
        p.used = Capacity::new(400, 512, 0);
        assert_eq!(p.available().cpu_millicores, 600);
        assert_eq!(p.available().mem_mb, 512);
        // Overcommit reports zero available, not underflow.
        p.used = Capacity::new(2_000, 4_096, 0);
        assert_eq!(p.available().cpu_millicores, 0);
    }

    #[test]
    fn het_devices_are_heterogeneous() {
        assert!(NodeClass::RaspberryPi4.speed_factor() < NodeClass::IntelNuc.speed_factor());
        assert_eq!(NodeClass::JetsonXavier.capacity().gpus, 1);
        assert!(!NodeClass::RaspberryPi4
            .virtualization()
            .supports(Virtualization::VM));
    }
}
