//! Core domain model: capacities, nodes, virtualization runtimes, services
//! and the service-instance lifecycle state machine (paper §6).

mod capacity;
mod node;
mod service;
mod virt;

pub use capacity::Capacity;
pub use node::{NodeClass, NodeProfile, WorkerSpec};
pub use service::{
    InstanceRecord, ServiceSpec, ServiceState, StateError, TaskSpec,
};
pub use virt::Virtualization;
