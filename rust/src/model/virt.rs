//! Virtualization technologies (SLA `virtualization` field, Schema 1) as a
//! bitmask — a worker advertises the set it supports, a task requires a
//! subset (`Q^virt ∈ A^virt` in Alg. 1/2). The bit layout matches the i32
//! encoding fed to the `ldp_score` HLO artifact.

/// Supported execution runtimes as a bitmask.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, Default)]
pub struct Virtualization(pub u32);

impl Virtualization {
    pub const CONTAINER: Virtualization = Virtualization(1 << 0); // docker/containerd
    pub const UNIKERNEL: Virtualization = Virtualization(1 << 1);
    pub const VM: Virtualization = Virtualization(1 << 2); // kvm/qemu microVM
    pub const WASM: Virtualization = Virtualization(1 << 3);
    pub const NONE: Virtualization = Virtualization(0);

    pub fn all() -> Virtualization {
        Virtualization(0b1111)
    }

    /// Does this (advertised) set support every bit of `req`?
    pub fn supports(&self, req: Virtualization) -> bool {
        self.0 & req.0 == req.0
    }

    pub fn union(&self, other: Virtualization) -> Virtualization {
        Virtualization(self.0 | other.0)
    }

    /// Parse the SLA string form (comma-separated names).
    pub fn parse(s: &str) -> Option<Virtualization> {
        let mut v = Virtualization::NONE;
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            v = v.union(match part.to_ascii_lowercase().as_str() {
                "container" | "docker" | "containerd" => Self::CONTAINER,
                "unikernel" => Self::UNIKERNEL,
                "vm" | "microvm" | "kvm" => Self::VM,
                "wasm" | "webassembly" => Self::WASM,
                _ => return None,
            });
        }
        Some(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn supports_requires_superset() {
        let w = Virtualization::CONTAINER.union(Virtualization::WASM);
        assert!(w.supports(Virtualization::CONTAINER));
        assert!(w.supports(Virtualization::NONE));
        assert!(w.supports(Virtualization::CONTAINER.union(Virtualization::WASM)));
        assert!(!w.supports(Virtualization::VM));
        assert!(!w.supports(Virtualization::CONTAINER.union(Virtualization::VM)));
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(
            Virtualization::parse("container"),
            Some(Virtualization::CONTAINER)
        );
        assert_eq!(
            Virtualization::parse("docker, wasm"),
            Some(Virtualization::CONTAINER.union(Virtualization::WASM))
        );
        assert_eq!(Virtualization::parse(""), Some(Virtualization::NONE));
        assert_eq!(Virtualization::parse("quantum"), None);
    }
}
