//! Cluster orchestrator (paper §3.2.2): the root's logical twin scoped to
//! one cluster. Ingests push-based worker telemetry over the MQTT broker,
//! aggregates ⟨Σ,μ,σ⟩ upward, runs the cluster-tier scheduler plugin
//! (ROM/LDP), deploys onto workers, sweeps worker health, recovers
//! failures locally and escalates to the root when the cluster cannot.

use std::any::Any;
use std::collections::{BTreeMap, BTreeSet};

use crate::geo::Area;
use crate::hierarchy::AggregateStats;
use crate::messaging::{
    labels, LinkHealth, MqttBroker, Outbox, WsLink, MQTT_FRAME_OVERHEAD, WS_FRAME_OVERHEAD,
};
use crate::model::{Capacity, NodeProfile, ServiceState};
use crate::netmanager::{InstanceLocation, ServiceIp, SubnetAllocator, TableEntry};
use crate::scheduler::{
    LdpContext, LdpScheduler, Placement, PlacementInput, RomScheduler, RomStrategy,
    TaskScheduler,
};
use crate::sim::{Actor, ActorId, Ctx, OakMsg, ReplacementReason, SimMsg, TimerKind};
use crate::sla::TaskSla;
use crate::util::{ClusterId, InstanceId, NodeId, ServiceId, SimTime, TaskId};
use crate::vivaldi::Coord;

use super::state::{InstanceTable, LocalInstance, WorkerTable};
use super::{costs, intervals, mem};

/// Which placement plugin this cluster runs (paper §6: pluggable; each
/// operator may customize).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SchedulerKind {
    RomBestFit,
    RomFirstFit,
    Ldp,
}

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub id: ClusterId,
    pub scheduler: SchedulerKind,
    pub aggregate_interval: SimTime,
    /// Delta-coalescing threshold for cluster→root aggregate reports: an
    /// aggregate tick only sends when a mean/total moved by more than
    /// this fraction since the last report (feasibility-relevant fields —
    /// worker count, best single worker, virtualization, area — always
    /// force a send). The worker-tier telemetry governor (§4.1) applied
    /// one tier up.
    pub aggregate_delta: f64,
    /// Staleness bound on the coalescing: resend unconditionally once the
    /// last report is this old, so the root's view is never more stale
    /// than this even under a perfectly steady fleet.
    pub aggregate_max_age: SimTime,
    pub health_interval: SimTime,
    pub worker_dead_after: SimTime,
    /// Advertised operation zone.
    pub area: Option<Area>,
    /// Gossip fan-out for Vivaldi peer hints.
    pub peer_hint_size: usize,
}

impl ClusterConfig {
    pub fn new(id: ClusterId, scheduler: SchedulerKind) -> Self {
        ClusterConfig {
            id,
            scheduler,
            aggregate_interval: intervals::cluster_aggregate(),
            aggregate_delta: 0.05,
            aggregate_max_age: intervals::aggregate_max_age(),
            health_interval: intervals::health_sweep(),
            worker_dead_after: intervals::worker_dead_after(),
            area: None,
            peer_hint_size: 3,
        }
    }
}

pub struct ClusterOrchestrator {
    pub cfg: ClusterConfig,
    root: ActorId,
    /// Worker table: node → profile (A_n view), slot-mapped — lookups are
    /// O(log n) instead of the old linear `Vec` scan per status change.
    pub workers: WorkerTable,
    worker_actors: BTreeMap<NodeId, ActorId>,
    last_report: BTreeMap<NodeId, SimTime>,
    pub broker: MqttBroker,
    subnets: SubnetAllocator,
    /// Instance records with task→instances and node→instances indices:
    /// table pushes, LDP refreshes and undeploy sweeps touch only the
    /// affected task/node instead of every instance in the cluster.
    instances: InstanceTable,
    /// Coalesced dissemination buffer: per destination worker, the set of
    /// tasks whose conversion-table row changed since the last flush.
    /// Destinations are captured at change time (so a teardown's
    /// authoritative empty row still reaches the former host); location
    /// snapshots are computed at flush time (intermediate flaps collapse).
    table_dirty: BTreeMap<NodeId, BTreeSet<TaskId>>,
    /// Whether a `TableFlush` tick is armed (lazy — idle clusters tick
    /// nothing).
    flush_scheduled: bool,
    /// Task → running locations within this cluster (LDP context + table
    /// resolution source).
    ldp_ctx: LdpContext,
    /// Workers that requested each task's ServiceIP (paper §5: "any
    /// future updates to the requested serviceIPs are automatically
    /// pushed to the worker") — updates go only to interested workers.
    interest: BTreeMap<TaskId, BTreeSet<NodeId>>,
    /// In-flight SLA-violation migrations: replacement → original
    /// instance (the original is undeployed once the replacement runs —
    /// paper §6: "the previous instance is undeployed" after the migrated
    /// one becomes operational).
    migrations: BTreeMap<InstanceId, InstanceId>,
    /// Monotonic mint for locally-created replacement instances
    /// (migration and recovery). A counter — not `original | tag` — so a
    /// replacement that itself fails or migrates again gets a *fresh* id
    /// instead of colliding with a live record.
    next_local: u64,
    /// Instance ids undeployed before any record existed: the root's
    /// undeploy raced the in-flight `DelegateTask`, which must be dropped
    /// on arrival instead of deploying an instance nobody tracks.
    undeploy_tombstones: BTreeSet<InstanceId>,
    /// Services the root has torn down (`UndeployService` seen). Late
    /// delegations, recoveries and migrations for them are refused.
    dead_services: BTreeSet<ServiceId>,
    /// Replacements announced to the root whose adoption verdict is
    /// still pending: replacement → (original, reason, target worker,
    /// task). Consulted when the `InstanceReplacedAck` arrives (refused
    /// ⇒ tear the replacement down; a recovery refusal escalates instead
    /// so the replica is not silently lost). Doubles as the
    /// minted-replacement log shipped in `ResyncSnapshot`: every entry
    /// here is an adoption the root may have never seen.
    pending_adoptions: BTreeMap<InstanceId, (InstanceId, ReplacementReason, NodeId, TaskId)>,
    /// The cluster's own lease on the root uplink, fed by root-originated
    /// traffic (the 5s liveness `Ping` is the cadence signal). Mirrors
    /// the root's per-cluster link state machine.
    uplink: WsLink,
    /// Set when the uplink lease was observed `Partitioned` on an
    /// aggregate tick; the first root message afterwards heals it and
    /// replays the outbox.
    uplink_partitioned: bool,
    /// Bounded-retry buffer for critical cluster→root messages sent
    /// while the lease is unhealthy (`ClusterReport`,
    /// `InstanceReplaced`, `DelegationResult`): the reliable transport's
    /// retransmit cap means a long cut WOULD drop them. At-least-once —
    /// the root's receive paths are idempotent — and budget-bounded: an
    /// entry that exhausts its retries is dropped and the post-heal
    /// anti-entropy resync becomes the recovery path of last resort.
    outbox: Outbox<OakMsg>,
    /// Outbox seq of the latest buffered `ClusterReport`; each newer
    /// report supersedes it (a fresher aggregate makes it meaningless).
    report_seq: Option<u64>,
    /// Replacement id → outbox seq of its buffered `InstanceReplaced`
    /// (cleared by the `InstanceReplacedAck`).
    replaced_seq: BTreeMap<InstanceId, u64>,
    /// Outbox drops already mirrored into metrics.
    outbox_dropped_seen: u64,
    /// Last scheduler wall time (reported to root for Fig. 6/8).
    pub last_calc: SimTime,
    pub sched_ops: u64,
    aggregate_ticks: u64,
    /// Delta-coalescing state: when the last `ClusterReport` went out and
    /// what it carried. Ticks whose aggregate moved less than
    /// `cfg.aggregate_delta` since then are suppressed (until
    /// `cfg.aggregate_max_age` forces a resend).
    last_aggregate: Option<(SimTime, AggregateStats)>,
    /// The `service_cpu` rows the last sent report carried: a changed
    /// row forces a send even when the capacity aggregate stayed inside
    /// the threshold, so the root's QoS-telemetry view (and a CPU-keyed
    /// autoscaler) is never staler than one aggregate tick after a
    /// change.
    last_service_cpu: Vec<(ServiceId, u64)>,
    /// Incarnation number of this orchestrator process. Starts at 1; a
    /// crash-restart comes up under `old + 1` (see [`Self::restarted`]).
    /// Stamped into every worker-bound command and the registration
    /// handshake so workers can fence messages queued by a dead
    /// incarnation (epoch 0 on the wire means unset/legacy).
    pub epoch: u64,
    /// True between a cold restart and the Recovering→Active transition:
    /// the tables are being rebuilt bottom-up from worker re-register
    /// censuses and are not yet authoritative — delegations are refused,
    /// the root's resync solicitation is deferred, and the grace timer
    /// (`intervals::recovery_grace`) ends the window.
    recovering: bool,
    /// A `ResyncRequest` arrived while still Recovering: answer it with
    /// the rebuilt census at the Recovering→Active transition instead of
    /// shipping a half-built snapshot.
    resync_pending: bool,
    registered: bool,
    started: bool,
}

/// Locally-minted replacement ids: bit 63 tags failure recoveries, bit 62
/// migration replacements; the incarnation epoch (low 6 bits) sits at
/// bits 56..62 — so a restarted orchestrator, whose mint counter starts
/// from zero again, can never re-issue an id the dead incarnation already
/// registered with the root — the cluster id sits at bits 48..56 and the
/// low bits hold `LOCAL_MINT_BASE + counter`. The base keeps the low
/// 32 bits (used by the worker's deploy-ack timer codes) disjoint from
/// root-minted ids, which count up from zero.
const RECOVERY_TAG: u64 = 1 << 63;
const MIGRATION_TAG: u64 = 1 << 62;
const LOCAL_MINT_BASE: u64 = 1 << 30;

impl ClusterOrchestrator {
    pub fn new(cfg: ClusterConfig, root: ActorId) -> Self {
        ClusterOrchestrator {
            cfg,
            root,
            workers: WorkerTable::default(),
            worker_actors: BTreeMap::new(),
            last_report: BTreeMap::new(),
            broker: MqttBroker::default(),
            subnets: SubnetAllocator::default(),
            instances: InstanceTable::default(),
            table_dirty: BTreeMap::new(),
            flush_scheduled: false,
            ldp_ctx: LdpContext::default(),
            interest: BTreeMap::new(),
            migrations: BTreeMap::new(),
            pending_adoptions: BTreeMap::new(),
            uplink: WsLink::new(SimTime::ZERO),
            uplink_partitioned: false,
            outbox: Outbox::new(4, SimTime::from_secs(8.0)),
            report_seq: None,
            replaced_seq: BTreeMap::new(),
            outbox_dropped_seen: 0,
            next_local: 0,
            undeploy_tombstones: BTreeSet::new(),
            dead_services: BTreeSet::new(),
            last_calc: SimTime::ZERO,
            sched_ops: 0,
            aggregate_ticks: 0,
            last_aggregate: None,
            last_service_cpu: Vec::new(),
            epoch: 1,
            recovering: false,
            resync_pending: false,
            registered: false,
            started: false,
        }
    }

    /// Cold-restart constructor: a fresh orchestrator process for a
    /// cluster whose previous incarnation crashed. All authoritative
    /// state is gone — tables rebuild bottom-up from worker re-register
    /// censuses during the Recovering window. `epoch` must be strictly
    /// greater than every epoch the old incarnation ever used, and `now`
    /// is the restart instant: the uplink lease starts from it (a lease
    /// born at time zero would read Partitioned immediately on a late
    /// restart and pollute the partition counters).
    pub fn restarted(cfg: ClusterConfig, root: ActorId, epoch: u64, now: SimTime) -> Self {
        let mut c = Self::new(cfg, root);
        c.epoch = epoch;
        c.recovering = true;
        c.uplink = WsLink::new(now);
        c
    }

    fn ensure_started(&mut self, ctx: &mut Ctx<'_>) {
        if !self.started {
            self.started = true;
            ctx.add_mem(mem::CLUSTER_BASE_MB);
            ctx.schedule(
                self.cfg.aggregate_interval,
                SimMsg::Timer(TimerKind::ClusterAggregate),
            );
            ctx.schedule(
                self.cfg.health_interval,
                SimMsg::Timer(TimerKind::HealthSweep),
            );
        }
    }

    /// Register with the root (call once after spawning).
    pub fn register(&mut self, ctx: &mut Ctx<'_>) {
        if !self.registered {
            self.registered = true;
            let msg = SimMsg::Oak(OakMsg::RegisterCluster {
                cluster: self.cfg.id,
                orchestrator: ctx.self_id,
                parent: crate::hierarchy::ROOT,
                epoch: self.epoch,
            });
            let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
            ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
        }
    }

    fn profile_mut(&mut self, node: NodeId) -> Option<&mut NodeProfile> {
        self.workers.get_mut(node)
    }
    fn profile(&self, node: NodeId) -> Option<&NodeProfile> {
        self.workers.get(node)
    }

    /// Live (non-terminal) instance records this cluster tracks, sorted by
    /// id — the census/leak-check view used by the churn harness. After a
    /// full drain this must be empty.
    pub fn live_instances(&self) -> Vec<(InstanceId, TaskId, NodeId, ServiceState)> {
        self.instances
            .iter()
            .filter(|(_, li)| !li.state.is_terminal())
            .map(|(iid, li)| (iid, li.task, li.node, li.state))
            .collect()
    }

    /// Total capacity currently reserved across this cluster's worker
    /// profiles. After a full drain this must be zero.
    pub fn reserved(&self) -> Capacity {
        self.workers
            .iter()
            .fold(Capacity::ZERO, |acc, w| acc + w.used)
    }

    /// Per-service observed CPU (mc) across this cluster's Running
    /// instances, from the latest worker telemetry — the rows shipped to
    /// the root on each (coalesced) aggregate report.
    fn service_cpu(&self) -> Vec<(ServiceId, u64)> {
        let mut per: BTreeMap<ServiceId, u64> = BTreeMap::new();
        for (_, li) in self.instances.iter() {
            if li.state == ServiceState::Running && li.observed_cpu_mc > 0 {
                *per.entry(li.task.service).or_insert(0) += li.observed_cpu_mc as u64;
            }
        }
        per.into_iter().collect()
    }

    /// Mint a fresh locally-unique instance id (see the tag constants).
    fn mint_local(&mut self, tag: u64) -> InstanceId {
        self.next_local += 1;
        InstanceId(
            tag | ((self.epoch & 0x3F) << 56)
                | ((self.cfg.id.0 as u64 & 0xFF) << 48)
                | (LOCAL_MINT_BASE + self.next_local),
        )
    }

    /// Any root-originated message proves the uplink works: refresh the
    /// lease, and when it was observed Partitioned, heal — replaying the
    /// buffered critical messages (at-least-once; the root's receive
    /// paths are idempotent).
    fn note_root_activity(&mut self, ctx: &mut Ctx<'_>) {
        self.uplink.on_activity(ctx.now);
        if self.uplink_partitioned {
            self.uplink_partitioned = false;
            ctx.metrics().inc("cluster.uplink_healed");
            for (_seq, msg) in self.outbox.replay_all(ctx.now) {
                ctx.metrics().inc("cluster.outbox_replayed");
                let wire = SimMsg::Oak(msg);
                let bytes = wire.default_wire_bytes() + WS_FRAME_OVERHEAD;
                // lint: allow(flow-handled, retransmit of a buffered critical message; the visible send at each enqueue site carries this flow edge)
                ctx.send(self.root, wire, bytes, labels::CLUSTER_TO_ROOT);
            }
        }
    }

    /// Record the retry obligation for a critical cluster→root message
    /// the caller just put on the wire: when the uplink lease is not
    /// Healthy, a copy is parked in the bounded-retry outbox — the
    /// reliable transport alone parks-and-retries only up to its
    /// retransmit cap, so a long cut would silently drop the message.
    /// Returns the outbox seq when a copy was buffered.
    fn buffer_critical(&mut self, ctx: &mut Ctx<'_>, wire: &SimMsg) -> Option<u64> {
        if self.uplink.health(ctx.now) == LinkHealth::Healthy {
            return None;
        }
        let SimMsg::Oak(payload) = wire else {
            return None;
        };
        ctx.metrics().inc("cluster.outbox_buffered");
        Some(self.outbox.enqueue(payload.clone(), ctx.now))
    }

    /// Register a locally-minted successor with the root (the cluster
    /// half of the replacement-tracking protocol). Sent at mint time so
    /// the root's placement view stays authoritative; the verdict comes
    /// back as `InstanceReplacedAck` (refused ⇒ teardown).
    fn announce_replacement(
        &mut self,
        ctx: &mut Ctx<'_>,
        original: InstanceId,
        replacement: InstanceId,
        reason: ReplacementReason,
    ) {
        let Some(li) = self.instances.get(replacement) else {
            return;
        };
        let (task, node) = (li.task, li.node);
        self.pending_adoptions
            .insert(replacement, (original, reason, node, task));
        let msg = SimMsg::Oak(OakMsg::InstanceReplaced {
            cluster: self.cfg.id,
            service: task.service,
            task,
            original,
            replacement,
            reason,
        });
        if let Some(seq) = self.buffer_critical(ctx, &msg) {
            self.replaced_seq.insert(replacement, seq);
        }
        let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
        ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
    }

    /// Locally finalize one instance into a terminal state: push the
    /// authoritative (empty) table rows, notify the root, drop the record
    /// and release the reserved capacity — exactly once. Used when the
    /// hosting worker can no longer ack the teardown (dead or
    /// deregistered): the control plane must not wait forever for a
    /// confirmation that cannot arrive, or the record and its reserved
    /// capacity leak.
    fn finalize_instance(
        &mut self,
        ctx: &mut Ctx<'_>,
        instance: InstanceId,
        state: ServiceState,
    ) {
        let Some(li) = self.instances.get_mut(instance) else {
            return;
        };
        li.state = state;
        let (task, node) = (li.task, li.node);
        self.refresh_ldp_target(task);
        // Buffer while the record is still present so the (former) host
        // is captured as a destination — the flush then sends it the
        // authoritative (empty) row.
        self.mark_table_dirty(ctx, task);
        let msg = SimMsg::Oak(OakMsg::InstanceStatus {
            instance,
            node,
            state,
        });
        let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
        ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
        if let Some(li) = self.instances.remove(instance) {
            if let Some(p) = self.profile_mut(li.node) {
                p.used -= li.request;
                p.instances = p.instances.saturating_sub(1);
            }
            ctx.add_mem(-mem::PER_INSTANCE_MB);
        }
    }

    /// Run the configured placement plugin over the live worker table
    /// (minus `exclude`, for migrations away from a violating worker).
    fn run_scheduler(
        &mut self,
        ctx: &mut Ctx<'_>,
        task: TaskId,
        sla: &TaskSla,
        exclude: Option<NodeId>,
    ) -> Placement {
        self.sched_ops += 1;
        let workers = self.workers.as_slice();
        // Cost scales with the candidate set actually scanned.
        let excluded = exclude.map_or(0, |x| usize::from(self.workers.contains(x)));
        let n = (workers.len() - excluded).max(1) as f64;
        let input = PlacementInput {
            sla,
            workers,
            service_hint: task.service,
            exclude,
        };
        let (placement, cost_ms) = match self.cfg.scheduler {
            SchedulerKind::RomBestFit => (
                RomScheduler {
                    strategy: RomStrategy::BestFit,
                }
                .place(&input),
                costs::ROM_PER_WORKER_MS * n,
            ),
            SchedulerKind::RomFirstFit => (
                RomScheduler {
                    strategy: RomStrategy::FirstFit,
                }
                .place(&input),
                costs::ROM_PER_WORKER_MS * n * 0.5,
            ),
            SchedulerKind::Ldp => {
                let seed = ctx.rng().next_u64();
                let orch_node = ctx.my_node();
                let probes = sla.s2u.len() as u32;
                // Probe pings are ground-truth network RTTs measured from
                // candidate workers towards the user's uplink (the
                // orchestrator node stands in for the user's attachment
                // point, Alg. 2 line 11). Measured **lazily**: only the
                // ≤probe_count sampled candidates are ever pinged —
                // O(probes), not an O(workers) fleet-wide pre-measure per
                // placement. Memoized so a node probed by several S2U
                // constraints is measured once.
                let pings = std::cell::Cell::new(0u32);
                let placement = {
                    let pings = &pings;
                    let mut rtt_memo: BTreeMap<NodeId, f64> = BTreeMap::new();
                    let ctx_ref = &mut *ctx;
                    let ping = move |node: NodeId, _c: &crate::sla::S2uConstraint| {
                        *rtt_memo.entry(node).or_insert_with(|| {
                            pings.set(pings.get() + 1);
                            ctx_ref.rtt_ms(node, orch_node)
                        })
                    };
                    let mut ldp =
                        LdpScheduler::new(&self.ldp_ctx, Box::new(ping), seed);
                    ldp.place(&input)
                };
                (
                    placement,
                    costs::LDP_PER_WORKER_MS * n
                        + costs::LDP_PING_MS * pings.get() as f64
                        + costs::LDP_TRILATERATION_MS * probes as f64,
                )
            }
        };
        ctx.charge_cpu(cost_ms);
        // Per-op scheduler cost, attributable by churn benches.
        ctx.metrics().observe("cluster.sched_ms", cost_ms);
        self.last_calc = SimTime::from_millis(cost_ms);
        placement
    }

    /// Mark a task's conversion-table row dirty for the workers that
    /// either host an instance of it or have requested its ServiceIP
    /// (paper §5's subscription semantics — no cluster-wide broadcast).
    /// Deltas coalesce in `table_dirty` until the next dissemination tick
    /// or an explicit [`Self::flush_tables`] barrier: one batched
    /// `TableUpdate` per destination instead of one message per change
    /// per target.
    fn mark_table_dirty(&mut self, ctx: &mut Ctx<'_>, task: TaskId) {
        let mut targets = self.instances.nodes_of_task(task);
        if let Some(interested) = self.interest.get(&task) {
            targets.extend(interested.iter().copied());
        }
        for node in targets {
            self.table_dirty.entry(node).or_default().insert(task);
        }
        if !self.flush_scheduled && !self.table_dirty.is_empty() {
            self.flush_scheduled = true;
            ctx.schedule(
                intervals::table_dissemination(),
                SimMsg::Timer(TimerKind::TableFlush),
            );
        }
    }

    /// Flush the coalesced dissemination buffer: one batched
    /// `TableUpdate` per destination worker carrying an authoritative
    /// snapshot (computed now, so intermediate flaps have collapsed) of
    /// every dirty task row. Dead/deregistered destinations are skipped —
    /// the authoritative update they miss is irrelevant to a corpse.
    fn flush_tables(&mut self, ctx: &mut Ctx<'_>) {
        if self.table_dirty.is_empty() {
            return;
        }
        let dirty = std::mem::take(&mut self.table_dirty);
        let mut snapshots: BTreeMap<TaskId, TableEntry> = BTreeMap::new();
        let mut sent = 0u64;
        for (node, tasks) in dirty {
            // Snapshot every dirty task — even rows whose only captured
            // destination is gone — so the interest GC below still sees
            // them (a subscriber dying before the flush must not pin a
            // dead service's interest row forever).
            let actor = self.worker_actors.get(&node).copied();
            let mut entries = Vec::with_capacity(tasks.len());
            for task in tasks {
                let e = snapshots.entry(task).or_insert_with(|| TableEntry {
                    task,
                    locations: self.locations_of(task),
                });
                if actor.is_some() {
                    entries.push(e.clone());
                }
            }
            let Some(actor) = actor else {
                continue;
            };
            let msg = SimMsg::Oak(OakMsg::TableUpdate { entries });
            let bytes = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
            ctx.send(actor, msg, bytes, labels::CLUSTER_TO_WORKER);
            sent += 1;
        }
        ctx.charge_cpu(costs::TABLE_OP_MS * snapshots.len().max(1) as f64);
        ctx.metrics().inc("cluster.table_flush");
        ctx.metrics().add("cluster.table_flush_msgs", sent);
        // Interest GC: once a dead service's task flushed its
        // authoritative empty row to every captured subscriber, the
        // subscription can never fire again — drop it. (Not earlier:
        // removing interest before this flush would strand subscribers
        // with stale rows.)
        for (task, entry) in &snapshots {
            if entry.locations.is_empty() && self.dead_services.contains(&task.service) {
                self.interest.remove(task);
            }
        }
    }

    fn locations_of(&self, task: TaskId) -> Vec<InstanceLocation> {
        self.instances
            .of_task(task)
            .filter(|(_, li)| li.state == ServiceState::Running)
            .map(|(iid, li)| {
                let rtt = self
                    .profile(li.node)
                    .map(|p| p.vivaldi.coord.distance(&Coord([0.0; 4])))
                    .unwrap_or(0.0);
                InstanceLocation {
                    instance: iid,
                    task,
                    node: li.node,
                    rtt_ms: rtt,
                }
            })
            .collect()
    }

    /// Update LDP context after placement changes.
    fn refresh_ldp_target(&mut self, task: TaskId) {
        let locs: Vec<(crate::geo::GeoPoint, Coord)> = self
            .instances
            .of_task(task)
            .filter(|(_, li)| li.state == ServiceState::Running)
            .filter_map(|(_, li)| {
                self.profile(li.node)
                    .map(|p| (p.spec.location, p.vivaldi.coord))
            })
            .collect();
        if locs.is_empty() {
            self.ldp_ctx.clear_target(task);
        } else {
            self.ldp_ctx.set_target(task, locs);
        }
    }

    /// Handle a dead worker: finalize its instances as Failed (record
    /// dropped, bookkeeping released — the reserved capacity died with
    /// the worker's profile), then try local re-placement and escalate to
    /// the root when the cluster cannot host them (paper §4.2).
    fn handle_worker_dead(&mut self, ctx: &mut Ctx<'_>, node: NodeId) {
        ctx.metrics().inc("cluster.worker_dead");
        // Release the per-worker bookkeeping charged at registration —
        // deregistration must mirror it or long churn runs drift the
        // cluster's reported footprint.
        if self.workers.remove(node).is_some() {
            ctx.add_mem(-mem::PER_WORKER_MB);
        }
        self.worker_actors.remove(&node);
        self.last_report.remove(&node);
        self.subnets.release(node);

        // The node index hands back exactly the dead worker's instances —
        // no full-table filter per death.
        let affected: Vec<(InstanceId, TaskId, TaskSla)> = self
            .instances
            .of_node(node)
            .filter(|(_, li)| !li.state.is_terminal())
            .map(|(iid, li)| (iid, li.task, li.sla.clone()))
            .collect();
        for (iid, task, sla) in affected {
            // An in-flight migration replacement died with its worker:
            // cancel the migration and keep the original running (the SLA
            // watchdog will retry if the violation persists).
            let was_replacement = self.migrations.remove(&iid).is_some();
            // The reverse: the dead instance was already being migrated
            // away — its replacement *is* the recovery, don't mint a
            // second one.
            let has_replacement = self.migrations.values().any(|o| *o == iid);

            self.finalize_instance(ctx, iid, ServiceState::Failed);

            if was_replacement {
                ctx.metrics().inc("cluster.migration_failed");
                continue;
            }
            if has_replacement || self.dead_services.contains(&task.service) {
                continue;
            }
            match self.run_scheduler(ctx, task, &sla, None) {
                Placement::Placed { worker, .. } => {
                    // Local recovery under a fresh locally-minted id,
                    // registered with the root as the successor of the
                    // dead instance so the global replica count stays
                    // authoritative.
                    let new_id = self.mint_local(RECOVERY_TAG);
                    self.deploy_to(
                        ctx,
                        new_id,
                        task,
                        sla,
                        worker,
                        Some((iid, ReplacementReason::LocalRecovery)),
                    );
                    self.announce_replacement(
                        ctx,
                        iid,
                        new_id,
                        ReplacementReason::LocalRecovery,
                    );
                    ctx.metrics().inc("cluster.local_recovery");
                }
                Placement::Infeasible => {
                    ctx.metrics().inc("cluster.escalated");
                    let msg = SimMsg::Oak(OakMsg::EscalateReschedule {
                        task,
                        instance: iid,
                        sla,
                    });
                    let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                    ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
                }
            }
        }
    }

    /// Begin a migration: find a different worker for the instance's
    /// task, deploy a replacement there, and remember to undeploy the
    /// original once the replacement reports Running (paper §4.2/§6:
    /// migration = rescheduling + deferred teardown). Returns true when a
    /// replacement deployment actually started. `escalate` selects the
    /// SLA-violation behavior (infeasible local placement escalates to
    /// the root); API-driven migrations pass false and are rejected
    /// instead — escalation would replicate without ever tearing the
    /// original down.
    fn start_migration(
        &mut self,
        ctx: &mut Ctx<'_>,
        original: InstanceId,
        escalate: bool,
    ) -> bool {
        if self.migrations.values().any(|o| *o == original) {
            return false; // already migrating
        }
        let Some(li) = self.instances.get(original) else {
            return false;
        };
        if li.state != ServiceState::Running {
            return false;
        }
        if self.dead_services.contains(&li.task.service) {
            // Teardown racing a migration: the replacement would outlive
            // the service.
            return false;
        }
        let (task, sla, current_node) = (li.task, li.sla.clone(), li.node);
        // Exclude the violating worker from candidates; with nobody else
        // to move to there is no migration to start.
        let others = self.workers.len() - usize::from(self.workers.contains(current_node));
        if others == 0 {
            return false;
        }
        // Run the placement over the reduced table (same plugin).
        let placement = self.run_scheduler(ctx, task, &sla, Some(current_node));
        match placement {
            Placement::Placed { worker, .. } => {
                ctx.metrics().inc("cluster.migration_started");
                let replacement = self.mint_local(MIGRATION_TAG);
                self.migrations.insert(replacement, original);
                self.deploy_to(
                    ctx,
                    replacement,
                    task,
                    sla,
                    worker,
                    Some((original, ReplacementReason::Migration)),
                );
                self.announce_replacement(
                    ctx,
                    original,
                    replacement,
                    ReplacementReason::Migration,
                );
                true
            }
            Placement::Infeasible => {
                if escalate {
                    // Cannot improve locally; escalate (paper §4.2).
                    let msg = SimMsg::Oak(OakMsg::EscalateReschedule {
                        task,
                        instance: original,
                        sla,
                    });
                    let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                    ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
                }
                false
            }
        }
    }

    fn deploy_to(
        &mut self,
        ctx: &mut Ctx<'_>,
        instance: InstanceId,
        task: TaskId,
        sla: TaskSla,
        worker: NodeId,
        origin: Option<(InstanceId, ReplacementReason)>,
    ) {
        // Reserve capacity eagerly so concurrent placements see it.
        let request = sla.request();
        if let Some(p) = self.profile_mut(worker) {
            p.used += request;
            p.instances += 1;
        }
        self.instances.insert(
            instance,
            LocalInstance {
                task,
                node: worker,
                state: ServiceState::Scheduled,
                request,
                observed_cpu_mc: 0,
                sla: sla.clone(),
            },
        );
        ctx.add_mem(mem::PER_INSTANCE_MB);
        let actor = self.worker_actors[&worker];
        let msg = SimMsg::Oak(OakMsg::DeployInstance {
            instance,
            task,
            request,
            image_mb: 60,
            service_ips: vec![
                ServiceIp::RoundRobin(task),
                ServiceIp::Closest(task),
            ],
            sla,
            origin,
            epoch: self.epoch,
        });
        let bytes = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
        ctx.send(actor, msg, bytes, labels::CLUSTER_TO_WORKER);
    }

    /// Ship the anti-entropy census to the root: every live instance
    /// plus the minted-replacement log (adoptions still awaiting a
    /// verdict — exactly the lineage edges the root may have missed).
    fn send_resync_snapshot(&mut self, ctx: &mut Ctx<'_>) {
        ctx.metrics().inc("cluster.resync_sent");
        let instances: Vec<(InstanceId, TaskId, ServiceState, NodeId)> = self
            .instances
            .iter()
            .filter(|(_, li)| !li.state.is_terminal())
            .map(|(iid, li)| (iid, li.task, li.state, li.node))
            .collect();
        let replacements: Vec<_> = self
            .pending_adoptions
            .iter()
            .map(|(repl, &(orig, reason, _node, task))| {
                (task.service, task, orig, *repl, reason)
            })
            .collect();
        let msg = SimMsg::Oak(OakMsg::ResyncSnapshot {
            cluster: self.cfg.id,
            instances,
            replacements,
        });
        let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
        ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
    }

    /// Recovering → Active: the census window is over and the rebuilt
    /// tables are now authoritative. Completes migration cutovers the
    /// crash froze (a census-seeded replacement already Running will
    /// never produce a *fresh* Running transition, so the normal
    /// cutover trigger can't fire) and answers a deferred resync
    /// solicitation with the full census.
    fn finish_recovery(&mut self, ctx: &mut Ctx<'_>) {
        if !self.recovering {
            return;
        }
        self.recovering = false;
        ctx.metrics().inc("cluster.recovery_completed");
        let ready: Vec<(InstanceId, InstanceId)> = self
            .migrations
            .iter()
            .filter(|(r, _)| {
                self.instances
                    .get(**r)
                    .map(|li| li.state == ServiceState::Running)
                    .unwrap_or(false)
            })
            .map(|(r, o)| (*r, *o))
            .collect();
        for (replacement, original) in ready {
            self.migrations.remove(&replacement);
            // The original may have died with a worker before the crash
            // (its record was never census-rebuilt): nothing to tear
            // down then, the stale cutover entry just retires.
            if self.instances.get(original).is_some() {
                ctx.metrics().inc("cluster.recovery_cutover");
                ctx.send_local(
                    ctx.self_id,
                    SimMsg::Oak(OakMsg::UndeployInstance {
                        instance: original,
                        epoch: self.epoch,
                    }),
                );
            }
        }
        if self.resync_pending {
            self.resync_pending = false;
            self.send_resync_snapshot(ctx);
        }
    }
}

impl Actor for ClusterOrchestrator {
    fn handle(&mut self, ctx: &mut Ctx<'_>, msg: SimMsg) {
        self.ensure_started(ctx);
        match msg {
            // Driver bootstrap: register with the root. A restarted
            // incarnation also arms the recovery-grace timer: once it
            // fires, the bottom-up rebuild is declared done
            // (Recovering → Active, see `finish_recovery`).
            SimMsg::Timer(TimerKind::Custom(0)) => {
                self.register(ctx);
                if self.recovering {
                    ctx.schedule(
                        intervals::recovery_grace(),
                        SimMsg::Timer(TimerKind::Custom(1)),
                    );
                }
            }

            // Recovery-grace expiry: the census window is over.
            SimMsg::Timer(TimerKind::Custom(1)) => {
                self.finish_recovery(ctx);
            }

            SimMsg::Oak(OakMsg::RegisterClusterAck { accepted }) => {
                ctx.charge_cpu(costs::PING_MS);
                if !accepted {
                    ctx.metrics().inc("cluster.register_rejected");
                }
            }

            SimMsg::Oak(OakMsg::RegisterWorker { spec, engine, census }) => {
                ctx.charge_cpu(costs::SUBMIT_MS * 0.5);
                let node = spec.node;
                if self.workers.contains(node) && census.is_empty() {
                    // Re-register handshake: a worker process restarted
                    // under an id this cluster still tracks. The
                    // returning engine has an empty instance set, so
                    // everything attributed to the old process died with
                    // it — run the dead-worker path (finalize + local
                    // recovery/escalation) before accepting the fresh
                    // registration below. A census-carrying re-register
                    // (orchestrator restart, not worker restart) takes
                    // the seeding path instead: the worker kept its
                    // containers, only this side's tables were lost —
                    // and a duplicate handshake must stay idempotent.
                    ctx.metrics().inc("cluster.worker_reregistered");
                    self.handle_worker_dead(ctx, node);
                }
                if !self.workers.contains(node) {
                    ctx.add_mem(mem::PER_WORKER_MB);
                    self.workers.insert(NodeProfile::new(spec));
                }
                let subnet = self.subnets.subnet_for(node);
                self.broker.subscribe(
                    &format!("cluster/{}/worker/{}/cmd", self.cfg.id.0, node.0),
                    engine,
                );
                self.worker_actors.insert(node, engine);
                self.last_report.insert(node, ctx.now);
                // Bottom-up rebuild: each census row this incarnation
                // does not track becomes a fresh `InstanceTable` record,
                // re-reserving the worker's capacity and re-arming the
                // replacement lineage (pending adoption + migration
                // cutover bookkeeping) exactly as the dead incarnation
                // held them. Rows already tracked are duplicates of an
                // earlier handshake and are skipped.
                let mut seeded_tasks: BTreeSet<TaskId> = BTreeSet::new();
                for row in census {
                    if row.state.is_terminal() || self.instances.get(row.instance).is_some()
                    {
                        continue;
                    }
                    ctx.metrics().inc("cluster.census_seeded");
                    if let Some(p) = self.profile_mut(node) {
                        p.used += row.request;
                        p.instances += 1;
                    }
                    self.instances.insert(
                        row.instance,
                        LocalInstance {
                            task: row.task,
                            node,
                            state: row.state,
                            request: row.request,
                            observed_cpu_mc: 0,
                            sla: row.sla,
                        },
                    );
                    ctx.add_mem(mem::PER_INSTANCE_MB);
                    if let Some((original, reason)) = row.origin {
                        // The adoption verdict may have died with the old
                        // incarnation's outbox: re-arm the pending entry
                        // (shipped to the root in the deferred resync
                        // snapshot; the root's adoption is idempotent).
                        self.pending_adoptions
                            .insert(row.instance, (original, reason, node, row.task));
                        if reason == ReplacementReason::Migration {
                            self.migrations.insert(row.instance, original);
                        }
                    }
                    seeded_tasks.insert(row.task);
                }
                for task in seeded_tasks {
                    self.refresh_ldp_target(task);
                    self.mark_table_dirty(ctx, task);
                }
                let msg = SimMsg::Oak(OakMsg::RegisterWorkerAck {
                    subnet,
                    epoch: self.epoch,
                });
                let bytes = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
                ctx.send(engine, msg, bytes, labels::CLUSTER_TO_WORKER);
            }

            SimMsg::Oak(OakMsg::WorkerReport {
                node,
                used,
                vivaldi,
                instances,
            }) => {
                ctx.charge_cpu(costs::WORKER_REPORT_MS);
                if !self.workers.contains(node) {
                    // A deregistered (previously dead) worker talking
                    // again: ignoring it keeps it out of `last_report`,
                    // where it would otherwise look alive to the health
                    // sweep without ever being schedulable.
                    ctx.metrics().inc("cluster.report_unknown_worker");
                    return;
                }
                self.last_report.insert(node, ctx.now);
                if let Some(p) = self.profile_mut(node) {
                    p.used = used;
                    p.vivaldi = vivaldi;
                }
                // Reconcile instance states reported by the NodeEngine.
                let mut changed_tasks: BTreeSet<TaskId> = BTreeSet::new();
                let mut violations: Vec<InstanceId> = Vec::new();
                for (iid, state, qos_ms, cpu_mc) in instances {
                    let mut forward = None;
                    if let Some(li) = self.instances.get_mut(iid) {
                        li.observed_cpu_mc = cpu_mc;
                        if li.state != state {
                            li.state = state;
                            forward = Some((li.task, li.node));
                        }
                        // SLA violation check (paper §6: observed lapses
                        // trigger implicit migration as a new scheduling
                        // request, weighted by rigidness).
                        let viol = li
                            .sla
                            .s2u
                            .iter()
                            .any(|c| qos_ms > c.latency_threshold_ms * 1.5);
                        if viol && li.sla.rigidness > 0.5 && state == ServiceState::Running
                        {
                            ctx.metrics().inc("cluster.sla_violation");
                            violations.push(iid);
                        }
                    }
                    if let Some((task, lnode)) = forward {
                        changed_tasks.insert(task);
                        let msg = SimMsg::Oak(OakMsg::InstanceStatus {
                            instance: iid,
                            node: lnode,
                            state,
                        });
                        let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                        ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
                    }
                }
                // Telemetry-driven flips ride the dissemination tick: a
                // report flipping k instances of one task buffers one
                // dirty row, not k × targets messages.
                for task in changed_tasks {
                    self.refresh_ldp_target(task);
                    self.mark_table_dirty(ctx, task);
                }
                for iid in violations {
                    self.start_migration(ctx, iid, true);
                }
            }

            SimMsg::Oak(OakMsg::InstanceStatus {
                instance,
                node,
                state,
            }) => {
                // Direct status from a NodeEngine (deploy ack path).
                ctx.charge_cpu(costs::WORKER_REPORT_MS);
                // Migration completion: the replacement is operational →
                // terminate the original (paper §6).
                if state == ServiceState::Running {
                    if let Some(original) = self.migrations.remove(&instance) {
                        ctx.metrics().inc("cluster.migration_completed");
                        let undeploy = SimMsg::Oak(OakMsg::UndeployInstance {
                            instance: original,
                            epoch: self.epoch,
                        });
                        ctx.send_local(ctx.self_id, undeploy);
                    }
                }
                let mut task_changed = None;
                if let Some(li) = self.instances.get_mut(instance) {
                    if li.state != state {
                        li.state = state;
                        task_changed = Some(li.task);
                    }
                }
                if let Some(task) = task_changed {
                    // Buffer while the record is still present so the
                    // (former) host is captured as a destination — on
                    // teardown the flushed snapshot clears its table row.
                    self.refresh_ldp_target(task);
                    self.mark_table_dirty(ctx, task);
                    let msg = SimMsg::Oak(OakMsg::InstanceStatus {
                        instance,
                        node,
                        state,
                    });
                    let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                    ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
                }
                let mut removed = false;
                if state.is_terminal() {
                    // Drop the record and release the reserved capacity:
                    // doing both on removal means a late duplicate
                    // terminal report cannot double-free (API lifecycle:
                    // undeploy → capacity release happens exactly once).
                    if let Some(li) = self.instances.remove(instance) {
                        if let Some(p) = self.profile_mut(li.node) {
                            p.used -= li.request;
                            p.instances = p.instances.saturating_sub(1);
                        }
                        ctx.add_mem(-mem::PER_INSTANCE_MB);
                        removed = true;
                    }
                }
                // Deploy/teardown-ack barrier: only when this ack
                // genuinely changed a row's meaning (an instance became
                // routable or stopped being so) flush the coalesced
                // buffer now instead of waiting out the dissemination
                // tick. A duplicate/no-op ack (including a re-delivered
                // terminal report for an already-dropped record) must not
                // flush unrelated buffered rows — that would defeat the
                // coalescing.
                if task_changed.is_some() || removed {
                    self.flush_tables(ctx);
                }
            }

            SimMsg::Oak(OakMsg::DelegateTask {
                task,
                instance,
                sla,
                attempt: _,
            }) => {
                // An undeploy that raced this delegation already arrived:
                // the instance (or its whole service) is cancelled, and
                // deploying it would leak a container nobody tracks.
                // A DelegateTask arriving is root traffic: it proves the
                // uplink (and may heal a partitioned lease — e.g. the
                // root's send was parked in the cut and just delivered).
                self.note_root_activity(ctx);
                if self.undeploy_tombstones.remove(&instance)
                    || self.dead_services.contains(&task.service)
                {
                    ctx.metrics().inc("cluster.delegation_tombstoned");
                    return;
                }
                if self.recovering {
                    // Mid-rebuild tables are not a placement basis:
                    // refuse so the root's priority list spills to the
                    // next cluster instead of parking the instance on a
                    // half-seen worker set.
                    ctx.metrics().inc("cluster.delegation_while_recovering");
                    let msg = SimMsg::Oak(OakMsg::DelegationResult {
                        task,
                        instance,
                        worker: None,
                        calc_time: SimTime::ZERO,
                    });
                    self.buffer_critical(ctx, &msg);
                    let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                    ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
                    return;
                }
                let placement = self.run_scheduler(ctx, task, &sla, None);
                let calc_time = self.last_calc;
                // The result is critical: the root's pending-delegation
                // entry (and any API waiter behind it) hangs until it
                // arrives, so it rides the outbox when the lease is
                // unhealthy. No ack exists — retries stop at the budget
                // and the resync census settles whatever was lost.
                match placement {
                    Placement::Placed { worker, .. } => {
                        self.deploy_to(ctx, instance, task, sla, worker, None);
                        let msg = SimMsg::Oak(OakMsg::DelegationResult {
                            task,
                            instance,
                            worker: Some(worker),
                            calc_time,
                        });
                        self.buffer_critical(ctx, &msg);
                        let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                        ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
                    }
                    Placement::Infeasible => {
                        ctx.metrics().inc("cluster.infeasible");
                        let msg = SimMsg::Oak(OakMsg::DelegationResult {
                            task,
                            instance,
                            worker: None,
                            calc_time,
                        });
                        self.buffer_critical(ctx, &msg);
                        let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                        ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
                    }
                }
            }

            SimMsg::Oak(OakMsg::InstanceReplacedAck {
                original: _,
                replacement,
                adopted,
            }) => {
                ctx.charge_cpu(costs::PING_MS);
                self.note_root_activity(ctx);
                // The verdict confirms delivery: clear the buffered
                // announcement so the outbox stops replaying it.
                if let Some(seq) = self.replaced_seq.remove(&replacement) {
                    self.outbox.ack(seq);
                }
                let pending = self.pending_adoptions.remove(&replacement);
                if adopted {
                    ctx.metrics().inc("cluster.replacement_adopted");
                    // Close the adoption/status reorder window: re-push
                    // the replacement's current state so a Running (or
                    // terminal) report that raced ahead of the adoption
                    // is not lost to the root forever.
                    let status = match self.instances.get(replacement) {
                        Some(li) => Some((li.node, li.state)),
                        // The replacement died before the verdict came
                        // back (second failure): the root adopted a
                        // record whose Failed report it may have dropped
                        // pre-adoption — settle it now.
                        None => pending.map(|(_, _, node, _)| (node, ServiceState::Failed)),
                    };
                    if let Some((node, state)) = status {
                        let msg = SimMsg::Oak(OakMsg::InstanceStatus {
                            instance: replacement,
                            node,
                            state,
                        });
                        let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                        ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
                    }
                } else {
                    // Root refused custody (retired service or broken
                    // lineage): the replacement must not outlive the
                    // refusal — same discipline as ServiceRetired.
                    ctx.metrics().inc("cluster.replacement_refused");
                    let escalate = match (pending, self.instances.get(replacement)) {
                        (Some((_, ReplacementReason::LocalRecovery, _, _)), Some(li))
                            if !self.dead_services.contains(&li.task.service) =>
                        {
                            // A refused *recovery* would silently lose a
                            // replica; hand the reschedule back to the
                            // root (which refuses retired services
                            // itself, so this cannot resurrect one).
                            Some((li.task, li.sla.clone()))
                        }
                        _ => None,
                    };
                    if self.migrations.remove(&replacement).is_some() {
                        ctx.metrics().inc("cluster.migration_cancelled");
                    }
                    ctx.send_local(
                        ctx.self_id,
                        SimMsg::Oak(OakMsg::UndeployInstance {
                            instance: replacement,
                            epoch: self.epoch,
                        }),
                    );
                    if let Some((task, sla)) = escalate {
                        let msg = SimMsg::Oak(OakMsg::EscalateReschedule {
                            task,
                            instance: replacement,
                            sla,
                        });
                        let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                        ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
                    }
                }
            }

            // `epoch` is not fenced here: the cluster is the fencing
            // *authority*, not a subject — root-originated teardowns
            // arrive stamped 0 and self-sends carry the current epoch.
            SimMsg::Oak(OakMsg::UndeployInstance { instance, epoch: _ }) => {
                ctx.charge_cpu(costs::TABLE_OP_MS);
                // A targeted teardown of a migration *replacement*
                // (root-side scale-shrink now sees adopted successors):
                // cancel the in-flight migration so the original keeps
                // running and the bookkeeping entry cannot pin it as
                // "already migrating" forever.
                if self.migrations.remove(&instance).is_some() {
                    ctx.metrics().inc("cluster.migration_cancelled");
                }
                // Cancel any in-flight migration *of this instance*: the
                // original is being torn down deliberately (scale-down or
                // a targeted undeploy), so its replacement must go too —
                // otherwise it survives as an extra replica the root
                // never tracked.
                let replacements: Vec<InstanceId> = self
                    .migrations
                    .iter()
                    .filter(|(_, o)| **o == instance)
                    .map(|(r, _)| *r)
                    .collect();
                for r in replacements {
                    self.migrations.remove(&r);
                    ctx.metrics().inc("cluster.migration_cancelled");
                    ctx.send_local(
                        ctx.self_id,
                        SimMsg::Oak(OakMsg::UndeployInstance {
                            instance: r,
                            epoch: self.epoch,
                        }),
                    );
                }
                match self.instances.get(instance) {
                    Some(li) => {
                        let node = li.node;
                        let reachable = self
                            .worker_actors
                            .get(&node)
                            .copied()
                            .filter(|_| !ctx.is_failed(node));
                        match reachable {
                            Some(a) => {
                                let msg = SimMsg::Oak(OakMsg::UndeployInstance {
                                    instance,
                                    epoch: self.epoch,
                                });
                                let bytes =
                                    msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
                                ctx.send(a, msg, bytes, labels::CLUSTER_TO_WORKER);
                            }
                            None => {
                                // The hosting worker is dead/deregistered
                                // and can never ack: finalize from the
                                // control plane instead of leaking the
                                // record and its reserved capacity.
                                self.finalize_instance(
                                    ctx,
                                    instance,
                                    ServiceState::Terminated,
                                );
                            }
                        }
                    }
                    None => {
                        // Undeploy for an instance this cluster never
                        // deployed: the matching DelegateTask is still in
                        // flight — tombstone the id so it dies on arrival.
                        // Duplicate undeploys leave unconsumable junk
                        // here (ids are never reused), bounded by the
                        // cap; anything old enough to be evicted has a
                        // delegation that would have arrived long ago.
                        self.undeploy_tombstones.insert(instance);
                        while self.undeploy_tombstones.len() > 4096 {
                            self.undeploy_tombstones.pop_first();
                        }
                    }
                }
            }

            // API-driven migration (paper §6): reschedule the instance on
            // a different worker; the original is torn down once the
            // replacement reports Running. No escalation on rejection —
            // the caller observes the (lack of) progress via status.
            SimMsg::Oak(OakMsg::MigrateInstance { instance }) => {
                ctx.charge_cpu(costs::SUBMIT_MS * 0.5);
                if !self.start_migration(ctx, instance, false) {
                    ctx.metrics().inc("cluster.migration_rejected");
                }
            }

            // Service-wide teardown: undeploy every local instance of the
            // service — including replacements this cluster minted itself
            // (migration/local recovery), which the root never tracked.
            SimMsg::Oak(OakMsg::UndeployService { service }) => {
                ctx.charge_cpu(costs::SUBMIT_MS * 0.5);
                ctx.metrics().inc("cluster.undeploy_service");
                // Remember the teardown: late delegations, recoveries and
                // migrations of this service are refused from here on
                // (service ids are never reused).
                self.dead_services.insert(service);
                // Range-scan the task index: the sweep touches only this
                // service's instances, not every record in the cluster.
                let local: Vec<(InstanceId, NodeId)> = self
                    .instances
                    .of_service(service)
                    .filter(|(_, li)| !li.state.is_terminal())
                    .map(|(iid, li)| (iid, li.node))
                    .collect();
                // Mark every subscribed task of the service dirty NOW:
                // subscribers must eventually receive the authoritative
                // empty row. The interest rows themselves are garbage-
                // collected by `flush_tables` once that empty row has
                // actually been flushed (removing them here would strand
                // subscribers with stale conversion-table entries).
                let subscribed: Vec<TaskId> = self
                    .interest
                    .range(
                        TaskId { service, index: 0 }..=TaskId {
                            service,
                            index: u16::MAX,
                        },
                    )
                    .map(|(t, _)| *t)
                    .collect();
                for task in subscribed {
                    self.mark_table_dirty(ctx, task);
                }
                // Abandon in-flight migrations of this service.
                let doomed: BTreeSet<InstanceId> =
                    local.iter().map(|(iid, _)| *iid).collect();
                self.migrations
                    .retain(|r, o| !(doomed.contains(r) || doomed.contains(o)));
                for (iid, node) in local {
                    let reachable = self
                        .worker_actors
                        .get(&node)
                        .copied()
                        .filter(|_| !ctx.is_failed(node));
                    match reachable {
                        Some(a) => {
                            let msg = SimMsg::Oak(OakMsg::UndeployInstance {
                                instance: iid,
                                epoch: self.epoch,
                            });
                            let bytes = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
                            ctx.send(a, msg, bytes, labels::CLUSTER_TO_WORKER);
                        }
                        // Dead worker: the ack will never come — finalize
                        // the record now.
                        None => {
                            self.finalize_instance(ctx, iid, ServiceState::Terminated)
                        }
                    }
                }
            }

            SimMsg::Oak(OakMsg::ResolveIp { from, query }) => {
                ctx.charge_cpu(costs::TABLE_OP_MS);
                if let Some(task) = query.task() {
                    if self.dead_services.contains(&task.service) {
                        // Retired service: answer with the authoritative
                        // empty row and do NOT register interest — a
                        // re-created interest row for a dead service can
                        // never be marked dirty again, so the flush-time
                        // GC could never collect it.
                        if let Some(actor) = self.worker_actors.get(&from) {
                            let msg = SimMsg::Oak(OakMsg::TableUpdate {
                                entries: vec![TableEntry {
                                    task,
                                    locations: Vec::new(),
                                }],
                            });
                            let bytes = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
                            ctx.send(*actor, msg, bytes, labels::CLUSTER_TO_WORKER);
                        }
                        return;
                    }
                    self.interest.entry(task).or_default().insert(from);
                    let locations = self.locations_of(task);
                    if locations.is_empty() {
                        // Recursive resolution up the hierarchy (§5).
                        let msg = SimMsg::Oak(OakMsg::ResolveIpUp {
                            cluster: self.cfg.id,
                            from,
                            query,
                        });
                        let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                        ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
                    } else if let Some(actor) = self.worker_actors.get(&from) {
                        let msg = SimMsg::Oak(OakMsg::TableUpdate {
                            entries: vec![TableEntry {
                                task,
                                locations,
                            }],
                        });
                        let bytes = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
                        ctx.send(*actor, msg, bytes, labels::CLUSTER_TO_WORKER);
                    }
                }
            }

            SimMsg::Oak(OakMsg::TableUpdate { entries }) => {
                // Root answered a recursive resolution: fan out to the
                // workers interested in the resolved tasks.
                ctx.charge_cpu(costs::TABLE_OP_MS);
                let mut targets: BTreeSet<NodeId> = BTreeSet::new();
                for e in &entries {
                    if let Some(set) = self.interest.get(&e.task) {
                        targets.extend(set.iter().copied());
                    }
                }
                let actors: Vec<ActorId> = targets
                    .iter()
                    .filter_map(|n| self.worker_actors.get(n).copied())
                    .collect();
                for a in actors {
                    let msg = SimMsg::Oak(OakMsg::TableUpdate {
                        entries: entries.clone(),
                    });
                    let bytes = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
                    ctx.send(a, msg, bytes, labels::CLUSTER_TO_WORKER);
                }
            }

            SimMsg::Oak(OakMsg::Ping) => {
                ctx.charge_cpu(costs::PING_MS);
                // The root's liveness ping is the uplink lease's cadence
                // signal (mirrors the root treating our Pong the same
                // way) — and the first ping after a partition heals it.
                self.note_root_activity(ctx);
                let msg = SimMsg::Oak(OakMsg::Pong {
                    cluster: self.cfg.id,
                });
                let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
            }

            SimMsg::Timer(TimerKind::ClusterAggregate) => {
                ctx.charge_cpu(costs::AGGREGATE_MS);
                // Aggregate over *available* capacities A_n = C_n − U_n.
                let avail: Vec<(Capacity, crate::model::Virtualization)> = self
                    .workers
                    .iter()
                    .map(|w| (w.available(), w.spec.virtualization()))
                    .collect();
                let stats = AggregateStats::from_workers(
                    avail.iter().map(|(c, v)| (c, *v)),
                    self.cfg.area,
                );
                // Delta-coalescing (the §4.1 worker governor one tier
                // up): only push upward when the aggregate moved past the
                // threshold, the piggybacked per-service CPU rows changed
                // (the root's QoS-telemetry view must not silently stale
                // behind an under-threshold capacity move), or the last
                // report aged out — so the root's view has bounded
                // staleness even for a steady cluster.
                let service_cpu = self.service_cpu();
                let due = match &self.last_aggregate {
                    None => true,
                    Some((at, last)) => {
                        ctx.now.saturating_sub(*at) >= self.cfg.aggregate_max_age
                            || stats.delta_exceeds(last, self.cfg.aggregate_delta)
                            || service_cpu != self.last_service_cpu
                    }
                };
                if due {
                    let running = self
                        .instances
                        .iter()
                        .filter(|(_, li)| li.state == ServiceState::Running)
                        .count();
                    self.last_aggregate = Some((ctx.now, stats.clone()));
                    self.last_service_cpu = service_cpu.clone();
                    ctx.metrics().inc("cluster.report_sent");
                    // A fresher report supersedes any older one still
                    // parked in the outbox: the root only wants the
                    // latest aggregate, so at most one ClusterReport is
                    // ever buffered for replay.
                    if let Some(old) = self.report_seq.take() {
                        self.outbox.ack(old);
                    }
                    let msg = SimMsg::Oak(OakMsg::ClusterReport {
                        cluster: self.cfg.id,
                        stats,
                        running_instances: running,
                        service_cpu,
                    });
                    self.report_seq = self.buffer_critical(ctx, &msg);
                    let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                    ctx.send(self.root, msg, bytes, labels::CLUSTER_TO_ROOT);
                } else {
                    ctx.metrics().inc("cluster.report_suppressed");
                }

                // Uplink lease sweep: the aggregate tick is this
                // orchestrator's steady heartbeat, so it doubles as the
                // partition detector (mirror of the root's LivenessPing
                // sweep). Root-originated traffic through
                // `note_root_activity` flips it back.
                if !self.uplink_partitioned
                    && self.uplink.health(ctx.now) == LinkHealth::Partitioned
                {
                    self.uplink_partitioned = true;
                    ctx.metrics().inc("cluster.uplink_partitioned");
                }

                // Outbox pump: re-send critical messages whose backoff
                // expired. The lease may still be down — the re-sends
                // just die in the cut — but retries are bounded, so a
                // short flap loses nothing and a long partition falls
                // back to the heal-time resync.
                for (_seq, msg) in self.outbox.due(ctx.now) {
                    ctx.metrics().inc("cluster.outbox_retry");
                    let wire = SimMsg::Oak(msg);
                    let bytes = wire.default_wire_bytes() + WS_FRAME_OVERHEAD;
                    // lint: allow(flow-handled, retransmit of a buffered critical message; the visible send at each enqueue site carries this flow edge)
                    ctx.send(self.root, wire, bytes, labels::CLUSTER_TO_ROOT);
                }
                if self.outbox.dropped > self.outbox_dropped_seen {
                    ctx.metrics().add(
                        "cluster.outbox_dropped",
                        self.outbox.dropped - self.outbox_dropped_seen,
                    );
                    self.outbox_dropped_seen = self.outbox.dropped;
                }

                // Vivaldi gossip: send each worker a small peer sample
                // (every 4th tick — membership changes slowly).
                self.aggregate_ticks += 1;
                let n = self.workers.len();
                if n > 1 && self.aggregate_ticks % 4 == 1 {
                    let hints: Vec<(NodeId, ActorId)> = self
                        .worker_actors
                        .iter()
                        .map(|(n, a)| (*n, *a))
                        .collect();
                    for (node, actor) in hints {
                        let mut peers = Vec::new();
                        for _ in 0..self.cfg.peer_hint_size {
                            let i = ctx.rng().below(n);
                            let p = &self.workers.as_slice()[i];
                            if p.spec.node != node {
                                peers.push((p.spec.node, p.vivaldi));
                            }
                        }
                        if !peers.is_empty() {
                            let msg = SimMsg::Oak(OakMsg::PeerHint { peers });
                            let bytes = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
                            ctx.send(actor, msg, bytes, labels::CLUSTER_TO_WORKER);
                        }
                    }
                }
                ctx.schedule(
                    self.cfg.aggregate_interval,
                    SimMsg::Timer(TimerKind::ClusterAggregate),
                );
            }

            SimMsg::Oak(OakMsg::ResyncRequest) => {
                ctx.charge_cpu(costs::AGGREGATE_MS);
                // Only a healed root asks, so the request itself is
                // proof of life (and replays the outbox first — the
                // root's reconciliation then sees both channels).
                self.note_root_activity(ctx);
                if self.recovering {
                    // A half-built census would masquerade as the
                    // authoritative ground truth and the root's phase-3
                    // sweep would fail every instance whose worker has
                    // not re-registered yet. Answer at Recovering→Active
                    // instead.
                    self.resync_pending = true;
                    ctx.metrics().inc("cluster.resync_deferred");
                    return;
                }
                self.send_resync_snapshot(ctx);
            }

            SimMsg::Timer(TimerKind::TableFlush) => {
                // Dissemination tick: flush the coalesced buffer. The
                // timer re-arms lazily — the next dirty row schedules the
                // next tick, so an idle cluster stops ticking.
                self.flush_scheduled = false;
                self.flush_tables(ctx);
            }

            SimMsg::Timer(TimerKind::HealthSweep) => {
                ctx.charge_cpu(costs::IDLE_TICK_MS);
                let dead: Vec<NodeId> = self
                    .last_report
                    .iter()
                    .filter(|(_, at)| {
                        ctx.now.saturating_sub(**at) >= self.cfg.worker_dead_after
                    })
                    .map(|(n, _)| *n)
                    .collect();
                for node in dead {
                    self.handle_worker_dead(ctx, node);
                }
                ctx.schedule(
                    self.cfg.health_interval,
                    SimMsg::Timer(TimerKind::HealthSweep),
                );
            }

            // API traffic terminates at the root; ServiceDeployed is a
            // root→client notification; ResyncSnapshot is this tier's
            // own cluster→root reply. Declared so `oakestra lint` can
            // prove every other OakMsg variant has an arm above.
            // lint: wildcard(OakMsg: ApiCall, ApiReturn, ServiceDeployed)
            _ => {}
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

// Re-export for WorkerSpec construction convenience in benches.
