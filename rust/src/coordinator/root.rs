//! Root orchestrator (paper §3.2.1): the centralized control plane.
//! System manager (cluster registry, liveness), service manager (SLA
//! intake via the typed northbound API [`crate::api`], lifecycle,
//! remedial actions) and root scheduler (cluster priority lists +
//! delegation) over the [`crate::coordinator::db`].

use std::any::Any;
use std::collections::{BTreeMap, BTreeSet};

use crate::api::{self, ApiEnvelope, ApiError, ApiRequest, ApiResponse, API_VERSION, MAX_REPLICAS};
use crate::hierarchy::ClusterTree;
use crate::messaging::{labels, LinkHealth, WsLink, WS_FRAME_OVERHEAD};
use crate::model::ServiceState;
use crate::sim::{Actor, ActorId, Ctx, OakMsg, ReplacementReason, SimMsg, TimerKind};
use crate::sla::TaskSla;
use crate::util::{ClusterId, InstanceId, ServiceId, SimTime, TaskId};

use super::db::{AdoptError, ServiceDb};
use super::fedstate::ClusterTable;
use super::{costs, intervals, mem};

/// Root tunables.
#[derive(Clone, Debug)]
pub struct RootConfig {
    /// How many clusters from the priority list to try before failing a
    /// task (paper: iterate the list highest-priority-first).
    pub max_delegation_attempts: u32,
    pub liveness_interval: SimTime,
}

impl Default for RootConfig {
    fn default() -> Self {
        RootConfig {
            max_delegation_attempts: 4,
            liveness_interval: intervals::liveness_ping(),
        }
    }
}

/// In-flight delegation bookkeeping for one task instance. The candidate
/// list is the top-K partial selection computed **once** when the
/// delegation starts; a spill (`DelegationResult{None}`) pops the next
/// entry in O(1) instead of re-ranking the cluster set, and `refused`
/// records every cluster that said no so a refill selection (taken only
/// when the precomputed list runs dry with attempts left) can never
/// re-offer one.
#[derive(Clone, Debug)]
struct PendingDelegation {
    task: TaskId,
    sla: TaskSla,
    /// Remaining candidate clusters (highest priority first).
    remaining: Vec<ClusterId>,
    /// Clusters that already refused this instance.
    refused: Vec<ClusterId>,
    /// Cluster currently holding the in-flight `DelegateTask`.
    current: ClusterId,
    attempt: u32,
}

/// Per-service deployment tracking for driver callbacks.
#[derive(Clone, Debug)]
struct DeployTracking {
    reply_to: Option<ActorId>,
    submitted_at: SimTime,
    notified: bool,
}

/// An API caller waiting on the asynchronous outcome of one instance's
/// delegation (placement failures surface as `NoFeasiblePlacement`).
#[derive(Clone, Copy, Debug)]
struct ApiWaiter {
    request_id: u64,
    reply_to: Option<ActorId>,
}

pub struct RootOrchestrator {
    pub cfg: RootConfig,
    /// Cluster topology (attach/detach, parent edges). Aggregates live in
    /// the indexed [`ClusterTable`] below, not in the tree.
    pub tree: ClusterTree,
    /// Indexed federation state: dense cluster aggregates + feasibility
    /// pre-filters, updated incrementally on report ingest and serving
    /// every delegation's top-K priority-list selection.
    pub fed: ClusterTable,
    /// ClusterId → orchestrator actor.
    cluster_actors: BTreeMap<ClusterId, ActorId>,
    /// Highest incarnation epoch each cluster has registered under. A
    /// re-register with a higher epoch is a crash-restart (fresh lease,
    /// degraded overlay, resync solicitation); one with a lower epoch is
    /// a straggler from a dead incarnation and is fenced.
    cluster_epochs: BTreeMap<ClusterId, u64>,
    links: BTreeMap<ClusterId, WsLink>,
    pub db: ServiceDb,
    pending: BTreeMap<InstanceId, PendingDelegation>,
    tracking: BTreeMap<ServiceId, DeployTracking>,
    /// Instance → API caller to notify if its placement fails.
    placement_watch: BTreeMap<InstanceId, ApiWaiter>,
    /// Clusters whose federation lease is currently `Partitioned`:
    /// cluster → when the partition was detected. Drives the Degraded
    /// service overlay, keeps new delegations away from the black hole,
    /// and arms the on-heal anti-entropy resync. The root deliberately
    /// does NOT fail or reschedule a partitioned cluster's instances —
    /// the cluster keeps operating autonomously and the post-heal
    /// census reconciles (no reschedule storm during the grace window).
    partitioned: BTreeMap<ClusterId, SimTime>,
    /// Clusters whose next `ResyncSnapshot` follows a crash-restart
    /// (not a partition heal). Only then may the reconciliation re-drive
    /// pending delegations parked on the cluster: the crash provably
    /// dropped the in-flight `DelegateTask`, so a re-offer cannot
    /// double-deploy — after a mere partition the original send may
    /// still be parked in the network and re-driving would race it.
    restart_resync: BTreeSet<ClusterId>,
    /// Scheduling decisions taken (for Fig. 6 instrumentation).
    pub root_sched_ops: u64,
    started: bool,
}

impl RootOrchestrator {
    pub fn new(cfg: RootConfig) -> Self {
        RootOrchestrator {
            cfg,
            tree: ClusterTree::new(),
            fed: ClusterTable::default(),
            cluster_actors: BTreeMap::new(),
            cluster_epochs: BTreeMap::new(),
            links: BTreeMap::new(),
            db: ServiceDb::default(),
            pending: BTreeMap::new(),
            tracking: BTreeMap::new(),
            placement_watch: BTreeMap::new(),
            partitioned: BTreeMap::new(),
            restart_resync: BTreeSet::new(),
            root_sched_ops: 0,
            started: false,
        }
    }

    fn ensure_started(&mut self, ctx: &mut Ctx<'_>) {
        if !self.started {
            self.started = true;
            ctx.add_mem(mem::ROOT_BASE_MB);
            ctx.schedule(self.cfg.liveness_interval, SimMsg::Timer(TimerKind::LivenessPing));
        }
    }

    /// Root-tier scheduling step (paper §4.2 step 1): one top-K partial
    /// selection over the indexed [`ClusterTable`] builds the priority
    /// list for the whole delegation (K = the attempt budget); later
    /// attempts continue down that list in O(1) (see the
    /// `DelegationResult{None}` arm) instead of re-ranking per attempt.
    fn delegate(&mut self, ctx: &mut Ctx<'_>, instance: InstanceId, task: TaskId, sla: TaskSla) {
        let k = self.cfg.max_delegation_attempts as usize;
        // Partitioned clusters are excluded up front: delegating into a
        // black hole would park the instance behind the retransmit cap
        // and burn the attempt budget on silence.
        let exclude: Vec<ClusterId> = self.partitioned.keys().copied().collect();
        let (ranked, scanned) = self.fed.top_k(&sla, k, &exclude);
        ctx.charge_cpu(costs::ROOT_SCHED_PER_CLUSTER_MS * scanned.max(1) as f64);
        ctx.metrics().inc("root.op.rank");
        ctx.metrics().observe("root.rank_scanned", scanned as f64);
        self.root_sched_ops += 1;

        let mut remaining: Vec<ClusterId> = ranked.iter().map(|c| c.cluster).collect();
        if remaining.is_empty() {
            // No feasible cluster at all: fail fast — the placement-watch
            // surfaces the async NoFeasiblePlacement instead of parking
            // the instance.
            ctx.metrics().observe("root.delegation_attempts", 0.0);
            self.fail_instance(ctx, instance, task);
            return;
        }
        let next = remaining.remove(0);
        let pd = PendingDelegation {
            task,
            sla,
            remaining,
            refused: Vec::new(),
            current: next,
            attempt: 0,
        };
        self.send_delegation(ctx, instance, next, pd);
    }

    /// Send one `DelegateTask` to `next` and park the bookkeeping. The
    /// caller has already picked the candidate (initial rank, O(1) spill
    /// step or refill selection). One checked lookup for every path: a
    /// cluster that vanished — or whose lease partitioned — between
    /// selection and send is skipped in favor of the next candidate on
    /// the list (the same semantics as the spill arm's skip), and only
    /// an empty list ends the delegation.
    fn send_delegation(
        &mut self,
        ctx: &mut Ctx<'_>,
        instance: InstanceId,
        next: ClusterId,
        mut pd: PendingDelegation,
    ) {
        let mut target = Some(next);
        loop {
            let Some(c) = target else {
                ctx.metrics().observe("root.delegation_attempts", pd.attempt as f64);
                self.fail_instance(ctx, instance, pd.task);
                return;
            };
            let actor = if self.partitioned.contains_key(&c) {
                None
            } else {
                self.cluster_actors.get(&c).copied()
            };
            if let Some(actor) = actor {
                pd.current = c;
                let msg = SimMsg::Oak(OakMsg::DelegateTask {
                    task: pd.task,
                    instance,
                    sla: pd.sla.clone(),
                    attempt: pd.attempt,
                });
                let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                if let Some(rec) = self.db.service_mut(pd.task.service) {
                    rec.placement.insert(instance, c);
                }
                ctx.metrics().inc("root.op.delegate_send");
                if pd.attempt > 0 {
                    ctx.metrics().inc("root.op.spill_send");
                }
                self.pending.insert(instance, pd);
                ctx.send(actor, msg, bytes, labels::ROOT_TO_CLUSTER);
                return;
            }
            target = None;
            while !pd.remaining.is_empty() {
                let n = pd.remaining.remove(0);
                if !pd.refused.contains(&n) {
                    target = Some(n);
                    break;
                }
            }
        }
    }

    /// Apply a lifecycle transition to a root DB record. Releases the
    /// per-instance bookkeeping memory exactly once: on the first
    /// transition into a terminal state (every live instance was charged
    /// at registration/mint time, so this is the single release point —
    /// scale-down, undeploy, failure and worker death all funnel here).
    fn transition_instance(
        &mut self,
        ctx: &mut Ctx<'_>,
        instance: InstanceId,
        service: ServiceId,
        to: ServiceState,
    ) -> bool {
        let Some(rec) = self.db.service_mut(service) else {
            return false;
        };
        let Some(inst) = rec.instance_mut(instance) else {
            return false;
        };
        if inst.state != to && inst.state.can_transition(to) {
            let _ = inst.transition(to);
            let pred = inst.predecessor;
            if to.is_terminal() {
                ctx.add_mem(-mem::PER_INSTANCE_MB);
                // A successor dying *before* its original (migration
                // cancelled by a scale-shrink or targeted undeploy, or
                // the replacement's worker failing mid-cutover) releases
                // the lineage link: the original is still the live head
                // of the chain and must stay migratable.
                if let Some(p) = pred {
                    let pred_live = rec
                        .instance(p)
                        .map(|i| !i.state.is_terminal())
                        .unwrap_or(false);
                    if pred_live {
                        rec.instance_mut(p).unwrap().successor = None;
                    }
                }
            }
            true
        } else {
            false
        }
    }

    fn fail_instance(&mut self, ctx: &mut Ctx<'_>, instance: InstanceId, task: TaskId) {
        ctx.metrics().inc("root.placement_failed");
        self.transition_instance(ctx, instance, task.service, ServiceState::Failed);
        self.pending.remove(&instance);
        // Surface the exhausted priority list to the API caller (§4.2).
        if let Some(w) = self.placement_watch.remove(&instance) {
            self.respond(
                ctx,
                w.reply_to,
                w.request_id,
                ApiResponse::Error(ApiError::NoFeasiblePlacement {
                    service: task.service,
                    task,
                }),
            );
        }
    }

    /// Deliver one API response/event to the caller.
    fn respond(
        &mut self,
        ctx: &mut Ctx<'_>,
        reply_to: Option<ActorId>,
        request_id: u64,
        response: ApiResponse,
    ) {
        if let Some(dst) = reply_to {
            // lint: route(client, API reply goes back to the northbound caller)
            ctx.send_local(
                dst,
                SimMsg::Oak(OakMsg::ApiReturn {
                    request_id,
                    response: Box::new(response),
                }),
            );
        }
    }

    /// Instruct the owning cluster to tear one instance down. Returns
    /// false when the instance's cluster is unknown (e.g. an instance the
    /// cluster re-placed locally — its teardown is cluster-internal).
    fn send_undeploy(
        &mut self,
        ctx: &mut Ctx<'_>,
        instance: InstanceId,
        cluster: Option<ClusterId>,
    ) -> bool {
        let Some(actor) = cluster.and_then(|c| self.cluster_actors.get(&c).copied()) else {
            ctx.metrics().inc("root.undeploy_unroutable");
            return false;
        };
        // Epoch 0 = unset: the cluster re-stamps its own epoch when it
        // forwards the teardown to the hosting worker, so root-originated
        // commands are never fenced.
        let msg = SimMsg::Oak(OakMsg::UndeployInstance { instance, epoch: 0 });
        let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
        ctx.send(actor, msg, bytes, labels::ROOT_TO_CLUSTER);
        true
    }

    /// Compute the scale plan for a service: which tasks need more
    /// instances (with their SLAs) and which surplus instances to tear
    /// down. Read-only so the caller can act on the plan afterwards.
    #[allow(clippy::type_complexity)]
    fn plan_scale(
        &self,
        service: ServiceId,
        task: Option<u16>,
        replicas: usize,
    ) -> Result<
        (
            Vec<(TaskId, usize, TaskSla)>,
            Vec<(InstanceId, Option<ClusterId>)>,
        ),
        ApiError,
    > {
        let rec = self
            .db
            .service(service)
            .ok_or(ApiError::UnknownService(service))?;
        if rec.retired {
            return Err(ApiError::ServiceRetired(service));
        }
        let targets: Vec<TaskId> = match task {
            Some(index) => {
                let tid = TaskId { service, index };
                if rec.spec.task(tid).is_none() {
                    return Err(ApiError::UnknownTask(tid));
                }
                vec![tid]
            }
            None => rec.spec.tasks.iter().map(|t| t.id).collect(),
        };
        let mut grow = Vec::new();
        let mut shrink = Vec::new();
        for tid in &targets {
            // Count *logical* replicas: an in-flight lineage pair — a
            // live original plus its live adopted successor (migration
            // mid-cutover) — is ONE replica, not two; the successor is
            // the original's future, not an extra copy. Counting raw
            // records would make a mid-migration scale-up under-grow
            // and a scale-to-current-count tear the pair apart. Each
            // pair is represented by its original (live head): tearing
            // the original down cascades the successor's teardown at
            // the cluster, removing the whole logical replica at once.
            let mut live: Vec<(u32, InstanceId)> = rec
                .instances
                .iter()
                .filter(|i| i.task == *tid && !i.state.is_terminal())
                .filter(|i| {
                    i.predecessor
                        .and_then(|p| rec.instance(p))
                        .map(|p| p.state.is_terminal())
                        .unwrap_or(true)
                })
                .map(|i| (i.generation, i.instance))
                .collect();
            if live.len() < replicas {
                let sla = rec.spec.task(*tid).unwrap().sla.clone();
                grow.push((*tid, replicas - live.len(), sla));
            } else if live.len() > replicas {
                // Tear down the newest *generations* first so the
                // longest-lived replicas survive (ordered by
                // generation, not raw id: locally-minted ids carry tag
                // bits that do not reflect age).
                live.sort();
                for (_, iid) in live.split_off(replicas) {
                    shrink.push((iid, rec.placement.get(&iid).copied()));
                }
            }
        }
        Ok((grow, shrink))
    }

    /// Dispatch one northbound API envelope (paper §3.2.1: the service
    /// manager's deployment/scaling/migration/teardown front door).
    /// Control-plane cost is charged *per operation kind* and mirrored
    /// into metrics, so churn benches can attribute root CPU to lifecycle
    /// ops instead of one flat submit tax.
    fn handle_api(&mut self, ctx: &mut Ctx<'_>, env: ApiEnvelope) {
        let ApiEnvelope {
            version,
            request_id,
            request,
            reply_to,
        } = env;
        let (cost_ms, op) = match &request {
            ApiRequest::SubmitService { .. } => (costs::SUBMIT_MS, "root.op.submit"),
            ApiRequest::ScaleService { .. } => (costs::SCALE_MS, "root.op.scale"),
            ApiRequest::MigrateInstance { .. } => (costs::MIGRATE_MS, "root.op.migrate"),
            ApiRequest::UndeployService { .. } => {
                (costs::UNDEPLOY_MS, "root.op.undeploy")
            }
            ApiRequest::ServiceStatus { .. } => (costs::STATUS_MS, "root.op.status"),
            ApiRequest::ListServices => (costs::STATUS_MS, "root.op.list"),
        };
        ctx.charge_cpu(cost_ms);
        ctx.metrics().inc(op);
        ctx.metrics().observe("root.api_op_ms", cost_ms);
        if version != API_VERSION {
            self.respond(
                ctx,
                reply_to,
                request_id,
                ApiResponse::Error(ApiError::UnsupportedVersion {
                    requested: version,
                    supported: API_VERSION,
                }),
            );
            return;
        }
        match request {
            ApiRequest::SubmitService { sla } => {
                if let Err(e) = sla.validate() {
                    ctx.metrics().inc("root.sla_rejected");
                    self.respond(
                        ctx,
                        reply_to,
                        request_id,
                        ApiResponse::Error(ApiError::InvalidSla(e)),
                    );
                    return;
                }
                let (service, instances) = self.db.register(sla, ctx.now);
                // Charge bookkeeping per *registered record*, not per SLA
                // row: the release side (transition_instance) frees one
                // PER_INSTANCE_MB per record that reaches a terminal
                // state, so tying the charge to the same unit keeps the
                // gauge drift-free over long churn runs.
                ctx.add_mem(mem::PER_INSTANCE_MB * instances.len() as f64);
                self.tracking.insert(
                    service,
                    DeployTracking {
                        reply_to,
                        submitted_at: ctx.now,
                        notified: false,
                    },
                );
                self.respond(
                    ctx,
                    reply_to,
                    request_id,
                    ApiResponse::Submitted {
                        service,
                        instances: instances.clone(),
                    },
                );
                // Delegate every task (deploy order = SLA order so that
                // S2S chain targets usually exist by dependents' turn).
                let rec = self.db.service(service).unwrap();
                let work: Vec<(InstanceId, TaskId, TaskSla)> = rec
                    .instances
                    .iter()
                    .zip(rec.spec.tasks.iter())
                    .map(|(inst, t)| (inst.instance, t.id, t.sla.clone()))
                    .collect();
                debug_assert_eq!(work.len(), instances.len());
                for (iid, tid, sla) in work {
                    self.placement_watch
                        .insert(iid, ApiWaiter { request_id, reply_to });
                    self.delegate(ctx, iid, tid, sla);
                }
            }

            ApiRequest::ScaleService {
                service,
                task,
                replicas,
            } => {
                if !(1..=MAX_REPLICAS).contains(&replicas) {
                    self.respond(
                        ctx,
                        reply_to,
                        request_id,
                        ApiResponse::Error(ApiError::InvalidReplicas {
                            requested: replicas,
                            max: MAX_REPLICAS,
                        }),
                    );
                    return;
                }
                let (grow, shrink) = match self.plan_scale(service, task, replicas) {
                    Ok(plan) => plan,
                    Err(e) => {
                        self.respond(ctx, reply_to, request_id, ApiResponse::Error(e));
                        return;
                    }
                };
                let mut added = Vec::new();
                for (tid, n, sla) in grow {
                    for _ in 0..n {
                        if let Some(iid) = self.db.mint_replacement(tid) {
                            ctx.metrics().inc("root.scale_up");
                            ctx.add_mem(mem::PER_INSTANCE_MB);
                            self.placement_watch
                                .insert(iid, ApiWaiter { request_id, reply_to });
                            self.delegate(ctx, iid, tid, sla.clone());
                            added.push(iid);
                        }
                    }
                }
                let mut removed = Vec::new();
                for (iid, cluster) in shrink {
                    // Cancel any in-flight delegation first: otherwise the
                    // priority-list retry (DelegationResult{None} → next
                    // cluster) could resurrect an instance reported as
                    // removed. The undeploy is still sent — the cluster
                    // may have deployed it already (no-op otherwise).
                    let was_pending = self.pending.remove(&iid).is_some();
                    self.placement_watch.remove(&iid);
                    if was_pending {
                        self.transition_instance(ctx, iid, service, ServiceState::Failed);
                    }
                    if self.send_undeploy(ctx, iid, cluster) {
                        ctx.metrics().inc("root.scale_down");
                        removed.push(iid);
                    }
                }
                self.respond(
                    ctx,
                    reply_to,
                    request_id,
                    ApiResponse::ScaleStarted {
                        service,
                        added,
                        removed,
                    },
                );
            }

            ApiRequest::MigrateInstance { service, instance } => {
                let Some(rec) = self.db.service(service) else {
                    self.respond(
                        ctx,
                        reply_to,
                        request_id,
                        ApiResponse::Error(ApiError::UnknownService(service)),
                    );
                    return;
                };
                if rec.retired {
                    self.respond(
                        ctx,
                        reply_to,
                        request_id,
                        ApiResponse::Error(ApiError::ServiceRetired(service)),
                    );
                    return;
                }
                let Some(inst) = rec.instance(instance) else {
                    self.respond(
                        ctx,
                        reply_to,
                        request_id,
                        ApiResponse::Error(ApiError::UnknownInstance(instance)),
                    );
                    return;
                };
                if let Some(successor) = inst.successor {
                    // The lineage already moved on (a migration or
                    // recovery superseded this id): name the successor so
                    // the caller can retarget.
                    self.respond(
                        ctx,
                        reply_to,
                        request_id,
                        ApiResponse::Error(ApiError::AlreadyReplaced {
                            instance,
                            successor,
                        }),
                    );
                    return;
                }
                if inst.state != ServiceState::Running {
                    self.respond(
                        ctx,
                        reply_to,
                        request_id,
                        ApiResponse::Error(ApiError::NotRunning(instance)),
                    );
                    return;
                }
                let cluster = rec.placement.get(&instance).copied();
                let Some(actor) = cluster.and_then(|c| self.cluster_actors.get(&c).copied())
                else {
                    self.respond(
                        ctx,
                        reply_to,
                        request_id,
                        ApiResponse::Error(ApiError::UnknownInstance(instance)),
                    );
                    return;
                };
                ctx.metrics().inc("root.migrations_requested");
                let msg = SimMsg::Oak(OakMsg::MigrateInstance { instance });
                let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                ctx.send(actor, msg, bytes, labels::ROOT_TO_CLUSTER);
                self.respond(
                    ctx,
                    reply_to,
                    request_id,
                    ApiResponse::MigrationStarted { instance },
                );
            }

            ApiRequest::UndeployService { service } => {
                let Some(rec) = self.db.service_mut(service) else {
                    self.respond(
                        ctx,
                        reply_to,
                        request_id,
                        ApiResponse::Error(ApiError::UnknownService(service)),
                    );
                    return;
                };
                // Retire the service before anything else: scale-ups,
                // migrations and reschedules racing this teardown must
                // find the door already closed.
                rec.retired = true;
                let live: Vec<InstanceId> = rec
                    .instances
                    .iter()
                    .filter(|i| !i.state.is_terminal())
                    .map(|i| i.instance)
                    .collect();
                let count = live.len();
                // Instances still waiting on delegation fail in place.
                for iid in live {
                    if self.pending.remove(&iid).is_some() {
                        self.transition_instance(ctx, iid, service, ServiceState::Failed);
                        self.placement_watch.remove(&iid);
                    }
                }
                // Broadcast the teardown: adopted replacements are
                // root-visible now, but clusters may still hold
                // replacements whose registration is in flight (or was
                // refused) — the service-wide broadcast catches those
                // strays and seeds the clusters' dead-service tombstones.
                let actors: Vec<ActorId> = self.cluster_actors.values().copied().collect();
                for actor in actors {
                    let msg = SimMsg::Oak(OakMsg::UndeployService { service });
                    let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                    ctx.send(actor, msg, bytes, labels::ROOT_TO_CLUSTER);
                }
                // Stop deploy-time tracking. Memory for the remaining
                // live instances is released as their Terminated acks
                // arrive (transition_instance is the single release
                // point, so a racing scale-down cannot double-free).
                self.tracking.remove(&service);
                ctx.metrics().inc("root.undeploys");
                self.respond(
                    ctx,
                    reply_to,
                    request_id,
                    ApiResponse::UndeployStarted {
                        service,
                        instances: count,
                    },
                );
            }

            ApiRequest::ServiceStatus { service } => {
                let response = match self.db.service(service) {
                    Some(rec) => {
                        if rec.is_degraded() {
                            // Degraded-mode staleness is surfaced, not
                            // hidden: the status view names the
                            // partitioned clusters whose rows are
                            // last-known-good (`stale_clusters`).
                            ctx.metrics().inc("root.status_stale");
                        }
                        ApiResponse::Status(api::status_of(rec))
                    }
                    None => ApiResponse::Error(ApiError::UnknownService(service)),
                };
                self.respond(ctx, reply_to, request_id, response);
            }

            ApiRequest::ListServices => {
                let rows = api::summarize(&self.db);
                self.respond(ctx, reply_to, request_id, ApiResponse::Services(rows));
            }
        }
    }

    /// First proof of life from a cluster marked partitioned: close the
    /// degraded window, lift the service overlay and solicit the
    /// anti-entropy census (paper §6: the WebSocket lease "triggers
    /// remedial actions in case of failures"). Idempotent — only the
    /// first proof after a detection acts.
    fn heal_partition(&mut self, ctx: &mut Ctx<'_>, cluster: ClusterId) {
        let Some(since) = self.partitioned.remove(&cluster) else {
            return;
        };
        let window = ctx.now.saturating_sub(since);
        ctx.metrics().inc("root.partition_healed");
        ctx.metrics()
            .observe("root.degraded_window_ms", window.as_millis());
        let restored = self.db.clear_cluster_degraded(cluster);
        ctx.metrics().add("root.services_restored", restored);
        if let Some(actor) = self.cluster_actors.get(&cluster).copied() {
            ctx.metrics().inc("root.resync_requested");
            let msg = SimMsg::Oak(OakMsg::ResyncRequest);
            let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
            ctx.send(actor, msg, bytes, labels::ROOT_TO_CLUSTER);
        }
    }

    /// Shared adoption path for live `InstanceReplaced` announcements
    /// and resync-replayed replacement-log entries: run the idempotent
    /// adoption machinery, mirror the placement/lineage/watch
    /// bookkeeping, and always ack the cluster (the ack is what clears
    /// its outbox entry and pending-adoption record, so replays settle
    /// instead of retrying forever).
    #[allow(clippy::too_many_arguments)]
    fn handle_replacement(
        &mut self,
        ctx: &mut Ctx<'_>,
        cluster: ClusterId,
        service: ServiceId,
        task: TaskId,
        original: InstanceId,
        replacement: InstanceId,
        reason: ReplacementReason,
    ) -> Result<bool, AdoptError> {
        let outcome = self.db.adopt_successor(service, task, original, replacement);
        let adopted = match outcome {
            Ok(newly) => {
                if newly {
                    ctx.metrics().inc(match reason {
                        ReplacementReason::Migration => "root.adopted_migration",
                        ReplacementReason::LocalRecovery => "root.adopted_recovery",
                    });
                    // The adopted record is live bookkeeping, charged
                    // exactly like a root-minted one and released on its
                    // terminal transition.
                    ctx.add_mem(mem::PER_INSTANCE_MB);
                    if let Some(rec) = self.db.service_mut(service) {
                        // The successor runs where its lineage ran:
                        // inherit the original's delegation target so
                        // shrink/undeploy/migrate can route to it.
                        rec.placement.insert(replacement, cluster);
                    }
                    // Inherit any placement-watch waiter: the caller
                    // asked about the lineage, not one id.
                    if let Some(w) = self.placement_watch.remove(&original) {
                        self.placement_watch.insert(replacement, w);
                    }
                    if reason == ReplacementReason::LocalRecovery {
                        // The original died with its worker; its Failed
                        // status may be in flight or lost, so settle the
                        // record (and release its bookkeeping) here. A
                        // later duplicate terminal report is a no-op.
                        self.transition_instance(
                            ctx,
                            original,
                            service,
                            ServiceState::Failed,
                        );
                    }
                }
                true
            }
            Err(e) => {
                ctx.metrics().inc(match e {
                    AdoptError::Retired => "root.adopt_refused_retired",
                    _ => "root.adopt_refused",
                });
                false
            }
        };
        if let Some(actor) = self.cluster_actors.get(&cluster).copied() {
            let msg = SimMsg::Oak(OakMsg::InstanceReplacedAck {
                original,
                replacement,
                adopted,
            });
            let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
            ctx.send(actor, msg, bytes, labels::ROOT_TO_CLUSTER);
        }
        outcome
    }

    fn maybe_notify_deployed(&mut self, ctx: &mut Ctx<'_>, service: ServiceId) {
        let Some(rec) = self.db.service(service) else {
            return;
        };
        if !rec.fully_running() {
            return;
        }
        let submitted = rec.submitted_at;
        if let Some(tr) = self.tracking.get_mut(&service) {
            if tr.notified {
                return;
            }
            tr.notified = true;
            let elapsed = ctx.now.saturating_sub(submitted);
            ctx.metrics().observe("root.deploy_time_ms", elapsed.as_millis());
            if let Some(dst) = tr.reply_to {
                // lint: route(client, deployment event goes back to the submitter)
                ctx.send_local(
                    dst,
                    SimMsg::Oak(OakMsg::ServiceDeployed { service, elapsed }),
                );
            }
        }
    }
}

impl Actor for RootOrchestrator {
    fn handle(&mut self, ctx: &mut Ctx<'_>, msg: SimMsg) {
        self.ensure_started(ctx);
        match msg {
            SimMsg::Oak(OakMsg::RegisterCluster {
                cluster,
                orchestrator,
                parent,
                epoch,
            }) => {
                ctx.charge_cpu(costs::SUBMIT_MS);
                match self.cluster_epochs.get(&cluster).copied() {
                    Some(cur) if epoch < cur => {
                        // A registration from a dead incarnation, parked
                        // in the network across its crash: fence it —
                        // answering (or worse, repointing the actor map
                        // at a corpse) would undo the live incarnation.
                        ctx.metrics().inc("root.register_stale_epoch");
                        return;
                    }
                    Some(cur) if epoch > cur => {
                        // Crash-restart: same cluster, higher incarnation.
                        // The fresh lease cancels a Suspect-window
                        // escalation in flight — a fast restart is not a
                        // partition, so `root.partition_detected` must
                        // not fire for it. State-wise the restart is
                        // treated like a healed partition: services go
                        // under the degraded overlay (status answers
                        // surface staleness, delegations route around)
                        // until the census converges — no reschedule
                        // storm against a cluster that is rebuilding.
                        ctx.metrics().inc("root.cluster_restarted");
                        self.cluster_epochs.insert(cluster, epoch);
                        self.cluster_actors.insert(cluster, orchestrator);
                        self.links.insert(cluster, WsLink::new(ctx.now));
                        if let Some(since) = self.partitioned.remove(&cluster) {
                            // The dead window already escalated: close
                            // the partition accounting here; the overlay
                            // below persists until the resync lands.
                            ctx.metrics().inc("root.partition_healed");
                            ctx.metrics().observe(
                                "root.degraded_window_ms",
                                ctx.now.saturating_sub(since).as_millis(),
                            );
                        }
                        let marked = self.db.mark_cluster_degraded(cluster, ctx.now);
                        ctx.metrics().add("root.services_degraded", marked);
                        let msg =
                            SimMsg::Oak(OakMsg::RegisterClusterAck { accepted: true });
                        let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                        ctx.send(orchestrator, msg, bytes, labels::ROOT_TO_CLUSTER);
                        // Solicit the anti-entropy census. The recovering
                        // cluster answers at its Recovering→Active edge;
                        // only that restart-resync may re-drive parked
                        // delegations (the crash dropped their sends).
                        self.restart_resync.insert(cluster);
                        ctx.metrics().inc("root.resync_requested");
                        let msg = SimMsg::Oak(OakMsg::ResyncRequest);
                        let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                        ctx.send(orchestrator, msg, bytes, labels::ROOT_TO_CLUSTER);
                        return;
                    }
                    _ => {}
                }
                let accepted = self.tree.attach(cluster, parent).is_ok();
                if accepted {
                    self.fed.register(cluster);
                    self.cluster_actors.insert(cluster, orchestrator);
                    self.cluster_epochs.insert(cluster, epoch);
                    self.links.insert(cluster, WsLink::new(ctx.now));
                }
                let msg = SimMsg::Oak(OakMsg::RegisterClusterAck { accepted });
                let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                ctx.send(orchestrator, msg, bytes, labels::ROOT_TO_CLUSTER);
            }

            SimMsg::Oak(OakMsg::ClusterReport {
                cluster,
                stats,
                running_instances,
                service_cpu,
            }) => {
                ctx.charge_cpu(costs::CLUSTER_REPORT_MS);
                // Incremental ingest: the entry's stats are replaced in
                // place and the feasibility pre-filters only move when a
                // filter-relevant field changed. Clusters delta-coalesce
                // on their side, so each applied report carries a
                // threshold-sized move (`root.aggregates.batches` vs the
                // clusters' sent/suppressed counters exposes the factor).
                if self.fed.apply_report(cluster, stats) {
                    ctx.metrics().inc("root.aggregates.batches");
                }
                // Per-service observed CPU piggybacks on the (coalesced)
                // aggregate report: refresh the root's QoS-telemetry view.
                self.db.apply_cluster_cpu(cluster, &service_cpu);
                if let Some(l) = self.links.get_mut(&cluster) {
                    l.on_activity(ctx.now);
                }
                // A buffered report replayed after a partition proves
                // the uplink works again — heal without waiting for the
                // next pong.
                self.heal_partition(ctx, cluster);
                ctx.metrics()
                    .add("root.instances_reported", running_instances as u64);
            }

            SimMsg::Oak(OakMsg::ApiCall(env)) => {
                self.handle_api(ctx, *env);
            }

            SimMsg::Oak(OakMsg::DelegationResult {
                task,
                instance,
                worker,
                calc_time,
            }) => {
                ctx.charge_cpu(costs::CLUSTER_REPORT_MS);
                ctx.metrics()
                    .observe("root.cluster_calc_ms", calc_time.as_millis());
                match worker {
                    Some(node) => {
                        if let Some(pd) = self.pending.remove(&instance) {
                            ctx.metrics()
                                .observe("root.delegation_attempts", (pd.attempt + 1) as f64);
                        }
                        // Placement succeeded: the API waiter has nothing
                        // more to fear from the delegation chain.
                        self.placement_watch.remove(&instance);
                        if let Some(rec) = self.db.service_mut(task.service) {
                            if let Some(inst) = rec.instance_mut(instance) {
                                if inst.state == ServiceState::Requested {
                                    let _ = inst.transition(ServiceState::Scheduled);
                                }
                                // A late result for an instance already
                                // cancelled (scale-down/undeploy raced the
                                // delegation) must not dress a terminal
                                // record up as placed.
                                if !inst.state.is_terminal() {
                                    inst.worker = Some(node);
                                }
                            }
                        }
                    }
                    None => {
                        // Priority-list spill (§4.2): the cluster refused,
                        // so continue down the list precomputed when the
                        // delegation started — an O(1) pop, not a re-rank.
                        if let Some(mut pd) = self.pending.remove(&instance) {
                            pd.refused.push(pd.current);
                            pd.attempt += 1;
                            let mut next = None;
                            if pd.attempt < self.cfg.max_delegation_attempts {
                                while !pd.remaining.is_empty() {
                                    let c = pd.remaining.remove(0);
                                    // Defensive: never re-offer a refusal,
                                    // and skip clusters gone — or
                                    // partitioned — since rank.
                                    if pd.refused.contains(&c)
                                        || !self.cluster_actors.contains_key(&c)
                                        || self.partitioned.contains_key(&c)
                                    {
                                        continue;
                                    }
                                    next = Some(c);
                                    ctx.charge_cpu(costs::ROOT_SPILL_STEP_MS);
                                    ctx.metrics().inc("root.op.spill_step");
                                    break;
                                }
                                // The list ran dry with attempts left (the
                                // feasible set was smaller than K at rank
                                // time, or shrank): one refill selection
                                // over *current* aggregates, excluding
                                // every cluster that already said no.
                                if next.is_none() {
                                    let mut exclude = pd.refused.clone();
                                    exclude.extend(self.partitioned.keys().copied());
                                    let (ranked, scanned) =
                                        self.fed.top_k(&pd.sla, 1, &exclude);
                                    ctx.charge_cpu(
                                        costs::ROOT_SCHED_PER_CLUSTER_MS
                                            * scanned.max(1) as f64,
                                    );
                                    ctx.metrics().inc("root.op.rank");
                                    ctx.metrics()
                                        .observe("root.rank_scanned", scanned as f64);
                                    next = ranked.first().map(|c| c.cluster);
                                }
                            }
                            match next {
                                Some(c) => {
                                    self.send_delegation(ctx, instance, c, pd);
                                }
                                None => {
                                    // Attempt budget or feasible set
                                    // exhausted mid-churn: fail fast so
                                    // the placement-watch surfaces the
                                    // async NoFeasiblePlacement now.
                                    ctx.metrics().observe(
                                        "root.delegation_attempts",
                                        pd.attempt as f64,
                                    );
                                    self.fail_instance(ctx, instance, task);
                                }
                            }
                        }
                    }
                }
            }

            SimMsg::Oak(OakMsg::InstanceStatus {
                instance,
                node,
                state,
            }) => {
                ctx.charge_cpu(costs::CLUSTER_REPORT_MS);
                // Resolve the owning service through the instance index
                // (instance ids are globally unique) — O(log n) instead
                // of a full database scan per report. Adopted successor
                // ids resolve here too, so cluster-minted replacements
                // are no longer dropped.
                match self.db.service_of_instance(instance) {
                    Some(sid) => {
                        if let Some(rec) = self.db.service_mut(sid) {
                            if let Some(inst) = rec.instance_mut(instance) {
                                inst.worker = Some(node);
                            }
                        }
                        self.transition_instance(ctx, instance, sid, state);
                        if state == ServiceState::Running {
                            self.maybe_notify_deployed(ctx, sid);
                        }
                    }
                    None => {
                        // Status for an id the root never minted nor
                        // adopted: either the InstanceReplaced that
                        // introduces it is still in flight (the ack echo
                        // re-delivers the state once adoption lands) or
                        // its registration was refused (the cluster is
                        // tearing it down).
                        ctx.metrics().inc("root.status_unknown_instance");
                    }
                }
            }

            SimMsg::Oak(OakMsg::InstanceReplaced {
                cluster,
                service,
                task,
                original,
                replacement,
                reason,
            }) => {
                ctx.charge_cpu(costs::ADOPT_MS);
                if let Some(l) = self.links.get_mut(&cluster) {
                    l.on_activity(ctx.now);
                }
                // A replayed announcement arriving after a partition is
                // proof of life too.
                self.heal_partition(ctx, cluster);
                let _ = self.handle_replacement(
                    ctx,
                    cluster,
                    service,
                    task,
                    original,
                    replacement,
                    reason,
                );
            }

            SimMsg::Oak(OakMsg::EscalateReschedule {
                task,
                instance,
                sla,
            }) => {
                // Cluster could not recover locally: root re-runs the
                // priority-list scheduling with a fresh instance (§4.2).
                // `mint_replacement` refuses retired services, so an
                // escalation racing an undeploy cannot resurrect the
                // service here.
                if let Some(new_id) = self.db.mint_replacement(task) {
                    ctx.metrics().inc("root.reschedules");
                    ctx.add_mem(mem::PER_INSTANCE_MB);
                    // Record successor lineage when the escalated
                    // instance is a known dead record (worker-death
                    // escalation). An SLA-violation escalation leaves a
                    // still-running original — that one is replication,
                    // not succession, and stays migratable.
                    if let Some(rec) = self.db.service_mut(task.service) {
                        let orig_dead = rec
                            .instance(instance)
                            .map(|i| i.state.is_terminal() && i.successor.is_none())
                            .unwrap_or(false);
                        if orig_dead {
                            rec.instance_mut(instance).unwrap().successor = Some(new_id);
                            rec.instance_mut(new_id).unwrap().predecessor =
                                Some(instance);
                        }
                    }
                    self.delegate(ctx, new_id, task, sla);
                }
            }

            SimMsg::Oak(OakMsg::ResolveIpUp {
                cluster,
                from,
                query,
            }) => {
                ctx.charge_cpu(costs::TABLE_OP_MS);
                if let Some(task) = query.task() {
                    let locs: Vec<crate::netmanager::InstanceLocation> = self
                        .db
                        .running_locations(task)
                        .into_iter()
                        .map(|(instance, node)| crate::netmanager::InstanceLocation {
                            instance,
                            task,
                            node,
                            rtt_ms: 0.0,
                        })
                        .collect();
                    if let Some(actor) = self.cluster_actors.get(&cluster) {
                        let msg = SimMsg::Oak(OakMsg::TableUpdate {
                            entries: vec![crate::netmanager::TableEntry {
                                task,
                                locations: locs,
                            }],
                        });
                        let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                        ctx.send(*actor, msg, bytes, labels::ROOT_TO_CLUSTER);
                    }
                    let _ = from;
                }
            }

            SimMsg::Oak(OakMsg::Pong { cluster }) => {
                ctx.charge_cpu(costs::PING_MS);
                // Pongs are the liveness signal now that aggregate
                // reports are delta-coalesced (a steady cluster may stay
                // silent past the link's suspect threshold otherwise).
                if let Some(l) = self.links.get_mut(&cluster) {
                    l.on_pong(ctx.now);
                }
                // The first pong after a partition heals the lease and
                // kicks off the anti-entropy resync.
                self.heal_partition(ctx, cluster);
            }

            SimMsg::Oak(OakMsg::ResyncSnapshot {
                cluster,
                instances,
                replacements,
            }) => {
                ctx.charge_cpu(costs::CLUSTER_REPORT_MS);
                ctx.metrics().inc("root.resyncs");
                if let Some(l) = self.links.get_mut(&cluster) {
                    l.on_activity(ctx.now);
                }
                // Phase 1: replay the minted-replacement log through the
                // idempotent adoption machinery. Entries the live
                // announcement (or an outbox replay) already delivered
                // come back `Ok(false)` — benign duplicates; a genuine
                // `LineageConflict` is the double-adoption the CI gate
                // watches for.
                for &(service, task, original, replacement, reason) in &replacements {
                    ctx.charge_cpu(costs::ADOPT_MS);
                    match self.handle_replacement(
                        ctx,
                        cluster,
                        service,
                        task,
                        original,
                        replacement,
                        reason,
                    ) {
                        Ok(true) => ctx.metrics().inc("root.resync_adopted"),
                        Ok(false) => {
                            ctx.metrics().inc("root.resync_adopt_duplicate")
                        }
                        Err(AdoptError::LineageConflict) => {
                            ctx.metrics().inc("root.resync_adopt_conflict")
                        }
                        Err(_) => {}
                    }
                }
                // Phase 2: the census is cluster-side truth for every
                // row it carries. Rows the root has already written off
                // (retired service, terminal record — a teardown the
                // partition swallowed) or never knew (an introduction
                // dropped past the retry budget with no adoptable
                // lineage) are true orphans: torn down, nothing else.
                let census: BTreeSet<InstanceId> =
                    instances.iter().map(|r| r.0).collect();
                for &(iid, task, state, node) in &instances {
                    ctx.charge_cpu(costs::TABLE_OP_MS);
                    let sid = task.service;
                    let known = self.db.service_of_instance(iid) == Some(sid);
                    let written_off = !known
                        || self
                            .db
                            .service(sid)
                            .map(|rec| {
                                rec.retired
                                    || rec
                                        .instance(iid)
                                        .map(|i| i.state.is_terminal())
                                        .unwrap_or(true)
                            })
                            .unwrap_or(true);
                    if written_off {
                        ctx.metrics().inc("root.resync_orphans");
                        self.send_undeploy(ctx, iid, Some(cluster));
                        continue;
                    }
                    // A delegation answered only by the census: its
                    // DelegationResult died in the partition — settle
                    // the pending entry and the API waiter now.
                    if self.pending.remove(&iid).is_some() {
                        self.placement_watch.remove(&iid);
                        ctx.metrics().inc("root.resync_settled_delegations");
                    }
                    if let Some(rec) = self.db.service_mut(sid) {
                        rec.placement.insert(iid, cluster);
                        if let Some(inst) = rec.instance_mut(iid) {
                            if inst.state == ServiceState::Requested {
                                let _ = inst.transition(ServiceState::Scheduled);
                            }
                            if !inst.state.is_terminal() {
                                inst.worker = Some(node);
                            }
                        }
                    }
                    self.transition_instance(ctx, iid, sid, state);
                    if state == ServiceState::Running {
                        self.maybe_notify_deployed(ctx, sid);
                    }
                }
                // Phase 3: root records placed in the cluster but absent
                // from the census are lost (the instance or its final
                // report died inside the partition): settle them Failed
                // and reschedule through the normal priority-list path —
                // measured recovery, not a blind grace-window storm.
                // Instances still pending delegation are skipped: the
                // cluster never deployed them and their `DelegateTask`
                // may still be parked in the network.
                let placed = self.db.live_placed_in(cluster);
                for (sid, task, iid) in placed {
                    if census.contains(&iid) || self.pending.contains_key(&iid) {
                        continue;
                    }
                    ctx.metrics().inc("root.resync_lost");
                    self.transition_instance(ctx, iid, sid, ServiceState::Failed);
                    self.placement_watch.remove(&iid);
                    let (retired, sla) = match self.db.service_mut(sid) {
                        Some(rec) => {
                            rec.placement.remove(&iid);
                            (rec.retired, rec.spec.task(task).map(|t| t.sla.clone()))
                        }
                        None => (true, None),
                    };
                    if retired {
                        continue;
                    }
                    let Some(sla) = sla else { continue };
                    if let Some(new_id) = self.db.mint_replacement(task) {
                        ctx.metrics().inc("root.reschedules");
                        ctx.add_mem(mem::PER_INSTANCE_MB);
                        // Lost-instance succession mirrors the escalate
                        // arm: link the lineage when the settled record
                        // has no successor yet, so status views keep the
                        // replacement chain intact.
                        if let Some(rec) = self.db.service_mut(sid) {
                            let orig_dead = rec
                                .instance(iid)
                                .map(|i| i.state.is_terminal() && i.successor.is_none())
                                .unwrap_or(false);
                            if orig_dead {
                                rec.instance_mut(iid).unwrap().successor = Some(new_id);
                                rec.instance_mut(new_id).unwrap().predecessor =
                                    Some(iid);
                            }
                        }
                        self.delegate(ctx, new_id, task, sla);
                    }
                }
                // Phase 4 (restart resyncs only): delegations parked on
                // this cluster whose instances the census does not carry
                // died with the crashed incarnation's inbox — the crash
                // provably dropped the `DelegateTask` (or its result), so
                // re-driving the delegation cannot double-deploy. After a
                // mere partition heal this sweep must NOT run: the
                // original send may still be parked in the network.
                if self.restart_resync.remove(&cluster) {
                    let stranded: Vec<(InstanceId, PendingDelegation)> = self
                        .pending
                        .iter()
                        .filter(|(iid, pd)| {
                            pd.current == cluster && !census.contains(iid)
                        })
                        .map(|(iid, pd)| (*iid, pd.clone()))
                        .collect();
                    for (iid, pd) in stranded {
                        ctx.metrics().inc("root.resync_redelegated");
                        self.pending.remove(&iid);
                        let next = pd.current;
                        self.send_delegation(ctx, iid, next, pd);
                    }
                }
                // Census converged: lift the degraded overlay armed at
                // the crash-restart re-registration. Idempotent — after
                // a partition heal (overlay already lifted) this clears
                // nothing.
                let restored = self.db.clear_cluster_degraded(cluster);
                ctx.metrics().add("root.services_restored", restored);
            }

            SimMsg::Timer(TimerKind::LivenessPing) => {
                ctx.charge_cpu(costs::IDLE_TICK_MS);
                let actors: Vec<ActorId> = self.cluster_actors.values().copied().collect();
                for a in actors {
                    let msg = SimMsg::Oak(OakMsg::Ping);
                    let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                    ctx.send(a, msg, bytes, labels::ROOT_TO_CLUSTER);
                }
                for l in self.links.values_mut() {
                    l.on_ping_sent();
                }
                // Partition detection sweep: a lease past
                // `partitioned_after` flips the cluster into degraded
                // mode — its services are marked (staleness surfaces on
                // status answers), new delegations route around it, and
                // the root deliberately does NOT fail or reschedule its
                // instances: the cluster keeps operating autonomously
                // and the post-heal resync reconciles, so a transient
                // cut never triggers a reschedule storm.
                let now = ctx.now;
                let newly: Vec<ClusterId> = self
                    .links
                    .iter()
                    .filter(|(c, l)| {
                        l.health(now) == LinkHealth::Partitioned
                            && !self.partitioned.contains_key(c)
                    })
                    .map(|(c, _)| *c)
                    .collect();
                for c in newly {
                    self.partitioned.insert(c, now);
                    ctx.metrics().inc("root.partition_detected");
                    let marked = self.db.mark_cluster_degraded(c, now);
                    ctx.metrics().add("root.services_degraded", marked);
                }
                ctx.schedule(
                    self.cfg.liveness_interval,
                    SimMsg::Timer(TimerKind::LivenessPing),
                );
            }

            // Root never receives worker-tier traffic or its own downward
            // sends; the manifest below keeps `oakestra lint` honest about
            // which OakMsg variants this wildcard deliberately swallows.
            // lint: wildcard(OakMsg: RegisterWorker, RegisterWorkerAck, WorkerReport)
            // lint: wildcard(OakMsg: PeerHint, DeployInstance, ResolveIp)
            _ => {}
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}
