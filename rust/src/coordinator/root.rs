//! Root orchestrator (paper §3.2.1): the centralized control plane.
//! System manager (cluster registry, liveness), service manager (SLA
//! intake, lifecycle, remedial actions) and root scheduler (cluster
//! priority lists + delegation) over the [`crate::coordinator::db`].

use std::any::Any;
use std::collections::BTreeMap;

use crate::hierarchy::{ClusterTree, ROOT};
use crate::messaging::{labels, WsLink, WS_FRAME_OVERHEAD};
use crate::model::ServiceState;
use crate::scheduler::rank_clusters;
use crate::sim::{Actor, ActorId, Ctx, OakMsg, SimMsg, TimerKind};
use crate::sla::TaskSla;
use crate::util::{ClusterId, InstanceId, ServiceId, SimTime, TaskId};

use super::db::ServiceDb;
use super::{costs, intervals, mem};

/// Root tunables.
#[derive(Clone, Debug)]
pub struct RootConfig {
    /// How many clusters from the priority list to try before failing a
    /// task (paper: iterate the list highest-priority-first).
    pub max_delegation_attempts: u32,
    pub liveness_interval: SimTime,
}

impl Default for RootConfig {
    fn default() -> Self {
        RootConfig {
            max_delegation_attempts: 4,
            liveness_interval: intervals::liveness_ping(),
        }
    }
}

/// In-flight delegation bookkeeping for one task instance.
#[derive(Clone, Debug)]
struct PendingDelegation {
    task: TaskId,
    sla: TaskSla,
    /// Remaining candidate clusters (highest priority first).
    remaining: Vec<ClusterId>,
    attempt: u32,
}

/// Per-service deployment tracking for driver callbacks.
#[derive(Clone, Debug)]
struct DeployTracking {
    reply_to: Option<ActorId>,
    submitted_at: SimTime,
    notified: bool,
}

pub struct RootOrchestrator {
    pub cfg: RootConfig,
    pub tree: ClusterTree,
    /// ClusterId → orchestrator actor.
    cluster_actors: BTreeMap<ClusterId, ActorId>,
    links: BTreeMap<ClusterId, WsLink>,
    pub db: ServiceDb,
    pending: BTreeMap<InstanceId, PendingDelegation>,
    tracking: BTreeMap<ServiceId, DeployTracking>,
    /// Scheduling decisions taken (for Fig. 6 instrumentation).
    pub root_sched_ops: u64,
    started: bool,
}

impl RootOrchestrator {
    pub fn new(cfg: RootConfig) -> Self {
        RootOrchestrator {
            cfg,
            tree: ClusterTree::new(),
            cluster_actors: BTreeMap::new(),
            links: BTreeMap::new(),
            db: ServiceDb::default(),
            pending: BTreeMap::new(),
            tracking: BTreeMap::new(),
            root_sched_ops: 0,
            started: false,
        }
    }

    fn ensure_started(&mut self, ctx: &mut Ctx<'_>) {
        if !self.started {
            self.started = true;
            ctx.add_mem(mem::ROOT_BASE_MB);
            ctx.schedule(self.cfg.liveness_interval, SimMsg::Timer(TimerKind::LivenessPing));
        }
    }

    /// Root-tier scheduling step (paper §4.2 step 1): rank clusters for a
    /// task and delegate to the best; on later attempts continue down the
    /// priority list.
    fn delegate(&mut self, ctx: &mut Ctx<'_>, instance: InstanceId, task: TaskId, sla: TaskSla) {
        let stats: Vec<(ClusterId, &crate::hierarchy::AggregateStats)> = self
            .tree
            .children_of(ROOT)
            .iter()
            .filter_map(|c| self.tree.stats(*c).map(|s| (*c, s)))
            .collect();
        ctx.charge_cpu(costs::ROOT_SCHED_PER_CLUSTER_MS * stats.len().max(1) as f64);
        self.root_sched_ops += 1;

        let ranked = rank_clusters(&sla, &stats);
        let remaining: Vec<ClusterId> = ranked
            .iter()
            .take(self.cfg.max_delegation_attempts as usize)
            .map(|c| c.cluster)
            .collect();

        let mut pd = PendingDelegation {
            task,
            sla,
            remaining,
            attempt: 0,
        };
        if let Some(next) = pd.remaining.first().copied() {
            pd.remaining.remove(0);
            let actor = self.cluster_actors[&next];
            let msg = SimMsg::Oak(OakMsg::DelegateTask {
                task,
                instance,
                sla: pd.sla.clone(),
                attempt: pd.attempt,
            });
            let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
            if let Some(rec) = self.db.service_mut(task.service) {
                rec.placement.insert(instance, next);
            }
            self.pending.insert(instance, pd);
            ctx.send(actor, msg, bytes, labels::ROOT_TO_CLUSTER);
        } else {
            // No candidate clusters at all: the task fails immediately.
            self.fail_instance(ctx, instance, task);
        }
    }

    fn fail_instance(&mut self, ctx: &mut Ctx<'_>, instance: InstanceId, task: TaskId) {
        ctx.metrics().inc("root.placement_failed");
        if let Some(rec) = self.db.service_mut(task.service) {
            if let Some(inst) = rec.instance_mut(instance) {
                let _ = inst.transition(ServiceState::Failed);
            }
        }
        self.pending.remove(&instance);
    }

    fn maybe_notify_deployed(&mut self, ctx: &mut Ctx<'_>, service: ServiceId) {
        let Some(rec) = self.db.service(service) else {
            return;
        };
        if !rec.fully_running() {
            return;
        }
        let submitted = rec.submitted_at;
        if let Some(tr) = self.tracking.get_mut(&service) {
            if tr.notified {
                return;
            }
            tr.notified = true;
            let elapsed = ctx.now.saturating_sub(submitted);
            ctx.metrics().observe("root.deploy_time_ms", elapsed.as_millis());
            if let Some(dst) = tr.reply_to {
                ctx.send_local(
                    dst,
                    SimMsg::Oak(OakMsg::ServiceDeployed { service, elapsed }),
                );
            }
        }
    }
}

impl Actor for RootOrchestrator {
    fn handle(&mut self, ctx: &mut Ctx<'_>, msg: SimMsg) {
        self.ensure_started(ctx);
        match msg {
            SimMsg::Oak(OakMsg::RegisterCluster {
                cluster,
                orchestrator,
                parent,
            }) => {
                ctx.charge_cpu(costs::SUBMIT_MS);
                let accepted = self.tree.attach(cluster, parent).is_ok();
                if accepted {
                    self.cluster_actors.insert(cluster, orchestrator);
                    self.links.insert(cluster, WsLink::new(ctx.now));
                }
                let msg = SimMsg::Oak(OakMsg::RegisterClusterAck { accepted });
                let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                ctx.send(orchestrator, msg, bytes, labels::ROOT_TO_CLUSTER);
            }

            SimMsg::Oak(OakMsg::ClusterReport {
                cluster,
                stats,
                running_instances,
            }) => {
                ctx.charge_cpu(costs::CLUSTER_REPORT_MS);
                let _ = self.tree.update_stats(cluster, stats);
                if let Some(l) = self.links.get_mut(&cluster) {
                    l.on_activity(ctx.now);
                }
                ctx.metrics()
                    .add("root.instances_reported", running_instances as u64);
            }

            SimMsg::Oak(OakMsg::SubmitService { sla, reply_to }) => {
                ctx.charge_cpu(costs::SUBMIT_MS);
                if sla.validate().is_err() {
                    ctx.metrics().inc("root.sla_rejected");
                    return;
                }
                ctx.add_mem(mem::PER_INSTANCE_MB * sla.constraints.len() as f64);
                let (service, instances) = self.db.register(sla, ctx.now);
                self.tracking.insert(
                    service,
                    DeployTracking {
                        reply_to,
                        submitted_at: ctx.now,
                        notified: false,
                    },
                );
                // Delegate every task (deploy order = SLA order so that
                // S2S chain targets usually exist by dependents' turn).
                let rec = self.db.service(service).unwrap();
                let work: Vec<(InstanceId, TaskId, TaskSla)> = rec
                    .instances
                    .iter()
                    .zip(rec.spec.tasks.iter())
                    .map(|(inst, t)| (inst.instance, t.id, t.sla.clone()))
                    .collect();
                debug_assert_eq!(work.len(), instances.len());
                for (iid, tid, sla) in work {
                    self.delegate(ctx, iid, tid, sla);
                }
            }

            SimMsg::Oak(OakMsg::DelegationResult {
                task,
                instance,
                worker,
                calc_time,
            }) => {
                ctx.charge_cpu(costs::CLUSTER_REPORT_MS);
                ctx.metrics()
                    .observe("root.cluster_calc_ms", calc_time.as_millis());
                match worker {
                    Some(node) => {
                        self.pending.remove(&instance);
                        if let Some(rec) = self.db.service_mut(task.service) {
                            if let Some(inst) = rec.instance_mut(instance) {
                                if inst.state == ServiceState::Requested {
                                    let _ = inst.transition(ServiceState::Scheduled);
                                }
                                inst.worker = Some(node);
                            }
                        }
                    }
                    None => {
                        // Try next cluster in the priority list (§4.2
                        // multi-cluster spill).
                        if let Some(mut pd) = self.pending.remove(&instance) {
                            pd.attempt += 1;
                            if let Some(next) = pd.remaining.first().copied() {
                                pd.remaining.remove(0);
                                let actor = self.cluster_actors[&next];
                                let msg = SimMsg::Oak(OakMsg::DelegateTask {
                                    task,
                                    instance,
                                    sla: pd.sla.clone(),
                                    attempt: pd.attempt,
                                });
                                let bytes =
                                    msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                                if let Some(rec) = self.db.service_mut(task.service) {
                                    rec.placement.insert(instance, next);
                                }
                                self.pending.insert(instance, pd);
                                ctx.send(actor, msg, bytes, labels::ROOT_TO_CLUSTER);
                            } else {
                                self.fail_instance(ctx, instance, task);
                            }
                        }
                    }
                }
            }

            SimMsg::Oak(OakMsg::InstanceStatus {
                instance,
                node,
                state,
            }) => {
                ctx.charge_cpu(costs::CLUSTER_REPORT_MS);
                // Find owning service (instance ids are globally unique).
                let service = self
                    .db
                    .services()
                    .find(|r| r.instance(instance).is_some())
                    .map(|r| r.spec.id);
                if let Some(sid) = service {
                    if let Some(rec) = self.db.service_mut(sid) {
                        if let Some(inst) = rec.instance_mut(instance) {
                            inst.worker = Some(node);
                            if inst.state != state && inst.state.can_transition(state) {
                                let _ = inst.transition(state);
                            }
                        }
                    }
                    if state == ServiceState::Running {
                        self.maybe_notify_deployed(ctx, sid);
                    }
                }
            }

            SimMsg::Oak(OakMsg::ReplicateTask { task }) => {
                // Replication = a fresh scheduling request for the same
                // task; the original instance keeps running (§6).
                ctx.charge_cpu(costs::SUBMIT_MS * 0.5);
                let sla = self
                    .db
                    .service(task.service)
                    .and_then(|rec| rec.spec.task(task).map(|t| t.sla.clone()));
                if let (Some(sla), Some(new_id)) = (sla, self.db.mint_replacement(task)) {
                    ctx.metrics().inc("root.replications");
                    self.delegate(ctx, new_id, task, sla);
                }
            }

            SimMsg::Oak(OakMsg::EscalateReschedule {
                task,
                instance: _,
                sla,
            }) => {
                // Cluster could not recover locally: root re-runs the
                // priority-list scheduling with a fresh instance (§4.2).
                if let Some(new_id) = self.db.mint_replacement(task) {
                    ctx.metrics().inc("root.reschedules");
                    self.delegate(ctx, new_id, task, sla);
                }
            }

            SimMsg::Oak(OakMsg::ResolveIpUp {
                cluster,
                from,
                query,
            }) => {
                ctx.charge_cpu(costs::TABLE_OP_MS);
                if let Some(task) = query.task() {
                    let locs: Vec<crate::netmanager::InstanceLocation> = self
                        .db
                        .running_locations(task)
                        .into_iter()
                        .map(|(instance, node)| crate::netmanager::InstanceLocation {
                            instance,
                            task,
                            node,
                            rtt_ms: 0.0,
                        })
                        .collect();
                    if let Some(actor) = self.cluster_actors.get(&cluster) {
                        let msg = SimMsg::Oak(OakMsg::TableUpdate {
                            entries: vec![crate::netmanager::TableEntry {
                                task,
                                locations: locs,
                            }],
                        });
                        let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                        ctx.send(*actor, msg, bytes, labels::ROOT_TO_CLUSTER);
                    }
                    let _ = from;
                }
            }

            SimMsg::Oak(OakMsg::Pong) => {
                ctx.charge_cpu(costs::PING_MS);
                // Activity tracking is per-cluster; pongs arrive tagged by
                // transport in a full implementation. Reports double as
                // liveness here (on_activity in ClusterReport).
            }

            SimMsg::Timer(TimerKind::LivenessPing) => {
                ctx.charge_cpu(costs::IDLE_TICK_MS);
                let actors: Vec<ActorId> = self.cluster_actors.values().copied().collect();
                for a in actors {
                    let msg = SimMsg::Oak(OakMsg::Ping);
                    let bytes = msg.default_wire_bytes() + WS_FRAME_OVERHEAD;
                    ctx.send(a, msg, bytes, labels::ROOT_TO_CLUSTER);
                }
                for l in self.links.values_mut() {
                    l.on_ping_sent();
                }
                ctx.schedule(
                    self.cfg.liveness_interval,
                    SimMsg::Timer(TimerKind::LivenessPing),
                );
            }

            _ => {}
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}
