//! Indexed cluster state — the data structures behind the cluster
//! orchestrator's hot paths.
//!
//! The orchestrator used to keep its worker table as a bare
//! `Vec<NodeProfile>` (every `profile()` lookup a linear scan) and its
//! instance records as one flat map (every `locations_of`/table push/LDP
//! refresh/undeploy sweep an O(instances) filter — O(instances²) per
//! churn round of status flips). These types replace that with:
//!
//! * [`WorkerTable`] — dense, registration-ordered profile storage plus a
//!   `NodeId → slot` map. Dense storage matters: the scheduler plugins
//!   take `&[NodeProfile]` and iterate it, and **iteration order feeds
//!   both the RNG (Vivaldi gossip sampling) and first-fit placement**, so
//!   removal compacts in order instead of swap-removing.
//! * [`InstanceTable`] — the `InstanceId → LocalInstance` records plus
//!   two secondary indices maintained in lockstep: `task → instance set`
//!   (table dissemination, LDP targets, per-task location queries;
//!   services range-scan it since [`crate::util::TaskId`] orders by
//!   `(service, index)`) and `node → instance set` (worker-death sweeps).
//!
//! Index invariants (checked by [`WorkerTable::check_consistent`] /
//! [`InstanceTable::check_consistent`] and the `indices` property suite):
//! every index entry points at a live record that agrees on the key, and
//! every record is reachable through each index — i.e. the indices are
//! always exactly what a brute-force linear scan would compute.

use std::collections::{BTreeMap, BTreeSet};

use crate::model::{Capacity, NodeProfile, ServiceState};
use crate::sla::TaskSla;
use crate::util::{InstanceId, NodeId, ServiceId, TaskId};

/// Dense slot-map of worker profiles keyed by [`NodeId`].
#[derive(Clone, Debug, Default)]
pub struct WorkerTable {
    profiles: Vec<NodeProfile>,
    slot: BTreeMap<NodeId, usize>,
}

impl WorkerTable {
    pub fn len(&self) -> usize {
        self.profiles.len()
    }
    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }
    pub fn contains(&self, node: NodeId) -> bool {
        self.slot.contains_key(&node)
    }

    pub fn get(&self, node: NodeId) -> Option<&NodeProfile> {
        self.slot.get(&node).map(|&i| &self.profiles[i])
    }
    pub fn get_mut(&mut self, node: NodeId) -> Option<&mut NodeProfile> {
        let i = *self.slot.get(&node)?;
        Some(&mut self.profiles[i])
    }

    /// Register a profile. Returns false (and keeps the existing entry)
    /// if the node is already present.
    pub fn insert(&mut self, profile: NodeProfile) -> bool {
        let node = profile.spec.node;
        if self.slot.contains_key(&node) {
            return false;
        }
        self.slot.insert(node, self.profiles.len());
        self.profiles.push(profile);
        true
    }

    /// Deregister a node, compacting the dense storage **in order** (an
    /// O(n) shift + slot fix-up — deaths are rare; lookups are not).
    pub fn remove(&mut self, node: NodeId) -> Option<NodeProfile> {
        let i = self.slot.remove(&node)?;
        let p = self.profiles.remove(i);
        for s in self.slot.values_mut() {
            if *s > i {
                *s -= 1;
            }
        }
        Some(p)
    }

    /// Profiles in registration order (the order placement plugins and
    /// gossip sampling see).
    pub fn iter(&self) -> std::slice::Iter<'_, NodeProfile> {
        self.profiles.iter()
    }
    pub fn as_slice(&self) -> &[NodeProfile] {
        &self.profiles
    }

    /// Validate the slot index against a brute-force scan.
    pub fn check_consistent(&self) -> Result<(), String> {
        if self.slot.len() != self.profiles.len() {
            return Err(format!(
                "slot count {} != profile count {}",
                self.slot.len(),
                self.profiles.len()
            ));
        }
        for (node, &i) in &self.slot {
            let Some(p) = self.profiles.get(i) else {
                return Err(format!("{node} slot {i} out of bounds"));
            };
            if p.spec.node != *node {
                return Err(format!("{node} slot {i} holds {}", p.spec.node));
            }
        }
        Ok(())
    }
}

/// Cluster-side record of one instance it manages.
#[derive(Clone, Debug)]
pub struct LocalInstance {
    /// Immutable after insertion — mutating it through `get_mut` would
    /// desynchronize the task index.
    pub task: TaskId,
    /// Immutable after insertion (the node index mirrors it).
    pub node: NodeId,
    pub state: ServiceState,
    pub request: Capacity,
    /// Latest observed CPU draw reported by the hosting worker, mc
    /// (QoS telemetry; mutable — no index mirrors it).
    pub observed_cpu_mc: u32,
    pub sla: TaskSla,
}

/// Instance records plus task→instances and node→instances indices.
#[derive(Clone, Debug, Default)]
pub struct InstanceTable {
    records: BTreeMap<InstanceId, LocalInstance>,
    by_task: BTreeMap<TaskId, BTreeSet<InstanceId>>,
    by_node: BTreeMap<NodeId, BTreeSet<InstanceId>>,
}

impl InstanceTable {
    pub fn len(&self) -> usize {
        self.records.len()
    }
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn get(&self, id: InstanceId) -> Option<&LocalInstance> {
        self.records.get(&id)
    }
    /// Mutable record access for state transitions. `task`/`node` must
    /// not be changed through this (see [`LocalInstance`]).
    pub fn get_mut(&mut self, id: InstanceId) -> Option<&mut LocalInstance> {
        self.records.get_mut(&id)
    }

    pub fn insert(&mut self, id: InstanceId, li: LocalInstance) {
        let (task, node) = (li.task, li.node);
        if let Some(old) = self.records.insert(id, li) {
            // Ids are never reused; a same-id overwrite would orphan the
            // old index rows. Repair rather than corrupt.
            self.unindex(id, old.task, old.node);
        }
        self.by_task.entry(task).or_default().insert(id);
        self.by_node.entry(node).or_default().insert(id);
    }

    pub fn remove(&mut self, id: InstanceId) -> Option<LocalInstance> {
        let li = self.records.remove(&id)?;
        self.unindex(id, li.task, li.node);
        Some(li)
    }

    fn unindex(&mut self, id: InstanceId, task: TaskId, node: NodeId) {
        if let Some(set) = self.by_task.get_mut(&task) {
            set.remove(&id);
            if set.is_empty() {
                self.by_task.remove(&task);
            }
        }
        if let Some(set) = self.by_node.get_mut(&node) {
            set.remove(&id);
            if set.is_empty() {
                self.by_node.remove(&node);
            }
        }
    }

    /// All records in ascending instance-id order.
    pub fn iter(&self) -> impl Iterator<Item = (InstanceId, &LocalInstance)> + '_ {
        self.records.iter().map(|(id, li)| (*id, li))
    }

    /// Records of one task, ascending id (same order a full scan yields).
    pub fn of_task(&self, task: TaskId) -> impl Iterator<Item = (InstanceId, &LocalInstance)> + '_ {
        self.by_task
            .get(&task)
            .into_iter()
            .flat_map(move |ids| ids.iter().map(move |id| (*id, &self.records[id])))
    }

    /// Records hosted on one node, ascending id.
    pub fn of_node(&self, node: NodeId) -> impl Iterator<Item = (InstanceId, &LocalInstance)> + '_ {
        self.by_node
            .get(&node)
            .into_iter()
            .flat_map(move |ids| ids.iter().map(move |id| (*id, &self.records[id])))
    }

    /// Records of every task of one service: a range scan over the task
    /// index ([`TaskId`] orders by `(service, index)`), so an undeploy
    /// sweep touches only the service's own instances.
    pub fn of_service(
        &self,
        service: ServiceId,
    ) -> impl Iterator<Item = (InstanceId, &LocalInstance)> + '_ {
        let lo = TaskId { service, index: 0 };
        let hi = TaskId {
            service,
            index: u16::MAX,
        };
        self.by_task
            .range(lo..=hi)
            .flat_map(move |(_, ids)| ids.iter().map(move |id| (*id, &self.records[id])))
    }

    /// Distinct nodes hosting at least one instance of `task`.
    pub fn nodes_of_task(&self, task: TaskId) -> BTreeSet<NodeId> {
        self.of_task(task).map(|(_, li)| li.node).collect()
    }

    /// Validate both indices against brute-force scans of the records.
    pub fn check_consistent(&self) -> Result<(), String> {
        let mut indexed = 0usize;
        for (task, ids) in &self.by_task {
            if ids.is_empty() {
                return Err(format!("empty task index row {task}"));
            }
            for id in ids {
                indexed += 1;
                match self.records.get(id) {
                    Some(li) if li.task == *task => {}
                    Some(li) => {
                        return Err(format!("{id} indexed under {task}, records {}", li.task))
                    }
                    None => return Err(format!("{id} in task index but not in records")),
                }
            }
        }
        if indexed != self.records.len() {
            return Err(format!(
                "task index covers {indexed} of {} records",
                self.records.len()
            ));
        }
        let mut indexed = 0usize;
        for (node, ids) in &self.by_node {
            if ids.is_empty() {
                return Err(format!("empty node index row {node}"));
            }
            for id in ids {
                indexed += 1;
                match self.records.get(id) {
                    Some(li) if li.node == *node => {}
                    Some(li) => {
                        return Err(format!("{id} indexed under {node}, records {}", li.node))
                    }
                    None => return Err(format!("{id} in node index but not in records")),
                }
            }
        }
        if indexed != self.records.len() {
            return Err(format!(
                "node index covers {indexed} of {} records",
                self.records.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geo::GeoPoint;
    use crate::model::{NodeClass, WorkerSpec};
    use crate::sla::simple_sla;

    fn profile(node: u32) -> NodeProfile {
        NodeProfile::new(WorkerSpec {
            node: NodeId(node),
            class: NodeClass::S,
            location: GeoPoint::default(),
        })
    }

    fn inst(service: u32, index: u16, node: u32) -> LocalInstance {
        LocalInstance {
            task: TaskId {
                service: ServiceId(service),
                index,
            },
            node: NodeId(node),
            state: ServiceState::Running,
            request: Capacity::new(100, 32, 0),
            observed_cpu_mc: 0,
            sla: simple_sla("t", 100, 32).constraints[0].clone(),
        }
    }

    #[test]
    fn worker_table_preserves_registration_order_across_removal() {
        let mut wt = WorkerTable::default();
        for n in [5u32, 2, 9, 7] {
            assert!(wt.insert(profile(n)));
        }
        assert!(!wt.insert(profile(2)), "duplicate registration refused");
        assert_eq!(wt.len(), 4);
        assert!(wt.get(NodeId(9)).is_some());
        wt.check_consistent().unwrap();

        wt.remove(NodeId(2)).unwrap();
        // Registration order survives the compaction (placement +
        // gossip iteration order must not shuffle on a death).
        let order: Vec<u32> = wt.iter().map(|p| p.spec.node.0).collect();
        assert_eq!(order, vec![5, 9, 7]);
        assert!(wt.get(NodeId(2)).is_none());
        assert!(wt.get_mut(NodeId(7)).is_some());
        wt.check_consistent().unwrap();
    }

    #[test]
    fn instance_table_indices_track_inserts_and_removals() {
        let mut it = InstanceTable::default();
        it.insert(InstanceId(1), inst(0, 0, 10));
        it.insert(InstanceId(2), inst(0, 0, 11));
        it.insert(InstanceId(3), inst(0, 1, 10));
        it.insert(InstanceId(4), inst(1, 0, 10));
        it.check_consistent().unwrap();

        let t00 = TaskId {
            service: ServiceId(0),
            index: 0,
        };
        let ids: Vec<u64> = it.of_task(t00).map(|(id, _)| id.0).collect();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(it.nodes_of_task(t00), [NodeId(10), NodeId(11)].into());
        let on10: Vec<u64> = it.of_node(NodeId(10)).map(|(id, _)| id.0).collect();
        assert_eq!(on10, vec![1, 3, 4]);
        let svc0: Vec<u64> = it.of_service(ServiceId(0)).map(|(id, _)| id.0).collect();
        assert_eq!(svc0, vec![1, 2, 3], "service range scan spans its tasks only");

        it.remove(InstanceId(2)).unwrap();
        it.remove(InstanceId(4)).unwrap();
        assert!(it.remove(InstanceId(4)).is_none());
        it.check_consistent().unwrap();
        assert_eq!(it.of_task(t00).count(), 1);
        assert_eq!(it.of_service(ServiceId(1)).count(), 0);
        assert_eq!(it.of_node(NodeId(10)).count(), 2);
    }
}
