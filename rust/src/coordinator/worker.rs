//! Worker NodeEngine + NetManager (paper §3.2.3 and §5): registers with
//! its cluster orchestrator, runs the push-based telemetry governor,
//! maintains its Vivaldi coordinate from peer gossip, deploys service
//! instances into the (simulated) container runtime, and serves
//! data-plane traffic through the semantic addressing stack (conversion
//! table → balancing policy → ProxyTUN tunnel).

use std::any::Any;
use std::collections::{BTreeMap, BTreeSet};

use crate::messaging::{labels, MQTT_FRAME_OVERHEAD};
use crate::model::{Capacity, ServiceState, WorkerSpec};
use crate::netmanager::{
    pick_instance, ConversionTable, Mdns, ProxyTun, ServiceIp,
};
use crate::sim::{
    Actor, ActorId, CensusRow, Ctx, DataMsg, OakMsg, ReplacementReason, SimMsg, TimerKind,
};
use crate::sla::TaskSla;
use crate::telemetry::{TelemetryGovernor, UpdatePolicy};
use crate::util::{InstanceId, NodeId, SimTime, TaskId};
use crate::vivaldi::VivaldiState;

use super::{costs, intervals, mem};

#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub spec: WorkerSpec,
    pub telemetry: UpdatePolicy,
    /// Per-request service time for hosted instances, ms (data plane).
    pub service_time_ms: f64,
    /// Steady-state duty cycle of a Running container: the observed CPU
    /// draw reported upstream is `request × run_util` (the simulated
    /// runtime's cgroup reading; the QoS-telemetry feed behind
    /// `ServiceStatus.observed_cpu_mc`).
    pub run_util: f64,
}

impl WorkerConfig {
    pub fn new(spec: WorkerSpec) -> Self {
        WorkerConfig {
            spec,
            // Paper §4.1: "a worker may only publish an update if its Δ
            // utilization crosses a threshold" — the default governor
            // suppresses no-change ticks with a 10 s freshness bound.
            telemetry: UpdatePolicy::DeltaThreshold {
                interval: intervals::worker_telemetry(),
                threshold: 0.05,
                max_age: SimTime::from_secs(10.0),
            },
            service_time_ms: 0.4,
            run_util: 0.7,
        }
    }
}

/// One locally hosted instance. Carries everything the census needs to
/// rebuild the cluster orchestrator's table row after a crash — the SLA
/// and replacement lineage ride along with the deploy command precisely
/// so they survive down here when the orchestrator's state does not.
#[derive(Clone, Debug)]
struct HostedInstance {
    task: TaskId,
    request: Capacity,
    state: ServiceState,
    /// Simulated QoS sample reported upstream (ms).
    qos_ms: f64,
    sla: TaskSla,
    origin: Option<(InstanceId, ReplacementReason)>,
}

pub struct WorkerEngine {
    pub cfg: WorkerConfig,
    orchestrator: ActorId,
    pub used: Capacity,
    hosted: BTreeMap<InstanceId, HostedInstance>,
    governor: TelemetryGovernor,
    pub vivaldi: VivaldiState,
    /// Latest peer states received via gossip (NodeId → state).
    peers: BTreeMap<NodeId, VivaldiState>,
    pub table: ConversionTable,
    pub tun: ProxyTun,
    pub mdns: Mdns,
    pub subnet: Option<u32>,
    /// Requests parked on a table miss, keyed by the queried ServiceIp.
    parked: Vec<(ServiceIp, DataMsg)>,
    /// Worker actors by node for tunnel forwarding (learned from table
    /// updates; the data plane needs actor handles to deliver).
    node_actors: BTreeMap<NodeId, ActorId>,
    /// Undeploys that arrived before their `DeployInstance` (jittered
    /// MQTT delivery can reorder the pair): the deploy must be refused on
    /// arrival or the container runs untracked forever.
    undeploy_tombstones: BTreeSet<InstanceId>,
    registered: bool,
    /// Highest cluster-orchestrator incarnation seen (via
    /// `RegisterWorkerAck`); commands stamped with a lower epoch come
    /// from a dead incarnation and are fenced. 0 = unset.
    pub epoch: u64,
}

impl WorkerEngine {
    pub fn new(cfg: WorkerConfig, orchestrator: ActorId) -> Self {
        let governor = TelemetryGovernor::new(cfg.telemetry);
        WorkerEngine {
            cfg,
            orchestrator,
            used: Capacity::ZERO,
            hosted: BTreeMap::new(),
            governor,
            vivaldi: VivaldiState::default(),
            peers: BTreeMap::new(),
            table: ConversionTable::default(),
            tun: ProxyTun::default(),
            mdns: Mdns::default(),
            subnet: None,
            parked: Vec::new(),
            node_actors: BTreeMap::new(),
            undeploy_tombstones: BTreeSet::new(),
            registered: false,
            epoch: 0,
        }
    }

    /// Let the data plane know how to reach a peer worker's actor (set up
    /// by the experiment driver; in a live system this is the tunnel
    /// endpoint address carried in table entries).
    pub fn learn_node_actor(&mut self, node: NodeId, actor: ActorId) {
        self.node_actors.insert(node, actor);
    }

    /// Failure/QoS injection for tests and experiments: set the QoS sample
    /// every hosted instance will report on the next telemetry tick.
    pub fn inject_qos(&mut self, qos_ms: f64) {
        for h in self.hosted.values_mut() {
            h.qos_ms = qos_ms;
        }
    }

    /// Number of instances currently hosted (running or starting).
    pub fn hosted_count(&self) -> usize {
        self.hosted.len()
    }

    /// Ids of the hosted instances, sorted (census view).
    pub fn hosted_ids(&self) -> Vec<InstanceId> {
        self.hosted.keys().copied().collect()
    }

    /// Kick off registration (call once via an injected Custom timer, or
    /// directly from the driver). The handshake carries the full local
    /// instance census — empty on a first join, the crash-recovery seed
    /// when a restarted orchestrator solicits re-registration.
    fn register(&mut self, ctx: &mut Ctx<'_>) {
        if self.registered {
            return;
        }
        let first = self.subnet.is_none();
        self.registered = true;
        if first {
            ctx.add_mem(mem::WORKER_BASE_MB);
        }
        let census: Vec<CensusRow> = self
            .hosted
            .iter()
            .map(|(id, h)| CensusRow {
                instance: *id,
                task: h.task,
                state: h.state,
                request: h.request,
                sla: h.sla.clone(),
                origin: h.origin,
            })
            .collect();
        let msg = SimMsg::Oak(OakMsg::RegisterWorker {
            spec: self.cfg.spec.clone(),
            engine: ctx.self_id,
            census,
        });
        let bytes = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
        ctx.send(self.orchestrator, msg, bytes, labels::WORKER_TO_CLUSTER);
    }

    fn report(&mut self, ctx: &mut Ctx<'_>) {
        let total = self.cfg.spec.capacity();
        if self
            .governor
            .should_publish(ctx.now, self.used, total)
        {
            let instances: Vec<(InstanceId, ServiceState, f64, u32)> = self
                .hosted
                .iter()
                .map(|(id, h)| {
                    // Observed per-container CPU draw: the runtime's
                    // cgroup reading, modeled as a fixed duty cycle of
                    // the reservation while Running (0 otherwise).
                    let cpu = if h.state == ServiceState::Running {
                        (h.request.cpu_millicores as f64 * self.cfg.run_util) as u32
                    } else {
                        0
                    };
                    (*id, h.state, h.qos_ms, cpu)
                })
                .collect();
            let msg = SimMsg::Oak(OakMsg::WorkerReport {
                node: self.cfg.spec.node,
                used: self.used,
                vivaldi: self.vivaldi,
                instances,
            });
            let bytes = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
            ctx.send(self.orchestrator, msg, bytes, labels::WORKER_TO_CLUSTER);
        }
        // NodeEngine housekeeping + per-container monitoring (Fig. 7b).
        ctx.charge_cpu(
            costs::WORKER_TICK_MS
                + costs::PER_INSTANCE_TICK_MS * self.hosted.len() as f64,
        );
    }

    /// Update own Vivaldi coordinate against gossiped peers using ground-
    /// truth RTT samples (the NodeEngine pings; the sim provides truth).
    fn vivaldi_tick(&mut self, ctx: &mut Ctx<'_>) {
        let me = self.cfg.spec.node;
        let peers: Vec<(NodeId, VivaldiState)> =
            self.peers.iter().map(|(n, s)| (*n, *s)).collect();
        for (node, state) in peers.iter().take(4) {
            let rtt = ctx.rtt_ms(me, *node);
            self.vivaldi.observe(state, rtt);
        }
        // Also spring against the orchestrator (always reachable).
        let orch_node = ctx.node_of(self.orchestrator);
        let rtt = ctx.rtt_ms(me, orch_node);
        self.vivaldi.observe(&VivaldiState::default(), rtt);
    }

    /// Serve a data-plane request addressed to a semantic ServiceIp.
    fn serve_request(&mut self, ctx: &mut Ctx<'_>, req: DataMsg) {
        let DataMsg::Request {
            id,
            from,
            target,
            bytes,
            sent_at,
        } = req
        else {
            return;
        };
        ctx.charge_cpu(costs::TABLE_OP_MS);
        match pick_instance(&mut self.table, &target) {
            Some(loc) => {
                if loc.node == self.cfg.spec.node {
                    // Local instance: serve immediately.
                    ctx.charge_cpu(self.cfg.service_time_ms);
                    let msg = SimMsg::Data(DataMsg::Response {
                        id,
                        bytes: 2048,
                        sent_at,
                    });
                    let b = bytes.max(2048);
                    ctx.send(from, msg, b, labels::DATA_PLANE);
                } else if let Some(actor) = self.node_actors.get(&loc.node).copied() {
                    // Tunnel to the hosting worker (per-packet overhead +
                    // possible handshake latency folded into a delayed
                    // forward).
                    let setup = self.tun.activate(loc.node, ctx.now);
                    self.tun.touch(loc.node, ctx.now);
                    let fwd = SimMsg::Data(DataMsg::Request {
                        id,
                        from,
                        target: ServiceIp::Instance(loc.instance),
                        bytes,
                        sent_at,
                    });
                    let b = bytes + 60; // tunnel encapsulation
                    if setup > SimTime::ZERO {
                        ctx.schedule_for(actor, setup, fwd);
                        ctx.metrics().record_msg(labels::DATA_PLANE, b);
                    } else {
                        ctx.send(actor, fwd, b, labels::DATA_PLANE);
                    }
                } else {
                    ctx.metrics().inc("worker.no_route");
                }
            }
            None => {
                // Table miss: park the request and resolve via cluster.
                self.parked.push((
                    target,
                    DataMsg::Request {
                        id,
                        from,
                        target,
                        bytes,
                        sent_at,
                    },
                ));
                let msg = SimMsg::Oak(OakMsg::ResolveIp {
                    from: self.cfg.spec.node,
                    query: target,
                });
                let b = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
                ctx.send(self.orchestrator, msg, b, labels::WORKER_TO_CLUSTER);
            }
        }
    }
}

impl Actor for WorkerEngine {
    fn handle(&mut self, ctx: &mut Ctx<'_>, msg: SimMsg) {
        match msg {
            // Driver bootstrap: a Custom(0) timer triggers registration.
            SimMsg::Timer(TimerKind::Custom(0)) => {
                self.register(ctx);
            }

            // Broker connection reset: the cluster orchestrator restarted
            // under a new incarnation and solicits re-registration. Run
            // the handshake again, this time with a populated census.
            SimMsg::Timer(TimerKind::Custom(2)) => {
                self.registered = false;
                ctx.metrics().inc("worker.reregistered");
                self.register(ctx);
            }

            SimMsg::Oak(OakMsg::RegisterWorkerAck { subnet, epoch }) => {
                if epoch < self.epoch {
                    // Ack from an incarnation that already died (in-flight
                    // reordering): never regress the fence.
                    ctx.metrics().inc("worker.epoch_fenced");
                    return;
                }
                self.epoch = epoch;
                let first = self.subnet.is_none();
                self.subnet = Some(subnet);
                if first {
                    // Start the telemetry loop — once: a re-registration
                    // ack after an orchestrator restart must not stack a
                    // second timer chain onto the surviving one.
                    let iv = self.governor.tick_interval();
                    ctx.schedule(iv, SimMsg::Timer(TimerKind::WorkerTelemetry));
                    ctx.schedule(
                        intervals::tunnel_gc(),
                        SimMsg::Timer(TimerKind::TunnelGc),
                    );
                }
            }

            SimMsg::Timer(TimerKind::WorkerTelemetry) => {
                self.vivaldi_tick(ctx);
                self.report(ctx);
                let iv = self.governor.tick_interval();
                ctx.schedule(iv, SimMsg::Timer(TimerKind::WorkerTelemetry));
            }

            SimMsg::Timer(TimerKind::TunnelGc) => {
                self.tun.gc(ctx.now);
                ctx.charge_cpu(costs::TABLE_OP_MS);
                ctx.schedule(
                    intervals::tunnel_gc(),
                    SimMsg::Timer(TimerKind::TunnelGc),
                );
            }

            SimMsg::Oak(OakMsg::PeerHint { peers }) => {
                ctx.charge_cpu(costs::PING_MS);
                for (n, s) in peers {
                    self.peers.insert(n, s);
                }
            }

            SimMsg::Oak(OakMsg::DeployInstance {
                instance,
                task,
                request,
                image_mb,
                service_ips: _,
                sla,
                origin,
                epoch,
            }) => {
                if epoch != 0 && epoch < self.epoch {
                    // Command from a dead incarnation: the restarted
                    // orchestrator rebuilt its tables from our census and
                    // knows nothing of this deploy — accepting it would
                    // leak the container forever.
                    ctx.metrics().inc("worker.epoch_fenced");
                    return;
                }
                ctx.charge_cpu(costs::DEPLOY_MS);
                if self.undeploy_tombstones.remove(&instance) {
                    // The teardown overtook this deploy in flight: refuse
                    // it and ack Terminated so the orchestrator releases
                    // its reservation.
                    ctx.metrics().inc("worker.deploy_tombstoned");
                    let msg = SimMsg::Oak(OakMsg::InstanceStatus {
                        instance,
                        node: self.cfg.spec.node,
                        state: ServiceState::Terminated,
                    });
                    let b = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
                    ctx.send(self.orchestrator, msg, b, labels::WORKER_TO_CLUSTER);
                    return;
                }
                let cap = self.cfg.spec.capacity();
                let after = self.used + request;
                if !cap.fits(&after) {
                    // Over-commitment race: reject; orchestrator frees the
                    // reservation on the Failed status.
                    let msg = SimMsg::Oak(OakMsg::InstanceStatus {
                        instance,
                        node: self.cfg.spec.node,
                        state: ServiceState::Failed,
                    });
                    let b = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
                    ctx.send(self.orchestrator, msg, b, labels::WORKER_TO_CLUSTER);
                    ctx.metrics().inc("worker.deploy_rejected");
                    return;
                }
                self.used = after;
                ctx.add_mem(request.mem_mb as f64 * 0.05 + 4.0); // runtime overhead
                self.hosted.insert(
                    instance,
                    HostedInstance {
                        task,
                        request,
                        state: ServiceState::Scheduled,
                        qos_ms: 0.0,
                        sla,
                        origin,
                    },
                );
                self.mdns
                    .register(&format!("task-{}-{}", task.service.0, task.index), task);
                // Container runtime: image pull + start latency.
                let me = self.cfg.spec.node;
                let total =
                    ctx.container_deploy_time(me, 0x1000 + task.service.0 as u64, image_mb);
                ctx.schedule(
                    total,
                    SimMsg::Timer(TimerKind::Custom(1_000_000 + instance.0 as u32)),
                );
            }

            // Container start completion (deploy ack).
            SimMsg::Timer(TimerKind::Custom(code)) if code >= 1_000_000 => {
                let instance = InstanceId((code - 1_000_000) as u64);
                // Locally-recovered instances carry the high bit; recover
                // the map key by scanning (codes are 32-bit truncated).
                let key = self
                    .hosted
                    .keys()
                    .copied()
                    .find(|k| (k.0 as u32) == instance.0 as u32);
                if let Some(k) = key {
                    if let Some(h) = self.hosted.get_mut(&k) {
                        h.state = ServiceState::Running;
                        h.qos_ms = 1.0;
                    }
                    let msg = SimMsg::Oak(OakMsg::InstanceStatus {
                        instance: k,
                        node: self.cfg.spec.node,
                        state: ServiceState::Running,
                    });
                    let b = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
                    ctx.send(self.orchestrator, msg, b, labels::WORKER_TO_CLUSTER);
                }
            }

            SimMsg::Oak(OakMsg::UndeployInstance { instance, epoch }) => {
                if epoch != 0 && epoch < self.epoch {
                    // Teardown queued by a dead incarnation — the rebuilt
                    // census may have re-legitimized this instance.
                    ctx.metrics().inc("worker.epoch_fenced");
                    return;
                }
                ctx.charge_cpu(costs::DEPLOY_MS * 0.3);
                match self.hosted.remove(&instance) {
                    None => {
                        // Not hosted (yet): remember the teardown in case
                        // the matching DeployInstance is still in flight.
                        // Duplicate undeploys (service-wide broadcast
                        // racing a targeted one) leave unconsumable junk
                        // here, bounded by the cap. Deploys arrive within
                        // milliseconds of their undeploy, so any entry
                        // old enough to be evicted (4096 teardowns later)
                        // has long since stopped mattering.
                        self.undeploy_tombstones.insert(instance);
                        while self.undeploy_tombstones.len() > 4096 {
                            self.undeploy_tombstones.pop_first();
                        }
                    }
                    Some(h) => {
                        self.used -= h.request;
                        ctx.add_mem(-(h.request.mem_mb as f64 * 0.05 + 4.0));
                        // Retire the local mDNS name when the last hosted
                        // instance of the task leaves this node.
                        if !self.hosted.values().any(|o| o.task == h.task) {
                            self.mdns.unregister(&format!(
                                "task-{}-{}",
                                h.task.service.0, h.task.index
                            ));
                        }
                        // Per-instance teardown ack (API lifecycle
                        // contract: every undeploy is confirmed
                        // instance-by-instance).
                        let msg = SimMsg::Oak(OakMsg::InstanceStatus {
                            instance,
                            node: self.cfg.spec.node,
                            state: ServiceState::Terminated,
                        });
                        let b = msg.default_wire_bytes() + MQTT_FRAME_OVERHEAD;
                        ctx.send(self.orchestrator, msg, b, labels::WORKER_TO_CLUSTER);
                    }
                }
            }

            SimMsg::Oak(OakMsg::TableUpdate { entries }) => {
                // Per ROW, not per message: a coalesced flush replaces k
                // rows and must cost what k single-row pushes did.
                ctx.charge_cpu(costs::TABLE_OP_MS * entries.len().max(1) as f64);
                self.table.apply_all(entries);
                // Retry parked requests whose task is now resolvable.
                let parked = std::mem::take(&mut self.parked);
                for (ip, req) in parked {
                    if self.table.lookup(&ip).is_some() {
                        self.serve_request(ctx, req);
                    } else {
                        self.parked.push((ip, req));
                    }
                }
            }

            SimMsg::Data(req @ DataMsg::Request { .. }) => {
                self.serve_request(ctx, req);
            }

            SimMsg::Data(DataMsg::StressLoad { rps }) => {
                // Nginx stress model: each request costs ~0.2 ms cpu.
                ctx.charge_cpu(rps * 0.2);
            }

            // Workers only speak the worker↔cluster subset of the
            // protocol; everything cluster↔root or client-facing lands in
            // the wildcard. Declared for `oakestra lint` protocol coverage.
            // lint: wildcard(OakMsg: RegisterCluster, RegisterClusterAck, ClusterReport)
            // lint: wildcard(OakMsg: Ping, Pong, ApiCall, ApiReturn, DelegateTask)
            // lint: wildcard(OakMsg: DelegationResult, UndeployService, ServiceDeployed)
            // lint: wildcard(OakMsg: MigrateInstance, InstanceReplaced, InstanceReplacedAck)
            // lint: wildcard(OakMsg: ResolveIpUp, EscalateReschedule)
            // lint: wildcard(OakMsg: ResyncRequest, ResyncSnapshot)
            _ => {}
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}
