//! Indexed federation state — the data structures behind the *root*
//! tier's scheduling hot paths (the design of
//! [`crate::coordinator::state`] applied one tier up).
//!
//! The root used to rebuild a `Vec<(ClusterId, &AggregateStats)>` from
//! the cluster tree and fully sort it (`rank_clusters`) for **every**
//! delegation attempt — O(clusters · log clusters) per task even when the
//! priority-list spill (`DelegationResult{None}` → next cluster) only
//! needed the *next* candidate. [`ClusterTable`] replaces that with:
//!
//! * dense, registration-ordered [`ClusterEntry`] storage plus a
//!   `ClusterId → slot` map (ordered compaction on deregister, mirroring
//!   [`crate::coordinator::WorkerTable`]);
//! * feasibility **pre-filter bitsets maintained on report ingest**, not
//!   at query time: non-empty clusters, one set per virtualization bit,
//!   and power-of-two buckets over the best single worker's cpu — a
//!   request can only fit clusters whose max-worker bucket is ≥ its own,
//!   so saturated clusters drop out of the scan before being scored;
//! * [`ClusterTable::top_k`] — K-bounded partial selection over the
//!   pre-filtered slots (no full sort; K = the delegation attempt budget)
//!   with an exclusion list so a spill refill never re-offers a cluster
//!   that just said no.
//!
//! Filter and score semantics are *shared* with the brute-force
//! [`crate::scheduler::rank_clusters`] (same [`cluster_feasible`] /
//! [`cluster_score`] functions), so `top_k(sla, k, &[])` is bit-identical
//! to `rank_clusters(..)` truncated to `k` — the `fedstate` property
//! suite asserts exactly that under random report/register/deregister/
//! query sequences, and [`ClusterTable::check_consistent`] validates the
//! bitsets against a brute-force recompute after every mutation.

use std::collections::BTreeMap;

use crate::hierarchy::AggregateStats;
use crate::model::Virtualization;
use crate::scheduler::{cluster_feasible, cluster_score, ClusterCandidate};
use crate::sla::TaskSla;
use crate::util::ClusterId;

/// Number of virtualization bits indexed (see [`Virtualization`]).
const VIRT_BITS: usize = 4;

/// Power-of-two cpu buckets for the max-worker pre-filter. Bucket 0 holds
/// zero-capacity entries; bucket `b ≥ 1` holds `floor(log2(cpu)) + 1`,
/// saturated at the top so huge values stay conservative.
const CAP_BUCKETS: usize = 32;

fn cap_bucket(cpu_millicores: u32) -> usize {
    if cpu_millicores == 0 {
        0
    } else {
        ((32 - cpu_millicores.leading_zeros()) as usize).min(CAP_BUCKETS - 1)
    }
}

/// A growable bitset over dense slot indices.
#[derive(Clone, Debug, Default)]
struct SlotSet {
    words: Vec<u64>,
}

impl SlotSet {
    fn grow(&mut self, slots: usize) {
        let need = slots.div_ceil(64);
        if self.words.len() < need {
            self.words.resize(need, 0);
        }
    }
    fn set(&mut self, i: usize) {
        self.grow(i + 1);
        self.words[i / 64] |= 1u64 << (i % 64);
    }
    fn clear(&mut self, i: usize) {
        if let Some(w) = self.words.get_mut(i / 64) {
            *w &= !(1u64 << (i % 64));
        }
    }
    fn contains(&self, i: usize) -> bool {
        self.words
            .get(i / 64)
            .map(|w| (w >> (i % 64)) & 1 == 1)
            .unwrap_or(false)
    }
    fn clear_all(&mut self) {
        for w in &mut self.words {
            *w = 0;
        }
    }
    fn word(&self, wi: usize) -> u64 {
        self.words.get(wi).copied().unwrap_or(0)
    }
}

/// One attached cluster's root-side scheduling view.
#[derive(Clone, Debug)]
pub struct ClusterEntry {
    pub cluster: ClusterId,
    /// Latest aggregate ⟨Σ,μ,σ⟩ the cluster pushed (delta-coalesced:
    /// clusters suppress reports that moved less than the configured
    /// threshold, so this is fresh-within-threshold, not per-tick).
    pub stats: AggregateStats,
    /// Aggregate reports applied to this entry (coalescing visibility).
    pub reports: u64,
}

/// The pre-filter key of one entry: (non-empty, virtualization bits,
/// max-worker cpu bucket). Bitset membership is exactly a function of
/// this key, so a report only touches the bitsets when the key moves.
type FilterKey = (bool, u32, usize);

/// Indexed cluster aggregates: dense registration-ordered storage, a
/// `ClusterId → slot` map and feasibility pre-filter bitsets maintained
/// incrementally on report ingest (see the module docs).
#[derive(Clone, Debug, Default)]
pub struct ClusterTable {
    entries: Vec<ClusterEntry>,
    slot: BTreeMap<ClusterId, usize>,
    /// Slots with `worker_count > 0`. Every other bitset is a subset.
    nonempty: SlotSet,
    /// Per virtualization bit: non-empty slots advertising that bit.
    virt: [SlotSet; VIRT_BITS],
    /// Per cpu bucket: non-empty slots whose max worker lands there.
    cap_cpu: [SlotSet; CAP_BUCKETS],
}

impl ClusterTable {
    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
    pub fn contains(&self, cluster: ClusterId) -> bool {
        self.slot.contains_key(&cluster)
    }

    /// Register a cluster (empty aggregate until its first report).
    /// Returns false (and keeps the existing entry) on a duplicate.
    pub fn register(&mut self, cluster: ClusterId) -> bool {
        if self.slot.contains_key(&cluster) {
            return false;
        }
        let i = self.entries.len();
        self.slot.insert(cluster, i);
        self.entries.push(ClusterEntry {
            cluster,
            stats: AggregateStats::default(),
            reports: 0,
        });
        self.grow_filters(i + 1);
        true
    }

    /// Deregister a cluster, compacting the dense storage in order (an
    /// O(n) shift + full bitset rebuild — departures are rare; ranking
    /// queries are not).
    pub fn deregister(&mut self, cluster: ClusterId) -> Option<AggregateStats> {
        let i = self.slot.remove(&cluster)?;
        let e = self.entries.remove(i);
        for s in self.slot.values_mut() {
            if *s > i {
                *s -= 1;
            }
        }
        self.rebuild_filters();
        Some(e.stats)
    }

    /// Ingest one aggregate report: replace the entry's stats and update
    /// the pre-filter bitsets **only when the filter key moved** — a
    /// mean/σ drift re-scores the cluster but touches no index. Returns
    /// false for unregistered clusters.
    pub fn apply_report(&mut self, cluster: ClusterId, stats: AggregateStats) -> bool {
        let Some(&i) = self.slot.get(&cluster) else {
            return false;
        };
        let old_key = Self::filter_key(&self.entries[i].stats);
        let new_key = Self::filter_key(&stats);
        self.entries[i].stats = stats;
        self.entries[i].reports += 1;
        if old_key != new_key {
            self.clear_filters(i);
            self.set_filters(i, new_key);
        }
        true
    }

    pub fn stats(&self, cluster: ClusterId) -> Option<&AggregateStats> {
        self.slot.get(&cluster).map(|&i| &self.entries[i].stats)
    }

    /// Cluster ids in registration order.
    pub fn clusters(&self) -> impl Iterator<Item = ClusterId> + '_ {
        self.entries.iter().map(|e| e.cluster)
    }

    pub fn iter(&self) -> impl Iterator<Item = &ClusterEntry> {
        self.entries.iter()
    }

    fn filter_key(stats: &AggregateStats) -> FilterKey {
        (
            stats.worker_count > 0,
            stats.virtualization.0,
            cap_bucket(stats.max_worker.cpu_millicores),
        )
    }

    fn grow_filters(&mut self, slots: usize) {
        self.nonempty.grow(slots);
        for v in &mut self.virt {
            v.grow(slots);
        }
        for b in &mut self.cap_cpu {
            b.grow(slots);
        }
    }

    fn set_filters(&mut self, i: usize, key: FilterKey) {
        let (nonempty, virt, bucket) = key;
        if !nonempty {
            // Empty clusters are never feasible: keep them out of every
            // set so the query-time intersection skips them for free.
            return;
        }
        self.nonempty.set(i);
        self.cap_cpu[bucket].set(i);
        for b in 0..VIRT_BITS {
            if (virt >> b) & 1 == 1 {
                self.virt[b].set(i);
            }
        }
    }

    fn clear_filters(&mut self, i: usize) {
        self.nonempty.clear(i);
        for v in &mut self.virt {
            v.clear(i);
        }
        for b in &mut self.cap_cpu {
            b.clear(i);
        }
    }

    fn rebuild_filters(&mut self) {
        self.nonempty.clear_all();
        for v in &mut self.virt {
            v.clear_all();
        }
        for b in &mut self.cap_cpu {
            b.clear_all();
        }
        self.grow_filters(self.entries.len());
        for i in 0..self.entries.len() {
            let key = Self::filter_key(&self.entries[i].stats);
            self.set_filters(i, key);
        }
    }

    /// Top-K priority-list selection for one task: intersect the
    /// pre-filter bitsets word-wise, run the exact
    /// [`cluster_feasible`]/[`cluster_score`] checks only on surviving
    /// slots, and keep the best K via bounded insertion — no full sort.
    /// `exclude` lists clusters that already refused this instance (the
    /// in-flight delegation's spill bookkeeping); they are skipped before
    /// scoring. Returns the candidates (best first, identical order to
    /// [`crate::scheduler::rank_clusters`] truncated to K) and the number
    /// of slots that survived the bitset pre-filter (the work actually
    /// done, which the root charges as scheduling cost).
    pub fn top_k(
        &self,
        sla: &TaskSla,
        k: usize,
        exclude: &[ClusterId],
    ) -> (Vec<ClusterCandidate>, usize) {
        if k == 0 || self.entries.is_empty() {
            return (Vec::new(), 0);
        }
        let req = sla.request();
        let req_virt = sla
            .virtualization_mask()
            .unwrap_or(Virtualization::CONTAINER);
        let req_bucket = cap_bucket(req.cpu_millicores);
        let words = self.entries.len().div_ceil(64);
        let mut out: Vec<ClusterCandidate> = Vec::with_capacity(k + 1);
        let mut scanned = 0usize;
        for wi in 0..words {
            let mut w = self.nonempty.word(wi);
            for b in 0..VIRT_BITS {
                if (req_virt.0 >> b) & 1 == 1 {
                    w &= self.virt[b].word(wi);
                }
            }
            let mut cap_union = 0u64;
            for bucket in req_bucket..CAP_BUCKETS {
                cap_union |= self.cap_cpu[bucket].word(wi);
            }
            w &= cap_union;
            while w != 0 {
                let i = wi * 64 + w.trailing_zeros() as usize;
                w &= w - 1;
                let e = &self.entries[i];
                if exclude.contains(&e.cluster) {
                    continue;
                }
                scanned += 1;
                if !cluster_feasible(&e.stats, &req, req_virt, sla.location.as_ref()) {
                    continue;
                }
                let cand = ClusterCandidate {
                    cluster: e.cluster,
                    score: cluster_score(&e.stats, &req),
                };
                // Bounded insertion under rank_clusters' exact comparator
                // (score desc, cluster asc — a strict total order, so the
                // top-K set and its order are unique).
                let pos = out
                    .iter()
                    .position(|c| {
                        cand.score
                            .total_cmp(&c.score)
                            .then(c.cluster.cmp(&cand.cluster))
                            == std::cmp::Ordering::Greater
                    })
                    .unwrap_or(out.len());
                if pos < k {
                    out.insert(pos, cand);
                    if out.len() > k {
                        out.pop();
                    }
                }
            }
        }
        (out, scanned)
    }

    /// Validate the slot map and every pre-filter bitset against a
    /// brute-force recompute from the dense entries.
    pub fn check_consistent(&self) -> Result<(), String> {
        if self.slot.len() != self.entries.len() {
            return Err(format!(
                "slot count {} != entry count {}",
                self.slot.len(),
                self.entries.len()
            ));
        }
        for (c, &i) in &self.slot {
            match self.entries.get(i) {
                Some(e) if e.cluster == *c => {}
                Some(e) => {
                    return Err(format!("{c} slot {i} holds {}", e.cluster))
                }
                None => return Err(format!("{c} slot {i} out of bounds")),
            }
        }
        for (i, e) in self.entries.iter().enumerate() {
            let (nonempty, virt, bucket) = Self::filter_key(&e.stats);
            if self.nonempty.contains(i) != nonempty {
                return Err(format!("{} nonempty bit wrong", e.cluster));
            }
            for b in 0..VIRT_BITS {
                let want = nonempty && (virt >> b) & 1 == 1;
                if self.virt[b].contains(i) != want {
                    return Err(format!("{} virt bit {b} wrong", e.cluster));
                }
            }
            for bk in 0..CAP_BUCKETS {
                let want = nonempty && bk == bucket;
                if self.cap_cpu[bk].contains(i) != want {
                    return Err(format!("{} cap bucket {bk} wrong", e.cluster));
                }
            }
        }
        // No stray bits beyond the live slots (a compaction bug would
        // leave ghosts that the word-wise scan then dereferences).
        let limit = self.nonempty.words.len() * 64;
        for i in self.entries.len()..limit {
            if self.nonempty.contains(i)
                || self.virt.iter().any(|v| v.contains(i))
                || self.cap_cpu.iter().any(|b| b.contains(i))
            {
                return Err(format!("stray filter bit at dead slot {i}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Capacity;
    use crate::scheduler::rank_clusters;
    use crate::sla::simple_sla;

    fn stats_of(workers: &[(u32, u32)]) -> AggregateStats {
        let caps: Vec<Capacity> = workers
            .iter()
            .map(|(c, m)| Capacity::new(*c, *m, 0))
            .collect();
        AggregateStats::from_workers(
            caps.iter().map(|c| (c, Virtualization::all())),
            None,
        )
    }

    fn brute(
        table: &ClusterTable,
        sla: &TaskSla,
        k: usize,
        exclude: &[ClusterId],
    ) -> Vec<ClusterCandidate> {
        let pairs: Vec<(ClusterId, &AggregateStats)> = table
            .iter()
            .filter(|e| !exclude.contains(&e.cluster))
            .map(|e| (e.cluster, &e.stats))
            .collect();
        let mut want = rank_clusters(sla, &pairs);
        want.truncate(k);
        want
    }

    #[test]
    fn cap_buckets_are_conservative() {
        assert_eq!(cap_bucket(0), 0);
        assert_eq!(cap_bucket(1), 1);
        assert_eq!(cap_bucket(1000), 10);
        assert_eq!(cap_bucket(1024), 11);
        // A request can only fit clusters in its bucket or above.
        assert!(cap_bucket(999) <= cap_bucket(1000));
        assert!(cap_bucket(u32::MAX) <= CAP_BUCKETS - 1);
    }

    #[test]
    fn top_k_matches_brute_force_rank() {
        let mut t = ClusterTable::default();
        for c in 1..=5u32 {
            assert!(t.register(ClusterId(c)));
        }
        assert!(!t.register(ClusterId(3)), "duplicate refused");
        t.apply_report(ClusterId(1), stats_of(&[(1500, 1024), (1500, 1024)]));
        t.apply_report(ClusterId(2), stats_of(&[(6000, 6000)]));
        t.apply_report(ClusterId(3), stats_of(&[(800, 512), (7000, 8000)]));
        t.apply_report(ClusterId(4), stats_of(&[(2000, 2048)]));
        // Cluster 5 never reports: empty, never a candidate.
        t.check_consistent().unwrap();

        let sla = simple_sla("t", 1000, 512);
        for k in 1..=5 {
            let (got, scanned) = t.top_k(&sla.constraints[0], k, &[]);
            assert_eq!(got, brute(&t, &sla.constraints[0], k, &[]), "k={k}");
            assert!(scanned <= 4, "empty cluster must not be scanned");
        }
        // Exclusion (spill bookkeeping) drops the refusing cluster.
        let excl = [ClusterId(2)];
        let (got, _) = t.top_k(&sla.constraints[0], 2, &excl);
        assert_eq!(got, brute(&t, &sla.constraints[0], 2, &excl));
        assert!(got.iter().all(|c| c.cluster != ClusterId(2)));
    }

    #[test]
    fn capacity_bucket_prefilter_skips_saturated_clusters() {
        let mut t = ClusterTable::default();
        t.register(ClusterId(1));
        t.register(ClusterId(2));
        t.apply_report(ClusterId(1), stats_of(&[(300, 1024)]));
        t.apply_report(ClusterId(2), stats_of(&[(4000, 4096)]));
        let sla = simple_sla("t", 1000, 256);
        let (got, scanned) = t.top_k(&sla.constraints[0], 4, &[]);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].cluster, ClusterId(2));
        // Cluster 1's max-worker bucket (300 → 9) is below the request
        // bucket (1000 → 10): the bitset intersection drops it unscanned.
        assert_eq!(scanned, 1);
    }

    #[test]
    fn report_ingest_moves_filter_membership() {
        let mut t = ClusterTable::default();
        t.register(ClusterId(7));
        let sla = simple_sla("t", 500, 128);
        assert!(t.top_k(&sla.constraints[0], 1, &[]).0.is_empty());
        t.apply_report(ClusterId(7), stats_of(&[(2000, 2048)]));
        t.check_consistent().unwrap();
        assert_eq!(t.top_k(&sla.constraints[0], 1, &[]).0.len(), 1);
        // The cluster saturates: its next report empties it again.
        t.apply_report(ClusterId(7), AggregateStats::default());
        t.check_consistent().unwrap();
        assert!(t.top_k(&sla.constraints[0], 1, &[]).0.is_empty());
        assert_eq!(t.stats(ClusterId(7)).unwrap().worker_count, 0);
        assert!(!t.apply_report(ClusterId(9), AggregateStats::default()));
    }

    #[test]
    fn deregister_compacts_in_order() {
        let mut t = ClusterTable::default();
        for c in [5u32, 2, 9, 7] {
            t.register(ClusterId(c));
            t.apply_report(ClusterId(c), stats_of(&[(c * 100, 512)]));
        }
        t.deregister(ClusterId(2)).unwrap();
        assert!(t.deregister(ClusterId(2)).is_none());
        let order: Vec<u32> = t.clusters().map(|c| c.0).collect();
        assert_eq!(order, vec![5, 9, 7], "registration order survives");
        t.check_consistent().unwrap();
        assert!(t.stats(ClusterId(9)).is_some());
        assert_eq!(t.len(), 3);
    }
}
