//! The Oakestra control plane (paper §3): root orchestrator, cluster
//! orchestrators and worker NodeEngines as simulation actors speaking the
//! [`crate::sim::OakMsg`] protocol over MQTT-like (intra-cluster) and
//! WebSocket-like (inter-cluster) transports.
//!
//! Responsibilities follow Fig. 1:
//! * [`RootOrchestrator`] — system manager + service manager + database:
//!   cluster registry, the typed northbound API (`OakMsg::ApiCall` /
//!   `OakMsg::ApiReturn` carrying [`crate::api::ApiRequest`] /
//!   [`crate::api::ApiResponse`]: SLA intake, scale up/down, explicit
//!   migration, teardown, status and listing), root-tier scheduling
//!   (priority list of clusters), delegation, service lifecycle
//!   tracking, recursive ServiceIP resolution, liveness of cluster
//!   links.
//! * [`ClusterOrchestrator`] — logical twin of the root scoped to one
//!   cluster: worker registry + telemetry ingestion, cluster-tier
//!   scheduling (ROM/LDP plugins), deployment, health sweeps, failure
//!   recovery and migration, conversion-table resolution.
//! * [`WorkerEngine`] — NodeEngine + NetManager on each worker: telemetry
//!   governor, Vivaldi updates, container deploy/undeploy, semantic
//!   addressing (conversion table, ProxyTUN, mDNS), data-plane serving.

mod cluster;
mod db;
mod fedstate;
mod root;
mod state;
mod worker;

pub use cluster::{ClusterConfig, ClusterOrchestrator, SchedulerKind};
pub use db::{AdoptError, ServiceDb, ServiceRecord};
pub use fedstate::{ClusterEntry, ClusterTable};
pub use root::{RootConfig, RootOrchestrator};
pub use state::{InstanceTable, LocalInstance, WorkerTable};
pub use worker::{WorkerConfig, WorkerEngine};

use crate::util::SimTime;

/// Control-plane CPU cost model, in milliseconds of one x86 core, charged
/// through [`crate::sim::Ctx::charge_cpu`]. These are Oakestra-side costs;
/// the baselines carry their own (heavier) tables in
/// [`crate::baselines::costs`]. Values are small because the paper's
/// measurement shows Oakestra's idle control plane at ~0.1–0.5% CPU.
pub mod costs {
    /// Parse + apply one worker telemetry report.
    pub const WORKER_REPORT_MS: f64 = 0.08;
    /// NodeEngine housekeeping per telemetry tick (2 s): stats collection,
    /// MQTT client, Vivaldi updates. ~0.1% of a core — the paper's ≈6×
    /// worker-CPU advantage over K3s comes from here vs kubelet ticks.
    pub const WORKER_TICK_MS: f64 = 4.0;
    /// Worker-side per-hosted-instance monitoring per tick (container
    /// stats via runtime API; 100 containers ≈ 65% of an S VM, leaving
    /// ~30% available — Fig. 7b).
    pub const PER_INSTANCE_TICK_MS: f64 = 13.0;
    /// Produce one aggregate + push to parent.
    pub const AGGREGATE_MS: f64 = 2.5;
    /// Root-side handling of a cluster report.
    pub const CLUSTER_REPORT_MS: f64 = 0.12;
    /// SLA validation + service registration at the root.
    pub const SUBMIT_MS: f64 = 0.8;
    /// Root-side handling of a ScaleService call (plan + mint/cancel).
    pub const SCALE_MS: f64 = 0.4;
    /// Root-side handling of a MigrateInstance call (lookup + forward).
    pub const MIGRATE_MS: f64 = 0.2;
    /// Root-side handling of an UndeployService call (fan-out broadcast).
    pub const UNDEPLOY_MS: f64 = 0.3;
    /// Root-side status/list read (database view construction).
    pub const STATUS_MS: f64 = 0.05;
    /// Root-side successor adoption (lineage validation + record mint +
    /// ack) for one cluster-announced replacement.
    pub const ADOPT_MS: f64 = 0.15;
    /// Root scheduling: per candidate cluster actually scanned (after the
    /// `ClusterTable` feasibility pre-filters — saturated or mismatched
    /// clusters drop out of the scan and are never charged).
    pub const ROOT_SCHED_PER_CLUSTER_MS: f64 = 0.02;
    /// One priority-list spill continuation (`DelegationResult{None}` →
    /// next precomputed candidate): O(1) bookkeeping, no re-rank.
    pub const ROOT_SPILL_STEP_MS: f64 = 0.004;
    /// Cluster scheduling: per worker scored (ROM).
    pub const ROM_PER_WORKER_MS: f64 = 0.012;
    /// Cluster scheduling: per worker feasibility + constraint math
    /// (LDP). Used to be 0.055 ms: the old implementation pre-measured an
    /// RTT towards *every* worker per placement, and that fleet-wide ping
    /// sweep was folded in here. Pings are now lazy (only the sampled
    /// probe candidates are measured — see `LDP_PING_MS`), so the
    /// per-worker term models just the filter/ranking math.
    pub const LDP_PER_WORKER_MS: f64 = 0.02;
    /// One lazy RTT probe issued towards a sampled candidate worker
    /// (Alg. 2 line 11), charged per ping actually performed.
    pub const LDP_PING_MS: f64 = 0.35;
    /// LDP per S2U trilateration (fixed GD solve).
    pub const LDP_TRILATERATION_MS: f64 = 0.9;
    /// Worker-side deploy bookkeeping (excl. container runtime itself).
    pub const DEPLOY_MS: f64 = 0.5;
    /// NetManager table resolution / update application.
    pub const TABLE_OP_MS: f64 = 0.03;
    /// Idle loop tick of any Oakestra component (health sweep, liveness).
    pub const IDLE_TICK_MS: f64 = 5.0;
    /// Liveness ping handling.
    pub const PING_MS: f64 = 0.01;
}

/// Resident-set sizes of the components in MB (paper Fig. 4c: Oakestra's
/// worker footprint ≈ tens of MB vs hundreds for kubelet).
pub mod mem {
    /// Root: Python services + database.
    pub const ROOT_BASE_MB: f64 = 410.0;
    /// Cluster orchestrator: Python twin + MQTT broker + local DB — ≈33%
    /// below the K3s master (paper Fig. 4c).
    pub const CLUSTER_BASE_MB: f64 = 330.0;
    /// NodeEngine + NetManager (Go): ≈18% below the K3s agent (Fig. 4c).
    pub const WORKER_BASE_MB: f64 = 130.0;
    /// Bookkeeping per tracked service instance.
    pub const PER_INSTANCE_MB: f64 = 0.6;
    /// Per registered worker at the cluster orchestrator.
    pub const PER_WORKER_MB: f64 = 0.8;
}

/// Default control-loop periods.
pub mod intervals {
    use super::SimTime;
    pub fn worker_telemetry() -> SimTime {
        SimTime::from_secs(2.0)
    }
    pub fn cluster_aggregate() -> SimTime {
        SimTime::from_secs(5.0)
    }
    pub fn health_sweep() -> SimTime {
        SimTime::from_secs(5.0)
    }
    pub fn liveness_ping() -> SimTime {
        SimTime::from_secs(5.0)
    }
    pub fn tunnel_gc() -> SimTime {
        SimTime::from_secs(30.0)
    }
    /// Conversion-table dissemination tick: buffered `TableEntry` deltas
    /// are flushed as one batched `TableUpdate` per destination worker at
    /// most this often (deploy/teardown acks flush immediately). The
    /// timer is armed lazily — an idle cluster schedules nothing.
    pub fn table_dissemination() -> SimTime {
        SimTime::from_millis(250.0)
    }
    /// Staleness bound on delta-coalesced cluster→root aggregate reports
    /// (three aggregate ticks): a steady cluster resends at least this
    /// often even when nothing moved past the threshold.
    pub fn aggregate_max_age() -> SimTime {
        SimTime::from_secs(15.0)
    }
    /// Worker considered dead after this much report silence.
    pub fn worker_dead_after() -> SimTime {
        SimTime::from_secs(12.0)
    }
    /// How long a restarted cluster orchestrator stays in Recovering,
    /// absorbing worker re-register censuses, before it declares its
    /// rebuilt tables authoritative (Recovering → Active). Sized to one
    /// worker telemetry period: every live worker re-registers within
    /// one solicited handshake round-trip, well inside this window.
    pub fn recovery_grace() -> SimTime {
        SimTime::from_secs(2.0)
    }
}
