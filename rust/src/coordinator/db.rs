//! The root database (paper §3.2.1): current state of all submitted
//! services and reported operational information from clusters.
//!
//! All maps are `BTreeMap`s: under churn workloads the database is
//! iterated on hot paths (status scans, summaries, censuses) and any
//! `HashMap` iteration order would leak the per-process hasher seed into
//! event ordering, breaking seed-determinism of the whole simulation.

use std::collections::{BTreeMap, BTreeSet};

use crate::model::{InstanceRecord, ServiceSpec, ServiceState, TaskSpec};
use crate::sla::ServiceSla;
use crate::util::{ClusterId, InstanceId, NodeId, ServiceId, SimTime, TaskId};

/// Why the root refused to adopt a cluster-minted successor. Every
/// refusal obliges the announcing cluster to tear the replacement down.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AdoptError {
    /// No record of the service at all.
    UnknownService,
    /// The service was undeployed: it may never grow again (mirrors
    /// [`ServiceRecord::retired`] / `mint_replacement`'s refusal).
    Retired,
    /// The claimed original was never registered with (or adopted by)
    /// the root — the lineage chain is broken.
    UnknownOriginal,
    /// Task does not belong to the service, or contradicts the
    /// original's task.
    TaskMismatch,
    /// The original already has a *different* successor, or the
    /// replacement id is already taken by an unrelated record.
    LineageConflict,
}

/// Root-side record of one submitted service.
#[derive(Clone, Debug)]
pub struct ServiceRecord {
    pub spec: ServiceSpec,
    pub sla: ServiceSla,
    pub submitted_at: SimTime,
    /// All instances ever created for this service (incl. migrations).
    /// Append-only — records are kept for lineage and post-mortem
    /// status, which is what keeps `slot` trivially correct. NEVER push
    /// to (or reorder) this directly: go through `push_instance`, or
    /// `instance()/instance_mut()` silently resolve to wrong records.
    pub instances: Vec<InstanceRecord>,
    /// Instance id → position in `instances`. The root resolves a record
    /// on every `InstanceStatus` under churn; this replaces the linear
    /// scan per report. Maintained by [`ServiceRecord::push_instance`].
    slot: BTreeMap<InstanceId, usize>,
    /// Which cluster each live instance was delegated to.
    pub placement: BTreeMap<InstanceId, ClusterId>,
    /// Latest observed CPU draw per cluster (mc, Running instances only),
    /// refreshed from the `service_cpu` rows piggybacked on (coalesced)
    /// `ClusterReport`s — the root's QoS-telemetry view of the service.
    pub observed_cpu: BTreeMap<ClusterId, u64>,
    /// Set once `UndeployService` is accepted: the service may never grow
    /// again (no scale-up, no migration replacements, no reschedules) —
    /// otherwise a teardown racing an in-flight recovery resurrects
    /// instances the broadcast already missed.
    pub retired: bool,
    /// Partition overlay (NOT a lifecycle state — instances keep their
    /// [`ServiceState`]): clusters currently unreachable that hold live
    /// placements of this service, with the time each degradation
    /// started. While non-empty, status answers for those placements are
    /// a last-known-good view and the root must not storm reschedules —
    /// the cluster keeps operating autonomously and the post-heal
    /// anti-entropy resync reconciles.
    pub degraded: BTreeMap<ClusterId, SimTime>,
}

impl ServiceRecord {
    /// The service counts as deployed when every task has ≥1 Running
    /// instance.
    pub fn fully_running(&self) -> bool {
        self.spec.tasks.iter().all(|t| {
            self.instances
                .iter()
                .any(|i| i.task == t.id && i.state == ServiceState::Running)
        })
    }

    /// Append an instance record, keeping the id→position index current.
    fn push_instance(&mut self, inst: InstanceRecord) {
        self.slot.insert(inst.instance, self.instances.len());
        self.instances.push(inst);
    }

    pub fn instance_mut(&mut self, id: InstanceId) -> Option<&mut InstanceRecord> {
        let i = *self.slot.get(&id)?;
        self.instances.get_mut(i)
    }

    pub fn instance(&self, id: InstanceId) -> Option<&InstanceRecord> {
        self.slot.get(&id).and_then(|&i| self.instances.get(i))
    }

    /// Total observed CPU draw across clusters (mc) — the aggregated
    /// telemetry `ServiceStatus` exposes.
    pub fn observed_cpu_mc(&self) -> u64 {
        self.observed_cpu.values().sum()
    }

    /// Whether any cluster holding this service's placements is currently
    /// partitioned (degraded-mode staleness applies to status answers).
    pub fn is_degraded(&self) -> bool {
        !self.degraded.is_empty()
    }
}

/// In-memory service database with id minting.
#[derive(Clone, Debug, Default)]
pub struct ServiceDb {
    services: BTreeMap<ServiceId, ServiceRecord>,
    /// Instance → owning service. Status/undeploy/migrate paths resolve
    /// instance ids on every report; without this the root pays an
    /// O(services × instances) scan per `InstanceStatus` under churn.
    /// Maintained at every record-creation point (register, mint,
    /// adopt); entries live as long as their records (which are kept for
    /// lineage and post-mortem status).
    index: BTreeMap<InstanceId, ServiceId>,
    /// Which services each cluster named in its last `service_cpu` rows —
    /// the reverse index that keeps [`ServiceDb::apply_cluster_cpu`]
    /// proportional to the reporting cluster's own rows instead of a
    /// full-database sweep per report.
    cpu_reported: BTreeMap<ClusterId, BTreeSet<ServiceId>>,
    next_service: u32,
    next_instance: u64,
}

impl ServiceDb {
    /// Register a validated SLA as a new service; returns the id and the
    /// freshly minted per-task instances (all `Requested`).
    pub fn register(&mut self, sla: ServiceSla, now: SimTime) -> (ServiceId, Vec<InstanceId>) {
        let id = ServiceId(self.next_service);
        self.next_service += 1;

        let tasks: Vec<TaskSpec> = sla
            .constraints
            .iter()
            .enumerate()
            .map(|(i, row)| TaskSpec {
                id: TaskId {
                    service: id,
                    index: i as u16,
                },
                name: format!("{}-{}", sla.name, i),
                request: row.request(),
                virtualization: row
                    .virtualization_mask()
                    .unwrap_or(crate::model::Virtualization::CONTAINER),
                image_mb: 50 + 10 * i as u32,
                sla: row.clone(),
            })
            .collect();

        let mut rec = ServiceRecord {
            spec: ServiceSpec {
                id,
                name: sla.name.clone(),
                tasks: Vec::new(),
            },
            sla,
            submitted_at: now,
            instances: Vec::new(),
            slot: BTreeMap::new(),
            placement: BTreeMap::new(),
            observed_cpu: BTreeMap::new(),
            retired: false,
            degraded: BTreeMap::new(),
        };
        let mut ids = Vec::new();
        for t in &tasks {
            let iid = InstanceId(self.next_instance);
            self.next_instance += 1;
            rec.push_instance(InstanceRecord::new(iid, t.id));
            self.index.insert(iid, id);
            ids.push(iid);
        }
        rec.spec.tasks = tasks;

        self.services.insert(id, rec);
        (id, ids)
    }

    /// Mint a replacement instance for a task (rescheduling/migration/
    /// replication — paper §4.2/§6). Refused for retired services: a
    /// teardown must never race a recovery into a resurrected instance.
    pub fn mint_replacement(&mut self, task: TaskId) -> Option<InstanceId> {
        let rec = self.services.get_mut(&task.service)?;
        if rec.retired {
            return None;
        }
        let iid = InstanceId(self.next_instance);
        self.next_instance += 1;
        let mut inst = InstanceRecord::new(iid, task);
        inst.generation = rec
            .instances
            .iter()
            .filter(|i| i.task == task)
            .map(|i| i.generation + 1)
            .max()
            .unwrap_or(0);
        rec.push_instance(inst);
        self.index.insert(iid, task.service);
        Some(iid)
    }

    /// Successor registration (the root half of the cluster→root
    /// replacement-tracking protocol): atomically adopt a cluster-minted
    /// `replacement` as the successor of `original`. The original record
    /// is retired from further migration by the lineage link; its
    /// lifecycle state still converges through the normal status path (a
    /// migration original keeps running until cutover). Duplicate
    /// announcements of the same lineage are idempotent (`Ok(false)`).
    /// Returns `Ok(true)` when a new record was created.
    pub fn adopt_successor(
        &mut self,
        service: ServiceId,
        task: TaskId,
        original: InstanceId,
        replacement: InstanceId,
    ) -> Result<bool, AdoptError> {
        let rec = self
            .services
            .get_mut(&service)
            .ok_or(AdoptError::UnknownService)?;
        if rec.retired {
            return Err(AdoptError::Retired);
        }
        if task.service != service || rec.spec.task(task).is_none() {
            return Err(AdoptError::TaskMismatch);
        }
        if let Some(existing) = rec.instance(replacement) {
            // Re-announcement (lost/duplicated ack): already adopted.
            return if existing.predecessor == Some(original) {
                Ok(false)
            } else {
                Err(AdoptError::LineageConflict)
            };
        }
        let Some(orig) = rec.instance(original) else {
            return Err(AdoptError::UnknownOriginal);
        };
        if orig.task != task {
            return Err(AdoptError::TaskMismatch);
        }
        if orig.successor.is_some() {
            // A different replacement already superseded the original.
            return Err(AdoptError::LineageConflict);
        }
        let mut inst = InstanceRecord::new(replacement, task);
        inst.generation = orig.generation + 1;
        inst.predecessor = Some(original);
        // The cluster deploys the replacement at mint time, so by the
        // time this registration arrives it is already past Requested.
        let _ = inst.transition(ServiceState::Scheduled);
        rec.instance_mut(original).unwrap().successor = Some(replacement);
        rec.push_instance(inst);
        self.index.insert(replacement, service);
        Ok(true)
    }

    /// Ingest one cluster's per-service observed-CPU rows (piggybacked on
    /// its aggregate report): refresh the cluster's column on every named
    /// service and clear it on services the cluster named last time but
    /// no longer reports (all their instances there stopped Running or
    /// left). The `cpu_reported` reverse index keeps this O(rows) — not a
    /// scan over every service in the database per report.
    pub fn apply_cluster_cpu(&mut self, cluster: ClusterId, rows: &[(ServiceId, u64)]) {
        let named: BTreeSet<ServiceId> = rows.iter().map(|(s, _)| *s).collect();
        if let Some(prev) = self.cpu_reported.get(&cluster) {
            for sid in prev.difference(&named) {
                if let Some(rec) = self.services.get_mut(sid) {
                    rec.observed_cpu.remove(&cluster);
                }
            }
        }
        for (sid, cpu) in rows {
            if let Some(rec) = self.services.get_mut(sid) {
                rec.observed_cpu.insert(cluster, *cpu);
            }
        }
        if named.is_empty() {
            self.cpu_reported.remove(&cluster);
        } else {
            self.cpu_reported.insert(cluster, named);
        }
    }

    /// Resolve the owning service of any instance the root has ever
    /// tracked — O(log n) via the instance index instead of a full
    /// database scan.
    pub fn service_of_instance(&self, instance: InstanceId) -> Option<ServiceId> {
        self.index.get(&instance).copied()
    }

    pub fn service(&self, id: ServiceId) -> Option<&ServiceRecord> {
        self.services.get(&id)
    }
    pub fn service_mut(&mut self, id: ServiceId) -> Option<&mut ServiceRecord> {
        self.services.get_mut(&id)
    }
    pub fn services(&self) -> impl Iterator<Item = &ServiceRecord> {
        self.services.values()
    }
    pub fn len(&self) -> usize {
        self.services.len()
    }
    pub fn is_empty(&self) -> bool {
        self.services.is_empty()
    }

    /// Mark every service with a live placement in `cluster` as degraded
    /// (the cluster's federation lease partitioned). Returns how many
    /// services were newly marked.
    pub fn mark_cluster_degraded(&mut self, cluster: ClusterId, now: SimTime) -> u64 {
        let mut marked = 0;
        for rec in self.services.values_mut() {
            if rec.retired || rec.degraded.contains_key(&cluster) {
                continue;
            }
            let placed = rec.instances.iter().any(|i| {
                !i.state.is_terminal() && rec.placement.get(&i.instance) == Some(&cluster)
            });
            if placed {
                rec.degraded.insert(cluster, now);
                marked += 1;
            }
        }
        marked
    }

    /// Lift the degraded overlay for `cluster` on heal. Returns how many
    /// services carried the marker.
    pub fn clear_cluster_degraded(&mut self, cluster: ClusterId) -> u64 {
        let mut cleared = 0;
        for rec in self.services.values_mut() {
            if rec.degraded.remove(&cluster).is_some() {
                cleared += 1;
            }
        }
        cleared
    }

    /// Every live (non-terminal) root record currently placed in
    /// `cluster` — the root's half of the anti-entropy census diff.
    pub fn live_placed_in(&self, cluster: ClusterId) -> Vec<(ServiceId, TaskId, InstanceId)> {
        let mut out = Vec::new();
        for (sid, rec) in &self.services {
            for i in &rec.instances {
                if !i.state.is_terminal()
                    && rec.placement.get(&i.instance) == Some(&cluster)
                {
                    out.push((*sid, i.task, i.instance));
                }
            }
        }
        out
    }

    /// All running locations of a task across clusters (root-tier
    /// ServiceIP resolution, paper §5 recursive table refresh).
    pub fn running_locations(&self, task: TaskId) -> Vec<(InstanceId, NodeId)> {
        self.services
            .get(&task.service)
            .map(|rec| {
                rec.instances
                    .iter()
                    .filter(|i| i.task == task && i.state == ServiceState::Running)
                    .filter_map(|i| i.worker.map(|w| (i.instance, w)))
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sla::simple_sla;

    #[test]
    fn register_creates_instances_per_task() {
        let mut db = ServiceDb::default();
        let mut sla = simple_sla("app", 1000, 100);
        sla.constraints.push(sla.constraints[0].clone());
        let (id, ids) = db.register(sla, SimTime::ZERO);
        assert_eq!(ids.len(), 2);
        let rec = db.service(id).unwrap();
        assert_eq!(rec.spec.tasks.len(), 2);
        assert!(!rec.fully_running());
        // Ids unique and sequential per registration.
        let (_, ids2) = db.register(simple_sla("b", 500, 64), SimTime::ZERO);
        assert!(ids2[0] > ids[1]);
    }

    #[test]
    fn fully_running_requires_every_task() {
        let mut db = ServiceDb::default();
        let mut sla = simple_sla("app", 1000, 100);
        sla.constraints.push(sla.constraints[0].clone());
        let (id, ids) = db.register(sla, SimTime::ZERO);
        for (k, iid) in ids.iter().enumerate() {
            {
                let rec = db.service_mut(id).unwrap();
                let inst = rec.instance_mut(*iid).unwrap();
                inst.transition(ServiceState::Scheduled).unwrap();
                inst.worker = Some(NodeId(k as u32));
                inst.transition(ServiceState::Running).unwrap();
            }
            if k == 0 {
                assert!(!db.service(id).unwrap().fully_running());
            }
        }
        assert!(db.service(id).unwrap().fully_running());
        assert_eq!(
            db.running_locations(TaskId {
                service: id,
                index: 1
            })
            .len(),
            1
        );
    }

    #[test]
    fn retired_services_refuse_replacements() {
        let mut db = ServiceDb::default();
        let (id, _) = db.register(simple_sla("app", 1000, 100), SimTime::ZERO);
        let task = TaskId {
            service: id,
            index: 0,
        };
        assert!(db.mint_replacement(task).is_some());
        db.service_mut(id).unwrap().retired = true;
        assert!(
            db.mint_replacement(task).is_none(),
            "an undeployed service must never grow again"
        );
    }

    #[test]
    fn adopt_successor_links_lineage_and_indexes() {
        let mut db = ServiceDb::default();
        let (id, ids) = db.register(simple_sla("app", 1000, 100), SimTime::ZERO);
        let task = TaskId {
            service: id,
            index: 0,
        };
        let repl = InstanceId(1 << 62 | 77);
        assert_eq!(db.adopt_successor(id, task, ids[0], repl), Ok(true));
        let rec = db.service(id).unwrap();
        assert_eq!(rec.instance(ids[0]).unwrap().successor, Some(repl));
        let r = rec.instance(repl).unwrap();
        assert_eq!(r.predecessor, Some(ids[0]));
        assert_eq!(r.generation, 1);
        assert_eq!(r.state, ServiceState::Scheduled, "adopted as deployed");
        assert_eq!(db.service_of_instance(repl), Some(id));
        assert_eq!(db.service_of_instance(ids[0]), Some(id));
        // Duplicate announcement of the same lineage is idempotent.
        assert_eq!(db.adopt_successor(id, task, ids[0], repl), Ok(false));
        // A *different* replacement for the same original is refused.
        assert_eq!(
            db.adopt_successor(id, task, ids[0], InstanceId(1 << 62 | 78)),
            Err(AdoptError::LineageConflict)
        );
        // Chained adoption: the replacement itself can be superseded.
        let repl2 = InstanceId(1 << 63 | 5);
        assert_eq!(db.adopt_successor(id, task, repl, repl2), Ok(true));
        assert_eq!(db.service(id).unwrap().instance(repl2).unwrap().generation, 2);
    }

    #[test]
    fn adopt_successor_refusals() {
        let mut db = ServiceDb::default();
        let (id, ids) = db.register(simple_sla("app", 1000, 100), SimTime::ZERO);
        let task = TaskId {
            service: id,
            index: 0,
        };
        let repl = InstanceId(1 << 62 | 1);
        // Unknown service.
        assert_eq!(
            db.adopt_successor(ServiceId(99), TaskId { service: ServiceId(99), index: 0 }, ids[0], repl),
            Err(AdoptError::UnknownService)
        );
        // Unknown original (lineage never registered).
        assert_eq!(
            db.adopt_successor(id, task, InstanceId(555), repl),
            Err(AdoptError::UnknownOriginal)
        );
        // Task not part of the service.
        assert_eq!(
            db.adopt_successor(id, TaskId { service: id, index: 7 }, ids[0], repl),
            Err(AdoptError::TaskMismatch)
        );
        // Retired service refuses adoption — an undeploy racing a
        // replacement registration must not resurrect the service.
        db.service_mut(id).unwrap().retired = true;
        assert_eq!(
            db.adopt_successor(id, task, ids[0], repl),
            Err(AdoptError::Retired)
        );
        assert!(db.service(id).unwrap().instance(repl).is_none());
    }

    #[test]
    fn cluster_cpu_rows_refresh_and_clear() {
        let mut db = ServiceDb::default();
        let (a, _) = db.register(simple_sla("a", 100, 32), SimTime::ZERO);
        let (b, _) = db.register(simple_sla("b", 100, 32), SimTime::ZERO);
        db.apply_cluster_cpu(ClusterId(1), &[(a, 70), (b, 140)]);
        db.apply_cluster_cpu(ClusterId(2), &[(a, 35)]);
        assert_eq!(db.service(a).unwrap().observed_cpu_mc(), 105);
        assert_eq!(db.service(b).unwrap().observed_cpu_mc(), 140);
        // Cluster 1 stops reporting b (drained there): its column clears,
        // other clusters' columns survive.
        db.apply_cluster_cpu(ClusterId(1), &[(a, 80)]);
        assert_eq!(db.service(a).unwrap().observed_cpu_mc(), 115);
        assert_eq!(db.service(b).unwrap().observed_cpu_mc(), 0);
        // Rows for unknown services are ignored.
        db.apply_cluster_cpu(ClusterId(1), &[(ServiceId(99), 10)]);
        assert_eq!(db.service(a).unwrap().observed_cpu_mc(), 35);
    }

    #[test]
    fn degraded_overlay_marks_and_clears_per_cluster() {
        let mut db = ServiceDb::default();
        let (a, ids_a) = db.register(simple_sla("a", 100, 32), SimTime::ZERO);
        let (b, ids_b) = db.register(simple_sla("b", 100, 32), SimTime::ZERO);
        db.service_mut(a)
            .unwrap()
            .placement
            .insert(ids_a[0], ClusterId(1));
        db.service_mut(b)
            .unwrap()
            .placement
            .insert(ids_b[0], ClusterId(2));
        let t = SimTime::from_secs(30.0);
        assert_eq!(db.mark_cluster_degraded(ClusterId(1), t), 1);
        // Idempotent: a second sweep marks nothing new.
        assert_eq!(db.mark_cluster_degraded(ClusterId(1), t), 0);
        assert!(db.service(a).unwrap().is_degraded());
        assert!(!db.service(b).unwrap().is_degraded());
        assert_eq!(
            db.live_placed_in(ClusterId(1)),
            vec![(
                a,
                TaskId {
                    service: a,
                    index: 0
                },
                ids_a[0]
            )]
        );
        // Terminal records leave the census view.
        db.service_mut(a)
            .unwrap()
            .instance_mut(ids_a[0])
            .unwrap()
            .state = ServiceState::Failed;
        assert!(db.live_placed_in(ClusterId(1)).is_empty());
        assert_eq!(db.clear_cluster_degraded(ClusterId(1)), 1);
        assert!(!db.service(a).unwrap().is_degraded());
        assert_eq!(db.clear_cluster_degraded(ClusterId(1)), 0);
    }

    #[test]
    fn replacement_bumps_generation() {
        let mut db = ServiceDb::default();
        let (id, _) = db.register(simple_sla("app", 1000, 100), SimTime::ZERO);
        let task = TaskId {
            service: id,
            index: 0,
        };
        let r1 = db.mint_replacement(task).unwrap();
        let r2 = db.mint_replacement(task).unwrap();
        let rec = db.service(id).unwrap();
        assert_eq!(rec.instance(r1).unwrap().generation, 1);
        assert_eq!(rec.instance(r2).unwrap().generation, 2);
        assert!(db
            .mint_replacement(TaskId {
                service: ServiceId(99),
                index: 0
            })
            .is_none());
    }
}
