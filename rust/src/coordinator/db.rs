//! The root database (paper §3.2.1): current state of all submitted
//! services and reported operational information from clusters.
//!
//! All maps are `BTreeMap`s: under churn workloads the database is
//! iterated on hot paths (status scans, summaries, censuses) and any
//! `HashMap` iteration order would leak the per-process hasher seed into
//! event ordering, breaking seed-determinism of the whole simulation.

use std::collections::BTreeMap;

use crate::model::{InstanceRecord, ServiceSpec, ServiceState, TaskSpec};
use crate::sla::ServiceSla;
use crate::util::{ClusterId, InstanceId, NodeId, ServiceId, SimTime, TaskId};

/// Root-side record of one submitted service.
#[derive(Clone, Debug)]
pub struct ServiceRecord {
    pub spec: ServiceSpec,
    pub sla: ServiceSla,
    pub submitted_at: SimTime,
    /// All instances ever created for this service (incl. migrations).
    pub instances: Vec<InstanceRecord>,
    /// Which cluster each live instance was delegated to.
    pub placement: BTreeMap<InstanceId, ClusterId>,
    /// Set once `UndeployService` is accepted: the service may never grow
    /// again (no scale-up, no migration replacements, no reschedules) —
    /// otherwise a teardown racing an in-flight recovery resurrects
    /// instances the broadcast already missed.
    pub retired: bool,
}

impl ServiceRecord {
    /// The service counts as deployed when every task has ≥1 Running
    /// instance.
    pub fn fully_running(&self) -> bool {
        self.spec.tasks.iter().all(|t| {
            self.instances
                .iter()
                .any(|i| i.task == t.id && i.state == ServiceState::Running)
        })
    }

    pub fn instance_mut(&mut self, id: InstanceId) -> Option<&mut InstanceRecord> {
        self.instances.iter_mut().find(|i| i.instance == id)
    }

    pub fn instance(&self, id: InstanceId) -> Option<&InstanceRecord> {
        self.instances.iter().find(|i| i.instance == id)
    }
}

/// In-memory service database with id minting.
#[derive(Clone, Debug, Default)]
pub struct ServiceDb {
    services: BTreeMap<ServiceId, ServiceRecord>,
    next_service: u32,
    next_instance: u64,
}

impl ServiceDb {
    /// Register a validated SLA as a new service; returns the id and the
    /// freshly minted per-task instances (all `Requested`).
    pub fn register(&mut self, sla: ServiceSla, now: SimTime) -> (ServiceId, Vec<InstanceId>) {
        let id = ServiceId(self.next_service);
        self.next_service += 1;

        let tasks: Vec<TaskSpec> = sla
            .constraints
            .iter()
            .enumerate()
            .map(|(i, row)| TaskSpec {
                id: TaskId {
                    service: id,
                    index: i as u16,
                },
                name: format!("{}-{}", sla.name, i),
                request: row.request(),
                virtualization: row
                    .virtualization_mask()
                    .unwrap_or(crate::model::Virtualization::CONTAINER),
                image_mb: 50 + 10 * i as u32,
                sla: row.clone(),
            })
            .collect();

        let mut instances = Vec::new();
        let mut ids = Vec::new();
        for t in &tasks {
            let iid = InstanceId(self.next_instance);
            self.next_instance += 1;
            instances.push(InstanceRecord::new(iid, t.id));
            ids.push(iid);
        }

        self.services.insert(
            id,
            ServiceRecord {
                spec: ServiceSpec {
                    id,
                    name: sla.name.clone(),
                    tasks,
                },
                sla,
                submitted_at: now,
                instances,
                placement: BTreeMap::new(),
                retired: false,
            },
        );
        (id, ids)
    }

    /// Mint a replacement instance for a task (rescheduling/migration/
    /// replication — paper §4.2/§6). Refused for retired services: a
    /// teardown must never race a recovery into a resurrected instance.
    pub fn mint_replacement(&mut self, task: TaskId) -> Option<InstanceId> {
        let rec = self.services.get_mut(&task.service)?;
        if rec.retired {
            return None;
        }
        let iid = InstanceId(self.next_instance);
        self.next_instance += 1;
        let mut inst = InstanceRecord::new(iid, task);
        inst.generation = rec
            .instances
            .iter()
            .filter(|i| i.task == task)
            .map(|i| i.generation + 1)
            .max()
            .unwrap_or(0);
        rec.instances.push(inst);
        Some(iid)
    }

    pub fn service(&self, id: ServiceId) -> Option<&ServiceRecord> {
        self.services.get(&id)
    }
    pub fn service_mut(&mut self, id: ServiceId) -> Option<&mut ServiceRecord> {
        self.services.get_mut(&id)
    }
    pub fn services(&self) -> impl Iterator<Item = &ServiceRecord> {
        self.services.values()
    }
    pub fn len(&self) -> usize {
        self.services.len()
    }
    pub fn is_empty(&self) -> bool {
        self.services.is_empty()
    }

    /// All running locations of a task across clusters (root-tier
    /// ServiceIP resolution, paper §5 recursive table refresh).
    pub fn running_locations(&self, task: TaskId) -> Vec<(InstanceId, NodeId)> {
        self.services
            .get(&task.service)
            .map(|rec| {
                rec.instances
                    .iter()
                    .filter(|i| i.task == task && i.state == ServiceState::Running)
                    .filter_map(|i| i.worker.map(|w| (i.instance, w)))
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sla::simple_sla;

    #[test]
    fn register_creates_instances_per_task() {
        let mut db = ServiceDb::default();
        let mut sla = simple_sla("app", 1000, 100);
        sla.constraints.push(sla.constraints[0].clone());
        let (id, ids) = db.register(sla, SimTime::ZERO);
        assert_eq!(ids.len(), 2);
        let rec = db.service(id).unwrap();
        assert_eq!(rec.spec.tasks.len(), 2);
        assert!(!rec.fully_running());
        // Ids unique and sequential per registration.
        let (_, ids2) = db.register(simple_sla("b", 500, 64), SimTime::ZERO);
        assert!(ids2[0] > ids[1]);
    }

    #[test]
    fn fully_running_requires_every_task() {
        let mut db = ServiceDb::default();
        let mut sla = simple_sla("app", 1000, 100);
        sla.constraints.push(sla.constraints[0].clone());
        let (id, ids) = db.register(sla, SimTime::ZERO);
        for (k, iid) in ids.iter().enumerate() {
            {
                let rec = db.service_mut(id).unwrap();
                let inst = rec.instance_mut(*iid).unwrap();
                inst.transition(ServiceState::Scheduled).unwrap();
                inst.worker = Some(NodeId(k as u32));
                inst.transition(ServiceState::Running).unwrap();
            }
            if k == 0 {
                assert!(!db.service(id).unwrap().fully_running());
            }
        }
        assert!(db.service(id).unwrap().fully_running());
        assert_eq!(
            db.running_locations(TaskId {
                service: id,
                index: 1
            })
            .len(),
            1
        );
    }

    #[test]
    fn retired_services_refuse_replacements() {
        let mut db = ServiceDb::default();
        let (id, _) = db.register(simple_sla("app", 1000, 100), SimTime::ZERO);
        let task = TaskId {
            service: id,
            index: 0,
        };
        assert!(db.mint_replacement(task).is_some());
        db.service_mut(id).unwrap().retired = true;
        assert!(
            db.mint_replacement(task).is_none(),
            "an undeployed service must never grow again"
        );
    }

    #[test]
    fn replacement_bumps_generation() {
        let mut db = ServiceDb::default();
        let (id, _) = db.register(simple_sla("app", 1000, 100), SimTime::ZERO);
        let task = TaskId {
            service: id,
            index: 0,
        };
        let r1 = db.mint_replacement(task).unwrap();
        let r2 = db.mint_replacement(task).unwrap();
        let rec = db.service(id).unwrap();
        assert_eq!(rec.instance(r1).unwrap().generation, 1);
        assert_eq!(rec.instance(r2).unwrap().generation, 2);
        assert!(db
            .mint_replacement(TaskId {
                service: ServiceId(99),
                index: 0
            })
            .is_none());
    }
}
